"""Paper Fig 17: deadline-scheduler batch matching.

Reproduces both heatmaps: QPS improvement of the deadline scheduler over
plain SiM, and the probability that a query targets the same page as another
unexpired queued query.  The paper's *negative* finding — batching only pays
at unrealistic skew (alpha ~ 1.3 -> ~3.7x) and is ineffective for normal
workloads on low-latency SLC — is the validation target.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import N_KEY_PAGES, Timer, emit
from repro.flash.params import DEFAULT_PARAMS
from repro.workload.runner import run
from repro.workload.ycsb import generate

ALPHAS = (0.5, 0.9, 1.1, 1.3)
DEADLINES_US = (2.0, 4.0, 8.0)


def same_page_probability(wl, deadline_ns: float, approx_rate_ns: float
                          ) -> float:
    """P(another unexpired same-page query in the window), estimated from
    arrival adjacency at the workload's observed throughput."""
    window = max(1, int(deadline_ns / approx_rate_ns))
    pages = wl.key_pages
    hits = 0
    for i in range(len(pages)):
        lo = max(0, i - window)
        if np.any(pages[lo:i] == pages[i]):
            hits += 1
    return hits / len(pages)


def main(scale: int = 1) -> None:
    n_q = 4000 * scale
    with Timer() as t:
        for alpha in ALPHAS:
            wl = generate(n_q, n_key_pages=N_KEY_PAGES, read_ratio=1.0,
                          alpha=alpha, seed=1)
            plain = run(wl, params=DEFAULT_PARAMS, system="sim",
                        cache_coverage=0.0)
            rate_ns = plain.makespan_ns / max(1, n_q)
            for ddl in DEADLINES_US:
                batched = run(wl, params=DEFAULT_PARAMS, system="sim",
                              cache_coverage=0.0,
                              batch_deadline_ns=ddl * 1000)
                p_same = same_page_probability(wl, ddl * 1000, rate_ns)
                emit(f"fig17_a{alpha}_d{ddl:.0f}us", t.elapsed_us,
                     f"qps_gain={batched.qps/plain.qps:.2f}_"
                     f"p_same_page={p_same:.2f}")


if __name__ == "__main__":
    main()
