"""Event-frontend latency sweep: offered QPS x NCQ scheduler policy.

The paper's tail-latency claims (Fig 15, §VII-D) hinge on reads not
queueing behind the deferred write-buffer program backlog: SiM's die
timelines split sense from program (program suspend), so a read-priority
command queue serves searches in sense+bus time while an in-order FIFO
queue parks them behind 80 us programs.  This sweep makes that gap a
CI-gated number:

  * a write-heavy skewed YCSB stream (read_ratio 0.5, alpha 0.9) replays
    through the event frontend at increasing offered Poisson QPS under
    ``fifo``, ``read_priority`` and ``fair_share`` scheduling;
  * per point: simulated per-request read p50/p99 (deterministic, but
    classified as timing by the regression checker — the hard gate is the
    ratio below) and achieved QPS;
  * at the saturating (highest) offered rate:
    ``latency_sweep_rp_vs_fifo_p99_speedup`` — FIFO p99 over
    read-priority p99 — gated >= 1.5x here AND floored in
    check_regression.py (RATIO_FLOORS);
  * exact event-loop accounting counters (events, dispatches, admitted,
    admission_waits, ncq_peak, programs) for the saturating FIFO and
    read-priority runs: arrivals are seeded, the loop is deterministic,
    so any drift is a semantic change and fails the exact-counter gate.

Usage:  PYTHONPATH=src:. python -m benchmarks.latency_sweep
"""
from __future__ import annotations

from benchmarks.common import Timer, emit, run_event, write_bench_json

QPS_GRID = (1e5, 3e5, 6e5)          # last point saturates the device
POLICIES = ("fifo", "read_priority", "fair_share")
READ_RATIO = 0.5
ALPHA = 0.9
P99_SPEEDUP_FLOOR = 1.5             # mirrored in check_regression.py


def main() -> None:
    reports: dict[tuple[str, float], object] = {}
    with Timer() as t:
        for qps in QPS_GRID:
            for policy in POLICIES:
                r = run_event(READ_RATIO, ALPHA, qps=qps, scheduler=policy,
                              write_high_water=8)
                reports[policy, qps] = r
                lat = r.latency
                emit(f"latency_sweep_{policy}_q{int(qps/1000)}k_p50_us",
                     lat.read_p50_ns / 1e3,
                     f"simulated_read_p50_offered={qps:.0f}qps")
                emit(f"latency_sweep_{policy}_q{int(qps/1000)}k_p99_us",
                     lat.read_p99_ns / 1e3,
                     f"simulated_read_p99_achieved={lat.qps:.0f}qps")
    emit("latency_sweep_wall_us", t.elapsed_us,
         f"{len(QPS_GRID) * len(POLICIES)}_event_runs")

    # The CI-gated claim: at saturation, read-priority beats FIFO's tail.
    sat = QPS_GRID[-1]
    fifo, rp = reports["fifo", sat], reports["read_priority", sat]
    speedup = fifo.latency.read_p99_ns / rp.latency.read_p99_ns
    assert speedup >= P99_SPEEDUP_FLOOR, \
        (f"read-priority p99 speedup {speedup:.2f}x < "
         f"{P99_SPEEDUP_FLOOR}x gate at {sat:.0f} offered qps")
    emit("latency_sweep_rp_vs_fifo_p99_speedup", speedup,
         f"saturating_qps={sat:.0f}_gate>={P99_SPEEDUP_FLOOR}x")

    # Both policies execute the same op stream — functional totals agree.
    assert fifo.counters.reads == rp.counters.reads
    assert fifo.programs == rp.programs

    # Exact event-loop accounting (seeded arrivals -> deterministic).
    for policy in ("fifo", "read_priority"):
        c = reports[policy, sat].counters
        n_ops = c.reads + c.writes + c.scans
        assert c.admitted + c.admission_waits == n_ops, \
            f"{policy}: admission accounting leak"
        tag = f"offered={sat:.0f}qps_seeded"
        emit(f"latency_sweep_{policy}_events", c.events, tag)
        emit(f"latency_sweep_{policy}_dispatches", c.dispatches, tag)
        emit(f"latency_sweep_{policy}_admitted", c.admitted, tag)
        emit(f"latency_sweep_{policy}_admission_waits", c.admission_waits,
             tag)
        emit(f"latency_sweep_{policy}_ncq_peak", c.ncq_peak, tag)
        emit(f"latency_sweep_{policy}_programs", c.programs, tag)

    write_bench_json("latency_sweep")


if __name__ == "__main__":
    main()
