"""Paper Fig 13: SiM energy consumption relative to baseline (NAND-side)."""
from __future__ import annotations

from benchmarks.common import (COVERAGES, DISTRIBUTIONS, READ_RATIOS, Timer,
                               emit, run_pair)


def main(scale: int = 1) -> None:
    cells = []
    with Timer() as t:
        for dist_name, alpha in DISTRIBUTIONS:
            for rr in READ_RATIOS:
                for cov in COVERAGES:
                    base, sim = run_pair(rr, alpha, cov,
                                         n_queries=4000 * scale)
                    ratio = sim.energy_pj / base.energy_pj
                    cells.append((dist_name, rr, cov, ratio))
    n = len(cells)
    for dist_name, rr, cov, r in cells:
        emit(f"fig13_{dist_name}_r{int(rr*100)}_c{int(cov*100)}",
             t.elapsed_us / n, f"energy_ratio={r:.2f}")
    typical = [r for d, rr, c, r in cells if 0.10 <= c <= 0.50 and rr <= 0.8]
    emit("fig13_typical_savings", t.elapsed_us / n,
         f"savings={1-min(typical):.0%}..{max(0.0, 1-max(typical)):.0%}"
         f"(paper_10-45%)")


if __name__ == "__main__":
    main()
