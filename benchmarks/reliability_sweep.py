"""BER sweep: wrong-result-rate gate for the reliability tier.

Two sweeps over the §IV-C fault pipeline, emitted as exact counters so
``check_regression.py`` can gate them (``reliability_*`` metrics match the
committed baseline bit-for-bit, and the two ``HARD_ZEROS`` must be zero in
every fresh run, baseline or not):

* **Verified sweep** — retention ages 0/45/90 days at ``base_ber=1e-4``
  push pages through the whole verdict ladder (age 0: mostly CLEAN opens;
  age 45: read-retries, ECC fallbacks and refresh marks; age 90: raw error
  counts beyond ``t_correctable``, surfacing as typed per-op errors).  A
  fused-lookup YCSB replay runs per backend per age under the *same* fault
  seed; the gate is (a) zero wrong results against the analytic oracle —
  every read either returns the exact stored value or a typed
  ``UncorrectableReadError``, never a silently wrong/missing one — and
  (b) bit-identical per-op outcomes across scalar/batched/sharded.

* **Unverified sweep** — clean storage, transient comparator noise only
  (``sense_ber=5e-4``), verification and miss-fallback disabled.  This is
  the approximate-search operating point the paper's §IV-C3 voting targets:
  the measured wrong-op rate must be nonzero at ``vote_k=1`` (proving the
  sweep actually exercises the noise path), must shrink under 3-pass
  voting, and must sit under ``sense_false_positive_bound`` (+3-sigma
  sampling slack — the bound is per-op, the measurement is 240 ops).

Run from the repo root:  PYTHONPATH=src python -m benchmarks.reliability_sweep
"""
from __future__ import annotations

import math

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.backend import make_backend
from repro.core.engine import SimChipArray
from repro.reliability import (FaultModel, ReliabilityPolicy,
                               ReliabilityState,
                               sense_false_negative_bound,
                               sense_false_positive_bound)
from repro.frontend import RunConfig, replay
from repro.workload.ycsb import generate

N_QUERIES = 240
N_KEY_PAGES = 12
N_CHIPS = 4
FAULT_SEED = 11
BASE_BER = 1e-4
SENSE_BER = 5e-4
AGES_DAYS = (0, 45, 90)
BACKENDS = ("scalar", "batched", "sharded")


def _workload():
    return generate(N_QUERIES, n_key_pages=N_KEY_PAGES, read_ratio=1.0,
                    alpha=0.9, seed=7)


def _expected_values(wl) -> np.ndarray:
    """Oracle: read-only stream, so every op's answer is the initial value
    the runner programs for key id k — ((k + 1) * phi64) | 1."""
    return (wl.keys.astype(np.uint64) + np.uint64(1)) \
        * np.uint64(0x9E3779B97F4A7C15) | np.uint64(1)


def _run(wl, backend_name: str, policy: ReliabilityPolicy,
         fault: FaultModel):
    arr = SimChipArray(n_chips=N_CHIPS,
                       pages_per_chip=max(wl.n_index_pages // N_CHIPS + 1,
                                          8),
                       device_seed=3)
    kw = {"use_kernel": False} if backend_name == "sharded" else {}
    rel = ReliabilityState(policy, fault)
    res = replay(wl, make_backend(backend_name, arr, **kw),
                 RunConfig.reliable(rel, burst=64, fused=True))
    return res, rel


def verified_sweep() -> None:
    wl = _workload()
    oracle = _expected_values(wl)
    policy = ReliabilityPolicy(verify_hits=True, fallback_on_miss=True,
                               vote_k=3)
    wrong = 0
    mismatch = 0
    for age in AGES_DAYS:
        fault = FaultModel(seed=FAULT_SEED, base_ber=BASE_BER,
                           retention_days=float(age), sense_ber=2e-4)
        runs = {}
        for name in BACKENDS:
            res, rel = _run(wl, name, policy, fault)
            runs[name] = res
            # Wrong result = anything that is neither the exact oracle
            # value nor a typed error: a silent miss or a wrong value.
            ok_hit = res.read_hits & (res.read_values == oracle)
            wrong += int(np.sum(~(ok_hit | res.read_errors)))
            if name == "scalar":
                emit(f"reliability_retries_age{age}", rel.stats.retries,
                     f"ber={fault.raw_ber():.2e}_vote_k={policy.vote_k}")
                emit(f"reliability_fallback_reads_age{age}",
                     rel.stats.fallback_reads,
                     "full_page_storage_mode_reads_open_plus_resolve")
                emit(f"reliability_uncorrectable_age{age}",
                     rel.stats.uncorrectable,
                     "outer_code_failures_as_typed_errors")
                emit(f"reliability_refreshes_age{age}", res.refreshes,
                     "stale_pages_rewritten_via_deferred_program")
        ref = runs["scalar"]
        for name in BACKENDS[1:]:
            r = runs[name]
            mismatch += int(np.sum(r.read_values != ref.read_values))
            mismatch += int(np.sum(r.read_hits != ref.read_hits))
            mismatch += int(np.sum(r.read_errors != ref.read_errors))
    # Hard gates (also re-checked by check_regression's HARD_ZEROS).
    assert wrong == 0, \
        f"{wrong} silently wrong results escaped the verified pipeline"
    assert mismatch == 0, \
        f"{mismatch} per-op divergences between backends under one seed"
    emit("reliability_wrong_results_verified", wrong,
         f"ages={AGES_DAYS}_x_backends={BACKENDS}_vs_analytic_oracle")
    emit("reliability_backend_mismatch", mismatch,
         "per_op_value+hit+error_diffs_vs_scalar_reference")


def unverified_sweep() -> None:
    wl = _workload()
    oracle = _expected_values(wl)
    rates = {}
    for vote_k in (1, 3):
        policy = ReliabilityPolicy(verify_hits=False,
                                   fallback_on_miss=False, vote_k=vote_k)
        fault = FaultModel(seed=FAULT_SEED, base_ber=0.0,
                           sense_ber=SENSE_BER)
        res, rel = _run(wl, "scalar", policy, fault)
        fp_ops = int(np.sum(res.read_hits & (res.read_values != oracle)))
        fn_ops = int(np.sum(~res.read_hits & ~res.read_errors))
        wrong_ops = fp_ops + fn_ops
        rates[vote_k] = wrong_ops / N_QUERIES
        bound = sense_false_positive_bound(SENSE_BER, vote_k) \
            + sense_false_negative_bound(SENSE_BER, vote_k)
        # The bound is a per-op probability; the measurement is N_QUERIES
        # deterministic Bernoulli draws, so allow 3-sigma sampling slack.
        slack = 3.0 * math.sqrt(bound * (1.0 - bound) / N_QUERIES)
        assert rates[vote_k] <= bound + slack, \
            (f"unverified wrong-op rate {rates[vote_k]:.4f} above analytic "
             f"bound {bound:.4f} (+{slack:.4f} slack) at vote_k={vote_k}")
        emit(f"reliability_fp_ops_unverified_k{vote_k}", fp_ops,
             f"sense_ber={SENSE_BER}_bound={bound:.4f}")
        emit(f"reliability_fn_ops_unverified_k{vote_k}", fn_ops,
             f"sense_ber={SENSE_BER}_vote_k={vote_k}")
    assert rates[1] > 0.0, \
        "unverified vote_k=1 run measured zero wrong ops — the sweep is " \
        "not exercising the sense-noise path"
    assert rates[3] <= rates[1], \
        f"3-pass voting did not reduce the wrong-op rate " \
        f"({rates[3]:.4f} > {rates[1]:.4f})"


def main() -> None:
    verified_sweep()
    unverified_sweep()
    write_bench_json("reliability_sweep")


if __name__ == "__main__":
    main()
