"""Kernel microbenchmarks: sim_search / sim_gather / sim_fused / attention.

On this CPU container kernels execute under the Pallas interpreter, so the
wall numbers are NOT TPU timings — they are recorded for regression tracking
and to exercise the full dispatch path.  The derived column carries the
analytic per-page byte traffic, which *is* hardware-independent.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.kernels.sim_search.ops import sim_search
from repro.kernels.sim_gather.ops import sim_gather
from repro.kernels.sim_fused.ops import sim_fused
from repro.kernels.flash_attention.ops import flash_attention


def main(scale: int = 1) -> None:
    rng = np.random.default_rng(0)
    n_pages, n_q = 64, 8
    lo = rng.integers(0, 2**32, (n_pages, 512), dtype=np.uint64
                      ).astype(np.uint32)
    hi = rng.integers(0, 2**32, (n_pages, 512), dtype=np.uint64
                      ).astype(np.uint32)
    q = rng.integers(0, 2**32, (n_q, 2), dtype=np.uint64).astype(np.uint32)
    m = np.full((n_q, 2), 0xFFFFFFFF, dtype=np.uint32)

    out = sim_search(lo, hi, q, m)                      # warm compile
    jax.block_until_ready(out)
    with Timer() as t:
        jax.block_until_ready(sim_search(lo, hi, q, m))
    emit("kernel_sim_search", t.elapsed_us,
         f"pages={n_pages}_q={n_q}_out_bytes_per_page=64_in_4096")

    chunks = rng.integers(0, 2**32, (n_pages, 64, 16), dtype=np.uint64
                          ).astype(np.uint32)
    bm = rng.integers(0, 2**32, (n_pages, 2), dtype=np.uint64
                      ).astype(np.uint32)
    g = sim_gather(chunks, bm, max_out=16)
    jax.block_until_ready(g)
    with Timer() as t:
        jax.block_until_ready(sim_gather(chunks, bm, max_out=16))
    emit("kernel_sim_gather", t.elapsed_us,
         f"pages={n_pages}_max_out=16_mxu_onehot_matmul")

    f = sim_fused(lo, hi, q[0], m[0], max_out=8)
    jax.block_until_ready(f)
    with Timer() as t:
        jax.block_until_ready(sim_fused(lo, hi, q[0], m[0], max_out=8))
    emit("kernel_sim_fused", t.elapsed_us,
         "one_page_pass_for_search+gather(saves_1_hbm_read)")

    B, S, H, HKV, D = 1, 256, 4, 2, 64
    qa = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    ka = jnp.asarray(rng.normal(size=(B, S, HKV, D)), jnp.bfloat16)
    va = jnp.asarray(rng.normal(size=(B, S, HKV, D)), jnp.bfloat16)
    o = flash_attention(qa, ka, va)
    jax.block_until_ready(o)
    with Timer() as t:
        jax.block_until_ready(flash_attention(qa, ka, va))
    flops = 4 * B * H * S * S * D
    emit("kernel_flash_attention", t.elapsed_us,
         f"causal_gqa_flops={flops}")


if __name__ == "__main__":
    main()
