"""Kernel microbenchmarks: sim_search / sim_gather / sim_fused / attention.

On this CPU container kernels execute under the Pallas interpreter, so the
wall numbers are NOT TPU timings — they are recorded for regression tracking
and to exercise the full dispatch path.  The derived column carries the
analytic per-page byte traffic, which *is* hardware-independent.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit, write_bench_json
from repro.backend import ShardedSsdBackend, make_backend
from repro.core.commands import Command
from repro.core.engine import SimChipArray
from repro.core.range_query import (evaluate_plan_on_pages,
                                    evaluate_plan_per_pass, exact_range)
from repro.kernels.sim_search.ops import sim_search
from repro.kernels.sim_gather.ops import sim_gather
from repro.kernels.sim_fused.ops import sim_fused
from repro.kernels.flash_attention.ops import flash_attention
from repro.frontend import RunConfig, replay
from repro.workload.ycsb import generate


def _programmed_backend(name: str, n_pages: int, seed: int = 5):
    arr = SimChipArray(n_chips=8, pages_per_chip=max(n_pages // 8 + 1, 8),
                       device_seed=seed)
    rng = np.random.default_rng(0)
    page_keys = [rng.integers(1, 2**62, 404, dtype=np.uint64)
                 for _ in range(n_pages)]
    for p, keys in enumerate(page_keys):
        arr.program_entries(p, keys)
    return make_backend(name, arr), page_keys


def backend_batch_comparison(n_pages: int = 32,
                             batch_sizes=(4, 16, 64)) -> None:
    """Scalar per-page path vs one-launch batched backend (§IV-E).

    Workload: Q concurrent point queries, each matched against all
    ``n_pages`` staged pages (the cross-page multi-query batch an index
    burst produces).  The scalar backend walks SimChip.search per
    (query, page); the batched backend stages everything and launches the
    sim_search kernel once.  Emitted derived column carries the speedup —
    the repo's regression gate wants >= 2x at Q >= 16.
    """
    for n_q in batch_sizes:
        scalar, page_keys = _programmed_backend("scalar", n_pages)
        batched, _ = _programmed_backend("batched", n_pages)
        rng = np.random.default_rng(1)
        queries = [int(page_keys[p][rng.integers(0, 404)])
                   for p in rng.integers(0, n_pages, n_q)]
        cmds = [Command.search(p, q)
                for q in queries for p in range(n_pages)]

        def burst(backend):
            tickets = [backend.submit_search(c) for c in cmds]
            backend.flush()
            return [t.result().match_count for t in tickets]

        counts_b = burst(batched)               # warm compile
        with Timer() as tb:
            burst(batched)
        counts_s = burst(scalar)
        with Timer() as ts:
            burst(scalar)
        assert counts_s == counts_b, "backend results diverged"
        speedup = ts.elapsed_us / tb.elapsed_us
        # Regression gate: batching must pay off once a burst is real.
        # (2x is far below the ~10x this container shows; headroom covers
        # interpret-mode timing noise.)
        assert n_q < 16 or speedup >= 2.0, \
            f"batched backend speedup {speedup:.1f}x < 2x at q={n_q}"
        n = len(cmds)
        emit("backend_scalar_search", ts.elapsed_us / n,
             f"q={n_q}_pages={n_pages}_searches={n}")
        emit("backend_batched_search", tb.elapsed_us / n,
             f"q={n_q}_pages={n_pages}_one_launch_speedup={speedup:.1f}x")


def functional_burst_comparison(n_queries: int = 384,
                                n_key_pages: int = 8) -> None:
    """End-to-end functional replay: scalar vs batched-split vs fused.

    The read-heavy YCSB stream is replayed three ways: per-command scalar
    chips, the batched backend's split path (search launch -> host bitmap
    decode -> gather launch, 2 launches/burst) and the fused lookup path
    (1 launch/burst, match->slot-select-value-gather in-kernel).  Page
    programming is identical setup for all three paths; a 1-query run per
    path measures it and its time is subtracted, so the emitted per-query
    numbers and the regression gate reflect burst execution only.  The gate
    mirrors the search section's: the fused path must beat the scalar
    reference by >= 2x (it shows more; headroom covers interpret-mode
    noise).  Values must be bit-identical across all three.
    """
    wl = generate(n_queries, n_key_pages=n_key_pages, read_ratio=1.0,
                  alpha=0.5, seed=9)
    wl_tiny = generate(1, n_key_pages=n_key_pages, read_ratio=1.0,
                       alpha=0.5, seed=9)
    pages_per_chip = max(wl.n_index_pages // 4 + 1, 8)

    def once(name: str, fused: bool, workload=wl):
        arr = SimChipArray(n_chips=4, pages_per_chip=pages_per_chip,
                           device_seed=3)
        return replay(workload, make_backend(name, arr),
                      RunConfig(burst=64, fused=fused))

    results, times = {}, {}
    for label, name, fused in (("scalar", "scalar", False),
                               ("batched", "batched", False),
                               ("fused", "batched", True)):
        once(name, fused)                       # warm compile caches
        once(name, fused, wl_tiny)              # ... incl. tiny-burst shapes
        with Timer() as t0:
            once(name, fused, wl_tiny)          # programming-dominated run
        with Timer() as t:
            results[label] = once(name, fused)
        times[label] = max(t.elapsed_us - t0.elapsed_us, 1.0)

    for r in results.values():
        np.testing.assert_array_equal(results["scalar"].read_values,
                                      r.read_values)
    assert results["fused"].kernel_launches == results["fused"].flushes, \
        "fused read burst must be exactly one launch per flush"
    speed_b = times["scalar"] / times["batched"]
    speed_f = times["scalar"] / times["fused"]
    assert speed_f >= 2.0, \
        f"fused replay speedup {speed_f:.1f}x < 2x gate"
    emit("functional_scalar", times["scalar"] / n_queries,
         f"q={n_queries}_per_command_reference")
    emit("functional_batched", times["batched"] / n_queries,
         f"q={n_queries}_2_launches_per_burst_speedup={speed_b:.1f}x")
    emit("functional_fused", times["fused"] / n_queries,
         f"q={n_queries}_1_launch_per_burst_speedup={speed_f:.1f}x")


def write_path_comparison(n_queries: int = 384,
                          n_key_pages: int = 8) -> None:
    """Coalescing DRAM write buffer vs per-write reprogram (§VI write path).

    The same write-heavy YCSB-A stream (read_ratio=0.5, alpha=0.9) replays
    twice on the batched backend: unbuffered, every write force-splits the
    open read burst and synchronously reprograms its value page (1 program
    + 1 dirty-row restage per write, zero coalescing); buffered, writes
    absorb into the DRAM write buffer (reads of dirty pages served from
    the overlay), hot pages coalesce last-wins and dirty pages drain in
    grouped deferred-program bursts at the high-water mark.  Read values
    must be bit-identical.  Gates: ``write_programs_buffered`` /
    ``write_staged_bytes_*`` are exact counters (programs MUST come out
    below n_writes — the §VI coalescing claim), and the buffered replay
    must beat the per-write replay >= 2x end to end
    (``write_coalesce_speedup``, also floored in check_regression.py).
    """
    wl = generate(n_queries, n_key_pages=n_key_pages, read_ratio=0.5,
                  alpha=0.9, seed=11)
    wl_tiny = generate(1, n_key_pages=n_key_pages, read_ratio=0.5,
                       alpha=0.9, seed=11)
    pages_per_chip = max(wl.n_index_pages // 4 + 1, 8)

    def once(buffered: bool, workload=wl):
        arr = SimChipArray(n_chips=4, pages_per_chip=pages_per_chip,
                           device_seed=3)
        return replay(workload, make_backend("batched", arr),
                      RunConfig(burst=64, fused=True, write_buffer=buffered,
                                write_high_water=8))

    results, times, staged = {}, {}, {}
    for label, buffered in (("per_write", False), ("buffered", True)):
        results[label] = once(buffered)         # warm compile caches
        once(buffered, wl_tiny)                 # ... incl. tiny-burst shapes
        with Timer() as t0:
            once(buffered, wl_tiny)             # programming-dominated run
        with Timer() as t1:
            r = once(buffered)
        with Timer() as t2:                     # best-of-2: timing noise
            once(buffered)                      # must not flap the gate
        setup = t0.elapsed_us
        times[label] = max(min(t1.elapsed_us, t2.elapsed_us) - setup, 1.0)
        staged[label] = r.staged_bytes

    rb, rp = results["buffered"], results["per_write"]
    np.testing.assert_array_equal(rp.read_values, rb.read_values)
    np.testing.assert_array_equal(rp.read_hits, rb.read_hits)
    assert rp.programs == rp.n_writes, "per-write path must not coalesce"
    assert rb.programs < rb.n_writes, \
        f"buffered replay must coalesce: {rb.programs} programs " \
        f"for {rb.n_writes} writes"
    speedup = times["per_write"] / times["buffered"]
    assert speedup >= 2.0, \
        f"write-buffer speedup {speedup:.1f}x < 2x gate"
    emit("functional_write_per_write", times["per_write"] / n_queries,
         f"q={n_queries}_writes={rp.n_writes}_1_program+1_burst_split_per_write")
    emit("functional_write_buffered", times["buffered"] / n_queries,
         f"q={n_queries}_writes={rb.n_writes}_grouped_programs"
         f"_overlay_hits={rb.buffer_read_hits}")
    emit("write_coalesce_speedup", speedup,
         f"per_write_over_buffered_q={n_queries}_ci_gate>=2x")
    emit("write_programs_per_write", rp.programs,
         f"programs==n_writes={rp.n_writes}_no_coalescing")
    emit("write_programs_buffered", rb.programs,
         f"n_writes={rb.n_writes}_high_water=8_hot_page_coalescing")
    emit("write_staged_bytes_per_write", staged["per_write"],
         "dirty_row_restage_per_write_plus_cold_arena")
    emit("write_staged_bytes_buffered", staged["buffered"],
         "grouped_program_staging_plus_cold_arena")


def staged_bytes_per_flush(n_pages: int = 32, n_q: int = 16) -> None:
    """Measure host->device page traffic across repeated identical flushes.

    With the device-resident plane store, the first flush stages the
    working set (4 KiB/page) and every later flush of the same pages ships
    ZERO page bytes — only the (Q, 2) query operands.  A reprogram
    invalidates exactly one arena row (one page restage).
    """
    backend, page_keys = _programmed_backend("batched", n_pages)
    rng = np.random.default_rng(2)
    cmds = [Command.search(p, int(page_keys[p][rng.integers(0, 404)]))
            for p in range(n_pages) for _ in range(n_q // 4)]

    deltas = []
    for _ in range(3):
        before = backend.stats.staged_bytes
        tickets = [backend.submit_search(c) for c in cmds]
        backend.flush()
        assert all(t.done for t in tickets)
        deltas.append(backend.stats.staged_bytes - before)
    assert deltas[0] == n_pages * 4096, deltas
    assert deltas[1] == deltas[2] == 0, \
        f"warm flush restaged page bytes: {deltas}"
    emit("backend_staged_bytes_flush0", deltas[0],
         f"pages={n_pages}_cold_arena_population_bytes")
    emit("backend_staged_bytes_warm", deltas[1],
         f"pages={n_pages}_steady_state_restage_bytes(must_be_0)")

    # One reprogram dirties exactly one row.
    backend.chips.program_entries(0, page_keys[0][::-1].copy())
    before = backend.stats.staged_bytes
    backend.search(Command.search(0, int(page_keys[0][5])))
    emit("backend_staged_bytes_after_reprogram",
         backend.stats.staged_bytes - before,
         "single_dirty_row_restage_bytes(=4096)")
    assert backend.stats.staged_bytes - before == 4096


def range_plan_comparison(n_pages: int = 32) -> None:
    """Fused Op.PLAN vs per-pass searches (Fig 10 in-latch accumulation).

    An exact 64-bit range decomposes into ~100 masked-equality passes; the
    per-pass path launches them as one batched search (Q = passes) and
    combines passes x pages bitmaps on the host, crossing 64 B per pass
    per page.  The fused PLAN path evaluates and combines every pass
    in-VMEM and ships ONE 64 B bitmap per page.  Gates: the result-byte
    counters are exact contracts (the drop == the plan's pass count), and
    the fused path must beat the per-pass batched path >= 2x end to end
    (``plan_fused_speedup``, also floored in check_regression.py).
    Scalar / batched / sharded results are asserted bit-identical.
    """
    rng = np.random.default_rng(7)
    page_keys = [rng.integers(1, 2**62, 404, dtype=np.uint64)
                 for _ in range(n_pages)]
    # A wide, unaligned exact range: the worst-case §V-C decomposition
    # (~2*width passes — the Fig 10 regime the fused path exists for).
    lo = 5
    hi = (1 << 62) - 3
    plan = exact_range(lo, hi, width=64)
    assert plan.n_passes > 90, plan.n_passes
    pages = list(range(n_pages))

    def programmed(name):
        if name == "sharded":
            be = ShardedSsdBackend.from_geometry(
                channels=4, dies_per_channel=2,
                pages_per_chip=n_pages // 8 + 1, device_seed=5)
        else:
            be = make_backend(name, SimChipArray(
                n_chips=8, pages_per_chip=n_pages // 8 + 1, device_seed=5))
        for p, keys in enumerate(page_keys):
            be.program_entries(p, keys)
        return be

    scalar = programmed("scalar")
    batched = programmed("batched")
    sharded = programmed("sharded")

    # Warm arenas + compile caches, and check cross-backend bit-parity.
    ref = evaluate_plan_on_pages(scalar, plan, pages)
    per_pass_ref = evaluate_plan_per_pass(batched, plan, pages)
    np.testing.assert_array_equal(ref, per_pass_ref)
    for be in (batched, sharded):
        np.testing.assert_array_equal(ref, evaluate_plan_on_pages(
            be, plan, pages))

    rb0 = batched.stats.result_bytes
    with Timer() as tpp:
        evaluate_plan_per_pass(batched, plan, pages)
    per_pass_bytes = batched.stats.result_bytes - rb0
    rb0 = batched.stats.result_bytes
    with Timer() as tf:
        evaluate_plan_on_pages(batched, plan, pages)
    fused_bytes = batched.stats.result_bytes - rb0
    # Best-of-2 on both timed paths: interpret-mode wall noise must not
    # flap the ratio gate.
    with Timer() as tpp2:
        evaluate_plan_per_pass(batched, plan, pages)
    with Timer() as tf2:
        evaluate_plan_on_pages(batched, plan, pages)
    t_pp = min(tpp.elapsed_us, tpp2.elapsed_us)
    t_f = min(tf.elapsed_us, tf2.elapsed_us)
    with Timer() as tsh:
        evaluate_plan_on_pages(sharded, plan, pages)
    with Timer() as tsc:
        evaluate_plan_on_pages(scalar, plan, pages)

    # Exact bandwidth contract: the drop equals the plan's pass count.
    assert fused_bytes == 64 * n_pages, fused_bytes
    assert per_pass_bytes == 64 * plan.n_passes * n_pages, per_pass_bytes
    speedup = t_pp / t_f
    assert speedup >= 2.0, \
        f"fused plan speedup {speedup:.1f}x < 2x gate"
    emit("range_plan_per_pass", t_pp / n_pages,
         f"passes={plan.n_passes}_pages={n_pages}_batched_search_combine")
    emit("range_plan_fused", t_f / n_pages,
         f"passes={plan.n_passes}_pages={n_pages}_one_plan_launch")
    emit("range_plan_fused_sharded", tsh.elapsed_us / n_pages,
         f"passes={plan.n_passes}_pages={n_pages}_geometry=4x2")
    emit("range_plan_scalar", tsc.elapsed_us / n_pages,
         f"passes={plan.n_passes}_pages={n_pages}_per_pass_chip_reference")
    emit("plan_fused_speedup", speedup,
         f"per_pass_over_fused_passes={plan.n_passes}_ci_gate>=2x")
    emit("plan_result_bytes_per_pass", per_pass_bytes,
         f"64B_x_{plan.n_passes}passes_x_{n_pages}pages")
    emit("plan_result_bytes_fused", fused_bytes,
         f"64B_x_{n_pages}pages_in_latch_combine")


def sharded_scaling(n_pages: int = 384, n_q: int = 384) -> None:
    """ShardedSsdBackend throughput at 1 vs 4 vs 16 chips (§VI-A scaling).

    The same point-query burst (one planted-key search per page) replays on
    1x1, 4x1 and 4x4 geometries.  Sharding shrinks the stacked launch's
    cross product — each chip's queries match only its own resident pages —
    so the burst gets *faster* as the chip count grows even though every
    geometry still issues ONE device dispatch.  The CI regression gate
    (benchmarks/check_regression.py) holds the 16-chip speedup >= 2x; this
    container shows ~5x.
    """
    rng = np.random.default_rng(0)
    page_keys = [rng.integers(1, 2**62, 404, dtype=np.uint64)
                 for _ in range(n_pages)]
    qrng = np.random.default_rng(1)
    probe = [int(page_keys[p][qrng.integers(0, 404)])
             for p in range(n_pages)]
    order = qrng.permutation(n_pages)[:n_q]
    times, counts = {}, {}
    for channels, dies in ((1, 1), (4, 1), (4, 4)):
        be = ShardedSsdBackend.from_geometry(
            channels=channels, dies_per_channel=dies,
            pages_per_chip=n_pages, device_seed=5)
        for p, keys in enumerate(page_keys):
            be.program_entries(p, keys)
        cmds = [Command.search(int(p), probe[int(p)]) for p in order]

        def burst():
            tickets = [be.submit_search(c) for c in cmds]
            be.flush()
            return [t.result().match_count for t in tickets]

        n_chips = channels * dies
        counts[n_chips] = burst()           # warm arena + compile
        burst()
        launches = be.stats.kernel_launches
        with Timer() as t:
            burst()
            burst()
        assert be.stats.kernel_launches == launches + 2, \
            "sharded burst must be one device dispatch, not one per chip"
        times[n_chips] = t.elapsed_us / 2
        emit(f"sharded_search_{n_chips}chip", times[n_chips] / n_q,
             f"q={n_q}_pages={n_pages}_geometry={channels}x{dies}"
             f"_one_stacked_launch")
    assert counts[1] == counts[4] == counts[16], \
        "sharded geometries diverged"
    speed4 = times[1] / times[4]
    speed16 = times[1] / times[16]
    # Regression gate: chip parallelism must keep paying off at 16 chips.
    assert speed16 >= 2.0, \
        f"sharded 16-chip speedup {speed16:.1f}x < 2x gate"
    emit("sharded_speedup_4chip", speed4,
         f"burst_time_1chip_over_4chip_q={n_q}")
    emit("sharded_speedup_16chip", speed16,
         f"burst_time_1chip_over_16chip_q={n_q}_ci_gate>=2x")


def functional_sharded_timeline(n_queries: int = 256,
                                n_key_pages: int = 8) -> None:
    """Functional replay on a 4x4 sharded backend with timeline coupling:
    emits the simulated per-burst latency distribution (fig14/15-style)
    and energy from the *functional* replay."""
    wl = generate(n_queries, n_key_pages=n_key_pages, read_ratio=0.9,
                  alpha=0.5, seed=9)
    be = ShardedSsdBackend.from_geometry(
        channels=4, dies_per_channel=4,
        pages_per_chip=max(wl.n_index_pages // 16 + 1, 8),
        device_seed=3, timeline=True)
    r = replay(wl, be, RunConfig(burst=64, fused=True))
    assert r.burst_latencies_ns is not None and r.sim_energy_pj > 0
    p = np.percentile(r.burst_latencies_ns, (50, 99))
    emit("sharded_functional_p50_us", p[0] / 1e3,
         "simulated_burst_latency_median_4x4_fused")
    emit("sharded_functional_p99_us", p[1] / 1e3,
         "simulated_burst_latency_tail_4x4_fused")
    emit("sharded_functional_energy_uj", r.sim_energy_pj / 1e6,
         f"simulated_chip_energy_q={n_queries}")


def crc_row_kernel_comparison(n_pages: int = 64) -> None:
    """Vectorized row-wise CRC vs the per-byte scalar loop.

    Every optimistic page open decodes a verification header whose body is
    CRC-64-protected, so an n-page flush's open burst runs n header CRCs —
    the folded table kernel (``crc64_rows`` + GF(2) length-shift fold) must
    beat the per-byte loop or the reliability tier's fast path is paying
    more than the §IV-C2 fallback it avoids.  Checksums are asserted
    bit-identical before timing, batch speedup is gated at >= 4x (a table
    pass over (k, 4096) uint8 amortizes the Python byte loop k ways).
    """
    from repro.core.ecc import (_crc32_bytewise, _crc64_bytewise, crc32,
                                crc64, crc64_rows)
    rng = np.random.default_rng(17)
    page = rng.integers(0, 256, 4096, dtype=np.uint64).astype(np.uint8)
    assert crc64(page) == _crc64_bytewise(page)
    assert crc32(page) == _crc32_bytewise(page)

    with Timer() as t_byte:
        _crc64_bytewise(page)
    with Timer() as t_fold:
        crc64(page)
    emit("crc64_page_bytewise_us", t_byte.elapsed_us,
         "4096B_per_byte_table_loop_reference")
    emit("crc64_page_folded_us", t_fold.elapsed_us,
         f"row_kernel+gf2_fold_speedup="
         f"{t_byte.elapsed_us / max(t_fold.elapsed_us, 1e-9):.1f}x")

    rows = rng.integers(0, 256, (n_pages, 4096), dtype=np.uint64
                        ).astype(np.uint8)
    with Timer() as t_loop:
        loop = np.array([_crc64_bytewise(r) for r in rows],
                        dtype=np.uint64)
    with Timer() as t_rows:
        batch = crc64_rows(rows)
    np.testing.assert_array_equal(loop, batch)
    speedup = t_loop.elapsed_us / max(t_rows.elapsed_us, 1e-9)
    assert speedup >= 4.0, \
        f"crc64_rows batch speedup {speedup:.1f}x < 4x gate"
    emit("crc64_rows_batch", t_rows.elapsed_us / n_pages,
         f"pages={n_pages}_one_table_pass_speedup={speedup:.1f}x")


def main(scale: int = 1) -> None:
    rng = np.random.default_rng(0)
    n_pages, n_q = 64, 8
    lo = rng.integers(0, 2**32, (n_pages, 512), dtype=np.uint64
                      ).astype(np.uint32)
    hi = rng.integers(0, 2**32, (n_pages, 512), dtype=np.uint64
                      ).astype(np.uint32)
    q = rng.integers(0, 2**32, (n_q, 2), dtype=np.uint64).astype(np.uint32)
    m = np.full((n_q, 2), 0xFFFFFFFF, dtype=np.uint32)

    out = sim_search(lo, hi, q, m)                      # warm compile
    jax.block_until_ready(out)
    with Timer() as t:
        jax.block_until_ready(sim_search(lo, hi, q, m))
    emit("kernel_sim_search", t.elapsed_us,
         f"pages={n_pages}_q={n_q}_out_bytes_per_page=64_in_4096")

    chunks = rng.integers(0, 2**32, (n_pages, 64, 16), dtype=np.uint64
                          ).astype(np.uint32)
    bm = rng.integers(0, 2**32, (n_pages, 2), dtype=np.uint64
                      ).astype(np.uint32)
    g = sim_gather(chunks, bm, max_out=16)
    jax.block_until_ready(g)
    with Timer() as t:
        jax.block_until_ready(sim_gather(chunks, bm, max_out=16))
    emit("kernel_sim_gather", t.elapsed_us,
         f"pages={n_pages}_max_out=16_mxu_onehot_matmul")

    f = sim_fused(lo, hi, q, m, max_out=8)
    jax.block_until_ready(f)
    with Timer() as t:
        jax.block_until_ready(sim_fused(lo, hi, q, m, max_out=8))
    emit(f"kernel_sim_fused_q{n_q}", t.elapsed_us,
         f"q={n_q}_one_page_pass_for_search+gather(saves_1_hbm_read)")

    B, S, H, HKV, D = 1, 256, 4, 2, 64
    qa = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    ka = jnp.asarray(rng.normal(size=(B, S, HKV, D)), jnp.bfloat16)
    va = jnp.asarray(rng.normal(size=(B, S, HKV, D)), jnp.bfloat16)
    o = flash_attention(qa, ka, va)
    jax.block_until_ready(o)
    with Timer() as t:
        jax.block_until_ready(flash_attention(qa, ka, va))
    flops = 4 * B * H * S * S * D
    emit("kernel_flash_attention", t.elapsed_us,
         f"causal_gqa_flops={flops}")

    backend_batch_comparison()
    functional_burst_comparison()
    write_path_comparison()
    staged_bytes_per_flush()
    range_plan_comparison()
    sharded_scaling()
    functional_sharded_timeline()
    crc_row_kernel_comparison()
    write_bench_json("kernel_micro")


if __name__ == "__main__":
    main()
