"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--scale N`` multiplies the
simulated query counts; ``--only fig12`` runs a single module; ``--skip-slow``
drops the full-grid figures (used by CI smoke runs).

The roofline report (framework §Roofline) is produced by
``benchmarks.roofline`` from the dry-run artifacts; run
``python -m repro.launch.dryrun --all`` first for that one.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (fig12_speedup, fig13_energy, fig14_latency,
                        fig15_tail, fig16a_writes, fig17_batch,
                        fig18_fullpage, kernel_micro, power_budget,
                        roofline, table1_transfer, table3_distribution)

MODULES = {
    "table1": table1_transfer,
    "table3": table3_distribution,
    "fig12": fig12_speedup,
    "fig13": fig13_energy,
    "fig14": fig14_latency,
    "fig15": fig15_tail,
    "fig16a": fig16a_writes,
    "fig17": fig17_batch,
    "fig18": fig18_fullpage,
    "kernels": kernel_micro,
    "power": power_budget,
    "roofline": roofline,
}
SLOW = {"fig12", "fig13", "fig14", "fig15", "fig17", "fig18"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--skip-slow", action="store_true")
    args = ap.parse_args(argv)

    names = [args.only] if args.only else list(MODULES)
    failures = 0
    print("name,us_per_call,derived")
    for name in names:
        if args.skip_slow and name in SLOW:
            continue
        mod = MODULES[name]
        try:
            if "scale" in mod.main.__code__.co_varnames:
                mod.main(scale=args.scale)
            else:
                mod.main()
        except Exception:
            failures += 1
            traceback.print_exc()
    return failures


if __name__ == "__main__":
    sys.exit(main())
