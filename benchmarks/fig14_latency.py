"""Paper Fig 14 + Fig 16b: median read latency reduction and IQR comparison.

Two series per (distribution, read-ratio) cell:

  * ``fig14_event_*`` — MEASURED: the event-driven frontend replays the
    stream against real programmed pages under Poisson arrivals, NCQ
    admission and read-priority scheduling; the median is over per-request
    latencies (arrival -> completion, queueing included);
  * ``fig14_ref_*`` — the closed-form analytic pair (baseline vs SiM),
    kept as the labeled reference series; the cache-coverage grid only
    exists here (the functional frontend has a write buffer, not a
    coverage-parameterized cache).
"""
from __future__ import annotations

from benchmarks.common import (COVERAGES, DISTRIBUTIONS, READ_RATIOS, Timer,
                               emit, run_event, run_pair)


def main(scale: int = 1) -> None:
    # Measured series: event frontend, per-request medians.
    with Timer() as te:
        for dist_name, alpha in DISTRIBUTIONS:
            for rr in READ_RATIOS:
                r = run_event(rr, alpha, n_queries=1200 * scale)
                emit(f"fig14_event_{dist_name}_r{int(rr*100)}",
                     te.elapsed_us,
                     f"read_p50={r.latency.read_p50_ns/1e3:.1f}us_"
                     f"qps={r.latency.qps:.0f}")

    # Reference series: closed-form analytic grid (coverage axis lives
    # here only).
    cells = []
    with Timer() as t:
        for dist_name, alpha in DISTRIBUTIONS:
            for rr in READ_RATIOS:
                for cov in COVERAGES:
                    base, sim = run_pair(rr, alpha, cov,
                                         n_queries=4000 * scale)
                    red = 1 - sim.read_median_ns / base.read_median_ns \
                        if base.read_median_ns else 0.0
                    cells.append((dist_name, rr, cov, red, base, sim))
    n = len(cells)
    for dist_name, rr, cov, red, _, _ in cells:
        emit(f"fig14_ref_{dist_name}_r{int(rr*100)}_c{int(cov*100)}",
             t.elapsed_us / n, f"closed_form_median_reduction={red:.1%}")
    emit("fig14_max_reduction", t.elapsed_us / n,
         f"max={max(c[3] for c in cells):.0%}(paper_up_to_89%)")

    # Fig 16b: 40% read, random distribution — medians + IQR error bars
    # (closed-form reference; the coverage knob has no event equivalent).
    with Timer() as t2:
        for cov in (0.10, 0.25, 0.50):
            base, sim = run_pair(0.4, 0.0, cov, n_queries=4000 * scale)
            emit(f"fig16b_c{int(cov*100)}", t2.elapsed_us,
                 f"base_med={base.read_median_ns/1e3:.0f}us_iqr="
                 f"{(base.read_p75_ns-base.read_p25_ns)/1e3:.0f}us_"
                 f"sim_med={sim.read_median_ns/1e3:.0f}us_iqr="
                 f"{(sim.read_p75_ns-sim.read_p25_ns)/1e3:.0f}us")


if __name__ == "__main__":
    main()
