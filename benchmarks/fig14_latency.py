"""Paper Fig 14 + Fig 16b: median read latency reduction and IQR comparison."""
from __future__ import annotations

from benchmarks.common import (COVERAGES, DISTRIBUTIONS, READ_RATIOS, Timer,
                               emit, run_pair)


def main(scale: int = 1) -> None:
    cells = []
    with Timer() as t:
        for dist_name, alpha in DISTRIBUTIONS:
            for rr in READ_RATIOS:
                for cov in COVERAGES:
                    base, sim = run_pair(rr, alpha, cov,
                                         n_queries=4000 * scale)
                    red = 1 - sim.read_median_ns / base.read_median_ns \
                        if base.read_median_ns else 0.0
                    cells.append((dist_name, rr, cov, red, base, sim))
    n = len(cells)
    for dist_name, rr, cov, red, _, _ in cells:
        emit(f"fig14_{dist_name}_r{int(rr*100)}_c{int(cov*100)}",
             t.elapsed_us / n, f"median_reduction={red:.1%}")
    emit("fig14_max_reduction", t.elapsed_us / n,
         f"max={max(c[3] for c in cells):.0%}(paper_up_to_89%)")

    # Fig 16b: 40% read, random distribution — medians + IQR error bars
    with Timer() as t2:
        for cov in (0.10, 0.25, 0.50):
            base, sim = run_pair(0.4, 0.0, cov, n_queries=4000 * scale)
            emit(f"fig16b_c{int(cov*100)}", t2.elapsed_us,
                 f"base_med={base.read_median_ns/1e3:.0f}us_iqr="
                 f"{(base.read_p75_ns-base.read_p25_ns)/1e3:.0f}us_"
                 f"sim_med={sim.read_median_ns/1e3:.0f}us_iqr="
                 f"{(sim.read_p75_ns-sim.read_p25_ns)/1e3:.0f}us")


if __name__ == "__main__":
    main()
