"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import time

from repro.backend import make_backend
from repro.core.engine import SimChipArray
from repro.flash.params import DEFAULT_PARAMS
from repro.frontend import RunConfig, RunReport, replay
from repro.workload.runner import run
from repro.workload.ycsb import generate

# Paper grids (§VI-A4/A5, §VII)
COVERAGES = (0.0, 0.10, 0.25, 0.50, 0.75)
READ_RATIOS = (1.0, 0.8, 0.6, 0.4, 0.2)
DISTRIBUTIONS = (("uniform", 0.0), ("skewed", 0.5), ("very_skewed", 0.9))

# Simulation scale (queries per grid point).  Small enough for the full
# grid to run in ~a minute; pass --scale N to benchmarks.run to multiply.
N_QUERIES = 4000
N_KEY_PAGES = 1024

# Event-frontend scale: the functional executor programs real pages, so
# the keyspace is smaller than the closed-form grid's (which never
# materializes data).  Geometry mirrors the paper's 8-channel device.
EVENT_N_QUERIES = 1200
EVENT_N_KEY_PAGES = 32
EVENT_N_CHIPS = 8


def run_pair(read_ratio: float, alpha: float, coverage: float, *,
             n_queries: int = N_QUERIES, seed: int = 1,
             **kw) -> tuple[RunReport, RunReport]:
    """Closed-form analytic baseline-vs-SiM pair (the reference series)."""
    wl = generate(n_queries, n_key_pages=N_KEY_PAGES, read_ratio=read_ratio,
                  alpha=alpha, seed=seed)
    base = run(wl, params=DEFAULT_PARAMS, system="baseline",
               cache_coverage=coverage, **{k: v for k, v in kw.items()
                                           if k != "full_page_read_ratio"})
    sim = run(wl, params=DEFAULT_PARAMS, system="sim",
              cache_coverage=coverage, **kw)
    return base, sim


def run_event(read_ratio: float, alpha: float, *,
              n_queries: int = EVENT_N_QUERIES, seed: int = 1,
              qps: float = 3e5, scheduler: str = "read_priority",
              concurrency: int = 8, write_high_water: int = 16,
              **kw) -> RunReport:
    """Measured event-frontend run: the op stream replayed against real
    programmed pages under Poisson arrivals, NCQ admission and the given
    scheduler — per-request latency distributions rather than the
    closed-form model's per-op service times."""
    wl = generate(n_queries, n_key_pages=EVENT_N_KEY_PAGES,
                  read_ratio=read_ratio, alpha=alpha, seed=seed)
    arr = SimChipArray(
        n_chips=EVENT_N_CHIPS,
        pages_per_chip=max(wl.n_index_pages // EVENT_N_CHIPS + 1, 8),
        device_seed=7)
    cfg = RunConfig.open_loop(qps, concurrency=concurrency,
                              scheduler=scheduler, burst=64,
                              write_buffer=True,
                              write_high_water=write_high_water,
                              seed=seed, **kw)
    return replay(wl, make_backend("scalar", arr), cfg)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        self._end = None
        return self

    def __exit__(self, *a):
        self._end = time.perf_counter()

    @property
    def elapsed_us(self) -> float:
        end = self._end if self._end is not None else time.perf_counter()
        return (end - self.t0) * 1e6


_METRICS: list[dict] = []


def emit(name: str, value: float, derived: str) -> None:
    """Print a metric row and record it for ``write_bench_json``.

    ``value`` is microseconds per call for timing metrics, raw units
    (e.g. bytes) for the few counter metrics — the ``derived`` tag says
    which.
    """
    _METRICS.append({"name": name, "value": round(float(value), 2),
                     "derived": derived})
    print(f"{name},{value:.2f},{derived}")


def write_bench_json(bench_name: str, path: str | None = None) -> str:
    """Persist every metric emitted so far as ``BENCH_<name>.json``.

    CI uploads these files as build artifacts so the perf trajectory
    accumulates across commits.  The default output directory is
    ``benchmarks/`` (next to the committed baselines), independent of the
    caller's cwd; ``BENCH_JSON_DIR`` overrides it.
    """
    out_dir = os.environ.get("BENCH_JSON_DIR") \
        or os.path.dirname(os.path.abspath(__file__))
    path = path or os.path.join(out_dir, f"BENCH_{bench_name}.json")
    with open(path, "w") as f:
        json.dump({"bench": bench_name, "metrics": _METRICS}, f, indent=2)
    print(f"wrote {len(_METRICS)} metrics -> {path}")
    return path
