"""Paper Table I: worst-case transfer comparison, SiM vs conventional B-Tree.

Back-of-the-envelope analytic model over the paper's own constants: a point
query moves 128 B (64 B bitmap + 64 B chunk) at 40 MHz x 8 bit in match mode
versus two full 4 KiB pages at 1600 MT/s in storage mode.  Currents from the
cited datasheets (11 mA low-speed vs 152 mA high-speed bus).
"""
from __future__ import annotations

from benchmarks.common import Timer, emit

BUS_VOLTAGE = 1.2


def rows():
    # (label, io_bytes, bus_MBps, current_mA)
    sim = ("sim", 128, 40.0, 11.0)
    base = ("baseline", 8192, 1600.0, 152.0)
    out = {}
    for label, io, mbps, ma in (sim, base):
        t_us = io / mbps                      # bytes / (MB/s) == us
        e_nj = BUS_VOLTAGE * ma * t_us        # V * mA * us = nJ
        out[label] = dict(io_bytes=io, bus_mhz=mbps, current_ma=ma,
                          latency_us=t_us, energy_nj=e_nj)
    return out


def main() -> None:
    with Timer() as t:
        r = rows()
    io_ratio = r["baseline"]["io_bytes"] / r["sim"]["io_bytes"]
    cur_ratio = r["baseline"]["current_ma"] / r["sim"]["current_ma"]
    e_ratio = r["baseline"]["energy_nj"] / r["sim"]["energy_nj"]
    emit("table1_io_ratio", t.elapsed_us, f"{io_ratio:.0f}x_less_io")
    emit("table1_current_ratio", t.elapsed_us,
         f"{cur_ratio:.1f}x_peak_current(paper_13x)")
    emit("table1_energy_ratio", t.elapsed_us,
         f"{e_ratio:.1f}x_energy(paper_22x)")
    emit("table1_latency", t.elapsed_us,
         f"sim={r['sim']['latency_us']:.1f}us_base="
         f"{r['baseline']['latency_us']:.1f}us(paper_3.2_vs_5.1)")


if __name__ == "__main__":
    main()
