"""Beyond-paper experiment (motivated by §II-B): throughput under a peak-
current cap.

The paper argues the I/O phase's peak current limits intra-SSD parallelism
and that match-mode's 11 mA bus (vs 152 mA storage-mode, Table I) lets more
operations run concurrently within the same power budget.  The paper never
quantifies this; we sweep the budget and report the QPS ratio.
"""
from __future__ import annotations

from benchmarks.common import N_KEY_PAGES, Timer, emit
from repro.flash.params import DEFAULT_PARAMS
from repro.workload.runner import run
from repro.workload.ycsb import generate

BUDGETS_MA = (3000.0, 1000.0, 450.0, 300.0, 160.0)


def main(scale: int = 1) -> None:
    wl = generate(3000 * scale, n_key_pages=N_KEY_PAGES, read_ratio=1.0,
                  alpha=0.0, seed=2)
    with Timer() as t:
        for budget in BUDGETS_MA:
            b = run(wl, params=DEFAULT_PARAMS, system="baseline",
                    cache_coverage=0.0, power_budget_ma=budget)
            s = run(wl, params=DEFAULT_PARAMS, system="sim",
                    cache_coverage=0.0, power_budget_ma=budget)
            slots_storage = max(1, int(budget
                                       / DEFAULT_PARAMS.bus_peak_ma_storage))
            slots_match = max(1, int(budget
                                     / DEFAULT_PARAMS.bus_peak_ma_match))
            emit(f"power_budget_{int(budget)}mA", t.elapsed_us,
                 f"sim_over_base_qps={s.qps / b.qps:.2f}_"
                 f"concurrent_bursts_storage={slots_storage}_"
                 f"match={slots_match}")


if __name__ == "__main__":
    main()
