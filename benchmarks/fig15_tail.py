"""Paper Fig 15: p99 tail read latency reduction (incl. the §VII-D corner
case where SiM's all-dirty write buffer causes sporadic write-back storms).

Two series per (distribution, read-ratio) cell:

  * ``fig15_event_*`` — MEASURED: event-frontend per-request p99 under
    FIFO vs read-priority NCQ scheduling.  The tail claim becomes
    directly observable: FIFO reads queue behind the deferred die-program
    backlog, read-priority reads program-suspend past it;
  * ``fig15_ref_*`` — the closed-form analytic baseline-vs-SiM grid,
    kept as the labeled reference series (coverage axis lives here only).
"""
from __future__ import annotations

from benchmarks.common import (COVERAGES, DISTRIBUTIONS, READ_RATIOS, Timer,
                               emit, run_event, run_pair)


def main(scale: int = 1) -> None:
    # Measured series: FIFO-vs-read-priority p99 on the write-heavier
    # cells, where the program backlog actually builds up.
    with Timer() as te:
        for dist_name, alpha in DISTRIBUTIONS:
            for rr in (0.6, 0.4, 0.2):
                p99 = {}
                for sched in ("fifo", "read_priority"):
                    r = run_event(rr, alpha, n_queries=1200 * scale,
                                  scheduler=sched)
                    p99[sched] = r.latency.read_p99_ns
                gain = p99["fifo"] / p99["read_priority"] \
                    if p99["read_priority"] else 0.0
                emit(f"fig15_event_{dist_name}_r{int(rr*100)}",
                     te.elapsed_us,
                     f"p99_fifo={p99['fifo']/1e3:.0f}us_rp="
                     f"{p99['read_priority']/1e3:.0f}us_gain={gain:.1f}x")

    # Reference series: closed-form analytic grid.
    cells = []
    with Timer() as t:
        for dist_name, alpha in DISTRIBUTIONS:
            for rr in READ_RATIOS:
                for cov in COVERAGES:
                    base, sim = run_pair(rr, alpha, cov,
                                         n_queries=4000 * scale)
                    red = 1 - sim.read_p99_ns / base.read_p99_ns \
                        if base.read_p99_ns else 0.0
                    cells.append((dist_name, rr, cov, red))
    n = len(cells)
    for dist_name, rr, cov, red in cells:
        emit(f"fig15_ref_{dist_name}_r{int(rr*100)}_c{int(cov*100)}",
             t.elapsed_us / n, f"closed_form_p99_reduction={red:.1%}")
    emit("fig15_max_reduction", t.elapsed_us / n,
         f"max={max(c[3] for c in cells):.0%}(paper_up_to_85%)")
    corner = [c for c in cells if c[1] <= 0.4 and c[0] == "very_skewed"
              and c[2] >= 0.5]
    emit("fig15_corner_case_regression", t.elapsed_us / n,
         f"worst={min(c[3] for c in corner):.0%}"
         f"(paper:_SiM_tail_can_regress_at_skewed_write-heavy)")


if __name__ == "__main__":
    main()
