"""Paper Fig 15: p99 tail read latency reduction (incl. the §VII-D corner
case where SiM's all-dirty write buffer causes sporadic write-back storms)."""
from __future__ import annotations

from benchmarks.common import (COVERAGES, DISTRIBUTIONS, READ_RATIOS, Timer,
                               emit, run_pair)


def main(scale: int = 1) -> None:
    cells = []
    with Timer() as t:
        for dist_name, alpha in DISTRIBUTIONS:
            for rr in READ_RATIOS:
                for cov in COVERAGES:
                    base, sim = run_pair(rr, alpha, cov,
                                         n_queries=4000 * scale)
                    red = 1 - sim.read_p99_ns / base.read_p99_ns \
                        if base.read_p99_ns else 0.0
                    cells.append((dist_name, rr, cov, red))
    n = len(cells)
    for dist_name, rr, cov, red in cells:
        emit(f"fig15_{dist_name}_r{int(rr*100)}_c{int(cov*100)}",
             t.elapsed_us / n, f"p99_reduction={red:.1%}")
    emit("fig15_max_reduction", t.elapsed_us / n,
         f"max={max(c[3] for c in cells):.0%}(paper_up_to_85%)")
    corner = [c for c in cells if c[1] <= 0.4 and c[0] == "very_skewed"
              and c[2] >= 0.5]
    emit("fig15_corner_case_regression", t.elapsed_us / n,
         f"worst={min(c[3] for c in corner):.0%}"
         f"(paper:_SiM_tail_can_regress_at_skewed_write-heavy)")


if __name__ == "__main__":
    main()
