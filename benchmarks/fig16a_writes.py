"""Paper Fig 16a: flash write volume relative to no caching
(40% reads, random distribution)."""
from __future__ import annotations

from benchmarks.common import Timer, emit, run_pair


def main(scale: int = 1) -> None:
    with Timer() as t:
        base0, sim0 = run_pair(0.4, 0.0, 0.0, n_queries=4000 * scale)
        for cov in (0.10, 0.25, 0.50, 0.75):
            base, sim = run_pair(0.4, 0.0, cov, n_queries=4000 * scale)
            emit(f"fig16a_c{int(cov*100)}", t.elapsed_us,
                 f"base_rel={base.programs/base0.programs:.2f}_"
                 f"sim_rel={sim.programs/sim0.programs:.2f}")


if __name__ == "__main__":
    main()
