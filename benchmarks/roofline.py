"""Roofline report: aggregates the dry-run JSON artifacts into the
EXPERIMENTS.md table.  Requires a prior
``python -m repro.launch.dryrun --all`` run.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import Timer, emit

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def main(scale: int = 1) -> None:
    with Timer() as t:
        files = sorted(DRYRUN_DIR.glob("*__single.json"))
    if not files:
        emit("roofline", t.elapsed_us,
             "no_dryrun_artifacts(run_repro.launch.dryrun_--all_first)")
        return
    worst, best = None, None
    for f in files:
        r = json.loads(f.read_text())
        cell = f"{r['arch']}_{r['shape']}"
        if "skipped" in r:
            emit(f"roofline_{cell}", t.elapsed_us, "SKIP_long_context")
            continue
        if r.get("status") != "ok":
            emit(f"roofline_{cell}", t.elapsed_us, "ERROR")
            continue
        rl = r["roofline"]
        frac = r["roofline_fraction"]
        emit(f"roofline_{cell}", t.elapsed_us,
             f"dom={rl['dominant']}_c={rl['compute_s']:.2f}s_"
             f"m={rl['memory_s']:.2f}s_coll={rl['collective_s']:.2f}s_"
             f"frac={frac:.4f}_useful={r['useful_flops_ratio']:.3f}")
        if r["shape"] == "train_4k":
            if worst is None or frac < worst[1]:
                worst = (cell, frac)
            if best is None or frac > best[1]:
                best = (cell, frac)
    if best:
        emit("roofline_best_train_cell", t.elapsed_us,
             f"{best[0]}_frac={best[1]:.4f}")
        emit("roofline_worst_train_cell", t.elapsed_us,
             f"{worst[0]}_frac={worst[1]:.4f}")


if __name__ == "__main__":
    main()
