"""Paper Fig 12: SiM QPS speedup over baseline across
(read ratio x cache coverage x query distribution)."""
from __future__ import annotations

from benchmarks.common import (COVERAGES, DISTRIBUTIONS, READ_RATIOS, Timer,
                               emit, run_pair)


def main(scale: int = 1) -> None:
    cells = []
    with Timer() as t:
        for dist_name, alpha in DISTRIBUTIONS:
            for rr in READ_RATIOS:
                for cov in COVERAGES:
                    base, sim = run_pair(rr, alpha, cov,
                                         n_queries=4000 * scale)
                    speedup = sim.qps / base.qps if base.qps else float("inf")
                    cells.append((dist_name, rr, cov, speedup))
    n = len(cells)
    for dist_name, rr, cov, s in cells:
        emit(f"fig12_{dist_name}_r{int(rr*100)}_c{int(cov*100)}",
             t.elapsed_us / n, f"speedup={s:.2f}")
    wh = [s for d, rr, c, s in cells if rr <= 0.4]
    ro = [s for d, rr, c, s in cells if rr == 1.0 and 0.0 < c <= 0.25]
    emit("fig12_write_heavy_max", t.elapsed_us / n,
         f"max_speedup={max(wh):.2f}(paper_up_to_9x)")
    emit("fig12_read_only_low_cov", t.elapsed_us / n,
         f"baseline_advantage={1-min(ro):.0%}(paper_8-20%)")


if __name__ == "__main__":
    main()
