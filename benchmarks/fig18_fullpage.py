"""Paper Fig 18: QPS speedup versus the fraction of reads that are SiM
point reads (the remainder are legitimate full-page reads, e.g. LSM
compaction or analytic scans).  sim_ratio=0 equals an all-full-page system."""
from __future__ import annotations

from benchmarks.common import Timer, emit, run_pair

SIM_READ_RATIOS = (0.0, 0.25, 0.50, 0.75, 1.0)


def main(scale: int = 1) -> None:
    with Timer() as t:
        for rr, tag in ((0.8, "read_dominant"), (0.2, "write_dominant")):
            for dist, alpha in (("uniform", 0.0), ("very_skewed", 0.9)):
                ref_qps = None
                for sim_ratio in SIM_READ_RATIOS:
                    base, sim = run_pair(
                        rr, alpha, 0.10, n_queries=4000 * scale,
                        full_page_read_ratio=1.0 - sim_ratio)
                    if ref_qps is None:
                        ref_qps = sim.qps      # all reads full-page
                    emit(f"fig18_{tag}_{dist}_s{int(sim_ratio*100)}",
                         t.elapsed_us,
                         f"qps_rel={sim.qps/ref_qps:.2f}")


if __name__ == "__main__":
    main()
