"""Chaos sweep: seeded device-fault schedules as an availability gate.

Replays one mixed YCSB stream through the replica-enabled sharded backend
under four seeded fault schedules (``repro.reliability.FaultSchedule``)
with the event frontend's robustness tier armed — per-read deadlines,
bounded seeded-backoff retries, replica failover, bad-block remap and
host-side degraded reads:

* **healthy** — the no-fault anchor: every counter must be zero and the
  replay bit-identical to the serial oracle;
* **transient_stall** — a die stalls for a window mid-run: reads blow
  their deadline, retry with exponential backoff and complete once the
  stall clears.  ``chaos_availability`` (completed / total ops) gates a
  hard >= 0.99 floor here;
* **dying_die** — stall bursts then a permanent die outage plus program
  failures: writes remap bad blocks to spares, reads fail over to
  replicas;
* **dead_chip** — a whole chip dead from t=0: every op touching it is
  served from replicas or the host-side scalar path, bit-identically.

The correctness discipline mirrors reliability_sweep: every completed op
must return the exact closed-form oracle value (initial value
``((k+1) * phi64) | 1`` or the last prior write's ``qi*2+1`` tag) — a
fault may delay an answer or fail it with a typed error, never change
it.  ``chaos_wrong_results`` is a HARD_ZERO in check_regression.py; the
per-schedule fault counters are seeded-deterministic and gate exactly.
An overload run (Poisson arrivals far past saturation with a bounded
overflow queue) additionally exercises the backpressure shed path.

Run from the repo root:  PYTHONPATH=src python -m benchmarks.chaos_sweep
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.backend.sharded import ShardedSsdBackend
from repro.core.engine import SimChipArray
from repro.frontend import RunConfig, replay
from repro.reliability import FaultSchedule
from repro.workload.ycsb import generate

N_QUERIES = 600
N_KEY_PAGES = 16
N_CHIPS = 4
REPLICAS = 2
SEED = 11

# Robustness knobs for the fault runs.  Healthy bursts on this geometry
# complete in < 300 us, so the 500 us deadline never fires fault-free; a
# burst caught by the 1 ms die stall blows it, and the bounded backoff
# ladder (100/200/400/800/1600 us) comfortably outlives the stall.
DEADLINE_NS = 500_000.0
MAX_RETRIES = 5
BACKOFF_BASE_NS = 100_000.0

SCHEDULES = (
    ("healthy", FaultSchedule.healthy(seed=SEED)),
    ("transient_stall", FaultSchedule.transient_stall(
        die=0, t_start_ms=0.05, dur_ms=1.0, seed=SEED)),
    ("dying_die", FaultSchedule.dying_die(
        die=1, t_fail_ms=0.5, program_fail_prob=0.05, seed=SEED)),
    ("dead_chip", FaultSchedule.dead_chip(chip=0, seed=SEED)),
)
# The stable FaultReport counter schema (see repro/frontend/report.py).
COUNTERS = ("timeouts", "retries", "backoff_waits", "hedges_won",
            "failovers", "remapped_blocks", "degraded_ops",
            "shed_requests", "replica_programs", "program_failures")


def _workload():
    return generate(N_QUERIES, n_key_pages=N_KEY_PAGES, read_ratio=0.8,
                    alpha=0.9, seed=7)


def _backend():
    """Replica-enabled sharded backend with spare-page headroom (replicas
    and bad-block remaps both allocate from the top of each chip)."""
    n_pages = N_KEY_PAGES * 2
    arr = SimChipArray(
        n_chips=N_CHIPS,
        pages_per_chip=(n_pages // N_CHIPS + 1) * (REPLICAS + 1),
        device_seed=3)
    return ShardedSsdBackend(arr, use_kernel=False, interpret=True,
                             replicas=REPLICAS)


def _oracle(wl) -> np.ndarray:
    """Serial-order closed-form answer for every read op.

    Valid for the FIFO concurrency-1 runs below: values are captured at
    FIRST dispatch (retries re-charge timing only), and zero-inter-
    arrival FIFO dispatches in stream order, so each read sees exactly
    the writes that precede it in the stream.
    """
    exp = np.zeros(len(wl.ops), dtype=np.uint64)
    last: dict[int, int] = {}
    for qi in range(len(wl.ops)):
        k = int(wl.keys[qi])
        if wl.ops[qi] == 1:
            last[k] = qi
        elif wl.ops[qi] == 0:
            if k in last:
                exp[qi] = np.uint64(last[k] * 2 + 1)
            else:
                exp[qi] = np.uint64(
                    (((k + 1) * 0x9E3779B97F4A7C15) % 2**64) | 1)
    return exp


def fault_schedule_sweep() -> None:
    wl = _workload()
    oracle = _oracle(wl)
    is_read = wl.ops == 0
    wrong = 0
    p99 = {}
    for name, sched in SCHEDULES:
        rep = replay(wl, _backend(), RunConfig.event_serial(
            fused=True, faults=sched, deadline_ns=DEADLINE_NS,
            max_retries=MAX_RETRIES, backoff_base_ns=BACKOFF_BASE_NS,
            seed=SEED))
        f = rep.faults
        ok = is_read & ~f.op_errors
        # Wrong result = a completed read whose value is not the exact
        # serial-order oracle answer.  Faults must surface as typed
        # errors/retries/failovers, never as silently wrong data.
        wrong += int(np.sum(rep.read_values[ok] != oracle[ok]))
        for c in COUNTERS:
            emit(f"chaos_{name}_{c}", getattr(f, c),
                 f"seeded_fault_schedule_{name}")
        emit(f"chaos_{name}_op_errors", f.n_op_errors,
             "typed_per_op_errors_timeout+degraded+shed")
        p99[name] = rep.latency.read_p99_ns
        emit(f"chaos_{name}_read_p99_us", rep.latency.read_p99_ns / 1e3,
             "simulated_read_p99_completed_ops_only")
        if name == "healthy":
            # replica_programs is write-path mirroring, nonzero even
            # fault-free; every *fault* counter must be zero.
            assert f.n_op_errors == 0 and all(
                getattr(f, c) == 0 for c in COUNTERS
                if c != "replica_programs"), \
                "healthy schedule produced nonzero fault counters"
        if name == "transient_stall":
            avail = 1.0 - f.n_op_errors / len(wl.ops)
            assert avail >= 0.99, \
                f"availability {avail:.4f} under transient stall " \
                "below the 0.99 floor"
            emit("chaos_availability", avail,
                 "completed_ops/total_under_transient_stall_floor_0.99")
    # Recovery work is charged to the flash timelines, so the stalled
    # run's tail must sit above the healthy tail — if it doesn't, the
    # retries were free, which means the timeline never saw them.
    assert p99["transient_stall"] > p99["healthy"], \
        "transient-stall p99 not above healthy p99 — recovery looks free"
    assert wrong == 0, \
        f"{wrong} completed ops returned wrong values under chaos"
    emit("chaos_wrong_results", wrong,
         "completed_ops_vs_serial_oracle_across_all_schedules")


def overload_shed() -> None:
    """Poisson arrivals far past saturation with a tiny overflow bound:
    the backpressure must shed (typed errors), and every op that still
    completes must return the exact oracle value — read-only stream, so
    the oracle is order-independent under read-priority scheduling."""
    wl = generate(N_QUERIES, n_key_pages=N_KEY_PAGES, read_ratio=1.0,
                  alpha=0.9, seed=7)
    oracle = _oracle(wl)
    rep = replay(wl, _backend(), RunConfig(
        mode="event", fused=True, arrival="poisson",
        arrival_rate_qps=5e5, concurrency=8, scheduler="read_priority",
        ncq_depth=16, shed_capacity=8, seed=SEED,
        faults=FaultSchedule.healthy(seed=SEED)))
    f = rep.faults
    assert f.shed_requests > 0, \
        "overload run shed nothing — backpressure path not exercised"
    ok = ~f.op_errors
    assert int(np.sum(ok)) >= 100, \
        "overload run completed too few ops for a meaningful oracle check"
    wrong = int(np.sum(rep.read_values[ok] != oracle[ok]))
    assert wrong == 0, f"{wrong} completed ops wrong under overload"
    emit("chaos_overload_shed_requests", f.shed_requests,
         "poisson_5e5qps_ncq16_overflow_cap8")
    emit("chaos_overload_completed_ok", int(np.sum(ok)),
         "non_shed_ops_all_oracle_exact")


def main() -> None:
    fault_schedule_sweep()
    overload_shed()
    write_bench_json("chaos_sweep")


if __name__ == "__main__":
    main()
