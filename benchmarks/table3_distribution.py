"""Paper Table III: query concentration of the top-4 keys per distribution."""
from __future__ import annotations

from benchmarks.common import N_KEY_PAGES, Timer, emit
from repro.workload.ycsb import KEYS_PER_PAGE, concentration_table


def main(scale: int = 1) -> None:
    n_keys = N_KEY_PAGES * KEYS_PER_PAGE
    with Timer() as t:
        for name, alpha in (("uniform", 0.0), ("skewed", 0.5),
                            ("very_skewed", 0.9)):
            top = concentration_table(n_keys, alpha)
            emit(f"table3_{name}", t.elapsed_us,
                 "_".join(f"{p:.4%}" for p in top))


if __name__ == "__main__":
    main()
