"""Diff a fresh BENCH_*.json against its committed baseline; exit 1 on
regression.

CI runs this after benchmarks/kernel_micro.py so the perf trajectory is a
*gate*, not just an uploaded artifact.  Three metric classes, picked by
name, each with its own tolerance discipline:

  * counter metrics (``*_bytes*``, ``*_programs*``) — byte-traffic
    invariants of the device-resident plane store (0 warm restage, 4096
    per dirty row) and the write path's exact program counts (buffered
    replay MUST coalesce below one program per write).  These are exact
    contracts: any drift fails.
  * ratio metrics (``*speedup*``) — dimensionless A/B throughput ratios
    measured in the same process, so machine speed cancels out.  They must
    stay above both an absolute floor (the gates the benchmark itself
    asserts, e.g. sharded 16-chip >= 2x) and ``RATIO_KEEP`` of baseline.
  * reliability counters (``reliability_*``) — the BER sweep's exact
    outcome counts (retries, fallback reads, refreshes, typed errors,
    unverified wrong-op counts).  Fault injection and sense noise are
    fully seeded, so these are deterministic and gated exactly, like the
    byte counters.  Two of them are additionally ``HARD_ZEROS``: the
    verified pipeline's wrong-result count and the cross-backend
    divergence count must be zero in the FRESH run regardless of what any
    baseline says — a nonzero value is a correctness bug, not a
    regression.
  * chaos counters (``chaos_*``) — the device-fault sweep's exact fault
    outcomes (timeouts, retries, failovers, remaps, degraded ops, shed
    requests — all seeded and deterministic, gated exactly), with two
    special cases: ``chaos_availability`` is a RATIO that must stay above
    the hard 0.99 floor under the transient-stall schedule, and
    ``chaos_wrong_results`` is a ``HARD_ZERO`` — device faults may delay
    an answer or fail it with a typed error, but a completed op must
    never return a wrong value.
  * timing metrics (everything else) — wall microseconds depend on the
    machine, and the committed baseline was measured on a dev container,
    not a GitHub runner: a gross slowdown (> ``TIMING_SLOWDOWN`` x
    baseline) is printed as a WARNING but does not fail the build unless
    ``BENCH_STRICT_TIMINGS=1`` (for same-machine A/B comparisons).  The
    hard gates ride the machine-independent classes above.

A metric present in the baseline but missing from the fresh run fails
(coverage regression); new metrics are reported and pass — commit an
updated baseline alongside the benchmark change that adds them.

Usage:
    python benchmarks/check_regression.py \
        benchmarks/BENCH_kernel_micro.json \
        benchmarks/BENCH_kernel_micro.baseline.json
"""
from __future__ import annotations

import json
import os
import sys

TIMING_SLOWDOWN = 3.0      # machine-noise headroom for wall-clock metrics
RATIO_KEEP = 0.5           # ratios may lose half their baseline margin...
RATIO_FLOORS = {           # ...but never dip below the hard gates
    "sharded_speedup_16chip": 2.0,
    "sharded_speedup_4chip": 1.2,
    "plan_fused_speedup": 2.0,
    "write_coalesce_speedup": 2.0,
    # Event frontend (benchmarks/latency_sweep.py): at saturating offered
    # QPS, read-priority NCQ scheduling must keep the read p99 at least
    # 1.5x better than in-order FIFO — the Fig 15 tail claim as a gate.
    "latency_sweep_rp_vs_fifo_p99_speedup": 1.5,
    # Chaos sweep (benchmarks/chaos_sweep.py): under the transient-stall
    # schedule with deadlines+retries armed, at least 99% of ops must
    # still complete (availability floor; the rest must fail typed).
    "chaos_availability": 0.99,
}
# Event-loop accounting metrics (benchmarks/latency_sweep.py): arrivals
# are seeded and the loop is deterministic, so these gate exactly, like
# the byte counters.
EVENT_COUNTER_SUFFIXES = ("_events", "_dispatches", "_admitted",
                          "_admission_waits", "_ncq_peak")
HARD_ZEROS = {             # must be 0 in every fresh run, baseline or not
    "reliability_wrong_results_verified",
    "reliability_backend_mismatch",
    "chaos_wrong_results",
}


def classify(name: str) -> str:
    if name == "chaos_availability":
        return "ratio"
    if name.startswith(("reliability_", "chaos_")):
        return "counter"
    if "speedup" in name:
        return "ratio"
    if "_bytes" in name or "_programs" in name:
        return "counter"
    if name.endswith(EVENT_COUNTER_SUFFIXES):
        return "counter"
    return "timing"


def check(fresh: dict, baseline: dict) -> tuple[list[str], list[str]]:
    """Returns (failures, timing_warnings)."""
    fresh_by_name: dict[str, list[float]] = {}
    for m in fresh["metrics"]:
        fresh_by_name.setdefault(m["name"], []).append(float(m["value"]))
    seen: dict[str, int] = {}
    failures: list[str] = []
    warnings: list[str] = []
    # Correctness zeros gate on the FRESH run alone: even a freshly
    # regenerated baseline must never grandfather a wrong result in.
    for name in sorted(HARD_ZEROS & fresh_by_name.keys()):
        for val in fresh_by_name[name]:
            if val != 0:
                failures.append(f"{name}: {val} != 0 (correctness "
                                "hard-zero, independent of baseline)")
    for m in baseline["metrics"]:
        name, base = m["name"], float(m["value"])
        idx = seen.get(name, 0)
        seen[name] = idx + 1
        got = fresh_by_name.get(name, [])
        if idx >= len(got):
            failures.append(f"{name}[{idx}]: missing from fresh run "
                            "(coverage regression)")
            continue
        val = got[idx]
        kind = classify(name)
        if kind == "counter":
            if val != base:
                failures.append(f"{name}[{idx}]: counter {val} != "
                                f"baseline {base} (exact contract)")
        elif kind == "ratio":
            floor = max(RATIO_FLOORS.get(name, 0.0),
                        base * RATIO_KEEP)
            if val < floor:
                failures.append(f"{name}[{idx}]: ratio {val:.2f} < "
                                f"floor {floor:.2f} "
                                f"(baseline {base:.2f})")
        else:
            if base > 0 and val > base * TIMING_SLOWDOWN:
                warnings.append(f"{name}[{idx}]: {val:.1f}us > "
                                f"{TIMING_SLOWDOWN}x baseline "
                                f"{base:.1f}us")
    extra = [n for n in fresh_by_name
             if n not in {m["name"] for m in baseline["metrics"]}]
    if extra:
        print(f"new metrics (not in baseline, passing): {sorted(extra)}")
    return failures, warnings


def main(argv: list[str]) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        fresh = json.load(f)
    with open(argv[2]) as f:
        baseline = json.load(f)
    failures, warnings = check(fresh, baseline)
    if warnings and os.environ.get("BENCH_STRICT_TIMINGS") == "1":
        failures += warnings
        warnings = []
    for line in warnings:
        print(f"  WARN (timing, advisory on foreign hardware) {line}")
    n = len(baseline["metrics"])
    if failures:
        print(f"PERF REGRESSION: {len(failures)} of {n} baseline metrics "
              "failed")
        for line in failures:
            print(f"  FAIL {line}")
        return 1
    print(f"perf check OK: {n} baseline metrics within tolerance"
          + (f" ({len(warnings)} timing warnings)" if warnings else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
