"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoints -> straggler watchdog, on a reduced model (CPU-sized; pass
--arch/--steps to scale, the same driver runs pod-scale configs).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    run = train(args.arch, steps=args.steps, batch=args.batch,
                seq_len=args.seq_len, ckpt_root=args.ckpt, ckpt_every=50,
                log_every=20)
    print(f"\nloss {run.losses[0]:.3f} -> {run.losses[-1]:.3f} over "
          f"{run.steps_run} steps; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
