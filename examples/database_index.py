"""Database indexes on SiM (paper §V-A/B): B+Tree primary index, extendible
hash index, and the I/O ledger against the CPU-centric baseline.

Run:  PYTHONPATH=src python examples/database_index.py
"""
import numpy as np

from repro.core.engine import SimChipArray
from repro.index.baseline import BaselineBTree
from repro.index.btree import SimBTree
from repro.index.hashindex import SimHashIndex


def main():
    rng = np.random.default_rng(0)
    keys = (rng.choice(10**9, size=5000, replace=False) + 1).astype(np.uint64)
    values = keys * np.uint64(17)

    print("=== B+Tree primary index (leaves on SiM) ===")
    bt = SimBTree(SimChipArray(n_chips=8, pages_per_chip=64))
    bt.bulk_load(keys, values)
    bb = BaselineBTree(SimChipArray(n_chips=8, pages_per_chip=64))
    bb.bulk_load(keys, values)
    probes = rng.choice(keys, size=200, replace=False)
    for k in probes:
        v_sim, v_base = bt.lookup(int(k)), bb.lookup(int(k))
        assert v_sim == v_base == int(k) * 17
    sim_io = bt.stats.bitmap_bytes + bt.stats.chunk_bytes
    print(f"200 point lookups agree with baseline")
    print(f"  SiM I/O:      {sim_io:>10,} B "
          f"({bt.stats.searches} searches, {bt.stats.gathers} gathers)")
    print(f"  baseline I/O: {bb.bytes_read:>10,} B "
          f"({bb.pages_read} full pages)")
    print(f"  reduction:    {bb.bytes_read / sim_io:.0f}x")

    print("\n=== range query (exact prefix decomposition, §V-C) ===")
    lo, hi = int(np.percentile(keys, 50)), int(np.percentile(keys, 52))
    r_sim = sorted(bt.range_query(lo, hi))
    r_base = sorted(bb.range_query(lo, hi))
    assert r_sim == r_base
    print(f"range [{lo}, {hi}) -> {len(r_sim)} rows, results identical")

    print("\n=== extendible hash index (bucket splits via §V-D) ===")
    h = SimHashIndex(SimChipArray(n_chips=8, pages_per_chip=512))
    for k in keys[:3000]:
        h.insert(int(k), int(k) % 99991)
    ok = all(h.lookup(int(k)) == int(k) % 99991 for k in keys[:3000:17])
    print(f"3000 inserts, lookups ok={ok}, bucket splits={h.splits} "
          f"(each split = 1 search + gather redistribution), "
          f"directory depth={h.global_depth}")


if __name__ == "__main__":
    main()
