"""Quickstart: the SiM command set in five minutes.

Builds a flash page of keys, runs search/gather commands against the
functional chip, then the same operations through the Pallas TPU kernels
(interpret mode on CPU), and shows the I/O arithmetic that motivates the
paper (Table I).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (Command, SimChip, pair_to_u64, unpack_bitmap)
from repro.core.bits import chunk_bitmap_from_slot_bitmap
from repro.core.page import build_page, mask_header_slots
from repro.kernels.layout import pages_to_planes
from repro.kernels.sim_search.ops import sim_search_pages
from repro.kernels.sim_fused.ops import sim_fused


def main():
    print("=== 1. program a page of keys into the chip ===")
    chip = SimChip(n_pages=16, device_seed=42)
    keys = np.arange(10_000, 10_504, dtype=np.uint64)      # 504 keys
    chip.program_entries(3, keys, timestamp_ns=1_000)
    print(f"stored {len(keys)} 8-byte keys in one 4 KiB page "
          f"(randomized on flash)")

    print("\n=== 2. search: ship the 8-byte query, get a 64 B bitmap ===")
    resp = chip.search(Command.search(3, 10_123))
    bitmap = mask_header_slots(resp.bitmap_words)
    slot = int(np.nonzero(unpack_bitmap(bitmap, 512))[0][0])
    print(f"search(10123) -> match at slot {slot} "
          f"(bitmap is {resp.bitmap_words.nbytes} bytes on the bus)")

    print("\n=== 3. gather: fetch only the matching 64 B chunk ===")
    cb = pair_to_u64(*chunk_bitmap_from_slot_bitmap(bitmap))
    g = chip.gather(Command.gather(3, cb))
    off = (slot % 8) * 8
    val = int.from_bytes(bytes(g.chunks[0][off:off + 8]), "little")
    print(f"gather -> {len(g.chunk_ids)} chunk(s), inner-parity ok="
          f"{bool(g.parity_ok.all())}, decoded key={val}")
    print(f"I/O: SiM moved {64 + 64} B; a page read moves 4096 B "
          f"({4096 // 128}x more)")

    print("\n=== 4. the same search through the Pallas TPU kernel ===")
    pages = np.stack([build_page(keys + 504 * p, p, device_seed=7).raw
                      for p in range(4)])
    out = sim_search_pages(pages, [10_623], [0xFFFFFFFFFFFFFFFF],
                           randomized=True, device_seed=7)
    hits = np.nonzero(unpack_bitmap(np.asarray(out[0]), xp=np))
    print(f"kernel search over 4 pages -> hit (page, slot) = "
          f"{list(zip(*map(lambda a: a.tolist(), hits)))}")

    print("\n=== 5. fused search+gather (one HBM page pass) ===")
    lo, hi = pages_to_planes(pages)
    from repro.core.bits import u64_array_to_pairs
    q = u64_array_to_pairs(np.array([10_623], dtype=np.uint64))[0]
    m = u64_array_to_pairs(np.array([0xFFFFFFFFFFFFFFFF],
                                    dtype=np.uint64))[0]
    bm, gathered, counts = sim_fused(lo, hi, q, m, max_out=4,
                                     randomized=True, device_seed=7)
    print(f"fused: per-page chunk counts = {np.asarray(counts).tolist()}")
    print("\nDone — see examples/database_index.py for the index "
          "structures and examples/serve_lm.py for the serving path.")


if __name__ == "__main__":
    main()
