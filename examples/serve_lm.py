"""End-to-end serving driver (the paper-native scenario): continuous
batching with the KV cache indexed by SiM pages — block-table lookups are
real search commands, sequence eviction is a §V-D partition sweep.

Run:  PYTHONPATH=src python examples/serve_lm.py [--requests 12]
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--no-paged", action="store_true")
    args = ap.parse_args()
    completions, engine, paged = serve(
        args.arch, n_requests=args.requests, paged=not args.no_paged)
    total = sum(len(c.tokens) for c in completions)
    print(f"\n{len(completions)} completions, {total} tokens generated")
    if paged is not None:
        print(f"block-table searches per generated token: "
              f"{paged.stats.searches / total:.1f}")


if __name__ == "__main__":
    main()
