"""Analytical range scans on a BitWeaving-packed secondary index (paper
Fig 9/10): exact prefix decomposition vs the paper's one-pass approximate
plan, with pass counts and false-positive rates.

Run:  PYTHONPATH=src python examples/range_query_analytics.py
"""
import numpy as np

from repro.core.bitweaving import Column, RowCodec
from repro.core.engine import SimChipArray
from repro.core.range_query import approximate_range, exact_range
from repro.index.secondary import SimSecondaryIndex


def main():
    rng = np.random.default_rng(1)
    codec = RowCodec([Column("gender", 1), Column("age", 7),
                      Column("salary", 20), Column("uid", 32)])
    n = 20_000
    rows = {"gender": rng.integers(0, 2, n),
            "age": rng.integers(18, 96, n),
            "salary": rng.integers(0, 200_000, n),
            "uid": np.arange(n)}
    si = SimSecondaryIndex(SimChipArray(n_chips=8, pages_per_chip=64), codec)
    si.load_rows(rows)
    print(f"loaded {n} rows into {si.n_pages} SiM pages")

    print("\n=== Fig 9: point predicate (gender == 1) ===")
    got = si.select_equals("gender", 1)
    print(f"-> {len(got)} rows with one masked search per page "
          f"({si.io_bitmap_bytes} B of bitmaps, {si.io_chunk_bytes} B of "
          f"chunks)")

    print("\n=== Fig 10: 2000 < salary < 7000 ===")
    truth = int(((rows['salary'] > 2000) & (rows['salary'] < 7000)).sum())
    for exact in (True, False):
        si.io_bitmap_bytes = si.io_chunk_bytes = 0
        got = si.select_range("salary", 2001, 7000, exact=exact)
        plan = codec.range("salary", 2001, 7000, exact=exact)
        tag = "exact " if exact else "approx"
        print(f"{tag}: {plan.n_passes:2d} passes -> {len(got)} rows "
              f"(truth {truth}), I/O {si.io_bitmap_bytes + si.io_chunk_bytes:,} B")

    print("\n=== approximate-plan error rate vs span (paper: low for "
          "uniform keys) ===")
    for lo, hi in [(1 << 12, 1 << 14), (5000, 6000), (100_000, 163_840)]:
        ap = approximate_range(lo, hi, width=20)
        ex = exact_range(lo, hi, width=20)
        ks = rng.integers(0, 1 << 20, size=100_000).astype(np.uint64)
        fp = int(ap.evaluate(ks).sum() - ex.evaluate(ks).sum())
        tp = int(ex.evaluate(ks).sum())
        print(f"[{lo:>7}, {hi:>7}): approx {ap.n_passes} passes, "
              f"exact {ex.n_passes} passes, false-positive rate "
              f"{fp / max(tp, 1):.2f}")


if __name__ == "__main__":
    main()
