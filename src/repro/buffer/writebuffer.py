"""Host-side coalescing write buffer (paper §VI: the whole DRAM cache acts
as a write buffer).

The paper's headline write-heavy speedup (Fig 12/13) does not come from
making programs faster — it comes from *not issuing most of them*: SiM
dedicates the SSD's DRAM to buffering updates while searches run in-flash,
so a hot page absorbs many writes and crosses to NAND once per flush
window, and reads of buffered pages are served straight from DRAM
(read-your-writes without touching the die).  TCAM-SSD draws the same
lesson from the command side: in-SSD search pays off only when updates are
batched against the search stream rather than interleaved one-by-one.

``WriteBuffer`` is that DRAM, keyed by page:

  * ``put(page, entries)`` absorbs a write — the full-page entry image is
    copied in; a page already dirty coalesces (last-wins, counted in
    ``stats.coalesced``);
  * ``get(page)`` is the read overlay: reads of a dirty page are served
    from the buffered image (a DRAM hit; counted in ``stats.read_hits``)
    instead of queuing a device command against a stale on-flash image;
  * ``flush(backend)`` drains every dirty page through the backend's
    deferred ``submit_program`` and issues ONE ``backend.flush()`` — the
    kernel backends execute the group as one chip-program pass plus one
    grouped plane-store scatter, and a timeline-coupled sharded backend
    reports the group's async die-program backlog and write latencies;
  * ``should_flush`` trips at the configurable ``high_water`` dirty-page
    mark, the knob that trades DRAM footprint against program batching.

The buffer holds *entry images*, not raw 4 KiB flash images: randomization,
ECC and page layout happen once, at program time, exactly like the eager
path — so replays through the buffer stay bit-identical to the unbuffered
reference (tests/test_writebuffer.py holds that across all backends).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class WriteBufferStats:
    writes: int = 0          # put() calls absorbed into the buffer
    coalesced: int = 0       # puts that overwrote an already-dirty page
    read_hits: int = 0       # overlay reads served from the buffer
    programs: int = 0        # page programs issued across all flushes
    flushes: int = 0         # non-empty flush() calls
    max_dirty: int = 0       # high-water mark actually reached


class WriteBuffer:
    """Coalescing page-image buffer in front of ``MatchBackend`` programs."""

    def __init__(self, *, high_water: int = 16):
        if high_water < 1:
            raise ValueError(f"high_water must be >= 1, got {high_water}")
        self.high_water = high_water
        # page addr -> (entries, kwargs); dict order = first-dirtied order.
        self._dirty: dict[int, tuple[np.ndarray, dict]] = {}
        self.stats = WriteBufferStats()

    # ------------------------------------------------------------- absorb
    def put(self, page_addr: int, entries, **kw) -> None:
        """Absorb a write: buffer the page's full entry image (copied)."""
        page_addr = int(page_addr)
        if page_addr in self._dirty:
            self.stats.coalesced += 1
        self._dirty[page_addr] = (
            np.array(entries, dtype=np.uint64, copy=True), kw)
        self.stats.writes += 1
        self.stats.max_dirty = max(self.stats.max_dirty, len(self._dirty))

    # ------------------------------------------------------------ overlay
    def get(self, page_addr: int) -> np.ndarray | None:
        """Read-your-writes overlay: the buffered entry image of a dirty
        page (newest write wins), or None when the page is clean — clean
        pages are served by the device, whose image is current."""
        entry = self._dirty.get(int(page_addr))
        if entry is None:
            return None
        self.stats.read_hits += 1
        return entry[0]

    @property
    def n_dirty(self) -> int:
        return len(self._dirty)

    @property
    def dirty_pages(self) -> list[int]:
        return list(self._dirty)

    @property
    def should_flush(self) -> bool:
        return len(self._dirty) >= self.high_water

    def would_trip(self, page_addr: int) -> bool:
        """Would ``put(page_addr, ...)`` reach the high-water mark?

        Exact pre-image of ``should_flush`` after the put: a page already
        dirty coalesces (dirty count unchanged), a clean page adds one.
        The event frontend uses this to decide whether a buffered write
        absorbs inline into the burst being composed or ends it (the
        drain resolves queued reads first, so it is a burst boundary).
        """
        return (len(self._dirty)
                + (int(page_addr) not in self._dirty)) >= self.high_water

    # -------------------------------------------------------------- drain
    def flush(self, backend) -> int:
        """Drain every dirty page as ONE deferred program group.

        Each page goes through ``backend.submit_program`` (already
        coalesced here, so one program per dirty page) and a single
        ``backend.flush()`` executes the group — grouped plane-store
        re-staging and timeline program-group accounting included.
        Returns the number of programs issued.
        """
        if not self._dirty:
            return 0
        dirty, self._dirty = self._dirty, {}
        tickets = [backend.submit_program(page_addr, entries, **kw)
                   for page_addr, (entries, kw) in dirty.items()]
        backend.flush()
        # Every program ticket must have resolved in THIS flush (SIM001):
        # a backend that left one pending would silently defer the page
        # image to some later burst, breaking read-your-writes for readers
        # that bypass the (now clean) overlay.
        unresolved = sum(1 for t in tickets if not t.done)
        if unresolved:
            raise RuntimeError(
                f"backend.flush() left {unresolved}/{len(tickets)} buffered "
                "page programs unresolved")
        self.stats.programs += len(dirty)
        self.stats.flushes += 1
        return len(dirty)
