from .writebuffer import WriteBuffer, WriteBufferStats

__all__ = ["WriteBuffer", "WriteBufferStats"]
