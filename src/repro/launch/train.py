"""End-to-end training driver.

CPU-runnable with reduced configs (examples/train_lm.py trains a ~few-M
model a few hundred steps); on a pod the same driver drives the full
configs — every distribution feature (sharding trees, FSDP constraints,
checkpoints, straggler watchdog, crash restart) goes through the exact code
the dry run lowers.

  python -m repro.launch.train --arch granite-3-8b --reduced --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses
from pathlib import Path

import jax

from repro.configs import get_config, reduced_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_model
from repro.parallel.sharding import (block_compute_shardings,
                                     shardings_for_tree)
from repro.train.checkpoint import (latest_step, load_checkpoint,
                                    save_checkpoint)
from repro.train.data import DataConfig, batch_at_step
from repro.train.ft import FailureInjector, StragglerWatchdog
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainRun:
    losses: list
    steps_run: int
    resumed_from: int
    straggler_events: int


def train(arch: str, *, steps: int = 50, reduced: bool = True,
          batch: int = 8, seq_len: int = 64, lr: float = 3e-3,
          ckpt_root: str | Path | None = None, ckpt_every: int = 20,
          crash_at: int | None = None, mesh=None, seed: int = 0,
          log_every: int = 10, verbose: bool = True) -> TrainRun:
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    mesh = mesh or make_host_mesh()
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                          moment_dtype=cfg.optimizer_dtype)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                          global_batch=batch, seed=seed)

    params, axes = init_model(jax.random.PRNGKey(seed), cfg)
    opt_state = init_opt_state(params, opt_cfg)
    p_sh = shardings_for_tree(params, axes, mesh, fsdp=cfg.fsdp)
    o_sh = {"m": p_sh, "v": p_sh,
            "step": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())}
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    start_step = 0
    if ckpt_root is not None:
        last = latest_step(ckpt_root)
        if last is not None:
            start_step, params, opt_state = load_checkpoint(
                last, params, opt_state, shardings=p_sh, opt_shardings=o_sh)
            if verbose:
                print(f"[train] resumed from {last} (step {start_step})")

    block_specs = None
    if cfg.fsdp and cfg.family != "ssm" and mesh.devices.size > 1:
        from repro.launch.specs import param_specs
        sds, ax = param_specs(cfg)
        block_specs = block_compute_shardings(sds["blocks"], ax["blocks"],
                                              mesh)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      block_specs=block_specs))
    watchdog = StragglerWatchdog()
    injector = FailureInjector(crash_at)
    losses = []
    resumed_from = start_step

    with mesh:
        for step in range(start_step, steps):
            watchdog.start_step(step)
            batch_data = batch_at_step(data_cfg, step)
            injector.maybe_crash(step)
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch_data)
            loss = float(metrics["loss"])
            losses.append(loss)
            ev = watchdog.end_step()
            if ev and verbose:
                print(f"[train] straggler: step {ev.step} "
                      f"{ev.slowdown:.1f}x median")
            if verbose and (step % log_every == 0 or step == steps - 1):
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if ckpt_root is not None and (step + 1) % ckpt_every == 0:
                save_checkpoint(Path(ckpt_root) / f"step_{step + 1}",
                                step + 1, params, opt_state,
                                config_name=cfg.name)
    return TrainRun(losses=losses, steps_run=len(losses),
                    resumed_from=resumed_from,
                    straggler_events=len(watchdog.events))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt", type=str, default=None)
    args = ap.parse_args()
    run = train(args.arch, steps=args.steps, reduced=args.reduced,
                batch=args.batch, seq_len=args.seq_len, ckpt_root=args.ckpt)
    print(f"[train] done: {run.steps_run} steps, "
          f"loss {run.losses[0]:.3f} -> {run.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
