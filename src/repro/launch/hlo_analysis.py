"""Post-SPMD HLO text analyzer with loop-trip multipliers.

Why: ``compiled.cost_analysis()`` counts a while-loop *body once* (verified
in tests/test_roofline.py), which under-counts scan-over-layers models by a
factor of n_layers, and the optimized-HLO text prints collective operands
without inline types.  This module parses the HLO text into computations,
resolves every instruction's shape, extracts while-loop trip counts from
their condition computations, and propagates multipliers:

    entry x1;  while body/cond x trip;  fusion / call / to_apply: inherit.

Per-cell outputs:
  * dot_flops        — 2 * result_elems * contracted_elems, trip-scaled
  * result_bytes     — sum of non-fusion instruction result sizes (an HBM
                       materialization proxy), trip-scaled
  * collective bytes — per kind (all-reduce / all-gather / reduce-scatter /
                       all-to-all / collective-permute), operand bytes,
                       trip-scaled
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
    # token-typed values (infeed/outfeed/callback sequencing) carry no data.
    "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# Two HLO text dialects cross this parser: the post-SPMD *optimized* dump
# (names carry a % sigil, headers carry a parameter signature) and the
# *unoptimized* `lower().compiler_ir("hlo")` text (no sigils, headers may
# be just `ENTRY main.15 {`).  The sigil is optional everywhere a name is
# *defined*; _OPERAND deliberately still requires it — operand extraction
# from free-form attribute text is only reliable on the optimized dialect.
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:[({]|$)")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# Tuple-shaped results parse through one nesting level — enough for the
# (buffer, (aux, aux)) tuples XLA emits; non-greedy `\(.*?\)` broke there.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s*([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _result_elems_and_dims(type_str: str) -> tuple[int, list[int]]:
    m = _SHAPE.search(type_str)
    if not m:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str               # text after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list

    def instr_map(self):
        return {i.name: i for i in self.instrs}


def parse_computations(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):            # computation header / close
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2), bool(m.group(1)), [])
                comps[cur.name] = cur
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if mi:
            cur.instrs.append(Instr(mi.group(1), mi.group(2), mi.group(3),
                                    mi.group(4)))
    return comps


def _trip_count(cond: Computation) -> int:
    """Scan/fori conditions compare an induction var to a constant."""
    best = 1
    for i in cond.instrs:
        if i.op == "constant" and i.type_str.startswith(("s32", "u32",
                                                         "s64", "u64")):
            m = re.match(r"([0-9]+)\)?", i.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_CALL_REFS = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_BRANCH_REFS = re.compile(r"branch_computations=\{([^}]*)\}")


@dataclasses.dataclass
class Analysis:
    dot_flops: float
    result_bytes: float
    collective_bytes: dict
    while_trips: list

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective_bytes.values()))


def analyze_hlo(hlo_text: str) -> Analysis:
    comps = parse_computations(hlo_text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return Analysis(0.0, 0.0, {k: 0 for k in COLLECTIVES}, [])

    # computation -> effective multiplier (max over call paths)
    mult: dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    trips: list = []
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(20):
        changed = False
        for comp in comps.values():
            m_here = mult[comp.name]
            if m_here == 0.0:
                continue
            for ins in comp.instrs:
                refs = _CALL_REFS.findall(ins.rest)
                branches = _BRANCH_REFS.findall(ins.rest)
                for b in branches:
                    refs.extend(_OPERAND.findall(b))
                if ins.op == "while":
                    body_cond = dict(re.findall(
                        r"(body|condition)=%?([\w\.\-]+)", ins.rest))
                    mcfg = _TRIP_CFG.search(ins.rest)
                    if mcfg:
                        trip = int(mcfg.group(1))
                    else:
                        cond_name = body_cond.get("condition")
                        trip = _trip_count(comps[cond_name]) \
                            if cond_name in comps else 1
                    for r in body_cond.values():
                        if r in comps and mult[r] < m_here * trip:
                            mult[r] = m_here * trip
                            changed = True
                else:
                    for r in refs:
                        if r in comps and mult[r] < m_here:
                            mult[r] = m_here
                            changed = True
        if not changed:
            break

    dot_flops = 0.0
    result_bytes = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    fusion_comps = {r for c in comps.values() for i in c.instrs
                    if i.op == "fusion"
                    for r in _CALL_REFS.findall(i.rest)}
    # In-place update accounting: a fusion whose root is a
    # dynamic-update-slice aliases its operand on TPU — the HBM traffic is
    # the *update slice*, not the whole carried buffer (scan-carried remat
    # stashes would otherwise dominate the memory term spuriously).
    dus_update_bytes: dict[str, int] = {}
    for c in comps.values():
        if not c.instrs:
            continue
        root = c.instrs[-1]
        dus = [i for i in c.instrs if i.op == "dynamic-update-slice"]
        # A fusion whose root (possibly through converts/bitcasts) is a DUS
        # over a same-shaped carried buffer aliases in place on TPU; count
        # the update operand, not the buffer.  (XLA:CPU wraps these in
        # whole-buffer f32<->bf16 converts — a backend artifact.)
        if dus and _shape_bytes(root.type_str) and len(dus) == 1:
            root_elems, _ = _result_elems_and_dims(root.type_str)
            dus_elems, _ = _result_elems_and_dims(dus[0].type_str)
            if root_elems == dus_elems:
                symbols = {i.name: i.type_str for i in c.instrs}
                ops = _OPERAND.findall(dus[0].rest.split(")")[0])
                if len(ops) >= 2:
                    dus_update_bytes[c.name] = _shape_bytes(
                        symbols.get(ops[1], ""))

    for comp in comps.values():
        m_here = mult[comp.name]
        if m_here == 0.0:
            continue
        symbols = {i.name: i.type_str for i in comp.instrs}
        is_fusion = comp.name in fusion_comps
        for ins in comp.instrs:
            if ins.op == "dot":
                n_out, _ = _result_elems_and_dims(ins.type_str)
                ops = _OPERAND.findall(ins.rest.split(")")[0])
                lhs_type = symbols.get(ops[0]) if ops else None
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                  ins.rest)
                contracted = 1
                if lhs_type and cdims and cdims.group(1):
                    _, ldims = _result_elems_and_dims(lhs_type)
                    for ci in cdims.group(1).split(","):
                        ci = int(ci)
                        if ci < len(ldims):
                            contracted *= ldims[ci]
                dot_flops += m_here * 2.0 * n_out * contracted
            base_op = ins.op
            for kind in COLLECTIVES:
                if base_op == kind or base_op == kind + "-start":
                    arg_names = _OPERAND.findall(ins.rest.split("),")[0])
                    b = sum(_shape_bytes(symbols.get(a, "")) for a in
                            arg_names)
                    if b == 0:       # operands may live outside (params)
                        b = _shape_bytes(ins.type_str)
                    coll[kind] += m_here * b
                    break
            if not is_fusion and ins.op not in ("parameter", "constant",
                                                "get-tuple-element",
                                                "tuple", "bitcast"):
                b = _shape_bytes(ins.type_str)
                if ins.op == "fusion":
                    called = _CALL_REFS.findall(ins.rest)
                    if called and called[0] in dus_update_bytes:
                        b = dus_update_bytes[called[0]]
                elif ins.op == "dynamic-update-slice":
                    ops_ = _OPERAND.findall(ins.rest.split(")")[0])
                    if len(ops_) >= 2:
                        b = _shape_bytes(symbols.get(ops_[1], "")) or b
                result_bytes += m_here * b
        if comp.name != entry.name:
            continue
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "while" and mult[comp.name] > 0:
                mcfg = _TRIP_CFG.search(ins.rest)
                if mcfg:
                    trips.append(int(mcfg.group(1)))
                    continue
                bc = dict(re.findall(r"(body|condition)=%?([\w\.\-]+)",
                                     ins.rest))
                cn = bc.get("condition")
                if cn in comps:
                    trips.append(_trip_count(comps[cn]))
    return Analysis(dot_flops=dot_flops, result_bytes=result_bytes,
                    collective_bytes=coll, while_trips=sorted(trips,
                                                              reverse=True))
