"""Serving driver: continuous batching over a reduced model on CPU, the
full config on a pod.  ``--paged`` routes the KV cache through the
SiM-paged block table (the paper's technique in the serving path).

  python -m repro.launch.serve --arch qwen3-4b --requests 8 --paged
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models.model import init_model
from repro.serve.batching import Request, ServeEngine
from repro.serve.kvcache import SimPagedKVCache


def serve(arch: str, *, n_requests: int = 8, reduced: bool = True,
          paged: bool = False, max_slots: int = 4, cache_len: int = 128,
          seed: int = 0, verbose: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    params, _ = init_model(jax.random.PRNGKey(seed), cfg)
    paged_cache = None
    if paged:
        paged_cache = SimPagedKVCache(cfg, n_pages=256, page_tokens=16)
    engine = ServeEngine(params, cfg, max_slots=max_slots,
                         cache_len=cache_len, paged_cache=paged_cache)
    rng = np.random.default_rng(seed)
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, 17)).tolist()
        engine.submit(Request(req_id=rid, prompt=prompt,
                              max_new_tokens=int(rng.integers(4, 13))))
    t0 = time.perf_counter()
    completions = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(c.tokens) for c in completions)
    if verbose:
        print(f"[serve] {len(completions)} requests, {total_tokens} tokens "
              f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s, "
              f"{engine.steps} engine steps)")
        if paged_cache is not None:
            s = paged_cache.stats
            print(f"[serve] SiM block table: {s.searches} searches, "
                  f"{s.programs} programs, {s.pages_allocated} pages alloc, "
                  f"{s.pages_freed} freed")
    return completions, engine, paged_cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    serve(args.arch, n_requests=args.requests, paged=args.paged,
          max_slots=args.slots)


if __name__ == "__main__":
    main()
