"""Roofline term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all per-chip (the partitioned
HLO module cost_analysis reports per-device numbers, and the hardware
constants are per-chip, so the chip count cancels):

    compute    = HLO_FLOPs_per_dev / peak_FLOP/s
    memory     = HLO_bytes_per_dev / HBM_bw
    collective = collective_bytes_per_dev / ICI_link_bw

collective bytes are not in cost_analysis: we parse the post-SPMD HLO text
and sum *operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e per-chip constants (assignment)
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind operand bytes summed over the module."""
    out = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s+[^=]*?\b(" + "|".join(COLLECTIVES)
                      + r")(?:-start|-done)?(?:\.\d+)?\(", stripped)
        if not m:
            continue
        kind = m.group(1)
        if "-done" in stripped.split("(")[0]:
            continue                      # avoid double counting async pairs
        # operand types are the dtype[dims] groups after the opening paren
        args = stripped[m.end():]
        shapes = _SHAPE_RE.findall(args)
        out[kind] += sum(_shape_bytes(d, dims) for d, dims in shapes)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float               # trip-scaled dot FLOPs per device
    bytes_accessed: float      # trip-scaled materialized result bytes
    coll: dict[str, int]       # per-kind collective operand bytes
    n_devices: int
    raw_cost_analysis: dict = dataclasses.field(default_factory=dict)
    while_trips: list = dataclasses.field(default_factory=list)

    @property
    def collective_total(self) -> int:
        return sum(self.coll.values())

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_total / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self, model_flops_per_dev: float) -> float:
        """Achievable MFU bound: useful-FLOPs time / dominant-term time."""
        if self.bound_s == 0:
            return 0.0
        return (model_flops_per_dev / PEAK_FLOPS) / self.bound_s

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "collective_bytes_per_dev": self.coll,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "raw_cost_analysis": self.raw_cost_analysis,
            "while_trips": self.while_trips,
        }


def analyze(compiled, n_devices: int) -> Roofline:
    """Roofline terms from the partitioned module.

    Uses the trip-count-aware HLO text analyzer (launch/hlo_analysis.py):
    ``compiled.cost_analysis()`` counts while bodies once, undercounting
    scan-over-layers models by n_layers (verified in tests), so its raw
    values are recorded for reference only.
    """
    from .hlo_analysis import analyze_hlo
    text = compiled.as_text()
    a = analyze_hlo(text)
    rl = Roofline(flops=a.dot_flops, bytes_accessed=a.result_bytes,
                  coll={k: int(v) for k, v in a.collective_bytes.items()},
                  n_devices=n_devices)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        rl.raw_cost_analysis = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    except Exception:
        rl.raw_cost_analysis = {}
    rl.while_trips = a.while_trips[:8]
    return rl


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs for the cell (global, not per-device):
    6·N_active·tokens for training, 2·N_active·tokens for inference."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        per_tok = 6 * n
        tokens = shape.global_batch * shape.seq_len
    elif shape.mode == "prefill":
        per_tok = 2 * n
        tokens = shape.global_batch * shape.seq_len
    else:                                  # decode: one token per sequence
        per_tok = 2 * n
        tokens = shape.global_batch
    return per_tok * tokens
