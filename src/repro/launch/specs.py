"""ShapeDtypeStruct stand-ins + sharding trees for every dry-run cell.

No device allocation happens here: parameters, optimizer state and caches
are produced with ``jax.eval_shape`` over the real constructors, so the
specs can never drift from the code that builds the live objects.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import InputShape, ModelConfig
from repro.models.model import init_model, make_caches
from repro.parallel.sharding import (batch_sharding, data_axes, replicated,
                                     shardings_for_tree, spec_for)
from repro.train.optimizer import AdamWConfig, init_opt_state


# ---------------------------------------------------------------- params

def param_specs(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical axes tree) without allocation.

    The axes tree is plain strings (not a JAX type), so it is captured via a
    side channel while eval_shape abstracts the arrays.
    """
    captured = {}

    def build():
        p, a = init_model(jax.random.PRNGKey(0), cfg)
        captured["axes"] = a
        return p

    sds = jax.eval_shape(build)
    return sds, captured["axes"]


def param_shardings(cfg: ModelConfig, mesh, axes, params_sds,
                    report=None):
    return shardings_for_tree(params_sds, axes, mesh, fsdp=cfg.fsdp,
                              report=report)


def opt_specs(cfg: ModelConfig, params_sds, opt_cfg: AdamWConfig):
    return jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), params_sds)


def opt_shardings(param_shards, opt_sds, mesh):
    return {"m": param_shards, "v": param_shards,
            "step": replicated(mesh)}


# ---------------------------------------------------------------- batches

def batch_specs(cfg: ModelConfig, shape: InputShape):
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        specs["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), dt)
    elif cfg.frontend == "audio_stub":
        specs["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), dt)
    return specs


def batch_shardings(cfg: ModelConfig, shape: InputShape, mesh):
    bsh = batch_sharding(mesh)
    axes = data_axes(mesh)
    dsize = 1
    for a in axes:
        dsize *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    if shape.global_batch % dsize != 0:       # e.g. long_500k's batch=1
        bsh = replicated(mesh)
    out = {"tokens": bsh, "labels": bsh}
    if cfg.frontend is not None:
        out["frontend"] = bsh
    return out


# ----------------------------------------------------------------- caches

CACHE_AXES = {
    "kv": (("layers", "batch", "kv_seq", "kv_heads", "head_dim"),) * 2,
    "mamba": (("layers", "batch", "mlp", None),
              ("layers", "batch", None, "mlp")),
    # xlstm recurrent states: (m_state C/n/m, s_state c/n/h/m)
    "states": (
        ((None, None, "batch", "heads", None, None),
         (None, None, "batch", "heads", None),
         (None, None, "batch", "heads")),
        ((None, "batch", "mlp"), (None, "batch", "mlp"),
         (None, "batch", "mlp"), (None, "batch", "mlp")),
    ),
}


def cache_specs(cfg: ModelConfig, shape: InputShape):
    b = shape.global_batch
    return jax.eval_shape(partial(make_caches, cfg, b, shape.seq_len))


def cache_shardings(cfg: ModelConfig, shape: InputShape, mesh, caches_sds):
    axes_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= axes_sizes[a]
    batch_ok = shape.global_batch % dsize == 0

    def one(path, leaf):
        key = path[0].key
        ax_group = CACHE_AXES[key]
        node = ax_group
        for k in path[1:]:
            node = node[k.idx]
        ax = list(node)
        if not batch_ok:
            ax = [None if a == "batch" else a for a in ax]
        return NamedSharding(
            mesh, spec_for(tuple(leaf.shape), tuple(ax), mesh,
                           fsdp=cfg.fsdp))
    return jax.tree_util.tree_map_with_path(one, caches_sds)


def act_sharding(cfg: ModelConfig, shape: InputShape, mesh):
    """(B, S, D) activation sharding: batch over (pod, data), D unsharded
    (tensor axes live in heads/mlp dims).  None batch axis when the cell's
    batch does not divide the data product (long_500k's B=1)."""
    axes_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= axes_sizes[a]
    if shape.global_batch % dsize != 0:
        return NamedSharding(mesh, P())
    first = daxes if len(daxes) > 1 else daxes[0]
    return NamedSharding(mesh, P(first, None, None))


def enc_out_spec(cfg: ModelConfig, shape: InputShape):
    if not cfg.encoder_layers:
        return None
    return jax.ShapeDtypeStruct(
        (shape.global_batch, cfg.encoder_seq, cfg.d_model),
        jnp.dtype(cfg.dtype))
