"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU tests of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
