"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization.

jax-version constraint: ``jax.sharding.AxisType`` (and the ``axis_types``
parameter of ``jax.make_mesh``) only exist from jax 0.5; on the pinned
jax 0.4.37 every mesh axis is implicitly Auto, which is exactly what we
ask for on newer jax — so ``make_mesh`` below is semantically identical
on both sides of the version split.
"""
from __future__ import annotations

import jax


def _auto_axis_types(n_axes: int):
    """(AxisType.Auto,) * n on jax >= 0.5, None on older jax."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n_axes


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where supported."""
    types = _auto_axis_types(len(axes))
    if types is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def production_mesh_spec(*, multi_pod: bool = False):
    """(shape, axes) of the production mesh — pure, testable without devices."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shape, axes


def make_production_mesh(*, multi_pod: bool = False):
    shape, axes = production_mesh_spec(multi_pod=multi_pod)
    return make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU tests of the sharded code paths."""
    return make_mesh((1, 1), ("data", "model"))
