import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first initialization).  Everything else follows.
# (No ``from __future__ import annotations`` here for the same reason —
# it would have to precede the XLA_FLAGS lines.)

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds ShapeDtypeStruct stand-ins (no allocation), jits
the train/prefill/decode step with explicit in/out shardings on the
production mesh, compiles, and records:

  * memory_analysis()  — per-device buffer sizes (fits/doesn't fit)
  * cost_analysis()    — FLOPs / bytes for the §Roofline terms
  * the collective mix parsed from the partitioned HLO

Results land in experiments/dryrun/<arch>__<shape>__<mesh>[__variant].json.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, shape_cells
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, model_flops
from repro.models.config import InputShape, ModelConfig
from repro.parallel.sharding import block_compute_shardings, replicated
from repro.serve.serve_step import serve_decode_step, serve_prefill
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mem_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                    # CPU backend
        return {"unavailable": str(e)}
    if ma is None:
        return {"unavailable": "None"}
    out = {}
    for field in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "temp_size_in_bytes",
                  "alias_size_in_bytes", "host_generated_code_size_in_bytes",
                  "host_argument_size_in_bytes", "host_output_size_in_bytes",
                  "host_temp_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    return out


def lower_cell(cfg: ModelConfig, shape: InputShape, mesh,
               opt_cfg: AdamWConfig | None = None,
               variant_tag: str = "baseline"):
    """Build + lower + compile one cell; returns (compiled, report dict)."""
    opt_cfg = opt_cfg or AdamWConfig(moment_dtype=cfg.optimizer_dtype)
    t0 = time.time()
    sharding_report: list = []
    params_sds, axes = S.param_specs(cfg)
    p_sh = S.param_shardings(cfg, mesh, axes, params_sds,
                             report=sharding_report)

    if shape.mode == "train":
        opt_sds = S.opt_specs(cfg, params_sds, opt_cfg)
        o_sh = S.opt_shardings(p_sh, opt_sds, mesh)
        b_sds = S.batch_specs(cfg, shape)
        b_sh = S.batch_shardings(cfg, shape, mesh)
        block_specs = None
        if cfg.fsdp and cfg.family != "ssm":
            block_specs = block_compute_shardings(
                params_sds["blocks"], axes["blocks"], mesh)
        act_spec = S.act_sharding(cfg, shape, mesh)
        step = make_train_step(cfg, opt_cfg, block_specs=block_specs,
                               act_spec=act_spec)
        metrics_sh = {"loss": replicated(mesh), "aux_loss": replicated(mesh),
                      "grad_norm": replicated(mesh), "lr": replicated(mesh)}
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, metrics_sh))
        with mesh:
            lowered = jitted.lower(params_sds, opt_sds, b_sds)

    elif shape.mode == "prefill":
        b_sds = S.batch_specs(cfg, shape)
        b_sh = S.batch_shardings(cfg, shape, mesh)

        act_spec = S.act_sharding(cfg, shape, mesh)

        def fn(params, tokens, frontend):
            return serve_prefill(params, cfg, tokens, shape.seq_len,
                                 frontend_embeds=frontend,
                                 act_spec=act_spec)

        fe_sds = b_sds.get("frontend")
        fe_sh = b_sh.get("frontend")
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh["tokens"], fe_sh))
        with mesh:
            lowered = jitted.lower(params_sds, b_sds["tokens"], fe_sds)

    else:  # decode
        c_sds = S.cache_specs(cfg, shape)
        c_sh = S.cache_shardings(cfg, shape, mesh, c_sds)
        b_sh = S.batch_shardings(cfg, shape, mesh)
        tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
        enc_sds = S.enc_out_spec(cfg, shape)
        enc_sh = b_sh["tokens"] if enc_sds is not None else None

        act_spec = S.act_sharding(cfg, shape, mesh)

        def fn(params, caches, token, index, enc_out):
            return serve_decode_step(params, cfg, token, caches, index,
                                     enc_out=enc_out, act_spec=act_spec)

        jitted = jax.jit(
            fn,
            in_shardings=(p_sh, c_sh, b_sh["tokens"], replicated(mesh),
                          enc_sh),
            out_shardings=(b_sh["tokens"], b_sh["tokens"], c_sh))
        with mesh:
            lowered = jitted.lower(params_sds, c_sds, tok_sds, idx_sds,
                                   enc_sds)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    n_dev = mesh.devices.size
    rl = analyze(compiled, n_dev)
    mflops = model_flops(cfg, shape)
    mflops_dev = mflops / n_dev
    report = {
        "arch": cfg.name, "shape": shape.name, "mode": shape.mode,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "n_devices": n_dev,
        "variant": variant_tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": _mem_analysis(compiled),
        "roofline": rl.to_dict(),
        "model_flops_global": mflops,
        "model_flops_per_dev": mflops_dev,
        "useful_flops_ratio": (mflops_dev / rl.flops) if rl.flops else 0.0,
        "roofline_fraction": rl.roofline_fraction(mflops_dev),
        "replicated_dims": [
            {"logical": l, "size": s, "axis": str(a)}
            for l, s, a in sharding_report],
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    return compiled, report


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             variant: str = "baseline", out_dir: Path = OUT_DIR) -> dict:
    cfg = get_config(arch)
    cfg = apply_variant(cfg, variant)
    cell = shape_cells(cfg)[shape_name]
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}" + (
        "" if variant == "baseline" else f"__{variant}")
    path = out_dir / f"{tag}.json"
    if cell is None:
        report = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "variant": variant, "skipped":
                  "full-attention arch at 500k context (DESIGN.md §4)"}
        path.write_text(json.dumps(report, indent=2))
        print(f"[dryrun] SKIP {tag}")
        return report
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        _, report = lower_cell(cfg, cell, mesh, variant_tag=variant)
        report["status"] = "ok"
    except Exception as e:
        report = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "variant": variant, "status": "error",
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    path.write_text(json.dumps(report, indent=2))
    status = report.get("status")
    extra = "" if status != "ok" else (
        f" dominant={report['roofline']['dominant']}"
        f" frac={report['roofline_fraction']:.3f}"
        f" compile={report['compile_s']}s")
    print(f"[dryrun] {status.upper()} {tag}{extra}", flush=True)
    return report


def apply_variant(cfg: ModelConfig, variant: str) -> ModelConfig:
    """Named perf variants used by the §Perf hillclimb."""
    if variant == "baseline":
        return cfg
    mods = {}
    for piece in variant.split("+"):
        if piece == "noremat":
            mods["remat"] = "none"
        elif piece == "fullremat":
            mods["remat"] = "full"
        elif piece == "nofsdp":
            mods["fsdp"] = False
        elif piece.startswith("mb"):
            pass     # microbatches handled by the caller
        else:
            raise ValueError(f"unknown variant piece {piece!r}")
    return dataclasses.replace(cfg, **mods)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", type=str, default="baseline")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = sorted(ARCHS) if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                rep = run_cell(arch, shape_name, mesh_kind, args.variant)
                if rep.get("status") == "error":
                    failures += 1
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
