"""Trace-time launch auditor: prove the one-launch-per-burst contract.

The kernel backends' performance story is a *shape* claim about the traced
program, not a style claim about the source: each flush phase must lower
to exactly ONE ``pallas_call`` (vmap over the chip axis included), with no
hidden host round trips (``pure_callback``/``io_callback``/explicit
transfers), stable retrace signatures across burst sizes (the pow2 padding
bounds distinct abstract signatures to O(log max_burst)), and byte
counters that reconcile against what the traced program actually moves.

The auditor enforces this dynamically: it wraps each backend's device
entry points (``sim_search``/``sim_plan``/``sim_fused_lookup``/
``sim_gather`` on batched, the ``_stacked_*`` jits on sharded) with a
recorder that re-traces every call via ``jax.make_jaxpr`` and summarizes
the jaxpr, then drives a scripted scenario through every flush path —
search (cold + warm), plan, lookup, gather, and the zero-launch
program-group — checking after each phase:

  * SIM101 — exactly one recorded launch per flush phase, exactly one
    ``pallas_call`` primitive per launch (recursively, through pjit);
  * SIM102 — zero forbidden primitives (callbacks, infeed/outfeed,
    device_put) anywhere in the traced launch;
  * SIM103 — distinct input-signature count across a burst-size sweep is
    within the O(log max_burst) pow2-padding bound;
  * SIM104 — ``staged_bytes`` deltas equal PAGE_BYTES x newly-staged
    pages (and ZERO when warm), ``result_bytes`` deltas equal the exact
    64 B-granular payload the command mix implies, plane operands in the
    jaxpr are exactly padded_rows(unique pages) x PAGE_BYTES, and
    ``kernel_launches`` equals the recorded launch count;
  * SIM105 — the unoptimized-HLO cross-check: parameter/ROOT bytes parsed
    from ``lower().compiler_ir('hlo')`` text (via launch/hlo_analysis)
    match the jaxpr operand/result bytes.

Failures surface as :class:`Finding` rows (path ``audit:<backend>``) that
flow through the same baseline/check gate as the AST lint.
"""
from __future__ import annotations

import contextlib
import dataclasses
import importlib
import math
from typing import Callable, Iterator

import jax

from repro.core.bits import PAGE_BYTES
from repro.core.commands import Command
from repro.core.engine import SimChipArray
from repro.core.range_query import exact_range
from repro.backend.base import MatchBackend, make_backend
from repro.backend.planestore import next_pow2, padded_rows
from repro.launch.hlo_analysis import _shape_bytes, parse_computations

from .findings import Finding

FORBIDDEN_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "callback", "debug_callback",
    "device_put", "infeed", "outfeed",
})

_PATCH_POINTS = {
    "batched": ("repro.backend.batched",
                ("sim_search", "sim_plan", "sim_fused_lookup", "sim_gather")),
    "sharded": ("repro.backend.sharded",
                ("_stacked_search", "_stacked_plan", "sim_fused_lookup",
                 "sim_gather")),
}


# --------------------------------------------------------------- jaxpr walk
def _sub_jaxprs(value) -> Iterator:
    v = getattr(value, "jaxpr", value)      # ClosedJaxpr -> Jaxpr
    if hasattr(v, "eqns"):
        yield v
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _sub_jaxprs(item)


def iter_eqns(jaxpr) -> Iterator:
    """All equations of a jaxpr, recursing through pjit/scan/cond bodies."""
    for eqn in jaxpr.eqns:
        yield eqn
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                yield from iter_eqns(sub)


def _aval_shape(v) -> tuple:
    a = v.aval
    return (tuple(a.shape), str(a.dtype))


def _aval_bytes(v) -> int:
    a = v.aval
    n = 1
    for d in a.shape:
        n *= int(d)
    return n * a.dtype.itemsize


@dataclasses.dataclass
class JaxprSummary:
    n_pallas: int
    primitives: tuple[str, ...]
    forbidden: tuple[str, ...]
    in_shapes: tuple[tuple, ...]
    out_shapes: tuple[tuple, ...]
    in_bytes: int
    out_bytes: int

    @property
    def signature(self) -> tuple:
        return self.in_shapes


def summarize_jaxpr(closed) -> JaxprSummary:
    prims = sorted({e.primitive.name for e in iter_eqns(closed.jaxpr)})
    n_pallas = sum(1 for e in iter_eqns(closed.jaxpr)
                   if e.primitive.name == "pallas_call")
    forbidden = tuple(p for p in prims if p in FORBIDDEN_PRIMITIVES)
    invars = closed.jaxpr.invars
    outvars = closed.jaxpr.outvars
    return JaxprSummary(
        n_pallas=n_pallas, primitives=tuple(prims), forbidden=forbidden,
        in_shapes=tuple(_aval_shape(v) for v in invars),
        out_shapes=tuple(_aval_shape(v) for v in outvars),
        in_bytes=sum(_aval_bytes(v) for v in invars),
        out_bytes=sum(_aval_bytes(v) for v in outvars))


# ----------------------------------------------------------------- recorder
@dataclasses.dataclass
class LaunchRecord:
    entry: str                       # patched entry point name
    summary: JaxprSummary
    pure: Callable                   # array-only closure (for HLO lowering)
    args: tuple                      # the concrete array operands


def _is_arraylike(v) -> bool:
    return hasattr(v, "shape") and hasattr(v, "dtype")


def _record_wrapper(orig, entry_name: str, records: list):
    def wrapped(*args, **kwargs):
        arr_pos = [i for i, a in enumerate(args) if _is_arraylike(a)]
        arr_kw = [k for k, v in kwargs.items() if _is_arraylike(v)]
        arrays = [args[i] for i in arr_pos] + [kwargs[k] for k in arr_kw]

        def pure(*vals):
            new_args = list(args)
            for i, v in zip(arr_pos, vals[:len(arr_pos)]):
                new_args[i] = v
            new_kw = dict(kwargs)
            for k, v in zip(arr_kw, vals[len(arr_pos):]):
                new_kw[k] = v
            return orig(*new_args, **new_kw)

        closed = jax.make_jaxpr(pure)(*arrays)
        records.append(LaunchRecord(entry=entry_name,
                                    summary=summarize_jaxpr(closed),
                                    pure=pure, args=tuple(arrays)))
        return orig(*args, **kwargs)
    return wrapped


@contextlib.contextmanager
def record_launches(kind: str):
    """Patch ``kind``'s device entry points; yields the record list."""
    modname, names = _PATCH_POINTS[kind]
    mod = importlib.import_module(modname)
    records: list[LaunchRecord] = []
    saved = {n: getattr(mod, n) for n in names}
    try:
        for n, f in saved.items():
            setattr(mod, n, _record_wrapper(f, n, records))
        yield records
    finally:
        for n, f in saved.items():
            setattr(mod, n, f)


# ------------------------------------------------------------ HLO cross-check
def hlo_cross_check(record: LaunchRecord) -> list[str]:
    """Parse the lowered (unoptimized) HLO and reconcile entry parameter /
    ROOT bytes against the jaxpr summary.  Returns mismatch messages."""
    text = jax.jit(record.pure).lower(*record.args) \
        .compiler_ir(dialect="hlo").as_hlo_text()
    comps = parse_computations(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None or not entry.instrs:
        return [f"{record.entry}: no ENTRY computation parsed from HLO"]
    msgs = []
    param_bytes = sum(_shape_bytes(i.type_str) for i in entry.instrs
                      if i.op == "parameter")
    root_bytes = _shape_bytes(entry.instrs[-1].type_str)
    if param_bytes != record.summary.in_bytes:
        msgs.append(f"{record.entry}: HLO parameter bytes {param_bytes} != "
                    f"jaxpr operand bytes {record.summary.in_bytes}")
    if root_bytes != record.summary.out_bytes:
        msgs.append(f"{record.entry}: HLO ROOT bytes {root_bytes} != "
                    f"jaxpr result bytes {record.summary.out_bytes}")
    return msgs


# ------------------------------------------------------------------- driver
def _key(page: int, i: int) -> int:
    """Distinct programmed u64 keys, high nibble tagged to dodge headers."""
    return (0xA << 60) | (page << 16) | i


N_KEY_PAGES = 6
VAL_BASE = 6
N_ENTRIES = 12


class _Auditor:
    def __init__(self, kind: str, *, use_kernel: bool = True,
                 hlo: bool = True):
        self.kind = kind
        self.hlo = hlo
        self.findings: list[Finding] = []
        n_chips = 4 if kind == "sharded" else 2
        self.chips = SimChipArray(n_chips=n_chips, pages_per_chip=64,
                                  device_seed=11)
        self.backend: MatchBackend = make_backend(
            kind, self.chips, page_block=8, lookup_block=8,
            use_kernel=use_kernel)
        for p in range(N_KEY_PAGES):
            self.backend.program_entries(
                p, [_key(p, i) for i in range(N_ENTRIES)])
            self.backend.program_entries(
                VAL_BASE + p,
                [(0xB << 60) | (p << 16) | i for i in range(N_ENTRIES)])

    def check(self, cond: bool, rule: str, symbol: str, slug: str,
              msg: str) -> None:
        if not cond:
            self.findings.append(Finding(
                rule, f"audit:{self.kind}", symbol, slug, message=msg))

    # ------------------------------------------------------------ one phase
    def run_phase(self, records: list, phase: str, submit, *,
                  expect_result_bytes: int, expect_staged_bytes: int,
                  expect_pages: int | None = None,
                  expect_launches: int = 1):
        r0 = len(records)
        stats = self.backend.stats
        staged0, result0 = stats.staged_bytes, stats.result_bytes
        launches0 = stats.kernel_launches
        tickets = submit()
        self.backend.flush()
        recs = records[r0:]

        self.check(len(recs) == expect_launches, "SIM101", phase,
                   "launch-count",
                   f"flush dispatched {len(recs)} launches, expected "
                   f"{expect_launches}")
        for rec in recs:
            s = rec.summary
            self.check(s.n_pallas == 1, "SIM101", phase,
                       f"pallas-count:{rec.entry}",
                       f"{rec.entry} traced to {s.n_pallas} pallas_call "
                       "primitives, expected exactly 1")
            self.check(not s.forbidden, "SIM102", phase,
                       f"forbidden:{rec.entry}",
                       f"{rec.entry} jaxpr contains forbidden primitives "
                       f"{list(s.forbidden)}")
            if expect_pages is not None:
                self.check_plane_operands(rec, phase, expect_pages)
            if self.hlo:
                for msg in hlo_cross_check(rec):
                    self.check(False, "SIM105", phase,
                               f"hlo-bytes:{rec.entry}", msg)

        self.check(
            stats.staged_bytes - staged0 == expect_staged_bytes, "SIM104",
            phase, "staged-bytes",
            f"staged_bytes moved {stats.staged_bytes - staged0}, expected "
            f"{expect_staged_bytes} (PAGE_BYTES x newly staged pages)")
        self.check(
            stats.kernel_launches - launches0 == expect_launches, "SIM104",
            phase, "counter:kernel_launches",
            f"kernel_launches counted "
            f"{stats.kernel_launches - launches0} for {len(recs)} "
            "recorded launches")

        for t in tickets:
            t.result()
        got = stats.result_bytes - result0
        self.check(got == expect_result_bytes, "SIM104", phase,
                   "result-bytes",
                   f"result_bytes moved {got}, expected "
                   f"{expect_result_bytes} from the submitted command mix")
        if recs and expect_result_bytes:
            out_bytes = sum(r.summary.out_bytes for r in recs)
            self.check(got <= out_bytes, "SIM104", phase,
                       "result-within-launch",
                       f"result_bytes {got} exceeds traced launch output "
                       f"{out_bytes}")
        return recs

    def check_plane_operands(self, rec: LaunchRecord, phase: str,
                             expect_pages: int) -> None:
        """The (padded) page-plane operands must be exactly
        padded_rows(unique pages) rows — PAGE_BYTES per padded row."""
        planes = [s for s in rec.summary.in_shapes
                  if s[0] and s[0][-1] == 512 and s[1] == "uint32"]
        self.check(len(planes) >= 2, "SIM104", phase,
                   f"plane-operands:{rec.entry}",
                   f"{rec.entry} jaxpr has {len(planes)} plane-shaped "
                   "operands, expected lo+hi")
        for dims, _ in planes[:2]:
            rows = 1
            for d in dims[:-1]:
                rows *= d
            self.check(rows == expect_pages, "SIM104", phase,
                       f"plane-rows:{rec.entry}",
                       f"{rec.entry} plane operand has {rows} padded rows "
                       f"({dims}), expected {expect_pages}")

    # ------------------------------------------------------------ scenario
    def expected_search_rows(self, addr_lists: list[list[int]]) -> int:
        """Padded plane rows for per-chip unique page lists (sharded) or a
        single flat list (batched)."""
        block = self.backend.page_block
        if self.kind == "batched":
            (addrs,) = addr_lists
            return padded_rows(len(addrs), block)
        n_pad = max(padded_rows(len(a), block) for a in addr_lists if a)
        c_pad = next_pow2(sum(1 for a in addr_lists if a))
        return c_pad * n_pad

    def per_chip(self, addrs: list[int]) -> list[list[int]]:
        if self.kind == "batched":
            return [sorted(set(addrs), key=addrs.index)]
        n = len(self.chips.chips)
        out: list[list[int]] = [[] for _ in range(n)]
        for a in addrs:
            if a not in out[a % n]:
                out[a % n].append(a)
        return out

    def run(self) -> list[Finding]:
        with record_launches(self.kind) as records:
            self._scenario(records)
        self._retrace_sweep()
        return self.findings

    def _scenario(self, records: list) -> None:
        b = self.backend

        # --- search, cold: 13 commands, 12 unique (query, page) cells ----
        search_cmds = [Command.search(p, _key(p, i))
                       for p in range(N_KEY_PAGES) for i in (0, 1)]
        search_cmds.append(Command.search(0, _key(0, 0)))    # dedup'd twin
        pages = [c.page_addr for c in search_cmds]
        self.run_phase(
            records, "search-cold",
            lambda: [b.submit_search(c) for c in search_cmds],
            expect_result_bytes=64 * 12,
            expect_staged_bytes=PAGE_BYTES * N_KEY_PAGES,
            expect_pages=self.expected_search_rows(self.per_chip(pages)))

        # --- search, warm: same pages, new queries -> ZERO page restage --
        warm_cmds = [Command.search(p, _key(p, 2))
                     for p in range(N_KEY_PAGES)]
        self.run_phase(
            records, "search-warm",
            lambda: [b.submit_search(c) for c in warm_cmds],
            expect_result_bytes=64 * N_KEY_PAGES,
            expect_staged_bytes=0,
            expect_pages=self.expected_search_rows(self.per_chip(
                [c.page_addr for c in warm_cmds])))

        # --- fused plans: 2 distinct plans, 7 commands, 6 unique cells ---
        plan_a = exact_range(_key(0, 0), _key(0, 8))
        plan_b = exact_range(_key(1, 0), _key(1, 4))
        plan_cmds = [Command.plan(p, plan_a.include, plan_a.exclude)
                     for p in range(4)]
        plan_cmds += [Command.plan(p, plan_b.include, plan_b.exclude)
                      for p in range(2)]
        plan_cmds.append(Command.plan(0, plan_a.include, plan_a.exclude))
        self.run_phase(
            records, "plan",
            lambda: [b.submit_plan(c) for c in plan_cmds],
            expect_result_bytes=64 * 6,
            expect_staged_bytes=0)

        # --- fused lookups: 4 hits + 1 miss; value pages stage cold ------
        lookup_cmds = [Command.lookup(i, VAL_BASE + i, _key(i, 1))
                       for i in range(4)]
        lookup_cmds.append(Command.lookup(0, VAL_BASE, _key(5, 999)))
        self.run_phase(
            records, "lookup",
            lambda: [b.submit_lookup(c) for c in lookup_cmds],
            expect_result_bytes=64 * 5 + 64 * 4,
            expect_staged_bytes=PAGE_BYTES * 4)      # value pages 6..9

        # --- gathers: explicit chunk bitmaps, 64 B per selected chunk ----
        bitmaps = [0b1011, 0b1, 0b1110001]
        gather_cmds = [Command.gather(p, bm)
                       for p, bm in enumerate(bitmaps)]
        n_chunks = sum(bin(bm).count("1") for bm in bitmaps)
        self.run_phase(
            records, "gather",
            lambda: [b.submit_gather(c) for c in gather_cmds],
            expect_result_bytes=64 * n_chunks,
            expect_staged_bytes=0)

        # --- program group: ZERO launches, coalescing + grouped restage --
        def submit_programs():
            new = [_key(2, 100 + i) for i in range(N_ENTRIES)]
            newer = [_key(2, 200 + i) for i in range(N_ENTRIES)]
            other = [_key(3, 300 + i) for i in range(N_ENTRIES)]
            return [b.submit_program(2, new), b.submit_program(2, newer),
                    b.submit_program(3, other)]

        stats = b.stats
        programs0, coalesced0 = stats.programs, stats.programs_coalesced
        self.run_phase(
            records, "program-group", submit_programs,
            expect_result_bytes=0,
            expect_staged_bytes=PAGE_BYTES * 2,      # pages 2+3, one scatter
            expect_launches=0)
        self.check(stats.programs - programs0 == 2, "SIM104",
                   "program-group", "counter:programs",
                   f"programs counted {stats.programs - programs0}, "
                   "expected 2 (page 2 coalesced last-wins + page 3)")
        self.check(stats.programs_coalesced - coalesced0 == 1, "SIM104",
                   "program-group", "counter:programs_coalesced",
                   f"programs_coalesced counted "
                   f"{stats.programs_coalesced - coalesced0}, expected 1")

        # --- post-program search: group restage means NO further staging -
        post_cmds = [Command.search(2, _key(2, 200)),
                     Command.search(3, _key(3, 300))]
        self.run_phase(
            records, "search-after-program",
            lambda: [b.submit_search(c) for c in post_cmds],
            expect_result_bytes=64 * 2,
            expect_staged_bytes=0)

    # -------------------------------------------------------- retrace sweep
    def _retrace_sweep(self, burst_sizes=(1, 2, 3, 4, 5, 6, 8, 12, 16)):
        """Distinct abstract signatures across a burst sweep must stay
        within the pow2-padding bound: O(log max_burst), not O(bursts)."""
        chips = SimChipArray(n_chips=4 if self.kind == "sharded" else 2,
                             pages_per_chip=64, device_seed=11)
        backend = make_backend(self.kind, chips, page_block=8,
                               lookup_block=8, use_kernel=True)
        for p in range(4):
            backend.program_entries(p, [_key(p, i) for i in range(32)])
        entry_names = ("sim_search", "_stacked_search")
        with record_launches(self.kind) as records:
            q = 0
            for size in burst_sizes:
                tickets = []
                for _ in range(size):
                    tickets.append(backend.submit_search(
                        Command.search(q % 4, _key(q % 4, q % 32))))
                    q += 1
                backend.flush()
                for t in tickets:
                    t.result()
        sigs = {r.summary.signature for r in records
                if r.entry in entry_names}
        bound = int(math.log2(next_pow2(max(burst_sizes)))) + 1
        self.check(0 < len(sigs) <= bound, "SIM103", "retrace-sweep",
                   "distinct-signatures",
                   f"{len(sigs)} distinct launch signatures across burst "
                   f"sizes {list(burst_sizes)}; pow2 padding bounds this "
                   f"by log2(max)+1 = {bound}")

        # Pure-arithmetic half of the same invariant, over the full range.
        for block in (8, 32):
            distinct = {padded_rows(n, block) for n in range(1, 1025)}
            bound = int(math.log2(next_pow2(-(-1024 // block)))) + 1
            self.check(len(distinct) <= bound, "SIM103", "retrace-sweep",
                       f"padded-rows-bound:block{block}",
                       f"padded_rows yields {len(distinct)} distinct row "
                       f"counts for n in 1..1024 at block {block} "
                       f"(bound {bound})")


def audit_backend(kind: str, *, use_kernel: bool = True,
                  hlo: bool = True) -> list[Finding]:
    """Run the full launch audit for one backend kind."""
    return _Auditor(kind, use_kernel=use_kernel, hlo=hlo).run()


def run_audit(kinds=("batched", "sharded"), *, hlo: bool = True
              ) -> list[Finding]:
    findings: list[Finding] = []
    for kind in kinds:
        findings.extend(audit_backend(kind, hlo=hlo))
    return findings
