"""Static gates for the backend protocol: AST contract lint + launch audit.

Two prongs, one CLI (``python -m repro.analysis``):

  * ``contracts`` — AST-based lint rules (SIM001..SIM004) over ``src/repro``
    that enforce the MatchBackend invariants documented in
    ``repro.backend.base`` (ticket discipline, observer completeness,
    host-sync-free hot paths, counter integrity);
  * ``launch_audit`` — a trace-time auditor (SIM101..SIM105) that drives the
    batched and sharded backends through every flush path, captures each
    device entry point's jaxpr, and proves one-``pallas_call``-per-burst,
    zero hidden callbacks, retrace-signature stability, and byte-exact
    counter reconciliation.

Accepted pre-existing findings are pinned in ``baseline.toml`` next to this
file; ``--check`` fails on any finding not in the baseline.
"""
from .findings import Finding

__all__ = ["Finding"]
