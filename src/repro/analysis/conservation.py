"""Runtime conservation audit of the timeline accounting (SIM201–204).

The static prongs (AST rules, jaxpr launch audit) prove the *shape* of
the accounting is right; this prong proves the books actually balance at
runtime.  It replays a small seeded YCSB slice per backend through the
real frontend with a metering ``BurstTimeline`` subclass that records
every resource-line occupancy interval ``SSDSim`` grants, then audits —
the timeline-layer sibling of SIM104's jaxpr byte reconciliation:

  * **SIM201 (busy-time conservation)** — every resource line (each
    die's sense and program timelines, each channel bus, the PCIe link)
    is a serial resource: its recorded intervals must not overlap, spans
    must be non-negative, and total busy time is bounded by the run's
    makespan.  A double-charged interval (the same sense billed twice)
    or a line busier than the clock trips it.
  * **SIM202 (energy conservation)** — the ``EnergyAccount`` must equal
    an independent recomputation from the metered events: #senses x
    ``e_sense_pj()``, #programs x ``e_program_pj()``, the per-transfer
    ``e_bus_pj`` sum and #match-queries x ``e_match_pj()``; the reported
    ``energy_pj`` must equal the sum of its components.  A dropped or
    doubled charge anywhere in the chain trips it.
  * **SIM203 (byte reconciliation)** — ``staged_bytes``,
    ``result_bytes`` and ``kernel_launches`` in the ``RunReport`` must
    equal the backend's own counters, and the simulator's
    ``internal_bytes``/``pcie_bytes`` must equal the bytes the metered
    bus/PCIe events actually carried.
  * **SIM204 (fault accounting)** — ``FaultStats`` must be consistent
    with the per-op error mask: ``n_op_errors`` equals the mask's
    popcount, a healthy schedule fires nothing, and a dead chip with
    replicas surfaces as failovers/degraded reads with zero op errors.

Findings carry path ``audit:<kind>`` and flow through the same
``(rule, path, symbol, slug)`` baseline diff as every other prong.
"""
from __future__ import annotations

import dataclasses

from .findings import Finding

#: audited resource-line tolerance: float accumulation across a few
#: hundred events stays far below a nanosecond
TOL_NS = 1e-6
REL_TOL = 1e-9


@dataclasses.dataclass
class LineEvent:
    """One occupancy interval granted on a serial resource line."""
    line: str                   # "die_sense:<d>" | "die_prog:<d>"
                                # | "chan:<c>" | "pcie"
    start_ns: float
    end_ns: float
    n_bytes: int = 0            # payload (bus/PCIe events only)
    match_mode: bool = False    # bus events: match vs storage transfer


def make_metered_timeline(params=None, *, n_chips: int | None = None):
    """A ``BurstTimeline`` whose ``SSDSim`` resource methods are wrapped
    to record :class:`LineEvent` intervals (``.events``) and match-query
    counts (``.match_queries``).  Records survive until the next
    ``reset()`` — ``frontend.replay`` resets after the page load, so the
    record covers exactly the measured window, like the latency lists.
    """
    from repro.flash.timeline import BurstTimeline

    class MeteredTimeline(BurstTimeline):
        def reset(self):
            # BurstTimeline.__init__ calls reset() before any subclass
            # state exists: containers must (re)initialize here.
            self.events: list[LineEvent] = []
            self.match_queries = 0
            super().reset()
            self._instrument(self.sim)

        def _instrument(self, sim):
            orig = {name: getattr(sim, name)
                    for name in ("_sense", "_program", "_bus", "_pcie",
                                 "_match")}

            def sense(page, ready):
                die = sim._die_of(page)
                free = float(sim.die_sense_free[die])
                end = orig["_sense"](page, ready)
                self.events.append(LineEvent(
                    f"die_sense:{die}", max(ready, free), end))
                return end

            def program(page, ready):
                die = sim._die_of(page)
                free = float(sim.die_prog_free[die])
                end = orig["_program"](page, ready)
                self.events.append(LineEvent(
                    f"die_prog:{die}", max(ready, free), end))
                return end

            def bus(page, ready, n_bytes, match_mode):
                chan = sim._chan_of(sim._die_of(page))
                free = float(sim.chan_free[chan])
                end = orig["_bus"](page, ready, n_bytes, match_mode)
                self.events.append(LineEvent(
                    f"chan:{chan}", max(ready, free), end,
                    n_bytes=n_bytes, match_mode=match_mode))
                return end

            def pcie(ready, n_bytes):
                free = float(sim.pcie_free)
                end = orig["_pcie"](ready, n_bytes)
                self.events.append(LineEvent(
                    "pcie", max(ready, free), end, n_bytes=n_bytes))
                return end

            def match(ready, n_queries=1):
                self.match_queries += n_queries
                return orig["_match"](ready, n_queries)

            sim._sense, sim._program = sense, program
            sim._bus, sim._pcie, sim._match = bus, pcie, match

    if params is None:
        params = BurstTimeline.for_chips(n_chips or 4).params
    return MeteredTimeline(params)


# ----------------------------------------------------------- pure checks
def busy_violations(events, makespan_ns: float) -> list[tuple[str, str]]:
    """SIM201: per-line interval sanity.  Returns ``(slug, message)``
    violations — empty when the books balance."""
    out: list[tuple[str, str]] = []
    by_line: dict[str, list[LineEvent]] = {}
    for ev in events:
        by_line.setdefault(ev.line, []).append(ev)
    for line, evs in sorted(by_line.items()):
        evs = sorted(evs, key=lambda e: (e.start_ns, e.end_ns))
        busy = 0.0
        prev_end = None
        for ev in evs:
            if ev.end_ns < ev.start_ns - TOL_NS:
                out.append((f"negative-span:{line}",
                            f"{line}: interval ends at {ev.end_ns} before "
                            f"it starts at {ev.start_ns}"))
                continue
            if prev_end is not None and ev.start_ns < prev_end - TOL_NS:
                out.append((f"overlap:{line}",
                            f"{line}: interval starting at {ev.start_ns} "
                            f"overlaps the previous one ending at "
                            f"{prev_end} — a serial resource was charged "
                            "twice for the same time"))
            busy += ev.end_ns - ev.start_ns
            prev_end = max(prev_end or 0.0, ev.end_ns)
        if busy > makespan_ns + TOL_NS:
            out.append((f"busy-exceeds-makespan:{line}",
                        f"{line}: {busy:.1f} ns of busy time inside a "
                        f"{makespan_ns:.1f} ns makespan — more work was "
                        "billed than wall-clock exists"))
    return out


def energy_violations(energy, params, *, n_senses: int, n_programs: int,
                      bus_events, match_queries: int
                      ) -> list[tuple[str, str]]:
    """SIM202: the ``EnergyAccount`` vs an independent recomputation from
    the metered events.  ``bus_events`` is an iterable of
    ``(n_bytes, match_mode)`` transfers."""
    out: list[tuple[str, str]] = []
    expected = {
        "sense_pj": n_senses * params.e_sense_pj(),
        "program_pj": n_programs * params.e_program_pj(),
        "bus_pj": sum(params.e_bus_pj(n, m) for n, m in bus_events),
        "match_pj": match_queries * params.e_match_pj(),
    }

    def close(a: float, b: float) -> bool:
        return abs(a - b) <= max(abs(a), abs(b)) * 1e-6 + 1e-9

    for comp, want in expected.items():
        got = getattr(energy, comp)
        if not close(got, want):
            out.append((f"component-mismatch:{comp}",
                        f"{comp} is {got:.3f} pJ but the metered events "
                        f"recompute {want:.3f} pJ — a charge was dropped "
                        "or doubled"))
    total = sum(getattr(energy, c) for c in expected)
    if not close(energy.total_pj, total):
        out.append(("total-mismatch:energy_pj",
                    f"energy_pj {energy.total_pj:.3f} != sum of components "
                    f"{total:.3f}"))
    return out


# ------------------------------------------------------------ the audit
class _Auditor:
    """Finding collector in the launch_audit idiom."""

    def __init__(self, kind: str):
        self.kind = kind
        self.findings: list[Finding] = []

    def check(self, ok: bool, rule: str, symbol: str, slug: str,
              message: str) -> None:
        if not ok:
            self.findings.append(Finding(
                rule, f"audit:{self.kind}", symbol, slug, message=message))

    def add(self, rule: str, symbol: str,
            violations: list[tuple[str, str]]) -> None:
        for slug, message in violations:
            self.findings.append(Finding(
                rule, f"audit:{self.kind}", symbol, slug, message=message))


def _audit_kind(kind: str) -> list[Finding]:
    import numpy as np

    from repro.backend.base import make_backend
    from repro.backend.sharded import ShardedSsdBackend
    from repro.core.engine import SimChipArray
    from repro.frontend import RunConfig, replay
    from repro.reliability import FaultSchedule
    from repro.workload.ycsb import generate

    aud = _Auditor(kind)
    wl = generate(120, n_key_pages=4, read_ratio=0.7, alpha=0.5, seed=2)
    if kind == "sharded":
        tl = make_metered_timeline(n_chips=4)
        backend = ShardedSsdBackend(
            SimChipArray(n_chips=4, pages_per_chip=64, device_seed=11),
            page_block=8, lookup_block=8, use_kernel=False, interpret=True,
            timeline=tl)
    else:
        tl = None
        backend = make_backend(kind, SimChipArray(
            n_chips=4, pages_per_chip=64, device_seed=11),
            page_block=8, lookup_block=8, use_kernel=False)
    rep = replay(wl, backend, RunConfig(burst=16))

    # --- SIM203: bytes reconcile backend <-> report (every kind)
    stats = backend.stats
    for field in ("staged_bytes", "result_bytes", "kernel_launches"):
        aud.check(getattr(rep.counters, field) == getattr(stats, field),
                  "SIM203", "replay", f"report-mismatch:{field}",
                  f"RunReport.counters.{field}="
                  f"{getattr(rep.counters, field)} != backend stats "
                  f"{getattr(stats, field)}")
    aud.check(stats.result_bytes > 0, "SIM203", "replay",
              "no-result-bytes",
              "a 120-op read-heavy replay produced zero result bytes")

    if tl is not None:
        # --- SIM201: per-line busy time vs makespan
        makespan_ns = max([tl.now] + [e.end_ns for e in tl.events])
        aud.add("SIM201", "timeline", busy_violations(tl.events,
                                                      makespan_ns))
        aud.check(rep.latency.makespan_ns == tl.now, "SIM201", "timeline",
                  "makespan-mismatch",
                  f"report makespan {rep.latency.makespan_ns} != timeline "
                  f"clock {tl.now}")
        # --- SIM202: energy account vs metered recomputation
        senses = sum(e.line.startswith("die_sense:") for e in tl.events)
        programs = sum(e.line.startswith("die_prog:") for e in tl.events)
        bus_events = [(e.n_bytes, e.match_mode) for e in tl.events
                      if e.line.startswith("chan:")]
        aud.add("SIM202", "timeline", energy_violations(
            tl.sim.energy, tl.params, n_senses=senses,
            n_programs=programs, bus_events=bus_events,
            match_queries=tl.match_queries))
        aud.check(rep.energy.total_pj == tl.sim.energy.total_pj,
                  "SIM202", "timeline", "report-mismatch:energy_pj",
                  f"report energy {rep.energy.total_pj} != timeline "
                  f"account {tl.sim.energy.total_pj}")
        # --- SIM203 (cross-layer leg): counters vs metered bytes
        aud.check(tl.sim.stats.internal_bytes
                  == sum(n for n, _ in bus_events),
                  "SIM203", "timeline", "bus-bytes-mismatch",
                  f"sim internal_bytes {tl.sim.stats.internal_bytes} != "
                  f"metered bus payload {sum(n for n, _ in bus_events)}")
        pcie = sum(e.n_bytes for e in tl.events if e.line == "pcie")
        aud.check(tl.sim.stats.pcie_bytes == pcie,
                  "SIM203", "timeline", "pcie-bytes-mismatch",
                  f"sim pcie_bytes {tl.sim.stats.pcie_bytes} != metered "
                  f"PCIe payload {pcie}")

    # --- SIM204: fault accounting (sharded only: the fault tier's home)
    if kind == "sharded":
        def replicated(replicas, faults):
            per_chip = (wl.n_index_pages // 4 + 1) * (replicas + 1)
            be = ShardedSsdBackend(
                SimChipArray(n_chips=4, pages_per_chip=per_chip,
                             device_seed=3),
                use_kernel=False, interpret=True, replicas=replicas)
            return replay(wl, be, RunConfig.event_serial(
                faults=faults, burst=16, seed=7))

        healthy = replicated(2, FaultSchedule.healthy(seed=7))
        f = healthy.faults
        aud.check((f.timeouts, f.retries, f.failovers, f.degraded_ops,
                   f.n_op_errors) == (0, 0, 0, 0, 0),
                  "SIM204", "faults", "healthy-run-fired",
                  "a healthy fault schedule produced nonzero fault "
                  "counters")
        dead = replicated(2, FaultSchedule.dead_chip(chip=0, seed=7))
        f = dead.faults
        aud.check(f.op_errors is not None
                  and len(f.op_errors) == len(wl.ops),
                  "SIM204", "faults", "mask-shape",
                  "op_errors mask does not cover every op")
        aud.check(f.op_errors is not None
                  and f.n_op_errors == int(np.sum(f.op_errors)),
                  "SIM204", "faults", "mask-count-mismatch",
                  f"n_op_errors={f.n_op_errors} != popcount of the "
                  "op_errors mask")
        aud.check(f.failovers > 0 and f.degraded_ops > 0,
                  "SIM204", "faults", "dead-chip-invisible",
                  "a dead chip with replicas produced no failovers or "
                  "degraded reads — the fault path did not run")
        aud.check(f.n_op_errors == 0, "SIM204", "faults",
                  "replicated-errors",
                  "replicas=2 should absorb a single dead chip with zero "
                  "op errors")
    return aud.findings


def run_conservation(kinds=("batched", "sharded")) -> list[Finding]:
    """Run the seeded conservation replays; returns all findings."""
    out: list[Finding] = []
    for kind in kinds:
        out.extend(_audit_kind(kind))
    return out
