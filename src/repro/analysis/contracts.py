"""AST contract linter driver: parse modules, dispatch to SIM rules.

The rules are *repo-specific*: they encode the MatchBackend protocol
invariants listed in ``repro.backend.base``'s module docstring (I1..I4,
cited by rule ID) rather than generic style.  Each rule lives in
``rules/sim00N_*.py`` and implements ``check(mod) -> Iterator[Finding]``
over a :class:`ParsedModule`; this module owns the shared AST plumbing —
function enumeration with qualnames, own-scope walking that does NOT
descend into nested function bodies (nested defs are separate scopes: a
deferred ``tail`` closure runs after the flush returns, so statements
inside it are not "in" the flush), and the fixture pragma that lets test
fixtures masquerade as in-scope files.

Fixture pragma: a leading comment ``# analysis: pretend-path=<rel path>``
re-homes a file for rule scoping, so known-bad fixtures under
``tests/analysis_fixtures/`` exercise path-scoped rules (SIM002 only looks
at engine.py/planestore.py, SIM003 at flush/ops.py scopes) without the
rules growing test-only configuration.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterator

from .findings import Finding, dedupe_slugs

_PRAGMA = re.compile(r"^#\s*analysis:\s*pretend-path=(\S+)\s*$")

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef)


def walk_own(node: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested scopes.

    Comprehension bodies ARE walked (they execute inline); nested function
    and class bodies are not (they execute later, in their own scope).
    """
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, _SCOPE_NODES):
            stack.extend(ast.iter_child_nodes(n))


def callee_name(call: ast.Call) -> str | None:
    """Final name of a call target: ``a.b.c(...)`` -> ``c``, ``f(...)`` -> ``f``."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def attr_root(node: ast.AST) -> str | None:
    """Root name of an attribute chain: ``np.bitwise_xor.at`` -> ``np``."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


@dataclasses.dataclass
class ParsedModule:
    rel_path: str              # scoping path (pragma-overridable), posix
    real_path: str             # where the file actually lives, posix
    tree: ast.Module
    source: str

    def functions(self) -> Iterator[tuple[str, ast.FunctionDef]]:
        """Every def in the module (nested included), with its qualname."""
        def visit(node: ast.AST, prefix: str):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    yield q, child
                    yield from visit(child, f"{q}.")
                elif isinstance(child, ast.ClassDef):
                    yield from visit(child, f"{prefix}{child.name}.")
                else:
                    yield from visit(child, prefix)
        yield from visit(self.tree, "")


def parse_module(path: Path, root: Path) -> ParsedModule:
    source = path.read_text()
    rel = path.relative_to(root).as_posix() if path.is_relative_to(root) \
        else path.as_posix()
    for line in source.splitlines()[:5]:
        m = _PRAGMA.match(line.strip())
        if m:
            rel = m.group(1)
            break
    return ParsedModule(rel_path=rel, real_path=path.as_posix(),
                        tree=ast.parse(source, filename=str(path)),
                        source=source)


def default_rules():
    from .rules import ALL_RULES
    return list(ALL_RULES)


def run_contracts(root: Path, paths: list[Path] | None = None,
                  rules=None) -> list[Finding]:
    """Lint every module under ``paths`` (default: ``src/repro``)."""
    root = Path(root)
    if paths is None:
        paths = [root / "src" / "repro"]
    if rules is None:
        rules = default_rules()
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    findings: list[Finding] = []
    for f in files:
        mod = parse_module(f, root)
        for rule in rules:
            if rule.applies_to(mod.rel_path):
                findings.extend(rule.check(mod))
    return dedupe_slugs(findings)
