"""Finding: one contract violation, keyed stably for the baseline.

The baseline key deliberately excludes line numbers: unrelated edits above
a pinned finding must not invalidate the pin.  ``(rule, path, symbol,
slug)`` identifies a finding by what it is and where it lives — the rule
ID, the repo-relative file, the enclosing function's qualname, and a short
rule-specific token (e.g. ``dropped:submit_program``).  Line numbers ride
along for display only.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                  # "SIM001".."SIM004" (lint), "SIM101".. (audit)
    path: str                  # repo-relative posix path (or audit:<kind>)
    symbol: str                # enclosing function qualname / audit step
    slug: str                  # stable rule-specific token
    message: str = ""          # human-readable one-liner (not in the key)
    line: int = 0              # display only (not in the key)

    def key(self) -> tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.slug)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{self.rule} {loc} [{self.symbol}] {self.slug}: {self.message}"


def dedupe_slugs(findings: list[Finding]) -> list[Finding]:
    """Disambiguate repeated keys with an ordinal suffix (``...#2``).

    Two independent violations of one rule in one function can produce the
    same slug; the baseline must be able to pin one without hiding the
    other, so repeats get a stable per-function ordinal.
    """
    seen: dict[tuple, int] = {}
    out: list[Finding] = []
    for f in findings:
        k = f.key()
        n = seen.get(k, 0)
        seen[k] = n + 1
        if n:
            f = dataclasses.replace(f, slug=f"{f.slug}#{n + 1}")
        out.append(f)
    return out
