"""CLI for the contract auditor: ``python -m repro.analysis``.

Default run executes all three prongs — the AST contract lint
(SIM001..SIM009) over ``src/repro`` and ``benchmarks/``, the trace-time
launch audit (SIM101..SIM105) over the batched and sharded backends, and
the runtime conservation audit (SIM201..SIM204) of the timeline
accounting — applies ``baseline.toml`` and prints every finding.
``--check`` turns non-baselined findings into a nonzero exit (the CI
gate); ``--write-baseline`` regenerates the allowlist from the current
tree (reasons of already-pinned entries are preserved); ``--github``
additionally emits ``::error`` problem-matcher annotations and
``--json-out`` dumps the full finding set for upload as a CI artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .baseline import apply_baseline, load_baseline, write_baseline
from .contracts import run_contracts
from .findings import Finding

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.toml"
REPO_ROOT = Path(__file__).resolve().parents[3]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="SiM backend-contract auditor: AST lint (SIM001..009) "
                    "+ jaxpr launch audit (SIM101..105) + runtime "
                    "conservation audit (SIM201..204).")
    p.add_argument("--check", action="store_true",
                   help="exit nonzero when any non-baselined finding exists")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit findings as JSON instead of text")
    p.add_argument("--json-out", type=Path, default=None,
                   help="additionally dump the finding sets as JSON to this "
                        "file (CI artifact)")
    p.add_argument("--github", action="store_true",
                   help="emit GitHub ::error problem-matcher annotations "
                        "for new findings")
    p.add_argument("--write-baseline", action="store_true",
                   help="regenerate the baseline from the current findings "
                        "(keeps reasons of entries that are still hit)")
    p.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                   help="allowlist path (default: the committed "
                        "src/repro/analysis/baseline.toml)")
    p.add_argument("--root", type=Path, default=REPO_ROOT,
                   help="repository root (default: inferred from package)")
    p.add_argument("--paths", type=Path, nargs="*", default=None,
                   help="lint these files/dirs instead of src/repro + "
                        "benchmarks")
    p.add_argument("--rules", nargs="*", default=None,
                   help="restrict the lint to these rule IDs (e.g. SIM001)")
    p.add_argument("--no-lint", action="store_true",
                   help="skip the AST contract lint")
    p.add_argument("--no-audit", action="store_true",
                   help="skip the trace-time launch audit")
    p.add_argument("--no-hlo", action="store_true",
                   help="skip the audit's compiled-HLO byte cross-check")
    p.add_argument("--no-conservation", action="store_true",
                   help="skip the runtime conservation audit (SIM201..204)")
    p.add_argument("--backends", nargs="*", default=("batched", "sharded"),
                   choices=("batched", "sharded"),
                   help="backend kinds the launch and conservation audits "
                        "drive")
    return p


def _select_rules(ids):
    from .rules import RULES_BY_ID
    unknown = [r for r in ids if r not in RULES_BY_ID]
    if unknown:
        raise SystemExit(f"unknown rule IDs {unknown}; "
                         f"known: {sorted(RULES_BY_ID)}")
    return [RULES_BY_ID[r] for r in ids]


def _default_paths(root: Path) -> list[Path]:
    paths = [root / "src" / "repro"]
    bench = root / "benchmarks"
    if bench.is_dir():
        paths.append(bench)
    return paths


def collect_findings(args) -> list[Finding]:
    findings: list[Finding] = []
    if not args.no_lint:
        rules = _select_rules(args.rules) if args.rules else None
        paths = args.paths if args.paths is not None \
            else _default_paths(args.root)
        findings.extend(run_contracts(args.root, paths=paths, rules=rules))
    if not args.no_audit:
        from .launch_audit import run_audit
        findings.extend(run_audit(kinds=tuple(args.backends),
                                  hlo=not args.no_hlo))
    if not args.no_conservation:
        from .conservation import run_conservation
        findings.extend(run_conservation(kinds=tuple(args.backends)))
    return findings


def _github_annotation(f: Finding) -> str:
    """One ::error problem-matcher line per new finding.  Audit findings
    (path ``audit:<kind>``) have no source location; they annotate the
    workflow without file/line coordinates."""
    msg = f"{f.rule} [{f.slug}] {f.symbol}: {f.message or f.slug}"
    msg = msg.replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A")
    if f.path.startswith("audit:"):
        return f"::error title={f.rule}::{msg}"
    return (f"::error file={f.path},line={max(f.line, 1)},"
            f"title={f.rule}::{msg}")


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    findings = collect_findings(args)
    entries = load_baseline(args.baseline)

    if args.write_baseline:
        reasons = {e.key(): e.reason for e in entries if e.reason}
        write_baseline(args.baseline, findings, reasons)
        print(f"wrote {len(findings)} accepted findings to {args.baseline}")
        return 0

    new, accepted, stale = apply_baseline(findings, entries)

    payload = {
        "new": [vars(f) for f in new],
        "accepted": [vars(f) for f in accepted],
        "stale": [vars(e) for e in stale],
    }
    if args.json_out is not None:
        args.json_out.write_text(json.dumps(payload, indent=2) + "\n")
    if args.as_json:
        print(json.dumps(payload, indent=2))
    else:
        for f in new:
            print(f.format())
        for e in stale:
            print(f"stale baseline entry (no longer found): "
                  f"{e.rule} {e.path} {e.symbol} [{e.slug}]",
                  file=sys.stderr)
        print(f"{len(new)} new finding(s), {len(accepted)} baselined, "
              f"{len(stale)} stale baseline entr(ies)", file=sys.stderr)
    if args.github:
        for f in new:
            print(_github_annotation(f))

    if args.check and new:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
