"""SIM005 — match results must be consumed with their error channel.

The reliability tier (repro.reliability) makes every match response carry
an error channel: ``SearchResponse.open_verdict`` reports the §IV-C2 page
open outcome, ``GatherResponse``/``LookupResponse`` carry ``parity_ok``,
and a page whose outer code failed surfaces as a per-ticket
``UncorrectableReadError``.  A consumer that reads ``bitmap_words`` /
``match_count`` / ``value_slot`` while ignoring all of those treats an
undecodable page as "no matches" — the exact silent-wrong-result class the
tier exists to eliminate (an all-zero bitmap from a dead page reads as a
legitimate miss).

The rule flags any function (own scope, nested defs are their own scope)
outside the plumbing layers that loads one of the match-result attributes
without also referencing the error channel: calling
:func:`repro.reliability.require_clean`, handling/raising
``UncorrectableReadError``, or inspecting ``open_verdict``/``parity_ok``
directly.  The plumbing itself — backends (they *produce* the responses),
kernels, the reliability package, and this analysis package — is exempt.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..contracts import ParsedModule, walk_own
from ..findings import Finding

_EXEMPT_PREFIXES = ("src/repro/backend/", "src/repro/analysis/",
                    "src/repro/kernels/", "src/repro/reliability/")

# Attributes whose load marks the function as a match-result consumer.
_CONSUMED = {"bitmap_words", "match_count", "value_slot"}

# Any of these in the same scope marks the error channel as handled.
_MARKER_ATTRS = {"open_verdict", "parity_ok"}
_MARKER_NAMES = {"require_clean", "UncorrectableReadError"}


class Sim005Verdicts:
    rule_id = "SIM005"
    title = "match-result consumers acknowledge the error/verdict channel"

    def applies_to(self, rel_path: str) -> bool:
        if not (rel_path.startswith("src/repro/")
                and rel_path.endswith(".py")):
            return False
        return not rel_path.startswith(_EXEMPT_PREFIXES)

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        for qualname, fn in mod.functions():
            consumed: dict[str, int] = {}
            handled = False
            for node in walk_own(fn):
                if isinstance(node, ast.Attribute):
                    if node.attr in _MARKER_ATTRS:
                        handled = True
                    elif node.attr in _CONSUMED \
                            and isinstance(node.ctx, ast.Load):
                        consumed.setdefault(node.attr, node.lineno)
                elif isinstance(node, ast.Name) \
                        and node.id in _MARKER_NAMES:
                    handled = True
            if consumed and not handled:
                for attr, line in sorted(consumed.items(),
                                         key=lambda kv: kv[1]):
                    yield Finding(
                        self.rule_id, mod.rel_path, qualname,
                        f"consumes:{attr}", line=line,
                        message=f"reads .{attr} without consulting the "
                                "error channel (require_clean / "
                                "UncorrectableReadError / open_verdict / "
                                "parity_ok): an uncorrectable page would "
                                "be consumed as an empty match result")
