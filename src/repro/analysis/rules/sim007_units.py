"""SIM007 — physical-unit discipline (invariant I5 in repro.backend.base).

Every quantity that crosses a layer boundary carries its dimension in its
name: ``_ns`` (time), ``_pj`` (energy), ``_bytes`` (payload), ``_prob``
(probability).  The proof methodology of the whole repo — exact counters
reconciled across backend -> timeline -> frontend -> report — only works
if a nanosecond never lands in a picojoule field.  This rule infers
dimensions from the suffix convention and taint-propagates them through
assignments, arithmetic, returns and call arguments on the dataflow
engine's per-function CFGs, with call-graph summaries for the return
dimension of project functions.

Findings (slugs):

  * ``mix:<a>+<b>``      — addition/subtraction/comparison of two known,
    disjoint dimensions (``lat_ns + cost_pj``);
  * ``mis-assign:<n>``   — a suffixed target assigned a value of a
    different known dimension (``energy_pj = t_ns``);
  * ``mis-call:<f>.<p>`` — a value of known dimension passed to a
    parameter or keyword whose suffix declares a different one (the
    "latency into an energy parameter two calls away" case — positional
    arguments are matched against the resolved callee's signature);
  * ``mis-return:<dim>`` — a function whose name declares a dimension
    returning a different known one.

Soundness: multiplication/division/modulo yield *unknown* (unit algebra —
conversions and rates are legitimate), and a check fires only when BOTH
sides have known dimensions with an empty intersection, so untyped
intermediates never false-positive.  Joins union the possible dimensions.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..contracts import ParsedModule, callee_name
from ..dataflow import (Bind, ForwardAnalysis, ProjectIndex, Test,
                        _DIM_PASSTHROUGH, build_cfg, suffix_dim)
from ..findings import Finding

_EMPTY = frozenset()


class DimAnalysis(ForwardAnalysis):
    """Forward dimension propagation over one function."""

    def __init__(self, fn: ast.FunctionDef, view):
        super().__init__(build_cfg(fn))
        self.fn = fn
        self.view = view
        self.returned: set[str] = set()

    def init_env(self) -> dict:
        env = {}
        a = self.fn.args
        for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
            d = suffix_dim(arg.arg)
            if d:
                env[arg.arg] = frozenset({d})
        return env

    # --------------------------------------------------------------- checks
    def _report(self, slug: str, node, msg: str) -> None:
        if self.report is not None:
            self.report(slug, node, msg)

    def _mix(self, a: frozenset, b: frozenset, node, what: str) -> None:
        if a and b and a.isdisjoint(b):
            self._report(f"mix:{'|'.join(sorted(a))}+{'|'.join(sorted(b))}",
                         node,
                         f"{what} of {'/'.join(sorted(a))} and "
                         f"{'/'.join(sorted(b))} quantities — incompatible "
                         "physical dimensions (I5 suffix convention)")

    # ----------------------------------------------------------- evaluation
    def eval(self, e, env: dict) -> frozenset:
        if e is None:
            return _EMPTY
        if isinstance(e, ast.Name):
            if e.id in env:
                return env[e.id]
            d = suffix_dim(e.id)
            return frozenset({d}) if d else _EMPTY
        if isinstance(e, ast.Attribute):
            d = suffix_dim(e.attr)
            return frozenset({d}) if d else _EMPTY
        if isinstance(e, ast.Constant):
            return _EMPTY
        if isinstance(e, ast.BinOp):
            left = self.eval(e.left, env)
            right = self.eval(e.right, env)
            if isinstance(e.op, (ast.Add, ast.Sub)):
                self._mix(left, right, e, "addition/subtraction")
                return left | right
            return _EMPTY            # *, /, //, %, **: unit algebra, unknown
        if isinstance(e, ast.UnaryOp):
            return self.eval(e.operand, env)
        if isinstance(e, ast.IfExp):
            self.eval(e.test, env)
            return self.eval(e.body, env) | self.eval(e.orelse, env)
        if isinstance(e, ast.Compare):
            dims = [self.eval(e.left, env)]
            dims += [self.eval(c, env) for c in e.comparators]
            for a, b in zip(dims, dims[1:]):
                self._mix(a, b, e, "comparison")
            return _EMPTY
        if isinstance(e, ast.BoolOp):
            out = _EMPTY
            for v in e.values:
                out |= self.eval(v, env)
            return out
        if isinstance(e, ast.NamedExpr):
            d = self.eval(e.value, env)
            if isinstance(e.target, ast.Name):
                env[e.target.id] = d
            return d
        if isinstance(e, ast.Call):
            return self.eval_call(e, env)
        if isinstance(e, ast.Subscript):
            self.eval(e.slice, env)
            return self.eval(e.value, env)
        if isinstance(e, ast.Starred):
            return self.eval(e.value, env)
        if isinstance(e, (ast.List, ast.Tuple, ast.Set)):
            for elt in e.elts:
                self.eval(elt, env)
            return _EMPTY
        if isinstance(e, ast.Dict):
            for k in e.keys:
                self.eval(k, env)
            for v in e.values:
                self.eval(v, env)
            return _EMPTY
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            for g in e.generators:
                self.eval(g.iter, env)
            return _EMPTY
        if isinstance(e, (ast.JoinedStr, ast.FormattedValue)):
            return _EMPTY
        return _EMPTY

    def eval_call(self, call: ast.Call, env: dict) -> frozenset:
        argdims = [self.eval(a, env) for a in call.args]
        name = callee_name(call)
        # keyword-name check works on ANY call — the kw name IS a signature
        for kw in call.keywords:
            d = self.eval(kw.value, env)
            kdim = suffix_dim(kw.arg)
            if kdim and d and kdim not in d:
                self._report(
                    f"mis-call:{name or '?'}.{kw.arg}", call,
                    f"keyword {kw.arg} (declares {kdim}) receives a "
                    f"{'/'.join(sorted(d))} value")
        # positional check needs the resolved callee's parameter names
        fi = self.view.resolve_unique(name)
        if fi is not None:
            params = fi.call_params(call)
            for i, arg in enumerate(call.args):
                if isinstance(arg, ast.Starred) or i >= len(params):
                    break
                pdim = suffix_dim(params[i])
                if pdim and argdims[i] and pdim not in argdims[i]:
                    self._report(
                        f"mis-call:{name}.{params[i]}", call,
                        f"parameter {params[i]} of {fi.qualname} (declares "
                        f"{pdim}) receives a "
                        f"{'/'.join(sorted(argdims[i]))} value")
        # return dimension: passthroughs, the callee's own suffix, summaries
        if name in _DIM_PASSTHROUGH:
            out = _EMPTY
            for d in argdims:
                out |= d
            for kw in call.keywords:
                out |= self.eval(kw.value, env)
            return out
        d = suffix_dim(name)
        if d:
            return frozenset({d})
        matches = self.view.resolve(name)
        if matches:
            summaries = {self.view.return_dims(m) for m in matches}
            if len(summaries) == 1:
                return summaries.pop()
        return _EMPTY

    # ------------------------------------------------------------- transfer
    def _bind(self, target, dims: frozenset, env: dict,
              check: bool, node=None) -> None:
        if isinstance(target, ast.Name):
            tdim = suffix_dim(target.id)
            if check and tdim and dims and tdim not in dims:
                self._report(
                    f"mis-assign:{target.id}", node or target,
                    f"{target.id} declares {tdim} but is assigned a "
                    f"{'/'.join(sorted(dims))} value")
            env[target.id] = dims or (frozenset({tdim}) if tdim else _EMPTY)
        elif isinstance(target, ast.Attribute):
            tdim = suffix_dim(target.attr)
            if check and tdim and dims and tdim not in dims:
                self._report(
                    f"mis-assign:{target.attr}", node or target,
                    f".{target.attr} declares {tdim} but is assigned a "
                    f"{'/'.join(sorted(dims))} value")
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, _EMPTY, env, False)
        # Subscript/Starred targets: no name to type

    def _target_dims(self, target, env: dict) -> frozenset:
        if isinstance(target, ast.Name):
            if target.id in env:
                return env[target.id]
            d = suffix_dim(target.id)
            return frozenset({d}) if d else _EMPTY
        if isinstance(target, ast.Attribute):
            d = suffix_dim(target.attr)
            return frozenset({d}) if d else _EMPTY
        return _EMPTY

    def transfer(self, st, env: dict) -> dict:
        env = dict(env)
        if isinstance(st, Test):
            self.eval(st.expr, env)
        elif isinstance(st, Bind):
            self._bind(st.target, self.eval(st.iter, env), env, False)
        elif isinstance(st, ast.Assign):
            dims = self.eval(st.value, env)
            if len(st.targets) == 1 \
                    and isinstance(st.targets[0], (ast.Tuple, ast.List)) \
                    and isinstance(st.value, (ast.Tuple, ast.List)) \
                    and len(st.targets[0].elts) == len(st.value.elts):
                for t, v in zip(st.targets[0].elts, st.value.elts):
                    self._bind(t, self.eval(v, env), env, True, st)
            else:
                for t in st.targets:
                    self._bind(t, dims, env, True, st)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self._bind(st.target, self.eval(st.value, env), env,
                           True, st)
        elif isinstance(st, ast.AugAssign):
            vdims = self.eval(st.value, env)
            tdims = self._target_dims(st.target, env)
            if isinstance(st.op, (ast.Add, ast.Sub)):
                self._mix(tdims, vdims, st, "augmented addition/subtraction")
                if isinstance(st.target, ast.Name):
                    env[st.target.id] = tdims | vdims
            # *=, /= etc: unit algebra — keep the declared dimension
        elif isinstance(st, ast.Return):
            dims = self.eval(st.value, env)
            if self.reporting:
                self.returned |= dims
                fdim = suffix_dim(self.fn.name)
                if fdim and dims and fdim not in dims:
                    self._report(
                        f"mis-return:{'|'.join(sorted(dims))}", st,
                        f"{self.fn.name} declares {fdim} but returns a "
                        f"{'/'.join(sorted(dims))} value")
        elif isinstance(st, ast.Expr):
            self.eval(st.value, env)
        elif isinstance(st, ast.Assert):
            self.eval(st.test, env)
        elif isinstance(st, (ast.With, ast.AsyncWith)):
            for item in st.items:
                d = self.eval(item.context_expr, env)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, d, env, False)
        return env


def function_return_dims(fi) -> frozenset:
    """Call-graph summary: the union of dimensions a function can return
    (computed by running its full CFG analysis; memoized by the index)."""
    view = ProjectIndex.get().with_module(fi.module)
    da = DimAnalysis(fi.node, view)
    da.run()
    return frozenset(da.returned)


class Sim007Units:
    rule_id = "SIM007"
    title = "suffix-declared dimensions (_ns/_pj/_bytes/_prob) never mix"

    def applies_to(self, rel_path: str) -> bool:
        return rel_path.endswith(".py")

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        view = ProjectIndex.get().with_module(mod)
        for qualname, fn in mod.functions():
            found: list[Finding] = []

            def report(slug, node, msg, _q=qualname, _out=found):
                _out.append(Finding(self.rule_id, mod.rel_path, _q, slug,
                                    message=msg,
                                    line=getattr(node, "lineno", 0)))
            DimAnalysis(fn, view).run(report)
            seen: set[str] = set()
            for f in found:
                if f.slug not in seen:      # one finding per site kind/fn
                    seen.add(f.slug)
                    yield f
