"""SIM001 — ticket discipline (invariant I1 in repro.backend.base).

Every ``submit_*`` call returns a Ticket that someone must resolve, and a
``.result()`` on a ticket submitted in the same function must be dominated
by a ``flush()`` — otherwise the call silently degrades to the eager
auto-flush path (one launch per command, the §IV-E anti-pattern) or, worse,
relies on a *later* burst's flush for resolution.

Two sub-rules, both per function scope (a nested def is its own scope —
cross-function discipline is covered dynamically by the launch audit):

  * ``dropped:<name>`` — a bare expression statement whose value is a
    ``submit_*`` call: the ticket is discarded, so nothing can ever verify
    the command resolved (the bug class fixed in WriteBuffer.flush).
  * ``result-no-flush:<name>`` — a ``submit_*`` at line S whose first
    ``.result()`` at line R >= S has no ``flush``/``drain`` call in
    (S, R].  Line-order is an approximation of dominance, precise enough
    for this codebase's straight-line submit/flush/result phrasing.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..contracts import ParsedModule, callee_name, walk_own
from ..findings import Finding

_FLUSH_NAMES = ("flush", "drain", "resolve_burst")


class Sim001Tickets:
    rule_id = "SIM001"
    title = "submit_* ticket must be flushed before .result(), never dropped"

    def applies_to(self, rel_path: str) -> bool:
        return rel_path.endswith(".py")

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        for qualname, fn in mod.functions():
            yield from self._check_function(mod, qualname, fn)

    def _check_function(self, mod, qualname, fn) -> Iterator[Finding]:
        submits: list[tuple[int, str]] = []
        flushes: list[int] = []
        results: list[int] = []
        dropped: list[tuple[int, str]] = []
        for node in walk_own(fn):
            if isinstance(node, ast.Expr) and isinstance(node.value,
                                                         ast.Call):
                name = callee_name(node.value)
                if name and name.startswith("submit_"):
                    dropped.append((node.lineno, name))
            if isinstance(node, ast.Call):
                name = callee_name(node)
                if name is None:
                    continue
                if name.startswith("submit_"):
                    submits.append((node.lineno, name))
                elif any(name == f or name.startswith(f + "_")
                         for f in _FLUSH_NAMES):
                    flushes.append(node.lineno)
                elif name == "result" and isinstance(node.func,
                                                     ast.Attribute):
                    results.append(node.lineno)
        for line, name in dropped:
            yield Finding(self.rule_id, mod.rel_path, qualname,
                          f"dropped:{name}", line=line,
                          message=f"return value of {name}() is discarded; "
                                  "the ticket can never be verified resolved")
        results.sort()
        flagged: set[str] = set()
        drop_lines = {ln for ln, _ in dropped}
        for s_line, s_name in submits:
            if s_line in drop_lines:
                continue                      # already reported as dropped
            for r_line in results:
                if r_line < s_line:
                    continue
                if not any(s_line < fl <= r_line for fl in flushes) \
                        and s_name not in flagged:
                    flagged.add(s_name)
                    yield Finding(
                        self.rule_id, mod.rel_path, qualname,
                        f"result-no-flush:{s_name}", line=r_line,
                        message=f".result() reachable after {s_name}() with "
                                "no dominating flush() — degrades to the "
                                "eager one-command-per-launch path")
                break                          # first result at/after submit
