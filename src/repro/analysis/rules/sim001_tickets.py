"""SIM001 — ticket discipline (invariant I1 in repro.backend.base).

Every ``submit_*`` call returns a Ticket that someone must resolve.  This
rule keeps the syntactic sub-check that needs no dataflow:

  * ``dropped:<name>`` — a bare expression statement whose value is a
    ``submit_*`` call: the ticket is discarded, so nothing can ever verify
    the command resolved (the bug class fixed in WriteBuffer.flush).

The historical ``result-no-flush`` sub-check (a ``.result()`` not
dominated by a ``flush()``) was line-order-approximate and flagged the
eager wrappers in ``backend.base`` as false positives; it now lives in
SIM009, re-grounded on the dataflow engine's CFGs and call summaries,
which proves those wrappers clean instead of allowlisting them.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..contracts import ParsedModule, callee_name, walk_own
from ..findings import Finding


class Sim001Tickets:
    rule_id = "SIM001"
    title = "submit_* tickets are never dropped on the floor"

    def applies_to(self, rel_path: str) -> bool:
        return rel_path.endswith(".py")

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        for qualname, fn in mod.functions():
            for node in walk_own(fn):
                if isinstance(node, ast.Expr) and isinstance(node.value,
                                                             ast.Call):
                    name = callee_name(node.value)
                    if name and name.startswith("submit_"):
                        yield Finding(
                            self.rule_id, mod.rel_path, qualname,
                            f"dropped:{name}", line=node.lineno,
                            message=f"return value of {name}() is "
                                    "discarded; the ticket can never be "
                                    "verified resolved")
