"""SIM006 — retry loops are bounded and seeded; no silent swallowing.

The device-fault tier (repro.reliability.device_faults, the backend
failover paths, the event frontend's timeout/backoff machinery) lives or
dies on three disciplines:

  * **bounded retry** — every retry loop must terminate: a
    ``while True:`` wrapping a ``try`` with no ``break`` in the loop's
    own body retries a failing command forever, which under a permanent
    outage converts a typed error into a hang.  Bounded forms
    (``for attempt in range(MAX_ATTEMPTS)``, a ``while`` with a real
    condition, or a loop that breaks) are fine;
  * **typed failures** — an ``except`` handler whose body is only
    ``pass`` (or ``...``) silently swallows the error channel; fault
    paths must re-raise, convert to a typed error, or record the outcome.

The historical third sub-check (bare ``default_rng()``) is superseded by
SIM008, which traces RNG entropy to a declared seed through real
dataflow instead of pattern-matching the empty-argument spelling.

Scope: the fault-handling layers only — ``src/repro/backend/``,
``src/repro/frontend/`` and ``src/repro/reliability/``.  Elsewhere an
infinite poll loop can be legitimate; in these paths it is exactly the
bug the chaos sweep exists to catch.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..contracts import ParsedModule, walk_own
from ..findings import Finding

_SCOPED_PREFIXES = ("src/repro/backend/", "src/repro/frontend/",
                    "src/repro/reliability/")
_LOOP_NODES = (ast.While, ast.For)
_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef)


def _is_true_const(test: ast.AST) -> bool:
    return isinstance(test, ast.Constant) and test.value is True


def _own_loop_body(loop: ast.While) -> Iterator[ast.AST]:
    """Walk a loop's body without descending into nested loops or scopes
    (a ``break`` there belongs to the inner loop, not this one)."""
    stack = [n for stmt in loop.body for n in [stmt]]
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, _LOOP_NODES + _SCOPE_NODES):
            stack.extend(ast.iter_child_nodes(n))


def _swallows_silently(handler: ast.ExceptHandler) -> bool:
    """Handler body is only ``pass`` / bare ``...`` — the error vanishes."""
    for stmt in handler.body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) \
                and isinstance(stmt.value, ast.Constant) \
                and stmt.value.value is Ellipsis:
            continue
        return False
    return True


def _handler_name(handler: ast.ExceptHandler) -> str:
    t = handler.type
    if t is None:
        return "bare"
    if isinstance(t, ast.Name):
        return t.id
    if isinstance(t, ast.Attribute):
        return t.attr
    if isinstance(t, ast.Tuple):
        return "+".join(_handler_name(ast.ExceptHandler(type=e))
                        for e in t.elts)
    return "expr"


class Sim006Retries:
    rule_id = "SIM006"
    title = "fault paths retry boundedly, seed their rngs, fail typed"

    def applies_to(self, rel_path: str) -> bool:
        return rel_path.startswith(_SCOPED_PREFIXES) \
            and rel_path.endswith(".py")

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        for qualname, fn in mod.functions():
            for node in walk_own(fn):
                # (a) silent exception swallowing
                if isinstance(node, ast.Try):
                    for h in node.handlers:
                        if _swallows_silently(h):
                            yield Finding(
                                self.rule_id, mod.rel_path, qualname,
                                f"swallows:{_handler_name(h)}",
                                line=h.lineno,
                                message="except body is only pass/... — "
                                        "the error vanishes; fault paths "
                                        "must re-raise, convert to a "
                                        "typed error, or record the "
                                        "outcome")
                # (b) unbounded retry: while True wrapping a try, no break
                elif isinstance(node, ast.While) \
                        and _is_true_const(node.test):
                    body = list(_own_loop_body(node))
                    has_try = any(isinstance(n, ast.Try) for n in body)
                    has_break = any(isinstance(n, ast.Break) for n in body)
                    if has_try and not has_break:
                        yield Finding(
                            self.rule_id, mod.rel_path, qualname,
                            "unbounded-retry", line=node.lineno,
                            message="while True around a try with no "
                                    "break: a permanent fault turns a "
                                    "typed error into a hang — bound the "
                                    "attempts (for attempt in "
                                    "range(MAX)) or break on success")
