"""SIM rule registry for the contract linter."""
from .sim001_tickets import Sim001Tickets
from .sim002_observers import Sim002Observers
from .sim003_hostsync import Sim003HostSync
from .sim004_counters import Sim004Counters

ALL_RULES = (Sim001Tickets(), Sim002Observers(), Sim003HostSync(),
             Sim004Counters())

RULES_BY_ID = {r.rule_id: r for r in ALL_RULES}
