"""SIM rule registry for the contract linter."""
from .sim001_tickets import Sim001Tickets
from .sim002_observers import Sim002Observers
from .sim003_hostsync import Sim003HostSync
from .sim004_counters import Sim004Counters
from .sim005_verdicts import Sim005Verdicts
from .sim006_retries import Sim006Retries
from .sim007_units import Sim007Units
from .sim008_seeds import Sim008Seeds
from .sim009_lifecycle import Sim009Lifecycle

ALL_RULES = (Sim001Tickets(), Sim002Observers(), Sim003HostSync(),
             Sim004Counters(), Sim005Verdicts(), Sim006Retries(),
             Sim007Units(), Sim008Seeds(), Sim009Lifecycle())

RULES_BY_ID = {r.rule_id: r for r in ALL_RULES}
