"""SIM009 — interprocedural ticket lifecycle (invariant I1, v2).

SIM001's original flush-before-result check was syntactic: any
``submit_*`` followed by ``.result()`` without a textual ``flush()`` in
between was flagged, which forced the four eager wrappers in
``backend.base`` (``search()`` = ``submit_search(cmd).result()``) into
``baseline.toml`` as allowlisted false positives.  This rule re-grounds
the check on the dataflow engine:

  * the abstract state is the set of *pending ticket tokens*
    (``<submit-name>@<line>``, starred when the submit sits inside a loop
    or comprehension and therefore stands for *many* tickets);
  * flush-named calls (``flush``/``drain``/``resolve_burst`` and their
    prefixed/suffixed spellings) clear the pending set, as does any call
    whose *call-graph summary* says it may flush (so a helper that
    flushes two frames down is proven clean, not allowlisted);
  * a call whose resolved callees all *leave tickets pending* (again a
    summary) adds a token — the interprocedural case no per-function
    rule could see;
  * ``.result()`` with a **single** straight-line pending ticket is the
    documented immediate mode (``Ticket.result`` auto-flushes) and is
    clean — this proves the four ``baseline.toml`` pins and lets us
    delete them.  ``.result()`` while two or more tickets are pending
    (or one looped token, which stands for many) relies on the
    auto-flush to resolve *other* commands' tickets mid-burst: finding
    ``result-no-flush:<submit-name>``.

``may_flush`` summaries deliberately do not propagate through
``result`` — resolving a burst via the auto-flush is exactly the
anti-pattern being policed, so routing a flush summary through it would
launder the violation.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..contracts import ParsedModule, callee_name
from ..dataflow import (Bind, ForwardAnalysis, ProjectIndex, Test,
                        build_cfg, calls_in, is_flush_name,
                        looped_call_ids)
from ..findings import Finding

_EMPTY = frozenset()
_PENDING = "@pending"


def _is_submit(name: str | None) -> bool:
    if not name:
        return False
    base = name.lstrip("_")
    return base == "submit" or base.startswith("submit_")


def _count(tokens: frozenset) -> int:
    """Abstract multiplicity: a starred (looped) token stands for many."""
    return sum(2 if t.endswith("*") else 1 for t in tokens)


class PendingAnalysis(ForwardAnalysis):
    """Pending-ticket set propagation over one function."""

    def __init__(self, fi, view):
        super().__init__(build_cfg(fi.node))
        self.fi = fi
        self.view = view
        self.looped = looped_call_ids(fi.node)
        self.exit_pending: frozenset = _EMPTY

    def init_env(self) -> dict:
        return {_PENDING: _EMPTY}

    def transfer(self, st, env: dict) -> dict:
        env = dict(env)
        if isinstance(st, (Test, Bind, ast.stmt)):
            for call in calls_in(st):
                self._call(call, env)
        return env

    def _call(self, call: ast.Call, env: dict) -> None:
        name = callee_name(call)
        pending = env.get(_PENDING, _EMPTY)
        if is_flush_name(name):
            env[_PENDING] = _EMPTY
            return
        if name == "result":
            if _count(pending) >= 2 and self.report is not None:
                for tok in sorted(pending):
                    submit = tok.split("@", 1)[0]
                    self.report(
                        f"result-no-flush:{submit}", call,
                        f".result() reached with {submit} (and other "
                        "commands) still pending — the auto-flush resolves "
                        "a multi-command burst implicitly; call flush() "
                        "first (I1)")
            env[_PENDING] = _EMPTY       # the auto-flush resolves everything
            return
        if _is_submit(name):
            star = "*" if id(call) in self.looped else ""
            env[_PENDING] = pending | {f"{name}@{call.lineno}{star}"}
            return
        matches = self.view.resolve(name)
        if not matches:
            return
        if any(self.view.may_flush(m) for m in matches):
            env[_PENDING] = _EMPTY
        elif all(self.view.leaves_pending(m) for m in matches):
            star = "*" if id(call) in self.looped else ""
            env[_PENDING] = pending | {f"{name}@{call.lineno}{star}"}

    def block_end(self, block, env: dict) -> None:
        if not block.succs:
            self.exit_pending |= env.get(_PENDING, _EMPTY)


def function_leaves_pending(fi) -> bool:
    """Call-graph summary: can this function return with tickets still
    pending (i.e. it submits without flushing/resolving before exit)?"""
    view = ProjectIndex.get().with_module(fi.module)
    pa = PendingAnalysis(fi, view)
    pa.run()
    return bool(pa.exit_pending)


class Sim009Lifecycle:
    rule_id = "SIM009"
    title = "no implicit multi-command flush via Ticket.result() (I1, v2)"

    def applies_to(self, rel_path: str) -> bool:
        return rel_path.endswith(".py")

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        view = ProjectIndex.get().with_module(mod)
        for fi in view._local:
            found: list[Finding] = []

            def report(slug, node, msg, _q=fi.qualname, _out=found):
                _out.append(Finding(self.rule_id, mod.rel_path, _q, slug,
                                    message=msg,
                                    line=getattr(node, "lineno", 0)))
            PendingAnalysis(fi, view).run(report)
            seen: set[str] = set()
            for f in found:
                if f.slug not in seen:
                    seen.add(f.slug)
                    yield f
