"""SIM008 — seed provenance (invariant I6 in repro.backend.base).

Every RNG construction in the tree must *dataflow-trace* to a declared
seed (``RunConfig.seed``, ``FaultSchedule.seed``, a ``seed`` parameter, a
literal) — the replay/chaos determinism contract is "same seed =>
byte-identical counters", and one generator drawing OS entropy anywhere
in the stack silently breaks every regression gate downstream.  This
upgrades SIM006's syntactic bare-``default_rng()`` check (now retired)
into a taint analysis on the dataflow engine:

  * taint sources: integer/string literals (deterministic), names and
    attributes matching the seed convention (``seed``, ``*_seed``,
    ``.seed``, ``entropy``), and calls to project functions whose
    summary says they return seeded values;
  * taint propagates through assignments, arithmetic (the repo's
    ``seed ^ 0xD1CE`` idiom), entropy lists (``[seed, key, attempt]`` —
    one seeded component makes the mix deterministic given the seed),
    and function returns;
  * a constructor argument that is only a *parameter* of the enclosing
    function is resolved interprocedurally: every call site in the
    project must pass a seeded value (or the parameter's default must be
    a literal) — otherwise the RNG's provenance is unproven.

Findings: ``unseeded-rng`` (no entropy argument at all) and
``untraced-rng[:param]`` (entropy that no dataflow path connects to a
declared seed).
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..contracts import ParsedModule, callee_name
from ..dataflow import (Bind, ForwardAnalysis, ProjectIndex, RNG_NAMES,
                        SEEDED, Test, _SEED_PASSTHROUGH, build_cfg,
                        calls_in, is_seed_name)
from ..findings import Finding

_EMPTY = frozenset()


def _syntactic_seed(e, seen_depth: int = 0) -> bool:
    """Caller-side, environment-free seededness of a call-site argument."""
    if seen_depth > 6 or e is None:
        return False
    if isinstance(e, ast.Constant):
        return True
    if isinstance(e, ast.Name):
        return is_seed_name(e.id)
    if isinstance(e, ast.Attribute):
        return is_seed_name(e.attr)
    if isinstance(e, (ast.List, ast.Tuple, ast.Set)):
        return any(_syntactic_seed(x, seen_depth + 1) for x in e.elts)
    if isinstance(e, ast.BinOp):
        return _syntactic_seed(e.left, seen_depth + 1) \
            or _syntactic_seed(e.right, seen_depth + 1)
    if isinstance(e, ast.UnaryOp):
        return _syntactic_seed(e.operand, seen_depth + 1)
    if isinstance(e, ast.Call):
        if callee_name(e) in _SEED_PASSTHROUGH | RNG_NAMES:
            return any(_syntactic_seed(a, seen_depth + 1) for a in e.args)
        return False
    return False


class SeedAnalysis(ForwardAnalysis):
    """Seed-taint propagation over one function; checks RNG constructions."""

    def __init__(self, fi, view):
        super().__init__(build_cfg(fi.node))
        self.fi = fi
        self.view = view
        self.returns_seeded = False

    def init_env(self) -> dict:
        env = {}
        a = self.fi.node.args
        for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
            env[arg.arg] = (frozenset({SEEDED}) if is_seed_name(arg.arg)
                            else frozenset({f"param:{arg.arg}"}))
        return env

    # ----------------------------------------------------------- evaluation
    def eval(self, e, env: dict) -> frozenset:
        if e is None:
            return _EMPTY
        if isinstance(e, ast.Constant):
            return frozenset({SEEDED})
        if isinstance(e, ast.Name):
            if is_seed_name(e.id):
                return frozenset({SEEDED})
            return env.get(e.id, _EMPTY)
        if isinstance(e, ast.Attribute):
            return frozenset({SEEDED}) if is_seed_name(e.attr) else _EMPTY
        if isinstance(e, (ast.List, ast.Tuple, ast.Set)):
            out = _EMPTY
            for elt in e.elts:
                out |= self.eval(elt, env)
            return out
        if isinstance(e, ast.BinOp):
            return self.eval(e.left, env) | self.eval(e.right, env)
        if isinstance(e, ast.UnaryOp):
            return self.eval(e.operand, env)
        if isinstance(e, ast.BoolOp):
            out = _EMPTY
            for v in e.values:
                out |= self.eval(v, env)
            return out
        if isinstance(e, ast.IfExp):
            return self.eval(e.body, env) | self.eval(e.orelse, env)
        if isinstance(e, ast.Subscript):
            return self.eval(e.value, env)
        if isinstance(e, ast.Starred):
            return self.eval(e.value, env)
        if isinstance(e, ast.NamedExpr):
            t = self.eval(e.value, env)
            if isinstance(e.target, ast.Name):
                env[e.target.id] = t
            return t
        if isinstance(e, ast.Call):
            name = callee_name(e)
            if name in _SEED_PASSTHROUGH | RNG_NAMES:
                out = _EMPTY
                for a in e.args:
                    out |= self.eval(a, env)
                for kw in e.keywords:
                    out |= self.eval(kw.value, env)
                return out
            matches = self.view.resolve(name)
            if matches and any(self.view.returns_seeded(m) for m in matches):
                return frozenset({SEEDED})
            return _EMPTY
        return _EMPTY

    # ------------------------------------------------------------- RNG check
    def _check_rng(self, call: ast.Call, env: dict) -> None:
        name = callee_name(call)
        if not call.args and not call.keywords:
            self.report(
                "unseeded-rng", call,
                f"{name}() with no entropy draws from the OS — the "
                "same-seed => byte-identical-counters contract (I6) "
                "requires a declared seed")
            return
        taint = _EMPTY
        for a in call.args:
            taint |= self.eval(a, env)
        for kw in call.keywords:
            taint |= self.eval(kw.value, env)
        if SEEDED in taint:
            return
        params = sorted({t[6:] for t in taint if t.startswith("param:")})
        if not params:
            self.report(
                "untraced-rng", call,
                f"{name}(...) entropy has no dataflow path to a declared "
                "seed (literal, seed-named value, or seeded-returning "
                "function)")
            return
        for p in params:
            ok, why = self._trace_param(p)
            if not ok:
                self.report(
                    f"untraced-rng:{p}", call,
                    f"{name}(...) entropy flows from parameter {p!r}, "
                    f"which is not proven seeded: {why}")

    def _trace_param(self, p: str) -> tuple[bool, str]:
        """Interprocedural leg: prove parameter ``p`` receives a seeded
        value at every project call site (or via a literal default)."""
        default = self._param_default(p)
        sites = self.view.call_sites(self.fi)
        if not sites and default is None:
            return False, "no call sites found and no literal default"
        for caller, call in sites:
            pairs = dict(self.fi.map_args(call))
            if p in pairs:
                if not _syntactic_seed(pairs[p]):
                    return False, (f"call site {caller.qualname} "
                                   f"(line {call.lineno}) passes an "
                                   "unseeded value")
            elif default is None or not _syntactic_seed(default):
                return False, (f"call site {caller.qualname} "
                               f"(line {call.lineno}) omits it and the "
                               "default is not a literal seed")
        return True, ""

    def _param_default(self, p: str):
        a = self.fi.node.args
        pos = [*a.posonlyargs, *a.args]
        for arg, d in zip(reversed(pos), reversed(a.defaults)):
            if arg.arg == p:
                return d
        for arg, d in zip(a.kwonlyargs, a.kw_defaults):
            if arg.arg == p and d is not None:
                return d
        return None

    # ------------------------------------------------------------- transfer
    def transfer(self, st, env: dict) -> dict:
        env = dict(env)
        if self.report is not None:
            for call in calls_in(st):
                if callee_name(call) in RNG_NAMES:
                    self._check_rng(call, env)
        if isinstance(st, Bind):
            self._bind(st.target, self.eval(st.iter, env), env)
        elif isinstance(st, Test):
            pass
        elif isinstance(st, ast.Assign):
            t = self.eval(st.value, env)
            for target in st.targets:
                self._bind(target, t, env)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self._bind(st.target, self.eval(st.value, env), env)
        elif isinstance(st, ast.AugAssign):
            if isinstance(st.target, ast.Name):
                env[st.target.id] = env.get(st.target.id, _EMPTY) \
                    | self.eval(st.value, env)
        elif isinstance(st, ast.Return):
            if self.reporting and SEEDED in self.eval(st.value, env):
                self.returns_seeded = True
        return env

    def _bind(self, target, taint: frozenset, env: dict) -> None:
        if isinstance(target, ast.Name):
            env[target.id] = taint
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, taint, env)


def function_returns_seeded(fi) -> bool:
    """Call-graph summary: can this function return a seeded value?"""
    view = ProjectIndex.get().with_module(fi.module)
    sa = SeedAnalysis(fi, view)
    sa.run()
    return sa.returns_seeded


class Sim008Seeds:
    rule_id = "SIM008"
    title = "every RNG construction dataflow-traces to a declared seed"

    def applies_to(self, rel_path: str) -> bool:
        return rel_path.endswith(".py")

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        view = ProjectIndex.get().with_module(mod)
        for fi in view._local:
            found: list[Finding] = []

            def report(slug, node, msg, _q=fi.qualname, _out=found):
                _out.append(Finding(self.rule_id, mod.rel_path, _q, slug,
                                    message=msg,
                                    line=getattr(node, "lineno", 0)))
            sa = SeedAnalysis(fi, view)
            sa.report = None
            sa.run(report)
            seen: set[str] = set()
            for f in found:
                if f.slug not in seen:
                    seen.add(f.slug)
                    yield f
