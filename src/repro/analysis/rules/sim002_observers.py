"""SIM002 — observer completeness (invariant I2 in repro.backend.base).

The PlaneStore arena only stays coherent because every mutation of a
stored page image notifies the write observers (``SimChip._notify`` /
``SimChipArray._notify_global``), and every arena-plane mutation updates
the dirty/staging bookkeeping.  A mutating method that skips the notify is
exactly the bug class that makes a kernel backend silently match against a
stale image.

Scope is path-keyed (the invariant is about these two files, not the whole
repo):

  * ``core/engine.py`` — methods that assign into ``pages``/``raw`` (or
    mutate them via ``np.<ufunc>.at``) must call a ``_notify*`` in the
    same method;
  * ``backend/planestore.py`` — methods that assign the device planes
    (``_lo``/``_hi``/``_ids``/``_seeds``) must touch the staging
    bookkeeping (``_dirty``/``staged_rows``/``staged_bytes``) in the same
    method.  ``PlaneStore._grow`` is the accepted exception (pinned in
    baseline.toml): growth is a content-preserving device-side copy.

``__init__`` is exempt — observers subscribe to constructed objects, so
construction is not an observable mutation.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..contracts import ParsedModule, callee_name, walk_own
from ..findings import Finding

_SCOPES = {
    "src/repro/core/engine.py": {
        "attrs": {"pages", "raw"},
        "notify": {"_notify", "_notify_global"},
    },
    "src/repro/backend/planestore.py": {
        "attrs": {"_lo", "_hi", "_ids", "_seeds"},
        "notify": {"_dirty", "staged_rows", "staged_bytes"},
    },
}


def _attrs_in(node: ast.AST, wanted: set[str]) -> set[str]:
    return {n.attr for n in ast.walk(node)
            if isinstance(n, ast.Attribute) and n.attr in wanted}


class Sim002Observers:
    rule_id = "SIM002"
    title = "page/plane mutations must notify write observers"

    def applies_to(self, rel_path: str) -> bool:
        return rel_path in _SCOPES

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        scope = _SCOPES[mod.rel_path]
        attrs, notify = scope["attrs"], scope["notify"]
        for qualname, fn in mod.functions():
            if fn.name == "__init__":
                continue
            mutated: dict[str, int] = {}       # attr -> first line
            notified = False
            for node in walk_own(fn):
                targets: list[ast.AST] = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target]
                elif isinstance(node, ast.Delete):
                    targets = node.targets
                elif isinstance(node, ast.Call) and callee_name(node) == "at":
                    # in-place ufunc mutation: np.<ufunc>.at(page.raw, ...)
                    for arg in node.args[:1]:
                        for a in _attrs_in(arg, attrs):
                            mutated.setdefault(a, node.lineno)
                for t in targets:
                    for a in _attrs_in(t, attrs):
                        mutated.setdefault(a, node.lineno)
                if isinstance(node, ast.Call) and callee_name(node) in notify:
                    notified = True
                elif isinstance(node, ast.Attribute) and node.attr in notify:
                    notified = True
            if mutated and not notified:
                attrs_hit = ",".join(sorted(mutated))
                yield Finding(
                    self.rule_id, mod.rel_path, qualname,
                    f"mutates:{attrs_hit}", line=min(mutated.values()),
                    message=f"assigns into {attrs_hit} without notifying "
                            f"observers ({'/'.join(sorted(notify))})")
