"""SIM004 — counter integrity (invariant I4 in repro.backend.base).

``BackendStats`` is the measurement instrument the whole performance story
rests on (staged/result byte exactness is asserted by tests and the launch
audit), so its fields may only move inside the accounting helpers: the
flush phases, submit/resolve paths, and the deferred ``tail`` closures.
A stray ``backend.stats.result_bytes += ...`` in an index structure or
workload runner would silently skew the Fig 12/13 reproduction.

Field names are parsed from ``backend/base.py``'s ``BackendStats`` class at
lint time (self-maintaining — adding a field extends the rule).  Classes
that own a *different* stats object (``self.stats = <OtherStats>()`` in
``__init__``, e.g. ``WriteBufferStats``, ``SimStats``) are exempt even
where field names collide.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterator

from ..contracts import ParsedModule, walk_own
from ..findings import Finding

_BACKEND_PREFIX = "src/repro/backend/"
_ALLOWED_EXACT = {"flush", "__init__", "tail"}
_ALLOWED_PREFIXES = ("_flush", "submit_", "resolve_", "_resolve",
                     "_execute", "program_entries")

# Fallback if backend/base.py can't be parsed (e.g. linting a single file
# outside the repo): the field list as of this rule's writing.
_FALLBACK_FIELDS = {
    "searches", "gathers", "lookups", "plans", "flushes", "kernel_launches",
    "staged_pages", "staged_queries", "staged_bytes", "batched_searches",
    "programs", "programs_coalesced", "result_bytes",
}


def _parse_backend_stats_fields(root: Path) -> set[str]:
    base = root / "src" / "repro" / "backend" / "base.py"
    try:
        tree = ast.parse(base.read_text())
    except OSError:
        return set(_FALLBACK_FIELDS)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "BackendStats":
            return {s.target.id for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)}
    return set(_FALLBACK_FIELDS)


def _owned_stats_classes(tree: ast.Module) -> set[str]:
    """Classes that construct their own (non-BackendStats) stats object."""
    owned: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for fn in node.body:
            if not (isinstance(fn, ast.FunctionDef)
                    and fn.name == "__init__"):
                continue
            for stmt in ast.walk(fn):
                if isinstance(stmt, ast.Assign) \
                        and isinstance(stmt.value, ast.Call) \
                        and isinstance(stmt.value.func, ast.Name) \
                        and stmt.value.func.id != "BackendStats":
                    for t in stmt.targets:
                        if isinstance(t, ast.Attribute) \
                                and t.attr == "stats":
                            owned.add(node.name)
    return owned


def _allowed(func_name: str) -> bool:
    return func_name in _ALLOWED_EXACT \
        or func_name.startswith(_ALLOWED_PREFIXES)


class Sim004Counters:
    rule_id = "SIM004"
    title = "BackendStats fields mutate only inside accounting helpers"

    def __init__(self):
        self._fields: set[str] | None = None

    def applies_to(self, rel_path: str) -> bool:
        return rel_path.startswith("src/repro/") and rel_path.endswith(".py")

    def _fields_for(self, mod: ParsedModule) -> set[str]:
        if self._fields is None:
            # real_path = <root>/src/repro/... -> root is 3 parents up from
            # the repro package dir; fall back to cwd-rooted lookup.
            p = Path(mod.real_path)
            root = p
            for anc in p.parents:
                if (anc / "src" / "repro" / "backend" / "base.py").exists():
                    root = anc
                    break
            self._fields = _parse_backend_stats_fields(root)
        return self._fields

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        fields = self._fields_for(mod)
        in_backend = mod.rel_path.startswith(_BACKEND_PREFIX)
        owned = _owned_stats_classes(mod.tree)

        def visit(node, prefix, cls):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    yield from check_fn(q, child, cls)
                    yield from visit(child, f"{q}.", cls)
                elif isinstance(child, ast.ClassDef):
                    yield from visit(child, f"{prefix}{child.name}.",
                                     child.name)
                else:
                    yield from visit(child, prefix, cls)

        def check_fn(qualname, fn, cls):
            if cls in owned and not in_backend:
                return
            for node in walk_own(fn):
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in targets:
                        field = self._stats_field_target(t, fields)
                        if field is None:
                            continue
                        if _allowed(fn.name):
                            continue
                        yield Finding(
                            self.rule_id, mod.rel_path, qualname,
                            f"mutates:{field}", line=node.lineno,
                            message=f"writes BackendStats.{field} outside "
                                    "the accounting helpers (flush/_flush_*/"
                                    "submit_*/resolve_*/tail)")

        yield from visit(mod.tree, "", None)

    @staticmethod
    def _stats_field_target(t: ast.AST, fields: set[str]) -> str | None:
        # X.stats.<field> = / += ...
        if isinstance(t, ast.Attribute) and t.attr in fields \
                and isinstance(t.value, ast.Attribute) \
                and t.value.attr == "stats":
            return t.attr
        # wholesale replacement: X.stats = ... (outside __init__ this
        # resets every counter behind the instrument's back)
        if isinstance(t, ast.Attribute) and t.attr == "stats":
            return "<stats>"
        return None
