"""SIM003 — no host sync in the hot path (invariant I3 in repro.backend.base).

The lazy result path only buys anything if the flush itself never blocks
on the device: a ``np.asarray``/``int()``/``.block_until_ready()`` on a
launch output inside ``flush``/``_flush_*``/``_dispatch*`` (or inside the
kernel ``ops.py`` wrappers that run under the flush) forces the transfer
at flush time and silently serializes burst k+1's staging behind burst k's
compute.  The host tail belongs in the deferred ``tail`` closures, which
is why nested defs are excluded from the hot scope.

Detection is taint-based: names assigned from device producers (the
``sim_*`` kernel entry points, ``_stacked_*``, ``PlaneStore.take``/
``take2d``, anything built by ``jnp.*``) are device values; a host-sync
construct applied to a tainted expression is a finding.  ``int()`` on a
plain host value in a flush (e.g. popcounting a numpy command bitmap) is
deliberately NOT a finding.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import Iterator

from ..contracts import ParsedModule, attr_root, callee_name, walk_own
from ..findings import Finding

_HOT_FILE_GLOBS = ("src/repro/kernels/*/ops.py",)
_HOT_PREFIXES = ("_flush", "_dispatch", "_stacked", "_execute_programs")

_PRODUCERS = {
    "sim_search", "sim_plan", "sim_gather", "sim_fused_lookup",
    "sim_search_kernel", "sim_plan_kernel", "sim_gather_kernel",
    "sim_fused_kernel", "sim_lookup_kernel",
    "sim_search_ref", "sim_plan_ref", "sim_gather_ref", "sim_fused_ref",
    "_stacked_search", "_stacked_plan", "take", "take2d",
    "planes_to_chunk_words_xp", "pallas_call",
}
_SYNC_ALWAYS = {"block_until_ready", "device_get", "copy_to_host_async"}
_SYNC_TAINTED_METHODS = {"item", "tolist"}
_COPY_FUNCS = {"asarray", "array", "copy"}     # flagged as np.<f>(tainted)
_CAST_FUNCS = {"int", "float", "bool"}


def _is_hot_file(rel_path: str) -> bool:
    return any(fnmatch.fnmatch(rel_path, g) for g in _HOT_FILE_GLOBS)


def _is_hot_function(name: str, rel_path: str, depth: int) -> bool:
    if _is_hot_file(rel_path):
        return True
    if depth > 0:                  # nested defs are deferred tails, not hot
        return False
    return name == "flush" or name.startswith(_HOT_PREFIXES)


def _is_device_expr(node: ast.AST, tainted: set[str]) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in tainted \
                and isinstance(n.ctx, ast.Load):
            return True
        if isinstance(n, ast.Call):
            name = callee_name(n)
            if name in _PRODUCERS:
                return True
            if isinstance(n.func, ast.Attribute) and \
                    attr_root(n.func) == "jnp":
                return True
    return False


def _taint(fn: ast.FunctionDef) -> set[str]:
    """Fixpoint over own-scope assignments: which names hold device values."""
    tainted: set[str] = set()
    assigns: list[tuple[list[ast.AST], ast.AST]] = []
    for node in walk_own(fn):
        if isinstance(node, ast.Assign) and node.value is not None:
            assigns.append((node.targets, node.value))
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)) \
                and getattr(node, "value", None) is not None:
            assigns.append(([node.target], node.value))
    for _ in range(len(assigns) + 1):
        changed = False
        for targets, value in assigns:
            if not _is_device_expr(value, tainted):
                continue
            for t in targets:
                for n in ast.walk(t):
                    # "_" is the conventional discard — tainting it would
                    # leak device-ness into unrelated comprehension targets.
                    if isinstance(n, ast.Name) and n.id != "_" \
                            and n.id not in tainted:
                        tainted.add(n.id)
                        changed = True
        if not changed:
            break
    return tainted


class Sim003HostSync:
    rule_id = "SIM003"
    title = "no host synchronization on device values in flush hot paths"

    def applies_to(self, rel_path: str) -> bool:
        return rel_path.startswith("src/repro/") and rel_path.endswith(".py")

    def check(self, mod: ParsedModule) -> Iterator[Finding]:
        hot: list[tuple[str, ast.FunctionDef]] = []

        def visit(node, prefix, fn_depth):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    if _is_hot_function(child.name, mod.rel_path, fn_depth):
                        hot.append((q, child))
                    visit(child, f"{q}.", fn_depth + 1)
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{prefix}{child.name}.", fn_depth)
                else:
                    visit(child, prefix, fn_depth)

        visit(mod.tree, "", 0)
        for qualname, fn in hot:
            yield from self._check_function(mod, qualname, fn)

    def _check_function(self, mod, qualname, fn) -> Iterator[Finding]:
        tainted = _taint(fn)
        for node in walk_own(fn):
            if not isinstance(node, ast.Call):
                continue
            name = callee_name(node)
            if name in _SYNC_ALWAYS and isinstance(node.func, ast.Attribute):
                yield self._finding(mod, qualname, name, node.lineno,
                                    f".{name}() blocks on the device")
            elif name in _SYNC_TAINTED_METHODS \
                    and isinstance(node.func, ast.Attribute) \
                    and _is_device_expr(node.func.value, tainted):
                yield self._finding(mod, qualname, name, node.lineno,
                                    f".{name}() forces a device->host "
                                    "transfer at flush time")
            elif name in _COPY_FUNCS and isinstance(node.func, ast.Attribute) \
                    and attr_root(node.func) == "np" \
                    and any(_is_device_expr(a, tainted) for a in node.args):
                yield self._finding(mod, qualname, f"np.{name}", node.lineno,
                                    f"np.{name}() on a device value copies "
                                    "it to host inside the flush")
            elif name in _CAST_FUNCS and isinstance(node.func, ast.Name) \
                    and node.args \
                    and _is_device_expr(node.args[0], tainted):
                yield self._finding(mod, qualname, name, node.lineno,
                                    f"{name}() on a device value is a "
                                    "blocking host sync")

    def _finding(self, mod, qualname, what, line, msg) -> Finding:
        return Finding(self.rule_id, mod.rel_path, qualname,
                       f"host-sync:{what}", line=line,
                       message=msg + " — move it into the deferred tail")
