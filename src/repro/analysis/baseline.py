"""Accepted-findings allowlist: load/write ``baseline.toml``.

The baseline pins pre-existing, *intentional* violations (e.g. the eager
``MatchBackend.search`` convenience wrappers are submit+result-without-
flush by design — ``Ticket.result`` auto-flushes) so the CI gate fails
only on NEW findings.  Keys are line-number-free (see findings.py), so
edits elsewhere in a pinned file don't churn the baseline.

Parsing prefers ``tomllib`` (3.11+) then ``tomli``; a minimal fallback
parser covers the restricted subset this file actually uses (an
``[[accepted]]`` array of string-valued tables), so the gate runs even on
a bare 3.10 interpreter.
"""
from __future__ import annotations

import dataclasses
import re
from pathlib import Path

from .findings import Finding

_HEADER = """\
# Accepted findings for `python -m repro.analysis --check`.
#
# Each [[accepted]] entry pins ONE pre-existing, reviewed violation by its
# stable key (rule, path, symbol, slug) — line numbers are deliberately not
# part of the key.  To accept a new finding, append an entry with a reason;
# to regenerate from the current tree, run:
#
#     PYTHONPATH=src python -m repro.analysis --write-baseline
#
# and then restore the reasons in review.  Removing code should remove its
# entry (stale entries are reported as warnings).
"""


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    symbol: str
    slug: str
    reason: str = ""

    def key(self) -> tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.slug)


def _parse_toml(text: str) -> dict:
    try:
        import tomllib
        return tomllib.loads(text)
    except ModuleNotFoundError:
        pass
    try:
        import tomli
        return tomli.loads(text)
    except ModuleNotFoundError:
        return _parse_minimal(text)


_KV = re.compile(r'^([A-Za-z_][\w\-]*)\s*=\s*"((?:[^"\\]|\\.)*)"\s*$')


def _parse_minimal(text: str) -> dict:
    """Fallback for interpreters without tomllib/tomli: parses only the
    ``[[accepted]]`` + string key/value subset baseline.toml uses."""
    out: dict = {"accepted": []}
    cur: dict | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[accepted]]":
            cur = {}
            out["accepted"].append(cur)
            continue
        m = _KV.match(line)
        if m and cur is not None:
            cur[m.group(1)] = m.group(2).replace('\\"', '"') \
                .replace("\\\\", "\\")
        elif cur is None:
            raise ValueError(f"unsupported baseline syntax: {line!r}")
    return out


def load_baseline(path: Path) -> list[BaselineEntry]:
    path = Path(path)
    if not path.exists():
        return []
    data = _parse_toml(path.read_text())
    entries = []
    for row in data.get("accepted", []):
        entries.append(BaselineEntry(
            rule=row.get("rule", ""), path=row.get("path", ""),
            symbol=row.get("symbol", ""), slug=row.get("slug", ""),
            reason=row.get("reason", "")))
    return entries


def _quote(s: str) -> str:
    return '"' + s.replace("\\", "\\\\").replace('"', '\\"') + '"'


def write_baseline(path: Path, findings: list[Finding],
                   reasons: dict[tuple, str] | None = None) -> None:
    """Emit a baseline pinning ``findings`` (sorted, stable output)."""
    reasons = reasons or {}
    blocks = []
    for f in sorted(findings, key=lambda f: f.key()):
        reason = reasons.get(f.key(), f.message)
        blocks.append("\n".join([
            "[[accepted]]",
            f"rule = {_quote(f.rule)}",
            f"path = {_quote(f.path)}",
            f"symbol = {_quote(f.symbol)}",
            f"slug = {_quote(f.slug)}",
            f"reason = {_quote(reason)}",
        ]))
    Path(path).write_text(_HEADER + "\n" + "\n\n".join(blocks) + "\n"
                          if blocks else _HEADER)


def apply_baseline(findings: list[Finding],
                   entries: list[BaselineEntry]):
    """Split findings into (new, accepted) and report stale pins."""
    pinned = {e.key(): e for e in entries}
    new: list[Finding] = []
    accepted: list[Finding] = []
    hit: set[tuple] = set()
    for f in findings:
        if f.key() in pinned:
            accepted.append(f)
            hit.add(f.key())
        else:
            new.append(f)
    stale = [e for k, e in pinned.items() if k not in hit]
    return new, accepted, stale
