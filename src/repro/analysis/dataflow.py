"""Interprocedural dataflow engine for the contract auditor (v2).

The first-generation rules (SIM001..SIM006) are syntactic and
per-function: they can spot a ``default_rng()`` with no argument, but not
a nanosecond flowing into a picojoule field two calls away, nor prove
that an RNG three assignments downstream of ``RunConfig.seed`` is in fact
seeded.  This module supplies the machinery the second-generation rules
(SIM007 units, SIM008 seed provenance, SIM009 ticket lifecycle) share:

  * **per-function CFGs** over the AST (:func:`build_cfg`) — statement
    blocks with branch/loop/try edges, loop back edges included, nested
    scopes opaque (a nested def is a value, not control flow);
  * **a forward dataflow solver** (:class:`ForwardAnalysis`) — join =
    key-wise set union, monotone transfer, worklist to fixpoint, then one
    reporting pass over every statement with its inflowing environment;
  * **abstract evaluators** — :meth:`ForwardAnalysis.transfer` delegates
    to rule-specific expression evaluation: physical *dimensions* inferred
    from the ``_ns``/``_pj``/``_bytes``/``_prob`` suffix convention
    (``backend.base`` invariant I5), *seed taint* for RNG provenance
    (I6), and *pending-ticket* sets for the flush-before-result contract
    (I1);
  * **call-graph summaries** (:class:`ProjectIndex`) — every function in
    ``src/repro`` indexed by bare name, with lazily-computed, memoized,
    cycle-guarded summaries: return dimension, returns-seeded, may-flush
    and leaves-pending.  Rules resolve a call through the module being
    linted first (so fixtures stay self-contained), then project-wide.

Soundness posture: the engine is tuned to *prove* the repo's real idioms
clean rather than to maximize findings.  Multiplication and division
yield an unknown dimension (unit conversions like ``t_start_ms * MS_NS``
and rates like ``bytes / seconds`` are legitimate), only the addition,
subtraction or comparison of two *known, disjoint* dimensions is a
finding; any literal or seed-named contribution to an RNG's entropy mix
counts as seeded (the repo's entropy-list idiom mixes a declared seed
with op indices); a single outstanding ticket auto-flushed by its own
``.result()`` is the documented immediate mode, only a multi-command
implicit flush is flagged.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Callable, Iterator

from .contracts import ParsedModule, callee_name, parse_module

# ------------------------------------------------------------------ suffixes
#: dimension suffixes of the repo-wide naming convention (backend.base I5)
DIMENSIONS = ("ns", "pj", "bytes", "prob")

_DIM_RE = re.compile(r"(?:^|_)(ns|pj|bytes|prob|probs)$", re.IGNORECASE)
_SEED_RE = re.compile(r"(?:^|_)(seed|seeds|entropy)(?:_|$)", re.IGNORECASE)

#: names whose value passes its arguments' dimension through unchanged
_DIM_PASSTHROUGH = frozenset({
    "min", "max", "sum", "abs", "float", "round", "maximum", "minimum",
})
#: names whose value passes its arguments' seed taint through unchanged
_SEED_PASSTHROUGH = frozenset({
    "int", "abs", "list", "tuple", "array", "asarray", "uint32", "uint64",
    "int32", "int64",
})
#: RNG constructors whose entropy must trace to a declared seed (I6)
RNG_NAMES = frozenset({
    "default_rng", "SeedSequence", "PRNGKey", "Philox", "PCG64", "MT19937",
})
#: syntactic flush spellings (shared with SIM001's historical list)
FLUSH_NAMES = ("flush", "drain", "resolve_burst")

SEEDED = "seeded"


def suffix_dim(name: str | None) -> str | None:
    """Dimension declared by a name's suffix, or None (``pcie_bytes`` ->
    ``bytes``, ``PAGE_BYTES`` -> ``bytes``, ``zipf_probs`` -> ``prob``)."""
    if not name:
        return None
    m = _DIM_RE.search(name)
    if not m:
        return None
    d = m.group(1).lower()
    return "prob" if d == "probs" else d


def is_seed_name(name: str | None) -> bool:
    return bool(name) and bool(_SEED_RE.search(name))


def is_flush_name(name: str | None) -> bool:
    if not name:
        return False
    base = name.lstrip("_")
    return any(base == f or base.startswith(f + "_") for f in FLUSH_NAMES)


# ----------------------------------------------------------------------- CFG
class Test:
    """Branch/loop condition evaluated in a block (no bindings)."""
    __slots__ = ("expr", "lineno")

    def __init__(self, expr: ast.expr):
        self.expr = expr
        self.lineno = getattr(expr, "lineno", 0)


class Bind:
    """A ``for target in iter`` header: binds target from iter's elements."""
    __slots__ = ("target", "iter", "lineno")

    def __init__(self, node: ast.For):
        self.target = node.target
        self.iter = node.iter
        self.lineno = node.lineno


@dataclasses.dataclass
class Block:
    idx: int
    stmts: list
    succs: list[int]


@dataclasses.dataclass
class CFG:
    blocks: list[Block]
    entry: int = 0

    def stmt_count(self) -> int:
        return sum(len(b.stmts) for b in self.blocks)


_SCOPE_STMTS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def build_cfg(fn: ast.FunctionDef) -> CFG:
    """Statement-level CFG of one function body.

    Compound statements decompose into blocks and edges (if/else join,
    loop back edge + exit edge, try body/handler/finally approximation);
    ``break``/``continue``/``return``/``raise`` terminate their block.
    Nested defs/classes stay opaque single statements in their block.
    """
    blocks: list[Block] = [Block(0, [], [])]

    def new_block() -> Block:
        b = Block(len(blocks), [], [])
        blocks.append(b)
        return b

    def edge(a: Block, b: Block) -> None:
        if b.idx not in a.succs:
            a.succs.append(b.idx)

    loop_stack: list[tuple[Block, Block]] = []   # (header, after)

    def seq(stmts, cur: Block | None) -> Block | None:
        for st in stmts:
            if cur is None:                      # unreachable tail
                cur = new_block()
            if isinstance(st, ast.If):
                cur.stmts.append(Test(st.test))
                body_in = new_block()
                edge(cur, body_in)
                body_out = seq(st.body, body_in)
                if st.orelse:
                    else_in = new_block()
                    edge(cur, else_in)
                    else_out = seq(st.orelse, else_in)
                else:
                    else_out = cur
                outs = [b for b in (body_out, else_out) if b is not None]
                if not outs:
                    cur = None
                else:
                    after = new_block()
                    for b in outs:
                        edge(b, after)
                    cur = after
            elif isinstance(st, (ast.While, ast.For, ast.AsyncFor)):
                head = new_block()
                edge(cur, head)
                head.stmts.append(Test(st.test) if isinstance(st, ast.While)
                                  else Bind(st))
                body_in = new_block()
                after = new_block()
                edge(head, body_in)
                edge(head, after)
                loop_stack.append((head, after))
                body_out = seq(st.body, body_in)
                loop_stack.pop()
                if body_out is not None:
                    edge(body_out, head)         # back edge
                cur = seq(st.orelse, after) if st.orelse else after
            elif isinstance(st, ast.Try):
                body_in = new_block()
                edge(cur, body_in)
                body_out = seq(st.body, body_in)
                if body_out is not None and st.orelse:
                    body_out = seq(st.orelse, body_out)
                outs = [body_out] if body_out is not None else []
                for h in st.handlers:
                    h_in = new_block()
                    edge(cur, h_in)              # exception may skip the body
                    if body_out is not None:
                        edge(body_out, h_in)     # or strike mid-body
                    h_out = seq(h.body, h_in)
                    if h_out is not None:
                        outs.append(h_out)
                if st.finalbody:
                    fin = new_block()
                    for o in outs:
                        edge(o, fin)
                    if not outs:
                        edge(cur, fin)           # finally always runs
                    cur = seq(st.finalbody, fin)
                elif not outs:
                    cur = None
                else:
                    after = new_block()
                    for o in outs:
                        edge(o, after)
                    cur = after
            elif isinstance(st, (ast.With, ast.AsyncWith)):
                cur.stmts.append(st)             # transfer binds the items
                cur = seq(st.body, cur)
            elif isinstance(st, (ast.Return, ast.Raise)):
                cur.stmts.append(st)
                cur = None
            elif isinstance(st, ast.Break):
                if loop_stack:
                    edge(cur, loop_stack[-1][1])
                cur = None
            elif isinstance(st, ast.Continue):
                if loop_stack:
                    edge(cur, loop_stack[-1][0])
                cur = None
            else:
                cur.stmts.append(st)
        return cur

    seq(fn.body, blocks[0])
    return CFG(blocks)


def calls_in(node: ast.AST) -> Iterator[ast.Call]:
    """Own-scope calls of a statement/expression in evaluation (post)order:
    a chained ``submit(...).result()`` yields the submit first.  Descends
    comprehensions (inline execution), not nested defs/lambdas."""
    def visit(n):
        if isinstance(n, _SCOPE_STMTS + (ast.Lambda,)):
            return
        for child in ast.iter_child_nodes(n):
            yield from visit(child)
        if isinstance(n, ast.Call):
            yield n
    if isinstance(node, Test):
        roots = [node.expr]
    elif isinstance(node, Bind):
        roots = [node.iter]
    elif isinstance(node, (ast.With, ast.AsyncWith)):
        # the body statements live in their own CFG block entries already
        roots = [item.context_expr for item in node.items]
    else:
        roots = [node]
    for r in roots:
        yield from visit(r)


def looped_call_ids(fn: ast.FunctionDef) -> set[int]:
    """``id()`` of every own-scope Call that can execute more than once per
    function entry: inside a loop body or a comprehension."""
    out: set[int] = set()

    def visit(n, in_loop: bool):
        if isinstance(n, _SCOPE_STMTS + (ast.Lambda,)) and n is not fn:
            return
        entering = in_loop or isinstance(
            n, (ast.For, ast.AsyncFor, ast.While, ast.ListComp, ast.SetComp,
                ast.DictComp, ast.GeneratorExp))
        if isinstance(n, ast.Call) and in_loop:
            out.add(id(n))
        for child in ast.iter_child_nodes(n):
            visit(child, entering)
    visit(fn, False)
    return out


# -------------------------------------------------------------------- solver
def join_envs(a: dict | None, b: dict) -> dict:
    if a is None:
        return dict(b)
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, frozenset()) | v
    return out


class ForwardAnalysis:
    """Worklist fixpoint over a CFG; subclass provides ``transfer``.

    Environments are ``dict[str, frozenset]`` (join = key-wise union, a
    finite lattice, so the fixpoint terminates).  ``run()`` solves block
    in-environments with reporting off, then makes one reporting pass so
    each check fires exactly once per program point.
    """

    def __init__(self, cfg: CFG):
        self.cfg = cfg
        self.reporting = False
        self.report: Callable[[str, ast.AST, str], None] | None = None

    def init_env(self) -> dict:
        return {}

    def transfer(self, st, env: dict) -> dict:     # pragma: no cover
        raise NotImplementedError

    def run(self, report=None) -> None:
        ins: dict[int, dict] = {self.cfg.entry: self.init_env()}
        work = [self.cfg.entry]
        while work:
            i = work.pop(0)
            env = dict(ins[i])
            for st in self.cfg.blocks[i].stmts:
                env = self.transfer(st, env)
            for s in self.cfg.blocks[i].succs:
                joined = join_envs(ins.get(s), env)
                if ins.get(s) != joined:
                    ins[s] = joined
                    if s not in work:
                        work.append(s)
        self.report = report
        self.reporting = True
        for b in self.cfg.blocks:
            env = dict(ins.get(b.idx) or self.init_env())
            for st in b.stmts:
                env = self.transfer(st, env)
            self.block_end(b, env)
        self.reporting = False

    def block_end(self, block: Block, env: dict) -> None:
        """Hook: called with each block's out-environment during the
        reporting pass (exit-state summaries hang off this)."""


# ------------------------------------------------------------- project index
@dataclasses.dataclass
class FunctionInfo:
    module: ParsedModule
    qualname: str
    name: str
    node: ast.FunctionDef
    is_method: bool
    params: list[str]
    # memoized summaries (None = not yet computed)
    _return_dims: frozenset | None = None
    _returns_seeded: bool | None = None
    _may_flush: bool | None = None
    _leaves_pending: bool | None = None

    def call_params(self, call: ast.Call) -> list[str]:
        """Parameter names as seen by this call form (``self`` dropped for
        attribute-form method calls)."""
        if self.is_method and isinstance(call.func, ast.Attribute) \
                and self.params:
            return self.params[1:]
        return self.params

    def map_args(self, call: ast.Call) -> list[tuple[str, ast.expr]]:
        params = self.call_params(call)
        pairs: list[tuple[str, ast.expr]] = []
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred) or i >= len(params):
                break
            pairs.append((params[i], arg))
        for kw in call.keywords:
            if kw.arg:
                pairs.append((kw.arg, kw.value))
        return pairs


def _index_functions(mod: ParsedModule) -> list[FunctionInfo]:
    out: list[FunctionInfo] = []

    def visit(node, prefix: str, in_class: bool):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                a = child.args
                params = [x.arg for x in (*a.posonlyargs, *a.args)]
                out.append(FunctionInfo(mod, q, child.name, child,
                                        in_class, params))
                visit(child, f"{q}.", False)
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.", True)
            else:
                visit(child, prefix, in_class)
    visit(mod.tree, "", False)
    return out


class ProjectIndex:
    """Bare-name function index + lazy call-graph summaries.

    Built once per process over ``src/repro`` (the analysis package knows
    where it lives); :meth:`with_module` overlays the module currently
    being linted so fixture files resolve their own helpers first.
    """

    _cached: "ProjectIndex | None" = None

    def __init__(self, modules: list[ParsedModule]):
        # Lazy summaries recurse through the call graph, and each summary
        # level costs a few dozen interpreter frames (solver + evaluator);
        # a 30-call chain overflows CPython's default 1000-frame limit.
        sys.setrecursionlimit(max(sys.getrecursionlimit(), 10_000))
        self.modules = modules
        self.by_name: dict[str, list[FunctionInfo]] = {}
        self.by_module: dict[str, list[FunctionInfo]] = {}
        for mod in modules:
            infos = _index_functions(mod)
            self.by_module[mod.real_path] = infos
            for fi in infos:
                self.by_name.setdefault(fi.name, []).append(fi)
        self._guard: set[int] = set()   # cycle guard for lazy summaries
        # out-of-project modules (fixtures, benchmarks) indexed on demand;
        # cached by path so FunctionInfo identity — which memoization and
        # the cycle guard key on — is stable across summary requests
        self._extra: dict[str, list[FunctionInfo]] = {}

    @classmethod
    def get(cls) -> "ProjectIndex":
        if cls._cached is None:
            pkg_root = Path(__file__).resolve().parents[1]   # src/repro
            repo_root = pkg_root.parents[1]
            mods = []
            for p in sorted(pkg_root.rglob("*.py")):
                try:
                    mods.append(parse_module(p, repo_root))
                except SyntaxError:       # pragma: no cover
                    continue
            cls._cached = cls(mods)
        return cls._cached

    def with_module(self, mod: ParsedModule) -> "ModuleView":
        return ModuleView(self, mod)

    # ------------------------------------------------------------ summaries
    def _guarded(self, fi: FunctionInfo, attr: str, default,
                 compute) -> object:
        cached = getattr(fi, attr)
        if cached is not None:
            return cached
        if id(fi) in self._guard:
            return default                 # recursion: bottom of the lattice
        self._guard.add(id(fi))
        try:
            value = compute(fi)
        finally:
            self._guard.discard(id(fi))
        setattr(fi, attr, value)
        return value

    def return_dims(self, fi: FunctionInfo) -> frozenset:
        from .rules.sim007_units import function_return_dims
        return self._guarded(fi, "_return_dims", frozenset(),
                             function_return_dims)

    def returns_seeded(self, fi: FunctionInfo) -> bool:
        from .rules.sim008_seeds import function_returns_seeded
        return self._guarded(fi, "_returns_seeded", False,
                             function_returns_seeded)

    def may_flush(self, fi: FunctionInfo) -> bool:
        return self._guarded(fi, "_may_flush", False, self._compute_flush)

    def leaves_pending(self, fi: FunctionInfo) -> bool:
        from .rules.sim009_lifecycle import function_leaves_pending
        return self._guarded(fi, "_leaves_pending", False,
                             function_leaves_pending)

    def _compute_flush(self, fi: FunctionInfo) -> bool:
        """A function may flush if it (transitively) calls a flush-named
        callee.  ``.result()`` deliberately does NOT count: resolving
        through the auto-flush is exactly what SIM009 polices, so routing
        a flush summary through ``result`` would launder the violation."""
        view = self.with_module(fi.module)
        for call in calls_in_function(fi.node):
            name = callee_name(call)
            if is_flush_name(name):
                return True
            if name == "result":
                continue
            matches = view.resolve(name)
            if matches and any(self.may_flush(m) for m in matches
                               if m is not fi):
                return True
        return False


class ModuleView:
    """Name resolution preferring the module under analysis."""

    def __init__(self, index: ProjectIndex, mod: ParsedModule):
        self.index = index
        self.mod = mod
        if mod.real_path in index.by_module:
            self._local = index.by_module[mod.real_path]
        elif mod.real_path in index._extra:
            self._local = index._extra[mod.real_path]
        else:
            self._local = index._extra.setdefault(mod.real_path,
                                                  _index_functions(mod))

    def resolve(self, name: str | None) -> list[FunctionInfo]:
        if not name:
            return []
        local = [fi for fi in self._local if fi.name == name]
        if local:
            return local
        return self.index.by_name.get(name, [])

    def resolve_unique(self, name: str | None) -> FunctionInfo | None:
        matches = self.resolve(name)
        return matches[0] if len(matches) == 1 else None

    def call_sites(self, fi: FunctionInfo) -> list[tuple[FunctionInfo,
                                                         ast.Call]]:
        """Every (caller, call) whose callee bare name is ``fi.name``,
        across the module under analysis and the whole project."""
        sites: list[tuple[FunctionInfo, ast.Call]] = []
        seen: set[str] = set()
        pools = [self._local]
        for path, infos in self.index.by_module.items():
            if infos is not self._local:
                pools.append(infos)
        for infos in pools:
            for caller in infos:
                key = f"{caller.module.real_path}:{caller.qualname}"
                if key in seen:
                    continue
                seen.add(key)
                for call in calls_in_function(caller.node):
                    if callee_name(call) == fi.name:
                        sites.append((caller, call))
        return sites

    # convenience passthroughs
    def return_dims(self, fi):
        return self.index.return_dims(fi)

    def returns_seeded(self, fi):
        return self.index.returns_seeded(fi)

    def may_flush(self, fi):
        return self.index.may_flush(fi)

    def leaves_pending(self, fi):
        return self.index.leaves_pending(fi)


def calls_in_function(fn: ast.FunctionDef) -> Iterator[ast.Call]:
    """Own-scope calls of a whole function body, evaluation order per
    statement (comprehensions descended, nested scopes not)."""
    for st in fn.body:
        yield from _calls_in_stmt(st, fn)


def _calls_in_stmt(st, fn) -> Iterator[ast.Call]:
    def visit(n):
        if isinstance(n, _SCOPE_STMTS + (ast.Lambda,)) and n is not st:
            return
        for child in ast.iter_child_nodes(n):
            yield from visit(child)
        if isinstance(n, ast.Call):
            yield n
    if isinstance(st, _SCOPE_STMTS):
        return
    yield from visit(st)
