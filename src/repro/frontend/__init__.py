"""Workload frontend: RunConfig in, RunReport out, serial or event-driven.

The public API of workload execution:

  * :class:`RunConfig`   — one validated, frozen knob surface (presets:
    ``eager()``, ``buffered()``, ``reliable()``, ``open_loop()``,
    ``event_serial()``);
  * :func:`replay`       — execute a workload's op stream against a
    MatchBackend, serially or through the event-loop simulator;
  * :class:`RunReport`   — the one result schema (nested ``latency`` /
    ``energy`` / ``counters`` / ``reliability`` sections) shared with the
    analytic simulator's ``workload.runner.run``.

:func:`replay` is the one functional entry point (the historical
``workload.runner.run_functional`` shim has been removed).
"""
from .config import ARRIVALS, MODES, SCHEDULERS, RunConfig
from .eventloop import EventLoop, Request
from .replay import ReplayCore, replay
from .report import (CounterReport, EnergyReport, LatencyReport,
                     ReliabilityReport, RunReport)
from .scheduler import (FairShareScheduler, FifoScheduler,
                        ReadPriorityScheduler, make_scheduler)

__all__ = [
    "ARRIVALS", "MODES", "SCHEDULERS", "RunConfig",
    "EventLoop", "Request",
    "ReplayCore", "replay",
    "CounterReport", "EnergyReport", "LatencyReport",
    "ReliabilityReport", "RunReport",
    "FairShareScheduler", "FifoScheduler", "ReadPriorityScheduler",
    "make_scheduler",
]
