"""Arrival processes: when each workload op reaches the frontend.

The serial replay has no notion of time — op ``qi`` executes when op
``qi - 1`` finishes.  The event frontend turns the same op stream into
*requests*: op ``qi`` belongs to client stream ``qi % concurrency`` and
arrives at a simulated timestamp drawn from the configured process:

  * ``zero``    — everything arrives at t=0 (closed backlog; with one
                  stream and FIFO this is the serial-parity anchor);
  * ``poisson`` — each stream is an independent Poisson process, the N
                  streams splitting ``arrival_rate_qps`` evenly; stream s
                  draws from ``default_rng([seed, s])`` so runs are
                  deterministic and streams are decorrelated;
  * ``trace``   — explicit per-op times from ``config.arrival_times_ns``
                  (the hypothesis NCQ-bound property and the crafted
                  program-backlog test drive this).

Within a stream, ops keep their workload order only if the times say so —
a trace may interleave arbitrarily; determinism, not ordering, is the
contract here.
"""
from __future__ import annotations

import numpy as np

from .config import RunConfig


def arrival_times(config: RunConfig,
                  n_ops: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-op (arrival_time_ns, stream_id) arrays for one workload."""
    streams = np.arange(n_ops, dtype=np.int64) % config.concurrency
    if config.arrival == "zero":
        return np.zeros(n_ops, dtype=np.float64), streams
    if config.arrival == "trace":
        times = np.asarray(config.arrival_times_ns, dtype=np.float64)
        if len(times) != n_ops:
            raise ValueError(
                f"arrival_times_ns has {len(times)} entries for "
                f"{n_ops} workload ops")
        return times, streams
    # Poisson: exponential inter-arrivals per stream, offered load split
    # evenly so the aggregate process is Poisson(arrival_rate_qps).
    mean_ns = 1e9 * config.concurrency / config.arrival_rate_qps
    times = np.zeros(n_ops, dtype=np.float64)
    for s in range(config.concurrency):
        idx = np.nonzero(streams == s)[0]
        if not len(idx):
            continue
        rng = np.random.default_rng([config.seed, s])
        times[idx] = np.cumsum(rng.exponential(mean_ns, size=len(idx)))
    return times, streams
