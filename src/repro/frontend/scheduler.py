"""Scheduler policies: which queued NCQ requests form the next burst.

The event loop asks its scheduler two questions, both answered as an
index into the live NCQ (a list of :class:`repro.frontend.eventloop.
Request`, arrival order) or None:

  * ``pick_read(ncq)``  — the next read to pull into the burst being
    composed (called repeatedly until the burst is full or it returns
    None);
  * ``pick(ncq)``       — the next request to execute when no read is
    selectable (a write or scan barrier op).

Policies differ in selection order and in how their read bursts interact
with the die *program* timelines (``wait_program_lines``):

  ============== ============================== =========================
  policy         read selection                 program contention
  ============== ============================== =========================
  fifo           strict arrival order; a read   read bursts queue BEHIND
                 burst ends at the first        outstanding die programs
                 non-read request               (no suspend)
  read_priority  reads jump the queue (any      reads bypass program
                 position); writes/scans run    lines — program-suspend /
                 only when no read is queued    read-priority dies
  fair_share     read_priority, but reads are   same as read_priority
                 taken round-robin across
                 client streams (per-tenant
                 fair share)
  ============== ============================== =========================

FIFO is the NCQ-as-shipped reference (and the serial-parity policy at
concurrency 1); read_priority is the SiM story — §VI's write buffer turns
programs into background work precisely so reads need not wait on them —
and the latency_sweep CI gate holds its p99 advantage over FIFO under a
write-heavy load.
"""
from __future__ import annotations

from .config import RunConfig

READ, WRITE, SCAN = 0, 1, 2


class FifoScheduler:
    """Strict arrival order; reads wait behind die-program backlog."""

    wait_program_lines = True

    def __init__(self, config: RunConfig):
        pass

    def pick(self, ncq) -> int | None:
        return 0 if ncq else None

    def pick_read(self, ncq) -> int | None:
        return 0 if ncq and ncq[0].kind == READ else None


class ReadPriorityScheduler:
    """Reads jump the queue and program-suspend past die backlogs."""

    wait_program_lines = False

    def __init__(self, config: RunConfig):
        pass

    def pick(self, ncq) -> int | None:
        return 0 if ncq else None

    def pick_read(self, ncq) -> int | None:
        for i, r in enumerate(ncq):
            if r.kind == READ:
                return i
        return None


class FairShareScheduler(ReadPriorityScheduler):
    """Read-priority with per-tenant round-robin read selection."""

    def __init__(self, config: RunConfig):
        self.concurrency = config.concurrency
        self._last = config.concurrency - 1   # so stream 0 serves first

    def pick_read(self, ncq) -> int | None:
        for off in range(1, self.concurrency + 1):
            s = (self._last + off) % self.concurrency
            for i, r in enumerate(ncq):
                if r.kind == READ and r.stream == s:
                    self._last = s
                    return i
        return None


_POLICIES = {
    "fifo": FifoScheduler,
    "read_priority": ReadPriorityScheduler,
    "fair_share": FairShareScheduler,
}


def make_scheduler(config: RunConfig):
    return _POLICIES[config.scheduler](config)
