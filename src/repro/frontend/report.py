"""RunReport: the one result schema of every workload executor.

Before this module there were two overlapping result shapes: the analytic
timing simulator returned ``RunResult`` (latency percentiles, energy,
SSD counters) and the functional replay returned ``FunctionalRunResult``
(bit-exact values, backend counters, and — when timeline-coupled — its own
latency fields under different names).  fig14/fig15 and the regression
gate had to know which executor produced what.  ``RunReport`` unifies
them: one top-level object with nested ``latency`` / ``energy`` /
``counters`` / ``reliability`` sections shared by

  * the analytic simulator (``workload.runner.run`` →
    :meth:`RunReport.from_analytic`),
  * the serial functional replay (``repro.frontend.replay`` with
    ``mode="serial"``), and
  * the event-driven frontend (``mode="event"``), which additionally
    fills the per-request latency distribution and the NCQ/admission
    counters.

The flat attribute names of the two legacy dataclasses remain available
as read-only properties (``report.read_median_ns``,
``report.n_reads``, ...) so pre-RunConfig callers keep working; new code
reads the nested sections.

Runs with the device-fault tier attached (``RunConfig(faults=...)`` or
any robustness knob armed) additionally fill the ``faults`` section — a
:class:`FaultReport` whose counter names are the stable schema the chaos
benchmark emits and the regression gate checks exactly:

  * ``timeouts`` — read bursts that blew their ``deadline_ns``;
  * ``retries`` — NCQ re-admissions of timed-out requests;
  * ``backoff_waits`` — seeded exponential-backoff sleeps taken;
  * ``hedges_won`` — hedged duplicate reads that beat the primary;
  * ``failovers`` — reads served from a replica because the primary
    chip was dead;
  * ``remapped_blocks`` — bad blocks remapped to spare pages after
    program failures;
  * ``degraded_ops`` — ops that fell back to host-side full-page reads
    through the scalar reference path;
  * ``shed_requests`` — arrivals refused with a typed error by the
    overload backpressure;
  * ``replica_programs`` / ``program_failures`` — write-path mirror
    traffic and injected program faults.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _percentile(lats, q: float) -> float:
    if lats is None or len(lats) == 0:
        return 0.0
    return float(np.percentile(np.asarray(lats), q))


@dataclasses.dataclass
class LatencyReport:
    """Simulated-time distribution of one run (ns unless suffixed)."""
    read_p50_ns: float = 0.0
    read_p25_ns: float = 0.0
    read_p75_ns: float = 0.0
    read_p99_ns: float = 0.0
    qps: float = 0.0              # measured throughput, ops/s
    makespan_ns: float = 0.0      # simulated wall time of the measured ops
    # Distributions (None where the executor does not model them):
    read_latencies_ns: np.ndarray | None = None   # per read op
    burst_latencies_ns: np.ndarray | None = None  # per backend flush
    write_latencies_ns: np.ndarray | None = None  # per page program

    @classmethod
    def from_read_latencies(cls, lats, *, makespan_ns: float = 0.0,
                            n_ops: int = 0, **kw) -> "LatencyReport":
        qps = n_ops / (makespan_ns / 1e9) if makespan_ns > 0 else 0.0
        return cls(read_p50_ns=_percentile(lats, 50),
                   read_p25_ns=_percentile(lats, 25),
                   read_p75_ns=_percentile(lats, 75),
                   read_p99_ns=_percentile(lats, 99),
                   qps=qps, makespan_ns=makespan_ns,
                   read_latencies_ns=(np.asarray(lats, dtype=np.float64)
                                      if lats is not None and len(lats)
                                      else None), **kw)


@dataclasses.dataclass
class EnergyReport:
    """NAND-side energy account (paper Fig 13 discipline)."""
    total_pj: float = 0.0


@dataclasses.dataclass
class CounterReport:
    """Exact op/resource counters; every field is machine-independent."""
    # op stream
    reads: int = 0
    writes: int = 0
    scans: int = 0
    # functional backend traffic
    flushes: int = 0             # backend flushes issued by the executor
    kernel_launches: int = 0     # device launches (0 on scalar)
    staged_bytes: int = 0        # host->device page bytes
    result_bytes: int = 0        # exact device->host result payload bytes
    programs: int = 0            # page programs issued
    write_flushes: int = 0       # write-buffer group flushes
    buffer_read_hits: int = 0    # reads served from the DRAM overlay
    # analytic-simulator resources
    senses: int = 0
    internal_bytes: int = 0
    pcie_bytes: int = 0
    batched_searches: int = 0
    cache_hit_rate: float = 0.0
    absorbed_writes: int = 0
    # event frontend
    events: int = 0              # events processed by the loop
    dispatches: int = 0          # device dispatches (bursts + barrier ops)
    admitted: int = 0            # requests admitted straight into the NCQ
    admission_waits: int = 0     # arrivals held at the NCQ high-water mark
    ncq_peak: int = 0            # max queued+inflight ever observed


@dataclasses.dataclass
class ReliabilityReport:
    """Per-op outcomes of the §IV-C tier (empty when not attached)."""
    read_errors: np.ndarray | None = None   # (N,) bool typed-error flags
    n_read_errors: int = 0
    refreshes: int = 0                      # stale pages rewritten at drain
    stats: object | None = None             # ReliabilityStats snapshot


@dataclasses.dataclass
class FaultReport:
    """Device-fault tier outcomes (all zero when the tier is off).

    Counter names are a stable schema — see the module docstring; the
    chaos-sweep benchmark emits them verbatim and the regression gate
    compares them exactly.
    """
    timeouts: int = 0            # read bursts past deadline_ns
    retries: int = 0             # NCQ re-admissions after timeout
    backoff_waits: int = 0       # exponential-backoff sleeps taken
    hedges_won: int = 0          # hedged duplicate reads that won
    failovers: int = 0           # replica reads after primary-chip death
    remapped_blocks: int = 0     # bad blocks remapped to spare pages
    degraded_ops: int = 0        # host-side scalar-path degraded ops
    shed_requests: int = 0       # arrivals refused by backpressure
    replica_programs: int = 0    # replica mirror programs issued
    program_failures: int = 0    # injected program faults observed
    op_errors: np.ndarray | None = None   # (N,) bool typed-error flags
    n_op_errors: int = 0


@dataclasses.dataclass
class RunReport:
    """One run, one shape — analytic, serial replay, or event-driven."""
    source: str = "serial"       # "analytic" | "serial" | "event"
    latency: LatencyReport = dataclasses.field(default_factory=LatencyReport)
    energy: EnergyReport = dataclasses.field(default_factory=EnergyReport)
    counters: CounterReport = dataclasses.field(
        default_factory=CounterReport)
    reliability: ReliabilityReport = dataclasses.field(
        default_factory=ReliabilityReport)
    faults: FaultReport = dataclasses.field(default_factory=FaultReport)
    # Functional replays only: bit-exact per-op outputs.
    read_values: np.ndarray | None = None   # (N,) uint64, 0 where no hit
    read_hits: np.ndarray | None = None     # (N,) bool
    scan_counts: np.ndarray | None = None   # (N,) int64, 0 off-scan ops
    # Event frontend only (config.record_trace): (t_ns, kind, qi) tuples.
    trace: tuple = ()

    # ----------------------------------------------------------- builders
    @classmethod
    def from_analytic(cls, *, qps, read_median_ns, read_p25_ns, read_p75_ns,
                      read_p99_ns, energy_pj, programs, senses,
                      internal_bytes, pcie_bytes, cache_hit_rate,
                      absorbed_writes, batched_searches, makespan_ns,
                      writes=0, scans=0, reads=0) -> "RunReport":
        """Package the closed-form simulator's measurement window."""
        return cls(
            source="analytic",
            latency=LatencyReport(
                read_p50_ns=read_median_ns, read_p25_ns=read_p25_ns,
                read_p75_ns=read_p75_ns, read_p99_ns=read_p99_ns,
                qps=qps, makespan_ns=makespan_ns),
            energy=EnergyReport(total_pj=energy_pj),
            counters=CounterReport(
                reads=reads, writes=writes, scans=scans, programs=programs,
                senses=senses, internal_bytes=internal_bytes,
                pcie_bytes=pcie_bytes, cache_hit_rate=cache_hit_rate,
                absorbed_writes=absorbed_writes,
                batched_searches=batched_searches))

    # ------------------------------------------------- legacy flat aliases
    # FunctionalRunResult names.
    @property
    def n_reads(self) -> int:
        return self.counters.reads

    @property
    def n_writes(self) -> int:
        return self.counters.writes

    @property
    def n_scans(self) -> int:
        return self.counters.scans

    @property
    def flushes(self) -> int:
        return self.counters.flushes

    @property
    def kernel_launches(self) -> int:
        return self.counters.kernel_launches

    @property
    def staged_bytes(self) -> int:
        return self.counters.staged_bytes

    @property
    def result_bytes(self) -> int:
        return self.counters.result_bytes

    @property
    def programs(self) -> int:
        return self.counters.programs

    @property
    def write_flushes(self) -> int:
        return self.counters.write_flushes

    @property
    def buffer_read_hits(self) -> int:
        return self.counters.buffer_read_hits

    @property
    def burst_latencies_ns(self):
        return self.latency.burst_latencies_ns

    @property
    def write_latencies_ns(self):
        return self.latency.write_latencies_ns

    @property
    def sim_makespan_ns(self) -> float:
        return self.latency.makespan_ns

    @property
    def sim_energy_pj(self) -> float:
        return self.energy.total_pj

    @property
    def read_errors(self):
        return self.reliability.read_errors

    @property
    def n_read_errors(self) -> int:
        return self.reliability.n_read_errors

    @property
    def refreshes(self) -> int:
        return self.reliability.refreshes

    @property
    def reliability_stats(self):
        return self.reliability.stats

    # RunResult (analytic) names.
    @property
    def qps(self) -> float:
        return self.latency.qps

    @property
    def read_median_ns(self) -> float:
        return self.latency.read_p50_ns

    @property
    def read_p25_ns(self) -> float:
        return self.latency.read_p25_ns

    @property
    def read_p75_ns(self) -> float:
        return self.latency.read_p75_ns

    @property
    def read_p99_ns(self) -> float:
        return self.latency.read_p99_ns

    @property
    def energy_pj(self) -> float:
        return self.energy.total_pj

    @property
    def senses(self) -> int:
        return self.counters.senses

    @property
    def internal_bytes(self) -> int:
        return self.counters.internal_bytes

    @property
    def pcie_bytes(self) -> int:
        return self.counters.pcie_bytes

    @property
    def cache_hit_rate(self) -> float:
        return self.counters.cache_hit_rate

    @property
    def absorbed_writes(self) -> int:
        return self.counters.absorbed_writes

    @property
    def batched_searches(self) -> int:
        return self.counters.batched_searches

    @property
    def makespan_ns(self) -> float:
        return self.latency.makespan_ns

    @property
    def writes(self) -> int:
        return self.counters.writes

    @property
    def scans(self) -> int:
        return self.counters.scans
