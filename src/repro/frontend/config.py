"""RunConfig: the one validated knob surface of the workload frontend.

``run_functional`` grew one keyword per PR (``burst``, ``fused``,
``write_buffer``, ``write_high_water``, ``reliability``) and the
event-driven frontend adds arrival processes, scheduler policies and NCQ
admission on top — a combinatorial kwarg sprawl no caller could validate.
This module collapses all of it into one frozen dataclass:

  * **execution mode** — ``mode="serial"`` is the classic synchronous
    replay (one client, a barrier per burst); ``mode="event"`` drives the
    same functional core through the event-loop simulator
    (:mod:`repro.frontend.eventloop`) with N concurrent client streams,
    a bounded NCQ and a scheduler policy;
  * **burst shaping** — ``burst`` (max reads coalesced per backend
    flush), ``fused`` (one fused lookup launch vs split search+gather);
  * **write path** — ``write_buffer``/``write_high_water`` (the §VI DRAM
    coalescing buffer with deferred grouped programs);
  * **reliability tier** — ``reliability=ReliabilityState(...)``;
  * **event frontend** — ``concurrency`` client streams, ``arrival``
    process (``zero``/``poisson``/``trace``), ``scheduler`` policy
    (``fifo``/``read_priority``/``fair_share``), ``ncq_depth`` bound and
    the per-stream ``seed``;
  * **fault tolerance** — ``faults`` (a seeded
    :class:`repro.reliability.FaultSchedule` of die/channel stalls, chip
    outages and program failures), per-command ``deadline_ns`` with
    ``max_retries`` bounded seeded-backoff re-admissions
    (``backoff_base_ns``), hedged reads after a ``hedge_quantile`` burst
    latency, and ``shed_capacity`` overload backpressure (arrivals beyond
    NCQ + shed_capacity complete with a typed error instead of queueing
    unboundedly).

Every combination is validated at construction (`__post_init__`), so a
config that constructs is a config that runs.  Named presets cover the
common shapes: ``RunConfig.eager()``, ``.buffered()``, ``.reliable()``,
``.open_loop()``, ``.event_serial()`` (the bit-parity anchor: event
mode degenerated to one stream, zero inter-arrival, FIFO — must replay
bit-identically to ``mode="serial"``) and ``.chaos()`` (event mode with
a fault schedule plus deadline/retry armed).
"""
from __future__ import annotations

import dataclasses
import typing

MODES = ("serial", "event")
ARRIVALS = ("zero", "poisson", "trace")
SCHEDULERS = ("fifo", "read_priority", "fair_share")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Validated, immutable configuration of one workload replay."""

    # --- execution mode
    mode: str = "serial"
    # --- backend burst shaping
    burst: int = 64
    fused: bool = False
    # --- write path (§VI DRAM write buffer)
    write_buffer: typing.Any = False     # bool | repro.buffer.WriteBuffer
    write_high_water: int = 16
    # --- reliability tier (repro.reliability.ReliabilityState | None)
    reliability: typing.Any = None
    # --- event frontend: arrivals
    concurrency: int = 1                 # concurrent client streams
    arrival: str = "zero"                # zero | poisson | trace
    arrival_rate_qps: float | None = None    # poisson: offered load, ops/s
    arrival_times_ns: tuple | None = None    # trace: explicit times (N,)
    # --- event frontend: queueing
    scheduler: str = "fifo"              # fifo | read_priority | fair_share
    ncq_depth: int = 64                  # bounded native command queue
    seed: int = 0                        # arrival-process seed root
    record_trace: bool = False           # keep the full event trace
    # --- fault tolerance (repro.reliability.FaultSchedule | None)
    faults: typing.Any = None
    deadline_ns: float | None = None     # per-read deadline (event mode)
    max_retries: int = 2                 # re-admissions before typed error
    backoff_base_ns: float = 50_000.0    # exp backoff base (seeded jitter)
    hedge_quantile: float | None = None  # hedge reads past this burst-lat q
    shed_capacity: int | None = None     # overflow slots before shedding

    # ------------------------------------------------------------ checks
    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.arrival not in ARRIVALS:
            raise ValueError(f"arrival {self.arrival!r} not in {ARRIVALS}")
        if self.scheduler not in SCHEDULERS:
            raise ValueError(
                f"scheduler {self.scheduler!r} not in {SCHEDULERS}")
        for field in ("burst", "write_high_water", "concurrency",
                      "ncq_depth"):
            v = getattr(self, field)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{field} must be an int >= 1, got {v!r}")
        if self.arrival == "poisson":
            if self.mode != "event":
                raise ValueError("poisson arrivals need mode='event'")
            if not self.arrival_rate_qps or self.arrival_rate_qps <= 0:
                raise ValueError("poisson arrivals need "
                                 f"arrival_rate_qps > 0, got "
                                 f"{self.arrival_rate_qps!r}")
        elif self.arrival_rate_qps is not None:
            raise ValueError(f"arrival_rate_qps only applies to "
                             f"arrival='poisson', not {self.arrival!r}")
        if self.arrival == "trace":
            if self.mode != "event":
                raise ValueError("trace arrivals need mode='event'")
            if self.arrival_times_ns is None:
                raise ValueError("trace arrivals need arrival_times_ns")
            object.__setattr__(self, "arrival_times_ns",
                               tuple(float(t) for t in
                                     self.arrival_times_ns))
            if any(t < 0 for t in self.arrival_times_ns):
                raise ValueError("arrival_times_ns must be >= 0")
        elif self.arrival_times_ns is not None:
            raise ValueError("arrival_times_ns only applies to "
                             f"arrival='trace', not {self.arrival!r}")
        if self.mode == "serial":
            # Event-only knobs left at non-defaults would silently not
            # apply — refuse instead.  (``faults`` IS allowed in serial
            # mode: outages/remaps act on the backend flush path; only the
            # queueing-time machinery needs the event loop.)
            for field, default in (("concurrency", 1),
                                   ("arrival", "zero"),
                                   ("scheduler", "fifo"),
                                   ("deadline_ns", None),
                                   ("hedge_quantile", None),
                                   ("shed_capacity", None)):
                if getattr(self, field) != default:
                    raise ValueError(
                        f"{field}={getattr(self, field)!r} needs "
                        "mode='event' (the serial replay has no queue)")
        if self.deadline_ns is not None and self.deadline_ns <= 0:
            raise ValueError(f"deadline_ns must be > 0, got "
                             f"{self.deadline_ns!r}")
        if not isinstance(self.max_retries, int) or self.max_retries < 0:
            raise ValueError(f"max_retries must be an int >= 0, got "
                             f"{self.max_retries!r}")
        if self.backoff_base_ns <= 0:
            raise ValueError(f"backoff_base_ns must be > 0, got "
                             f"{self.backoff_base_ns!r}")
        if self.hedge_quantile is not None and not (
                0.0 < self.hedge_quantile < 1.0):
            raise ValueError(f"hedge_quantile must be in (0, 1), got "
                             f"{self.hedge_quantile!r}")
        if self.shed_capacity is not None and (
                not isinstance(self.shed_capacity, int)
                or self.shed_capacity < 0):
            raise ValueError(f"shed_capacity must be an int >= 0, got "
                             f"{self.shed_capacity!r}")
        if self.faults is not None:
            from repro.reliability import FaultSchedule
            if not isinstance(self.faults, FaultSchedule):
                raise ValueError(f"faults must be a FaultSchedule, got "
                                 f"{self.faults!r}")
        if not isinstance(self.write_buffer, bool):
            from repro.buffer.writebuffer import WriteBuffer
            if not isinstance(self.write_buffer, WriteBuffer):
                raise ValueError("write_buffer must be a bool or a "
                                 f"WriteBuffer, got {self.write_buffer!r}")

    # ------------------------------------------------------------ presets
    @classmethod
    def eager(cls, **kw) -> "RunConfig":
        """Serial replay, eager per-write programs — the bit-exactness
        reference every other configuration is held to."""
        return cls(**kw)

    @classmethod
    def buffered(cls, *, write_high_water: int = 16, **kw) -> "RunConfig":
        """Serial replay through the §VI DRAM write buffer: hot-page
        coalescing, grouped deferred programs, overlay reads."""
        return cls(write_buffer=True, write_high_water=write_high_water,
                   **kw)

    @classmethod
    def reliable(cls, reliability, **kw) -> "RunConfig":
        """Serial replay with the §IV-C reliability tier attached."""
        if reliability is None:
            raise ValueError("reliable() needs a ReliabilityState")
        return cls(reliability=reliability, **kw)

    @classmethod
    def open_loop(cls, arrival_rate_qps: float, *, concurrency: int = 16,
                  scheduler: str = "read_priority", **kw) -> "RunConfig":
        """Open-loop event-driven run: Poisson arrivals at the offered
        QPS across ``concurrency`` client streams."""
        return cls(mode="event", arrival="poisson",
                   arrival_rate_qps=arrival_rate_qps,
                   concurrency=concurrency, scheduler=scheduler, **kw)

    @classmethod
    def event_serial(cls, **kw) -> "RunConfig":
        """The degenerate event config — one stream, zero inter-arrival,
        FIFO — whose replay must be bit-identical to ``mode='serial'``
        (tests/test_frontend.py holds this across every backend)."""
        return cls(mode="event", arrival="zero", concurrency=1,
                   scheduler="fifo", **kw)

    @classmethod
    def chaos(cls, faults, *, deadline_ns: float = 2_000_000.0,
              max_retries: int = 4, scheduler: str = "read_priority",
              **kw) -> "RunConfig":
        """Event-driven run under a device fault schedule with the
        robustness tier armed: per-read deadlines, bounded seeded-backoff
        retries, read-priority scheduling.  Hedging and shedding stay off
        unless asked for — they change the latency story."""
        if faults is None:
            raise ValueError("chaos() needs a FaultSchedule")
        return cls(mode="event", faults=faults, deadline_ns=deadline_ns,
                   max_retries=max_retries, scheduler=scheduler, **kw)

    # ------------------------------------------------------------- helper
    def with_(self, **kw) -> "RunConfig":
        """A copy with the given fields replaced (re-validated)."""
        return dataclasses.replace(self, **kw)
