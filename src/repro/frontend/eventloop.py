"""Event-loop frontend: NCQ admission, scheduled bursts, async programs.

The serial replay answers "what does the device compute"; this module
answers "when", under contention.  It is a next-event time-advance
simulator in the FTL-simulator shape:

  * **arrivals** — every workload op becomes a timestamped request on one
    of N client streams (:mod:`repro.frontend.arrivals`);
  * **admission** — a bounded NCQ of ``config.ncq_depth`` slots; arrivals
    beyond the bound wait in an overflow queue (``admission_waits``) and
    are admitted as completions free slots — admission wait is part of
    the request's measured latency, which is how saturation shows up in
    the p99 sweeps;
  * **scheduling** — a :mod:`repro.frontend.scheduler` policy composes
    the next device burst from the queued requests: up to ``burst`` reads
    coalesce into one flush (the §IV-E batch), writes and scans dispatch
    as barrier ops;
  * **service** — each burst is charged to this frontend's own
    :class:`repro.flash.timeline.BurstTimeline` (die sense/program lines,
    channel buses, the PCIe link), started at the dispatch event's
    timestamp.  Under FIFO, read bursts additionally queue behind each
    die's outstanding program backlog; read-priority policies
    program-suspend past it — with t_program = 5 x t_read this gap is the
    whole fig15-under-contention story;
  * **background programs** — writes never hold the device: an eager
    program or a §VI write-buffer group flush queues on the die program
    timelines and completes as a later ``prog_done`` event, contending
    with FIFO reads exactly like the deferred backlog it is;
  * **robustness tier** (armed by ``RunConfig`` fault knobs) — read
    bursts carry a per-command ``deadline_ns``; a burst that blows it
    raises a ``read_timeout`` event, and each timed-out request either
    re-admits at the NCQ *head* after a seeded exponential backoff
    (``backoff_base_ns * 2**(attempt-1)`` plus jitter from
    ``default_rng([seed, 0xB0FF, qi, attempt])``) or — past
    ``max_retries`` — completes with a typed ``CommandTimeoutError``
    flag.  ``hedge_quantile`` fires a duplicate (hedged) read once the
    burst's latency exceeds that quantile of prior burst latencies; the
    duplicate's work is charged to the flash timelines, and the request
    finishes at whichever copy wins.  ``shed_capacity`` bounds the
    overflow queue: arrivals beyond NCQ + shed complete immediately with
    a typed ``OverloadShedError`` flag instead of queueing unboundedly.
    Retries re-dispatch for *timing only* — the functional value was
    captured at first dispatch, so a retry can delay a result but never
    change it (zero-wrong-results invariant).  Pages whose primary chip
    is dead at service time are charged as replica ``degraded_reads``
    on the failover chip, mirroring the sharded backend's routing.

The *functional* execution rides the same :class:`ReplayCore` as the
serial driver, invoked in dispatch order — so at
``RunConfig.event_serial()`` (one stream, zero inter-arrival, FIFO) the
backend sees the identical command sequence and the replay is
bit-identical to ``mode="serial"`` (tests/test_frontend.py).

Timing is deliberately backend-independent: the scalar backend gets the
same simulated clock as the sharded one, so load sweeps don't need a
kernel build.  The per-burst resource accounting mirrors the sharded
backend's ChipBurst reports (unique pages -> senses + open-verification
bus bytes; per read -> match + bitmap + chunk payloads; per scan page ->
one fused-plan match + one 64 B bitmap).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.flash.params import (BITMAP_BYTES, CHUNK_BYTES,
                                OPEN_OVERHEAD_BYTES, PAGE_BYTES)
from repro.flash.timeline import BurstTimeline, ChipBurst
from repro.workload.ycsb import Workload

from .arrivals import arrival_times
from .config import RunConfig
from .replay import ReplayCore
from .report import EnergyReport, LatencyReport, RunReport
from .scheduler import READ, make_scheduler

QUERY_BYTES = 16     # (query, mask) uint32 pairs shipped per search


@dataclasses.dataclass
class Request:
    """One workload op as an NCQ entry."""
    qi: int            # op index in the workload stream
    stream: int        # client stream (qi % concurrency)
    kind: int          # op code: 0 read, 1 write, 2 scan
    t_arrive: float    # arrival time, ns (admission wait counts from here)
    attempt: int = 0   # timeout re-admissions so far (robustness tier)
    served: bool = False   # functional value already captured (a retry
                           # re-dispatches for timing only, never re-executes)


class EventLoop:
    """Drives one ReplayCore through arrivals/NCQ/scheduler events."""

    def __init__(self, workload: Workload, backend, config: RunConfig):
        self.core = ReplayCore(workload, backend, config)
        self.config = config
        self.wl = workload
        self.n_chips = len(self.core.backend.chips.chips)
        # The frontend owns its clock: one BurstTimeline sized to the
        # backend's chip count, independent of any backend-attached
        # timeline (which, in event mode, is ignored).
        self.timeline = BurstTimeline.for_chips(self.n_chips)
        self.params = self.timeline.params
        self.sched = make_scheduler(config)
        # Robustness tier: the fault state is owned by the core (shared
        # with a fault-aware backend); this loop schedules its stall
        # windows onto the frontend timeline and fills its counters.
        self.fault_state = self.core.fault_state
        if self.fault_state is not None:
            self.timeline.attach_faults(self.fault_state)

        self.heap: list = []               # (t, seq, kind, payload)
        self._seq = 0
        self.ncq: list[Request] = []
        self.overflow: list[Request] = []
        self.inflight = 0                  # dispatched, not yet completed
        self.busy = False                  # a read/scan burst is in service
        self.n_done = 0
        self.t_last = 0.0
        self.read_lats: list[float] = []
        self.trace: list[tuple] = []
        self.events = self.dispatches = 0
        self.admitted = self.admission_waits = 0
        self.ncq_peak = 0

    # ------------------------------------------------------------ plumbing
    def _push(self, t: float, kind: str, payload) -> None:
        self._seq += 1
        heapq.heappush(self.heap, (t, self._seq, kind, payload))

    def _note(self, t: float, kind: str, qi: int) -> None:
        if self.config.record_trace:
            self.trace.append((t, kind, qi))

    def _depth(self) -> int:
        return len(self.ncq) + self.inflight

    def _note_peak(self) -> None:
        self.ncq_peak = max(self.ncq_peak, self._depth())

    def _admit(self, t: float) -> None:
        while self.overflow and self._depth() < self.config.ncq_depth:
            req = self.overflow.pop(0)
            self.ncq.append(req)
            self._note(t, "admit", req.qi)
            self._note_peak()

    def _complete(self, req: Request, t: float, *,
                  was_inflight: bool = True) -> None:
        if was_inflight:
            self.inflight -= 1
        if req.kind == READ:
            self.read_lats.append(t - req.t_arrive)
        self.n_done += 1
        self._note(t, "complete", req.qi)

    # -------------------------------------------------------------- events
    def _handle(self, t: float, kind: str, payload) -> None:
        if kind == "arrive":
            req: Request = payload
            self._note(t, "arrive", req.qi)
            cap = self.config.shed_capacity
            if self._depth() < self.config.ncq_depth:
                self.ncq.append(req)
                self.admitted += 1
                self._note_peak()
            elif cap is not None and len(self.overflow) >= cap:
                # Overload backpressure: refuse with a typed error rather
                # than queue unboundedly (OverloadShedError semantics).
                self.fault_state.stats.shed_requests += 1
                self.core.op_errors[req.qi] = True
                self.n_done += 1
                self._note(t, "shed", req.qi)
            else:
                self.overflow.append(req)
                self.admission_waits += 1
        elif kind == "read_done":
            for req in payload:
                self._complete(req, t)
            self.busy = False
        elif kind == "read_timeout":
            # The burst blew its deadline: every member either re-admits
            # after a seeded backoff or exhausts into a typed error.  The
            # device itself stays busy until burst_free — the timeout
            # frees the *client*, not the flash resources.
            st = self.fault_state.stats
            for req in payload:
                st.timeouts += 1
                self.inflight -= 1
                if req.attempt >= self.config.max_retries:
                    # CommandTimeoutError semantics: typed per-op error.
                    self.core.op_errors[req.qi] = True
                    self.n_done += 1
                    self._note(t, "timeout_error", req.qi)
                else:
                    req.attempt += 1
                    st.backoff_waits += 1
                    self._push(t + self._backoff_ns(req.qi, req.attempt),
                               "readmit", req)
            self._admit(t)
        elif kind == "burst_free":
            self.busy = False
        elif kind == "readmit":
            # Head re-admission: a retried command beats fresh queue
            # entries to the next burst (it has already waited longest).
            self.fault_state.stats.retries += 1
            self.ncq.insert(0, payload)
            self._note(t, "readmit", payload.qi)
            self._note_peak()
        elif kind == "scan_done":
            self._complete(payload, t)
            self.busy = False
        elif kind == "write_done":
            self._complete(payload, t)
        else:                              # prog_done: background program
            self._note(t, "prog_done", payload)

    # ---------------------------------------------------------- dispatching
    def _pump(self, t: float) -> None:
        """Admit waiting arrivals, then keep the device fed."""
        if self.fault_state is not None:
            self.fault_state.advance(t)    # fault clock follows dispatch
        self._admit(t)
        while not self.busy:
            if self.sched.pick_read(self.ncq) is not None:
                self._issue_reads(t)
                continue
            i = self.sched.pick(self.ncq)
            if i is None:
                return
            req = self.ncq.pop(i)
            if req.kind == 2:
                self._issue_scan(req, t)
            else:
                self._issue_write(req, t)

    def _issue_reads(self, t: float) -> None:
        """Compose and dispatch one read burst.

        Reads are pulled one at a time so an overlay-served read (a DRAM
        hit that never reaches the device) completes immediately, frees
        its NCQ slot, and lets the overflow backfill *within the same
        dispatch* — which is exactly how the serial replay fills bursts
        (overlay reads don't consume burst slots), and what keeps the
        concurrency-1 FIFO replay bit-identical.

        Buffered writes absorb into DRAM without touching the flash
        image, so — exactly as in the serial op loop — they are NOT
        burst barriers: a write the scheduler selects mid-burst executes
        inline and the pull continues.  The exception is a write that
        trips the high-water drain: its group flush reprograms flash, so
        queued reads must resolve first — it ends the burst (and runs
        after the read_done, which the serial ordering permits because
        nothing else can execute in between).
        """
        core, cfg = self.core, self.config
        batch: list[Request] = []
        n_retry = 0                        # re-dispatches (timing only)
        while len(core.pending) + n_retry < cfg.burst:
            i = self.sched.pick_read(self.ncq)
            if i is None:
                if not self._absorb_inline(t):
                    break
                continue
            req = self.ncq.pop(i)
            self._note(t, "dispatch", req.qi)
            if req.served:
                # A retried command: its value was captured at first
                # dispatch (reads are idempotent) — it joins the burst
                # for service timing only, never re-executes.
                batch.append(req)
                self.inflight += 1
                n_retry += 1
            elif core.queue_read(req.qi):
                req.served = True
                batch.append(req)
                self.inflight += 1
            else:
                self._complete(req, t + self.params.dram_hit_ns,
                               was_inflight=False)
                self._admit(t)
        if not batch:
            return
        lat = self.timeline.observe_flush(
            self._read_burst_counts(batch), at=t,
            wait_program_lines=self.sched.wait_program_lines)
        core.resolve_burst()
        self.dispatches += 1
        self.busy = True
        lat = self._maybe_hedge(batch, t, lat)
        deadline = cfg.deadline_ns
        if deadline is not None and lat > deadline:
            self._push(t + deadline, "read_timeout", batch)
            self._push(t + lat, "burst_free", None)
        else:
            self._push(t + lat, "read_done", batch)

    HEDGE_MIN_SAMPLES = 16     # burst-latency history before hedging arms

    def _backoff_ns(self, qi: int, attempt: int) -> float:
        """Exponential backoff with seeded jitter (deterministic per
        (seed, op, attempt) — same run, same waits, byte for byte)."""
        base = self.config.backoff_base_ns
        jitter = float(np.random.default_rng(
            [self.config.seed, 0xB0FF, qi, attempt]).random()) * base
        return base * (2.0 ** (attempt - 1)) + jitter

    def _maybe_hedge(self, batch: list[Request], t: float,
                     lat: float) -> float:
        """Fire a hedged duplicate of a slow burst; return effective lat.

        Once enough burst latencies have been observed, a burst slower
        than the ``hedge_quantile`` of the prior history dispatches a
        duplicate at ``t + hedge_delay``; the duplicate's senses, matches
        and bus bytes are charged to the flash timelines (no free
        recovery) and the batch completes at whichever copy finishes
        first.  ``hedges_won`` counts the duplicates that won.
        """
        q = self.config.hedge_quantile
        if q is None:
            return lat
        hist = self.timeline.burst_latencies
        if len(hist) <= self.HEDGE_MIN_SAMPLES:   # history excludes current
            return lat
        delay = float(np.percentile(np.asarray(hist[:-1]), q * 100.0))
        if lat <= delay:
            return lat
        hedge_lat = self.timeline.observe_flush(
            self._read_burst_counts(batch), at=t + delay,
            wait_program_lines=self.sched.wait_program_lines)
        if delay + hedge_lat < lat:
            self.fault_state.stats.hedges_won += 1
            return delay + hedge_lat
        return lat

    def _absorb_inline(self, t: float) -> bool:
        """Mid-burst: execute the next write inline iff it only absorbs.

        Returns True when a buffered, non-tripping write was consumed
        (the read pull continues); False when the burst must end — no
        write selectable, eager-program mode (a write is a read-your-
        writes barrier there), or the write would trip the high-water
        group drain.
        """
        core = self.core
        if core.wb is None:
            return False
        i = self.sched.pick(self.ncq)
        if i is None or self.ncq[i].kind != 1:
            return False
        qi = self.ncq[i].qi
        if core.wb.would_trip(int(self.wl.value_pages[qi])):
            return False
        self._issue_write(self.ncq.pop(i), t)
        return True

    def _route_chip(self, page: int) -> tuple[int, bool]:
        """Chip serving ``page`` now: the primary, or — primary dead —
        the first live replica chip, mirroring the sharded backend's
        ``(chip + r) % n`` replica striping.  Returns (chip, degraded)."""
        chip = page % self.n_chips
        if self.fault_state is None or not self.fault_state.chip_dead(chip):
            return chip, False
        for r in range(1, getattr(self.core.backend, "replicas", 1)):
            c = (chip + r) % self.n_chips
            if not self.fault_state.chip_dead(c):
                return c, True
        return chip, False     # no live replica: the op fails typed anyway

    def _read_burst_counts(self, batch: list[Request]) -> list[ChipBurst]:
        """Per-chip resource counts of one read burst (see module doc).

        A page whose primary chip is dead charges a full-page degraded
        read on its failover chip (the host-side scalar path moves the
        whole page) instead of in-flash match work.
        """
        bursts: dict[int, ChipBurst] = {}

        def b(chip: int) -> ChipBurst:
            return bursts.setdefault(chip, ChipBurst(chip))

        opened: set[int] = set()
        degraded: set[int] = set()
        for req in batch:
            kp = int(self.wl.key_pages[req.qi])
            vp = int(self.wl.value_pages[req.qi])
            for p in (kp, vp):              # page opens amortize per burst
                if p in opened:
                    continue
                opened.add(p)
                chip, is_degraded = self._route_chip(p)
                cb = b(chip)
                if is_degraded:
                    degraded.add(p)
                    cb.degraded_reads += 1
                    cb.pcie_bytes += PAGE_BYTES
                else:
                    cb.senses += 1
                    cb.bus_match_bytes += OPEN_OVERHEAD_BYTES
            if kp not in degraded:          # degraded pages match host-side
                kb = b(kp % self.n_chips)
                kb.matches += 1
                kb.bus_match_bytes += BITMAP_BYTES
                kb.pcie_bytes += BITMAP_BYTES + QUERY_BYTES
            if vp not in degraded:          # speculative value-page gather
                vb = b(vp % self.n_chips)
                vb.bus_match_bytes += CHUNK_BYTES
                vb.pcie_bytes += CHUNK_BYTES
        return [bursts[c] for c in sorted(bursts)]

    def _issue_scan(self, req: Request, t: float) -> None:
        self._note(t, "dispatch", req.qi)
        self.dispatches += 1
        pages = self.core.scan(req.qi)     # functional execution
        bursts: dict[int, ChipBurst] = {}
        for p in pages:                    # fused plan: one 64 B per page
            cb = bursts.setdefault(p % self.n_chips,
                                   ChipBurst(p % self.n_chips))
            cb.senses += 1
            cb.matches += 1
            cb.bus_match_bytes += BITMAP_BYTES
            cb.pcie_bytes += BITMAP_BYTES
        if bursts:
            lat = self.timeline.observe_flush(
                [bursts[c] for c in sorted(bursts)], at=t,
                wait_program_lines=self.sched.wait_program_lines)
        else:
            lat = self.params.mmio_ns      # empty range: command rtt only
        self.inflight += 1
        self.busy = True
        self._push(t + lat, "scan_done", req)

    def _issue_write(self, req: Request, t: float) -> None:
        """Execute a write; its program cost runs in the background."""
        self._note(t, "dispatch", req.qi)
        self.dispatches += 1
        kind, pages = self.core.write(req.qi)
        # Replicated backends program every mirror chip ((chip + r) % n
        # striping), so the frontend timeline charges them all; prog_done
        # tracks the primary program only.
        reps = getattr(self.core.backend, "replicas", 1)
        if kind == "program":              # eager per-write program
            for pg in pages:
                for r in range(reps):
                    lat = self.timeline.observe_program(
                        (pg + r) % self.n_chips, at=t)
                    if r == 0:
                        self._push(t + lat, "prog_done", pg)
            done = t + self.params.mmio_ns
        elif kind == "flush":              # high-water group drain
            chips = [(p + r) % self.n_chips
                     for p in pages for r in range(reps)]
            lats = self.timeline.observe_program_group(
                chips, restage_chips=chips, at=t)
            for pg, lat in zip(pages, lats[::reps]):
                self._push(t + lat, "prog_done", pg)
            done = t + self.params.dram_hit_ns
        else:                              # absorbed into the DRAM buffer
            done = t + self.params.dram_hit_ns
        self.inflight += 1
        self._push(done, "write_done", req)

    # ----------------------------------------------------------------- run
    def run(self) -> RunReport:
        n = len(self.wl.ops)
        times, streams = arrival_times(self.config, n)
        for qi in range(n):
            self._push(float(times[qi]), "arrive",
                       Request(qi, int(streams[qi]), int(self.wl.ops[qi]),
                               float(times[qi])))
        while self.heap:
            t = self.heap[0][0]
            # Drain every event at this timestamp before scheduling, so a
            # zero-inter-arrival backlog is visible as one batch (parity
            # with the serial replay) and ties stay deterministic.
            while self.heap and self.heap[0][0] == t:
                _, _, kind, payload = heapq.heappop(self.heap)
                self.events += 1
                self._handle(t, kind, payload)
            self.t_last = t
            self._pump(t)
        if self.n_done != n:
            raise RuntimeError(
                f"event loop drained with {self.n_done}/{n} ops complete")
        # End of stream: the final write-buffer drain + reliability
        # refreshes happen "after" the last event, like the serial finish.
        pages = self.core.finish()
        if pages:
            reps = getattr(self.core.backend, "replicas", 1)
            chips = [(p + r) % self.n_chips
                     for p in pages for r in range(reps)]
            self.timeline.observe_program_group(chips, restage_chips=chips,
                                                at=self.t_last)
        return self._report()

    def _report(self) -> RunReport:
        rep = self.core.report("event")
        tl = self.timeline
        makespan = max(tl.now, self.t_last)
        rep.latency = LatencyReport.from_read_latencies(
            self.read_lats, makespan_ns=makespan, n_ops=len(self.wl.ops),
            burst_latencies_ns=np.asarray(tl.burst_latencies),
            write_latencies_ns=np.asarray(tl.write_latencies))
        rep.energy = EnergyReport(total_pj=tl.energy_pj)
        c = rep.counters
        c.events = self.events
        c.dispatches = self.dispatches
        c.admitted = self.admitted
        c.admission_waits = self.admission_waits
        c.ncq_peak = self.ncq_peak
        rep.trace = tuple(self.trace)
        return rep
