"""Event-loop frontend: NCQ admission, scheduled bursts, async programs.

The serial replay answers "what does the device compute"; this module
answers "when", under contention.  It is a next-event time-advance
simulator in the FTL-simulator shape:

  * **arrivals** — every workload op becomes a timestamped request on one
    of N client streams (:mod:`repro.frontend.arrivals`);
  * **admission** — a bounded NCQ of ``config.ncq_depth`` slots; arrivals
    beyond the bound wait in an overflow queue (``admission_waits``) and
    are admitted as completions free slots — admission wait is part of
    the request's measured latency, which is how saturation shows up in
    the p99 sweeps;
  * **scheduling** — a :mod:`repro.frontend.scheduler` policy composes
    the next device burst from the queued requests: up to ``burst`` reads
    coalesce into one flush (the §IV-E batch), writes and scans dispatch
    as barrier ops;
  * **service** — each burst is charged to this frontend's own
    :class:`repro.flash.timeline.BurstTimeline` (die sense/program lines,
    channel buses, the PCIe link), started at the dispatch event's
    timestamp.  Under FIFO, read bursts additionally queue behind each
    die's outstanding program backlog; read-priority policies
    program-suspend past it — with t_program = 5 x t_read this gap is the
    whole fig15-under-contention story;
  * **background programs** — writes never hold the device: an eager
    program or a §VI write-buffer group flush queues on the die program
    timelines and completes as a later ``prog_done`` event, contending
    with FIFO reads exactly like the deferred backlog it is.

The *functional* execution rides the same :class:`ReplayCore` as the
serial driver, invoked in dispatch order — so at
``RunConfig.event_serial()`` (one stream, zero inter-arrival, FIFO) the
backend sees the identical command sequence and the replay is
bit-identical to ``mode="serial"`` (tests/test_frontend.py).

Timing is deliberately backend-independent: the scalar backend gets the
same simulated clock as the sharded one, so load sweeps don't need a
kernel build.  The per-burst resource accounting mirrors the sharded
backend's ChipBurst reports (unique pages -> senses + open-verification
bus bytes; per read -> match + bitmap + chunk payloads; per scan page ->
one fused-plan match + one 64 B bitmap).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.flash.params import (BITMAP_BYTES, CHUNK_BYTES,
                                OPEN_OVERHEAD_BYTES)
from repro.flash.timeline import BurstTimeline, ChipBurst
from repro.workload.ycsb import Workload

from .arrivals import arrival_times
from .config import RunConfig
from .replay import ReplayCore
from .report import EnergyReport, LatencyReport, RunReport
from .scheduler import READ, make_scheduler

QUERY_BYTES = 16     # (query, mask) uint32 pairs shipped per search


@dataclasses.dataclass
class Request:
    """One workload op as an NCQ entry."""
    qi: int            # op index in the workload stream
    stream: int        # client stream (qi % concurrency)
    kind: int          # op code: 0 read, 1 write, 2 scan
    t_arrive: float    # arrival time, ns (admission wait counts from here)


class EventLoop:
    """Drives one ReplayCore through arrivals/NCQ/scheduler events."""

    def __init__(self, workload: Workload, backend, config: RunConfig):
        self.core = ReplayCore(workload, backend, config)
        self.config = config
        self.wl = workload
        self.n_chips = len(self.core.backend.chips.chips)
        # The frontend owns its clock: one BurstTimeline sized to the
        # backend's chip count, independent of any backend-attached
        # timeline (which, in event mode, is ignored).
        self.timeline = BurstTimeline.for_chips(self.n_chips)
        self.params = self.timeline.params
        self.sched = make_scheduler(config)

        self.heap: list = []               # (t, seq, kind, payload)
        self._seq = 0
        self.ncq: list[Request] = []
        self.overflow: list[Request] = []
        self.inflight = 0                  # dispatched, not yet completed
        self.busy = False                  # a read/scan burst is in service
        self.n_done = 0
        self.t_last = 0.0
        self.read_lats: list[float] = []
        self.trace: list[tuple] = []
        self.events = self.dispatches = 0
        self.admitted = self.admission_waits = 0
        self.ncq_peak = 0

    # ------------------------------------------------------------ plumbing
    def _push(self, t: float, kind: str, payload) -> None:
        self._seq += 1
        heapq.heappush(self.heap, (t, self._seq, kind, payload))

    def _note(self, t: float, kind: str, qi: int) -> None:
        if self.config.record_trace:
            self.trace.append((t, kind, qi))

    def _depth(self) -> int:
        return len(self.ncq) + self.inflight

    def _note_peak(self) -> None:
        self.ncq_peak = max(self.ncq_peak, self._depth())

    def _admit(self, t: float) -> None:
        while self.overflow and self._depth() < self.config.ncq_depth:
            req = self.overflow.pop(0)
            self.ncq.append(req)
            self._note(t, "admit", req.qi)
            self._note_peak()

    def _complete(self, req: Request, t: float, *,
                  was_inflight: bool = True) -> None:
        if was_inflight:
            self.inflight -= 1
        if req.kind == READ:
            self.read_lats.append(t - req.t_arrive)
        self.n_done += 1
        self._note(t, "complete", req.qi)

    # -------------------------------------------------------------- events
    def _handle(self, t: float, kind: str, payload) -> None:
        if kind == "arrive":
            req: Request = payload
            self._note(t, "arrive", req.qi)
            if self._depth() < self.config.ncq_depth:
                self.ncq.append(req)
                self.admitted += 1
                self._note_peak()
            else:
                self.overflow.append(req)
                self.admission_waits += 1
        elif kind == "read_done":
            for req in payload:
                self._complete(req, t)
            self.busy = False
        elif kind == "scan_done":
            self._complete(payload, t)
            self.busy = False
        elif kind == "write_done":
            self._complete(payload, t)
        else:                              # prog_done: background program
            self._note(t, "prog_done", payload)

    # ---------------------------------------------------------- dispatching
    def _pump(self, t: float) -> None:
        """Admit waiting arrivals, then keep the device fed."""
        self._admit(t)
        while not self.busy:
            if self.sched.pick_read(self.ncq) is not None:
                self._issue_reads(t)
                continue
            i = self.sched.pick(self.ncq)
            if i is None:
                return
            req = self.ncq.pop(i)
            if req.kind == 2:
                self._issue_scan(req, t)
            else:
                self._issue_write(req, t)

    def _issue_reads(self, t: float) -> None:
        """Compose and dispatch one read burst.

        Reads are pulled one at a time so an overlay-served read (a DRAM
        hit that never reaches the device) completes immediately, frees
        its NCQ slot, and lets the overflow backfill *within the same
        dispatch* — which is exactly how the serial replay fills bursts
        (overlay reads don't consume burst slots), and what keeps the
        concurrency-1 FIFO replay bit-identical.

        Buffered writes absorb into DRAM without touching the flash
        image, so — exactly as in the serial op loop — they are NOT
        burst barriers: a write the scheduler selects mid-burst executes
        inline and the pull continues.  The exception is a write that
        trips the high-water drain: its group flush reprograms flash, so
        queued reads must resolve first — it ends the burst (and runs
        after the read_done, which the serial ordering permits because
        nothing else can execute in between).
        """
        core, cfg = self.core, self.config
        batch: list[Request] = []
        while len(core.pending) < cfg.burst:
            i = self.sched.pick_read(self.ncq)
            if i is None:
                if not self._absorb_inline(t):
                    break
                continue
            req = self.ncq.pop(i)
            self._note(t, "dispatch", req.qi)
            if core.queue_read(req.qi):
                batch.append(req)
                self.inflight += 1
            else:
                self._complete(req, t + self.params.dram_hit_ns,
                               was_inflight=False)
                self._admit(t)
        if not batch:
            return
        lat = self.timeline.observe_flush(
            self._read_burst_counts(batch), at=t,
            wait_program_lines=self.sched.wait_program_lines)
        core.resolve_burst()
        self.dispatches += 1
        self.busy = True
        self._push(t + lat, "read_done", batch)

    def _absorb_inline(self, t: float) -> bool:
        """Mid-burst: execute the next write inline iff it only absorbs.

        Returns True when a buffered, non-tripping write was consumed
        (the read pull continues); False when the burst must end — no
        write selectable, eager-program mode (a write is a read-your-
        writes barrier there), or the write would trip the high-water
        group drain.
        """
        core = self.core
        if core.wb is None:
            return False
        i = self.sched.pick(self.ncq)
        if i is None or self.ncq[i].kind != 1:
            return False
        qi = self.ncq[i].qi
        if core.wb.would_trip(int(self.wl.value_pages[qi])):
            return False
        self._issue_write(self.ncq.pop(i), t)
        return True

    def _read_burst_counts(self, batch: list[Request]) -> list[ChipBurst]:
        """Per-chip resource counts of one read burst (see module doc)."""
        bursts: dict[int, ChipBurst] = {}

        def b(chip: int) -> ChipBurst:
            return bursts.setdefault(chip, ChipBurst(chip))

        opened: set[int] = set()
        for req in batch:
            kp = int(self.wl.key_pages[req.qi])
            vp = int(self.wl.value_pages[req.qi])
            for p in (kp, vp):              # page opens amortize per burst
                if p not in opened:
                    opened.add(p)
                    cb = b(p % self.n_chips)
                    cb.senses += 1
                    cb.bus_match_bytes += OPEN_OVERHEAD_BYTES
            kb = b(kp % self.n_chips)
            kb.matches += 1
            kb.bus_match_bytes += BITMAP_BYTES
            kb.pcie_bytes += BITMAP_BYTES + QUERY_BYTES
            vb = b(vp % self.n_chips)       # speculative value-page gather
            vb.bus_match_bytes += CHUNK_BYTES
            vb.pcie_bytes += CHUNK_BYTES
        return [bursts[c] for c in sorted(bursts)]

    def _issue_scan(self, req: Request, t: float) -> None:
        self._note(t, "dispatch", req.qi)
        self.dispatches += 1
        pages = self.core.scan(req.qi)     # functional execution
        bursts: dict[int, ChipBurst] = {}
        for p in pages:                    # fused plan: one 64 B per page
            cb = bursts.setdefault(p % self.n_chips,
                                   ChipBurst(p % self.n_chips))
            cb.senses += 1
            cb.matches += 1
            cb.bus_match_bytes += BITMAP_BYTES
            cb.pcie_bytes += BITMAP_BYTES
        if bursts:
            lat = self.timeline.observe_flush(
                [bursts[c] for c in sorted(bursts)], at=t,
                wait_program_lines=self.sched.wait_program_lines)
        else:
            lat = self.params.mmio_ns      # empty range: command rtt only
        self.inflight += 1
        self.busy = True
        self._push(t + lat, "scan_done", req)

    def _issue_write(self, req: Request, t: float) -> None:
        """Execute a write; its program cost runs in the background."""
        self._note(t, "dispatch", req.qi)
        self.dispatches += 1
        kind, pages = self.core.write(req.qi)
        chips = [p % self.n_chips for p in pages]
        if kind == "program":              # eager per-write program
            for pg, c in zip(pages, chips):
                lat = self.timeline.observe_program(c, at=t)
                self._push(t + lat, "prog_done", pg)
            done = t + self.params.mmio_ns
        elif kind == "flush":              # high-water group drain
            lats = self.timeline.observe_program_group(
                chips, restage_chips=chips, at=t)
            for pg, lat in zip(pages, lats):
                self._push(t + lat, "prog_done", pg)
            done = t + self.params.dram_hit_ns
        else:                              # absorbed into the DRAM buffer
            done = t + self.params.dram_hit_ns
        self.inflight += 1
        self._push(done, "write_done", req)

    # ----------------------------------------------------------------- run
    def run(self) -> RunReport:
        n = len(self.wl.ops)
        times, streams = arrival_times(self.config, n)
        for qi in range(n):
            self._push(float(times[qi]), "arrive",
                       Request(qi, int(streams[qi]), int(self.wl.ops[qi]),
                               float(times[qi])))
        while self.heap:
            t = self.heap[0][0]
            # Drain every event at this timestamp before scheduling, so a
            # zero-inter-arrival backlog is visible as one batch (parity
            # with the serial replay) and ties stay deterministic.
            while self.heap and self.heap[0][0] == t:
                _, _, kind, payload = heapq.heappop(self.heap)
                self.events += 1
                self._handle(t, kind, payload)
            self.t_last = t
            self._pump(t)
        if self.n_done != n:
            raise RuntimeError(
                f"event loop drained with {self.n_done}/{n} ops complete")
        # End of stream: the final write-buffer drain + reliability
        # refreshes happen "after" the last event, like the serial finish.
        pages = self.core.finish()
        if pages:
            chips = [p % self.n_chips for p in pages]
            self.timeline.observe_program_group(chips, restage_chips=chips,
                                                at=self.t_last)
        return self._report()

    def _report(self) -> RunReport:
        rep = self.core.report("event")
        tl = self.timeline
        makespan = max(tl.now, self.t_last)
        rep.latency = LatencyReport.from_read_latencies(
            self.read_lats, makespan_ns=makespan, n_ops=len(self.wl.ops),
            burst_latencies_ns=np.asarray(tl.burst_latencies),
            write_latencies_ns=np.asarray(tl.write_latencies))
        rep.energy = EnergyReport(total_pj=tl.energy_pj)
        c = rep.counters
        c.events = self.events
        c.dispatches = self.dispatches
        c.admitted = self.admitted
        c.admission_waits = self.admission_waits
        c.ncq_peak = self.ncq_peak
        rep.trace = tuple(self.trace)
        return rep
