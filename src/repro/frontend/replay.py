"""The functional execution core, shared by the serial and event drivers.

``run_functional`` used to be one 300-line closure pile: bulk load, burst
accumulation, split/fused resolution, the write-buffer path, scans and the
reliability drains all interleaved with the serial op loop.  The event
frontend needs the same semantics under a *different* driver — requests
admitted by an NCQ and grouped by a scheduler instead of replayed in
stream order — so the op semantics live here, in :class:`ReplayCore`, and
each driver owns only the question "when does the next op execute":

  * :func:`replay` with ``mode="serial"`` iterates the op stream exactly
    like the historical ``run_functional`` (reads accumulate to ``burst``,
    writes/scans are barriers) — bit-identical to the pre-refactor code;
  * :mod:`repro.frontend.eventloop` (``mode="event"``) admits ops through
    a bounded NCQ and lets a scheduler policy compose the bursts; with
    one stream, zero inter-arrival and FIFO it degenerates to the serial
    order and must replay bit-identically (the correctness anchor in
    tests/test_frontend.py).

Everything stateful about one replay — the host value mirror, the pending
read burst, the depth-1 lazy drain pipeline, the DRAM write buffer, the
reliability drains — is ReplayCore state; the drivers never touch the
backend directly.
"""
from __future__ import annotations

import numpy as np

from repro.backend import as_backend
from repro.buffer.writebuffer import WriteBuffer
from repro.core.bits import SLOTS_PER_CHUNK, unpack_bitmap
from repro.core.commands import Command
from repro.core.page import mask_header_slots
from repro.core.range_query import evaluate_plan_on_pages, exact_range
from repro.reliability import (DegradedReadError, UncorrectableReadError,
                               require_clean)
from repro.workload.ycsb import KEYS_PER_PAGE, Workload, value_page_of

from .config import RunConfig
from .report import (CounterReport, EnergyReport, FaultReport,
                     LatencyReport, ReliabilityReport, RunReport)

FULL_MASK = 0xFFFFFFFFFFFFFFFF


class ReplayCore:
    """Executes one workload's ops against a MatchBackend, driver-agnostic.

    Key id ``k`` lives on key page ``k // 504`` at entry ``k % 504`` with
    stored key ``k + 1`` (nonzero, distinct from the vacant-slot
    sentinel); its value sits at the same entry of the §V-A paired value
    page.  See the historical ``run_functional`` docstring (now on
    :func:`replay`) for the full path semantics — split vs fused bursts,
    the depth-1 lazy pipeline, the write buffer, scans, reliability.
    """

    def __init__(self, workload: Workload, backend, config: RunConfig):
        if workload.keys is None:
            raise ValueError("workload has no key stream "
                             "(regenerate with ycsb.generate)")
        self.workload = workload
        self.config = config
        self.backend = backend = as_backend(backend)
        self.n_key_pages = workload.n_index_pages // 2
        self.n_keys = self.n_key_pages * KEYS_PER_PAGE
        self.stored_keys = np.arange(1, self.n_keys + 1, dtype=np.uint64)
        # Deterministic initial values (odd, so never the vacant sentinel).
        self.values = (self.stored_keys * np.uint64(0x9E3779B97F4A7C15)) \
            | np.uint64(1)

        for p in range(self.n_key_pages):
            s = p * KEYS_PER_PAGE
            backend.program_entries(
                p, self.stored_keys[s:s + KEYS_PER_PAGE])
            backend.program_entries(
                value_page_of(p, self.n_key_pages),
                self.values[s:s + KEYS_PER_PAGE])

        # Fault injection corrupts the images loaded above (install also
        # switches every later flush onto the reliability path).
        self.reliability = config.reliability
        if self.reliability is not None:
            self.reliability.install(backend)

        # Device-fault tier: outages/stalls/program failures attach AFTER
        # the bulk load (the load is setup — a chip dead at t=0 keeps its
        # loaded image and is served via replicas from the first real op).
        self.fault_state = None
        if (config.faults is not None or config.deadline_ns is not None
                or config.hedge_quantile is not None
                or config.shed_capacity is not None):
            from repro.reliability import DeviceFaultState, FaultSchedule
            self.fault_state = DeviceFaultState(
                config.faults or FaultSchedule.healthy(seed=config.seed))
            if hasattr(backend, "enable_device_faults"):
                backend.enable_device_faults(self.fault_state)

        # Timeline-coupled backends (sharded + BurstTimeline) measure the
        # replayed op stream only — the bulk load is setup, not workload.
        self.timeline = getattr(backend, "timeline", None)
        if self.timeline is not None:
            self.timeline.reset()

        wb = config.write_buffer
        if wb is True:
            wb = WriteBuffer(high_water=config.write_high_water)
        self.wb: WriteBuffer | None = wb or None

        n = len(workload.ops)
        self.out = np.zeros(n, dtype=np.uint64)
        self.hits = np.zeros(n, dtype=bool)
        self.read_errors = np.zeros(n, dtype=bool)
        self.op_errors = np.zeros(n, dtype=bool)   # fault-tier typed errors
        self.scan_counts = np.zeros(n, dtype=np.int64)
        self.flushes = 0
        self.n_reads = self.n_writes = self.n_scans = 0
        self.programs = self.write_flushes = 0
        self.refreshes = 0
        self.pending: list[int] = []        # op indices of queued reads
        self._inflight: list[list] = []     # flushed, not-yet-drained bursts
        self._resolve = (self._resolve_burst_fused if config.fused
                         else self._resolve_burst_split)

    # -------------------------------------------------------------- reads
    def queue_read(self, qi: int) -> bool:
        """Queue read op ``qi`` into the open burst.

        Returns False when the read was served from the write-buffer
        overlay instead (read-your-writes from DRAM: a dirty value page
        answers straight from the buffered image — no device command;
        key pages are never written, so a buffered value page always
        implies the key exists on its key page).
        """
        self.n_reads += 1
        if self.wb is not None:
            overlay = self.wb.get(int(self.workload.value_pages[qi]))
            if overlay is not None:
                k = int(self.workload.keys[qi])
                self.out[qi] = overlay[k % KEYS_PER_PAGE]
                self.hits[qi] = True
                return False
        self.pending.append(qi)
        return True

    def resolve_burst(self) -> None:
        """Flush the open read burst (no-op when nothing is pending)."""
        self._resolve()

    def _drain(self, lookups) -> None:
        for qi, t in lookups:
            try:
                r = require_clean(t.result())
            except UncorrectableReadError:
                self.read_errors[qi] = True
                continue
            except DegradedReadError:
                self.op_errors[qi] = True   # no live replica left
                continue
            if r.value_slot is None:
                continue
            self.out[qi] = int.from_bytes(r.value, "little")
            self.hits[qi] = True

    def drain_inflight(self) -> None:
        while self._inflight:
            self._drain(self._inflight.pop(0))

    def _resolve_burst_fused(self) -> None:
        """One submit_lookup per read: the whole burst is ONE launch.

        With lazy tickets the flush only *dispatches* the launch; this
        burst's host tail is deferred until the NEXT burst has been
        flushed (depth-1 pipeline), so staging of burst k+1 overlaps
        device compute of burst k.  Results are position-tagged, so the
        deferred drain is order-independent and bit-identical.
        """
        if not self.pending:
            return
        wl, backend = self.workload, self.backend
        lookups = [(qi, backend.submit_lookup(Command.lookup(
            int(wl.key_pages[qi]), int(wl.value_pages[qi]),
            int(self.stored_keys[wl.keys[qi]]), FULL_MASK)))
            for qi in self.pending]
        self.pending.clear()
        backend.flush()
        self.flushes += 1
        self._inflight.append(lookups)
        while len(self._inflight) > 1:
            self._drain(self._inflight.pop(0))

    def _resolve_burst_split(self) -> None:
        """Search launch, host bitmap decode, then gather launch."""
        if not self.pending:
            return
        wl, backend = self.workload, self.backend
        # Page routing comes from the workload's own placement fields so
        # the timing executor (run) and this one always model the same
        # layout.
        searches = [(qi, backend.submit_search(Command.search(
            int(wl.key_pages[qi]),
            int(self.stored_keys[wl.keys[qi]]), FULL_MASK)))
            for qi in self.pending]
        self.pending.clear()
        backend.flush()
        self.flushes += 1
        gathers = []
        for qi, t in searches:
            try:
                bitmap = mask_header_slots(
                    require_clean(t.result()).bitmap_words)
            except UncorrectableReadError:
                self.read_errors[qi] = True
                continue
            except DegradedReadError:
                self.op_errors[qi] = True
                continue
            slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
            if slots.size == 0:
                continue
            value_slot = int(slots[0])      # same entry on the value page
            gathers.append((qi, value_slot, backend.submit_gather(
                Command.gather(int(wl.value_pages[qi]),
                               1 << (value_slot // SLOTS_PER_CHUNK)))))
        backend.flush()
        self.flushes += 1
        for qi, value_slot, g in gathers:
            off = (value_slot % SLOTS_PER_CHUNK) * 8
            try:
                r = require_clean(g.result())
            except UncorrectableReadError:
                self.read_errors[qi] = True
                continue
            except DegradedReadError:
                self.op_errors[qi] = True
                continue
            self.out[qi] = int.from_bytes(
                bytes(r.chunks[0][off:off + 8]), "little")
            self.hits[qi] = True

    # -------------------------------------------------------------- scans
    def scan_pages(self, qi: int) -> list[int]:
        """Key pages scan op ``qi`` touches (same placement arithmetic as
        the timing executor, so every driver models one footprint)."""
        wl = self.workload
        k = int(wl.keys[qi])
        lo = k + 1
        hi = min(lo + int(wl.scan_lens[qi]), self.n_keys + 1)
        if lo >= hi:
            return []
        p0 = (lo - 1) // KEYS_PER_PAGE     # page of stored key lo
        p1 = (hi - 2) // KEYS_PER_PAGE     # page of stored key hi - 1
        return list(range(p0, min(p1, self.n_key_pages - 1) + 1))

    def scan(self, qi: int) -> list[int]:
        """YCSB-E scan: ONE Op.PLAN per touched key page, fused in-latch.

        Scans key ids [k, k + len); stored key of id k is k + 1, and ids
        are laid out contiguously (page p holds ids [p*504, (p+1)*504)),
        so the plan only needs the pages overlapping the stored-key range
        [lo, hi).  Key pages are never reprogrammed, so a scan needs no
        ordering against the write stream — only the open read burst is
        resolved first so the plan flush stays a dedicated launch.
        Returns the touched pages (the event driver's timing footprint).
        """
        self.resolve_burst()
        wl = self.workload
        pages = self.scan_pages(qi)
        if not pages:
            return pages
        k = int(wl.keys[qi])
        lo = k + 1
        hi = min(lo + int(wl.scan_lens[qi]), self.n_keys + 1)
        try:
            bitmaps = evaluate_plan_on_pages(
                self.backend, exact_range(lo, hi, width=64), pages)
        except UncorrectableReadError:
            # Any touched page failing outer-code decode voids the whole
            # scan — a partial count would be a silently wrong result.
            self.read_errors[qi] = True
            self.flushes += 1
            self.n_scans += 1
            return pages
        except DegradedReadError:
            self.op_errors[qi] = True
            self.flushes += 1
            self.n_scans += 1
            return pages
        self.flushes += 1
        total = 0
        for bm in bitmaps:
            bits = unpack_bitmap(mask_header_slots(bm), 512)
            total += int(bits.sum())
        self.scan_counts[qi] = total
        self.n_scans += 1
        return pages

    # ------------------------------------------------------------- writes
    def write(self, qi: int) -> tuple[str, list[int]]:
        """Execute write op ``qi``.

        Returns the device-side effect for the driver's timing model:
        ``("program", [page])`` for an eager per-write program,
        ``("absorb", [])`` when the DRAM buffer swallowed it, or
        ``("flush", pages)`` when it tripped the high-water mark and the
        listed dirty pages drained as one deferred-program group.
        """
        self.n_writes += 1
        wl = self.workload
        k = int(wl.keys[qi])
        self.values[k] = np.uint64(qi * 2 + 1)   # tagged by op index, odd
        p = k // KEYS_PER_PAGE
        s = p * KEYS_PER_PAGE
        vpage = value_page_of(p, self.n_key_pages)
        if self.wb is not None:
            # Absorb into the DRAM buffer; the on-flash image stays as
            # queued reads expect it until the grouped flush below.
            self.wb.put(vpage, self.values[s:s + KEYS_PER_PAGE])
            if self.wb.should_flush:
                return "flush", self.flush_write_buffer()
            return "absorb", []
        self.resolve_burst()                # read-your-writes ordering
        if self.reliability is not None:
            # The reliability finalize verifies hits against the on-flash
            # image at RESOLVE time (selective verification is a re-read,
            # not a kernel output), so the image must not change under an
            # in-flight burst: drain the depth-1 pipeline before
            # reprogramming.
            self.drain_inflight()
        self.backend.program_entries(
            vpage, self.values[s:s + KEYS_PER_PAGE])
        self.programs += 1
        return "program", [vpage]

    def flush_write_buffer(self) -> list[int]:
        """Drain the dirty set as ONE deferred-program group; returns the
        programmed pages (empty when the buffer was clean)."""
        if self.wb is None or not self.wb.n_dirty:
            return []
        self.resolve_burst()        # queued reads precede the programs
        if self.reliability is not None:
            self.drain_inflight()
        pages = self.wb.dirty_pages
        self.programs += self.wb.flush(self.backend)
        self.write_flushes += 1
        return pages

    # ------------------------------------------------------------- finish
    def finish(self) -> list[int]:
        """End of stream: final burst, final buffer drain, full drain and
        reliability refreshes.  Returns the final program-group pages."""
        self.resolve_burst()
        pages = self.flush_write_buffer()
        self.drain_inflight()
        if self.reliability is not None:
            self.refreshes = _drain_refreshes(self.backend,
                                              self.reliability)
        return pages

    # ------------------------------------------------------------- report
    def report(self, source: str) -> RunReport:
        stats = self.backend.stats
        rep = RunReport(
            source=source,
            read_values=self.out, read_hits=self.hits,
            scan_counts=self.scan_counts if self.n_scans else None,
            counters=CounterReport(
                reads=self.n_reads, writes=self.n_writes,
                scans=self.n_scans, flushes=self.flushes,
                kernel_launches=stats.kernel_launches,
                staged_bytes=stats.staged_bytes,
                result_bytes=stats.result_bytes,
                programs=self.programs, write_flushes=self.write_flushes,
                buffer_read_hits=(self.wb.stats.read_hits
                                  if self.wb is not None else 0)),
            reliability=ReliabilityReport(
                read_errors=(self.read_errors
                             if self.reliability is not None else None),
                n_read_errors=int(self.read_errors.sum()),
                refreshes=self.refreshes,
                stats=(self.reliability.stats
                       if self.reliability is not None else None)))
        if self.fault_state is not None:
            fs = self.fault_state.stats
            rep.faults = FaultReport(
                timeouts=fs.timeouts, retries=fs.retries,
                backoff_waits=fs.backoff_waits, hedges_won=fs.hedges_won,
                failovers=fs.failovers,
                remapped_blocks=fs.remapped_blocks,
                degraded_ops=fs.degraded_ops,
                shed_requests=fs.shed_requests,
                replica_programs=fs.replica_programs,
                program_failures=fs.program_failures,
                op_errors=self.op_errors,
                n_op_errors=int(self.op_errors.sum()))
        if self.timeline is not None:
            rep.latency = LatencyReport(
                burst_latencies_ns=np.asarray(
                    self.timeline.burst_latencies),
                write_latencies_ns=np.asarray(
                    self.timeline.write_latencies),
                makespan_ns=self.timeline.now)
            rep.energy = EnergyReport(total_pj=self.timeline.energy_pj)
        return rep


def _drain_refreshes(backend, reliability) -> int:
    """Rewrite every page the open bursts flagged CLEAN_NEEDS_REFRESH.

    A refresh is read-through-ECC then reprogram: sub-threshold raw errors
    are corrected (the simulator's ``_repair`` restores the clean image),
    the entries are re-extracted and ride the deferred ``Op.PROGRAM`` path
    with a fresh timestamp — so the rewrite groups and coalesces exactly
    like workload writes and later opens see a young, error-free page.
    Pages whose raw error count exceeds the outer-code budget cannot be
    refreshed (the data is gone); they stay marked and keep surfacing as
    typed errors.
    """
    from repro.core.page import entries_from_plain
    chips = backend.chips
    tickets = []
    for addr in sorted(reliability.refresh_due):
        chip, local = chips.route(addr)
        sp = chip.pages.get(local)
        if sp is None:
            continue
        if sp.injected_error_bits > reliability.policy.ecc.t_correctable:
            continue                       # beyond refresh: uncorrectable
        if sp.injected_error_bits:
            reliability.stats.corrected_bits += sp.injected_error_bits
            chip._repair(sp, local)
        plain = chip._derandomize_page(sp, local)
        entries = entries_from_plain(plain, sp.n_entries)
        tickets.append(backend.submit_program(
            addr, entries, timestamp_ns=reliability.now_ns))
    if tickets:
        backend.flush()
    reliability.refresh_due.clear()
    reliability.stats.refreshes += len(tickets)
    return len(tickets)


def replay(workload: Workload, backend,
           config: RunConfig = RunConfig()) -> RunReport:
    """Execute the op stream against real pages through a MatchBackend.

    The canonical functional entry point (the old ``run_functional``
    kwargs live on in :class:`RunConfig`).  ``config.mode`` picks the
    driver:

    ``"serial"`` — the classic synchronous replay.  Reads accumulate into
    bursts of up to ``config.burst`` queries.  With ``fused=False`` the
    burst's searches flush as one batch, then its value gathers as a
    second — two kernel launches on the batched backend.  With
    ``fused=True`` every read becomes a ``submit_lookup`` and the whole
    burst resolves in one fused launch, with the depth-1 lazy pipeline
    overlapping adjacent bursts.  Writes are eager per-write programs, or
    — with ``write_buffer`` — absorb into the §VI DRAM buffer, serve
    overlay reads, and drain in grouped deferred-program bursts at the
    high-water mark.  Scans replay as fused Op.PLAN bursts.  With a
    ``reliability`` state attached the replay runs against fault-injected
    pages and per-op errors surface in ``report.reliability``.

    ``"event"`` — the event-loop simulator: ops *arrive* (Poisson, trace
    or all-at-zero), queue in a bounded NCQ, and a scheduler policy
    composes the device bursts; the report additionally carries the
    per-request simulated latency distribution and admission counters.
    At ``RunConfig.event_serial()`` the replay is bit-identical to
    ``"serial"``.
    """
    if config.mode == "event":
        from .eventloop import EventLoop
        return EventLoop(workload, backend, config).run()
    core = ReplayCore(workload, backend, config)
    wl = workload
    for qi in range(len(wl.ops)):
        if wl.ops[qi] == 0:
            if core.queue_read(qi) and len(core.pending) >= config.burst:
                core.resolve_burst()
        elif wl.ops[qi] == 2:
            core.scan(qi)
        else:
            core.write(qi)
    core.finish()
    return core.report("serial")
