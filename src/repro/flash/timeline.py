"""Timeline coupling for functional backends: flushes -> SSD resource time.

The functional path (``frontend.replay``, the index structures, the sharded
backend) computes bit-exact results but, on its own, no latency: time lives
in the analytic simulator (flash/ssd.py).  This module is the adapter that
joins them.  A ``ShardedSsdBackend`` reports every flush as a list of
per-chip ``ChipBurst`` records — how many page senses, match ops and bus
bytes each chip contributed to the burst — and ``BurstTimeline`` replays
those counts against a real ``SSDSim``'s monotone resource timelines (die
sense/program lines, per-channel internal buses, the PCIe link).  The
result: ``frontend.replay`` returns measured bitmaps/values *plus* a
simulated latency distribution and energy account per burst, so
fig14/15-style latency plots are reproducible from the functional backend
rather than only from the closed-form simulator.

Accounting model (per paper §III-B/§IV-E, mirrored from SSDSim.read_sim):

  * every unique page a chip's burst touches costs one array sense on that
    chip's die timeline (the page open), amortized over all of the chip's
    queued queries — the §IV-E batch-matching amortization;
  * match ops serialize on the die after its senses (t_match each).  A
    fused range plan (Op.PLAN) charges one match op per include/exclude
    pass — the latches still evaluate every pass — but only ONE 64 B
    combined bitmap per page on the bus (the in-latch Fig 10 accumulation);
    the per-pass split path would cross 64 B per pass per page;
  * match-mode payloads (open verification transfers, 64 B bitmaps, 64 B
    gathered chunks) share the chip's *channel* bus timeline, so chips on
    one channel contend while chips on different channels overlap — the
    channel parallelism the paper's speedups come from;
  * dirty-plane restages (pages reprogrammed since the last flush that
    touches them) cross the channel bus in *storage* mode before the chip
    can serve match mode — the deferred half of the write path, i.e. the
    dirty-page stall.  Overwrites of one page within a window coalesce
    (only the final image crosses, as in an application-managed write
    buffer), and a written page that is never searched again defers its
    bus hop indefinitely; cold first-touch arena staging is a
    TPU-residency artifact and is never charged;
  * every chip's results funnel through the one PCIe link.

Writes (``observe_program``) model SiM's application-managed write buffer:
the program queues on the die's separate program timeline (read-priority /
program-suspend, as in SSDSim) and the client clock does NOT advance — the
cost surfaces later, as restage bytes and program-line backlog.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .params import FlashParams, PAGE_BYTES
from .ssd import SSDSim


@dataclasses.dataclass
class ChipBurst:
    """One chip's share of one flush, in resource-consumption units."""
    chip: int                   # chip index == die index (see geometry note)
    senses: int = 0             # array senses (unique pages opened)
    matches: int = 0            # SiM match ops executed
    bus_match_bytes: int = 0    # match-mode channel payload (bitmaps/chunks)
    bus_storage_bytes: int = 0  # storage-mode payload (dirty-plane restage)
    pcie_bytes: int = 0         # host-link payload
    retry_senses: int = 0       # extra senses from §IV-C2 read retries
    fallback_reads: int = 0     # full-page storage-mode reads (ECC fallback)
    degraded_reads: int = 0     # full-page reads served host-side off a
                                # replica because the primary chip is dead
                                # (device-fault tier; charged like fallback)


class BurstTimeline:
    """Feeds per-chip flush reports into SSDSim's resource timelines.

    Geometry: chip index c maps to die c (and therefore channel
    ``c % params.channels``, SSDSim's own die->channel striping), so the
    adapter requires ``params.n_dies`` chips.  Construct with
    ``BurstTimeline.for_chips(n_chips)`` to get a square-ish default.
    """

    def __init__(self, params: FlashParams):
        self.params = params
        # Device-fault state (repro.reliability.DeviceFaultState) or None;
        # survives reset() — the replay attaches it once, before the
        # post-load reset.
        self.faults = None
        self.reset()

    @staticmethod
    def for_chips(n_chips: int, base: FlashParams | None = None
                  ) -> "BurstTimeline":
        """Params with ``channels x dies_per_channel == n_chips``, keeping
        the channel count near the paper's 8 (or n_chips if smaller)."""
        base = base or FlashParams()
        channels = n_chips
        for c in (8, 4, 2):
            if n_chips % c == 0 and n_chips >= c:
                channels = c
                break
        return BurstTimeline(dataclasses.replace(
            base, channels=channels, dies_per_channel=n_chips // channels))

    # ------------------------------------------------------------- control
    def reset(self) -> None:
        """Zero the clock, timelines, latencies and energy (keep params).

        ``frontend.replay`` calls this after the initial page load so the
        recorded distribution covers the replayed op stream only.
        """
        self.sim = SSDSim(self.params, n_index_pages=0, cache_pages=0,
                          system="sim")
        self.now = 0.0
        self.burst_latencies: list[float] = []
        self.write_latencies: list[float] = []

    def attach_faults(self, state) -> None:
        """Attach a DeviceFaultState: transient stall windows active at
        each service time are scheduled onto the SSDSim resource lines
        (``block_die``/``block_channel``) before the chains run."""
        self.faults = state

    def _apply_stalls(self, t: float) -> None:
        if self.faults is None:
            return
        for w in self.faults.stalls_active_at(t):
            if w.kind == "die":
                self.sim.block_die(w.target % self.params.n_dies,
                                   w.t_end_ns)
            else:
                self.sim.block_channel(w.target % self.params.channels,
                                       w.t_end_ns)

    @property
    def n_chips(self) -> int:
        return self.params.n_dies

    @property
    def energy_pj(self) -> float:
        return self.sim.energy.total_pj

    def latency_percentiles(self, qs=(50, 99)) -> dict[int, float]:
        lats = np.asarray(self.burst_latencies or [0.0])
        return {int(q): float(np.percentile(lats, q)) for q in qs}

    # ------------------------------------------------------------- events
    def observe_flush(self, bursts: list[ChipBurst], *,
                      at: float | None = None,
                      wait_program_lines: bool = False) -> float:
        """Advance the clock across one flush; returns the burst latency.

        All chips start at the flush submit time (``at``, default the
        adapter clock ``self.now``); each chip's chain is restage ->
        senses -> matches -> match-mode bus -> PCIe.  Die timelines
        overlap freely, channel buses serialize chips per channel, the
        PCIe link serializes everything — queueing falls out of SSDSim's
        max(ready, resource_free) discipline.

        ``wait_program_lines`` models a FIFO command queue without
        program suspend: each chip's chain additionally queues behind the
        die's outstanding program backlog.  The default (False) is the
        read-priority discipline baked into SSDSim's split sense/program
        timelines — reads suspend programs and never wait on them.
        """
        if not bursts:
            return 0.0
        sim = self.sim
        start = self.now if at is None else at
        self._apply_stalls(start)
        end = start
        for b in bursts:
            die = b.chip % self.params.n_dies
            t = start
            if wait_program_lines:
                t = max(t, float(sim.die_prog_free[die]))
            if b.bus_storage_bytes:
                t = sim._bus(die, t, b.bus_storage_bytes, match_mode=False)
            # Reliability tier: a read-retried open re-senses the page; an
            # ECC fallback decode additionally moves the WHOLE page over
            # the channel bus in storage mode (the §IV-C "give up and read
            # it out" path) before match mode resumes.  Device-fault
            # degraded reads (replica failover to host) are charged the
            # same way: one sense plus a full page in storage mode — no
            # free recovery.
            for _ in range(b.retry_senses + b.fallback_reads
                           + b.degraded_reads):
                t = sim._sense(die, t)
            if b.fallback_reads or b.degraded_reads:
                t = sim._bus(die, t,
                             (b.fallback_reads + b.degraded_reads)
                             * PAGE_BYTES, match_mode=False)
            for _ in range(b.senses):
                t = sim._sense(die, t)
            if b.matches:
                t = sim._match(t, b.matches)
            if b.bus_match_bytes:
                t = sim._bus(die, t, b.bus_match_bytes, match_mode=True)
            if b.pcie_bytes:
                t = sim._pcie(t, b.pcie_bytes)
            end = max(end, t)
        end += self.params.mmio_ns
        self.burst_latencies.append(end - start)
        self.now = max(self.now, end)
        return end - start

    def observe_program(self, chip: int, *,
                        at: float | None = None) -> float:
        """A page program: PCIe in, program on the die's program timeline.

        The channel-bus hop is charged when the dirty plane restages at a
        later flush (``bus_storage_bytes``) — write-back is deferred and
        overwrites coalesce, so at most one bus crossing per page per
        write window (see the module docstring for the exact semantics).
        The clock does not advance — SiM's write buffer is asynchronous;
        backlog surfaces via the die timelines.  ``at`` overrides the
        submit time (the event frontend passes its dispatch timestamp);
        the return value is the program's completion latency from submit.
        """
        sim = self.sim
        start = self.now if at is None else at
        self._apply_stalls(start)
        t = sim._pcie(start, PAGE_BYTES)
        t = sim._program(chip % self.params.n_dies, t)
        self.write_latencies.append(t - start)
        return t - start

    def observe_program_group(self, chips: list[int],
                              restage_chips: list[int] | None = None,
                              *, at: float | None = None) -> list[float]:
        """A deferred write-buffer flush: the whole dirty group at once.

        Each page crosses PCIe (serialized on the one link) and queues on
        its die's program timeline — dies program in parallel, a hot die
        accumulates backlog.  ``restage_chips`` lists the pages whose
        device-resident planes re-staged with the group: each crosses its
        channel bus in storage mode (the write-back hop; overwrites
        already coalesced, so it is at most one hop per page per group).
        The client clock does NOT advance — SiM's write buffer drains
        asynchronously; the cost surfaces as program-line backlog and bus
        occupancy.  Returns the per-program completion latencies, which
        also append to ``write_latencies``.
        """
        start = self.now if at is None else at
        out = [self.observe_program(c, at=at) for c in chips]
        for c in restage_chips or ():
            self.sim._bus(c % self.params.n_dies, start, PAGE_BYTES,
                          match_mode=False)
        return out
