"""Hardware parameters — paper Table II, plus Table I bus currents.

All times in nanoseconds, energies in picojoules, currents in mA, voltages
in V.  Derived quantities are properties so a config override stays
consistent.

Geometry note: Table II lists (die, plane, block, page) = (2, 1, 32, 128)
with a 4 KiB *logical* page; the paper's footnote 1 fixes 4 KiB as the
logical page size while 3D-NAND physical pages are 16 KiB.  We model logical
pages directly and size the array to the paper's experimental setup (650 MiB
index = 65 % of visible capacity -> 1 GiB visible), i.e. 512 logical pages
per block.  This scaling is recorded here because the Table II numbers alone
(256 MiB) cannot host the paper's own 650 MiB index.
"""
from __future__ import annotations

import dataclasses

US = 1000.0          # ns per us
MS = 1000.0 * US


@dataclasses.dataclass(frozen=True)
class FlashParams:
    # --- geometry
    channels: int = 8
    dies_per_channel: int = 2
    planes_per_die: int = 1
    blocks_per_plane: int = 32
    pages_per_block: int = 512          # logical 4 KiB pages (see note)
    page_bytes: int = 4096

    # --- array timings (ns)
    t_read_ns: float = 16 * US          # SLC sense
    t_program_ns: float = 80 * US
    t_erase_ns: float = 1 * MS

    # --- SiM match engine
    sim_clock_hz: float = 33e6
    sim_cycles_per_match: int = 10

    # --- internal (ONFi NV-DDR3) bus, 8-bit wide
    bus_width_bits: int = 8
    match_mode_mt_s: float = 80e6       # transfers/s in match mode
    storage_mode_mt_s: float = 800e6

    # --- external PCIe Gen3 interface
    pcie_bus_bits: int = 128
    pcie_clock_hz: float = 250e6

    # --- electrical
    bus_voltage: float = 1.2
    nand_voltage: float = 3.3
    bus_active_ma: float = 5.0          # equalized per §VII-B footnote 5
    bus_idle_ua: float = 10.0
    nand_read_ma: float = 25.0
    nand_program_ma: float = 25.0
    sim_match_ma: float = 2.5
    # Table I peak currents (used only by the power-budget experiments)
    bus_peak_ma_storage: float = 152.0
    bus_peak_ma_match: float = 11.0

    # --- host-side constants
    dram_hit_ns: float = 1 * US         # page-cache hit service time
    cpu_search_ns: float = 2 * US       # host SIMD search of a loaded page
    mmio_ns: float = 1 * US             # NVMe command doorbell/completion
    # Per-I/O kernel cost of the conventional DMA path (block layer, DMA
    # mapping, interrupt, page-cache insertion).  The paper's SiM path
    # "communicates entirely through NVMe's command interface (MMIO) and
    # bypasses the conventional DMA procedures" (§VI-A3) — so this cost is
    # baseline-only.  ~10 us is a standard figure for the Linux NVMe stack.
    host_io_overhead_ns: float = 10 * US

    # ------------------------------------------------------------ derived
    @property
    def n_dies(self) -> int:
        return self.channels * self.dies_per_channel

    @property
    def pages_per_die(self) -> int:
        return (self.planes_per_die * self.blocks_per_plane
                * self.pages_per_block)

    @property
    def total_pages(self) -> int:
        return self.n_dies * self.pages_per_die

    @property
    def capacity_bytes(self) -> int:
        return self.total_pages * self.page_bytes

    @property
    def match_bus_bytes_per_ns(self) -> float:
        return self.match_mode_mt_s * (self.bus_width_bits / 8) / 1e9

    @property
    def storage_bus_bytes_per_ns(self) -> float:
        return self.storage_mode_mt_s * (self.bus_width_bits / 8) / 1e9

    @property
    def pcie_bytes_per_ns(self) -> float:
        return self.pcie_clock_hz * (self.pcie_bus_bits / 8) / 1e9

    @property
    def t_match_ns(self) -> float:
        return self.sim_cycles_per_match / self.sim_clock_hz * 1e9

    def bus_time_ns(self, n_bytes: int, match_mode: bool) -> float:
        bw = (self.match_bus_bytes_per_ns if match_mode
              else self.storage_bus_bytes_per_ns)
        return n_bytes / bw

    def pcie_time_ns(self, n_bytes: int) -> float:
        return n_bytes / self.pcie_bytes_per_ns

    # ------------------------------------------------------------- energy
    # E[pJ] = V * I[mA] * t[ns]  (V * mA * ns = pJ)
    def e_sense_pj(self) -> float:
        return self.nand_voltage * self.nand_read_ma * self.t_read_ns

    def e_program_pj(self) -> float:
        return self.nand_voltage * self.nand_program_ma * self.t_program_ns

    def e_match_pj(self) -> float:
        return self.nand_voltage * self.sim_match_ma * self.t_match_ns

    def e_bus_pj(self, n_bytes: int, match_mode: bool) -> float:
        t = self.bus_time_ns(n_bytes, match_mode)
        return self.bus_voltage * self.bus_active_ma * t


# Payload sizes (paper §VII-B)
BITMAP_BYTES = 64          # search response
CHUNK_BYTES = 64           # gather unit
OPEN_OVERHEAD_BYTES = 256  # verification transfer on page_open
PAGE_BYTES = 4096

DEFAULT_PARAMS = FlashParams()
