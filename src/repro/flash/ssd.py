"""Timeline-based SSD simulator for the paper's system evaluation (§VI–VII).

Resources are modelled as monotone free-time timelines (die array ops,
per-channel internal buses, the PCIe link, and an optional peak-current pool
for bus transfers per §II-B).  A closed loop of clients issues queries; every
query walks its phase chain, each phase starting at
max(ready, resource_free).  This captures queueing delay, die/channel
parallelism, sense/transfer pipelining and the dirty-eviction stalls that
drive the paper's results, at ~1 us of Python per simulated query — fast
enough for the full Fig 12–18 grids.

Two systems share the machinery (§VI-A3):
  * ``baseline``: CPU-centric — full 4 KiB page reads through the OS page
    cache (clean inserts compete with the write buffer), host-side search;
  * ``sim``: SiM — search+gather commands in match mode, reads bypass the
    cache entirely, the whole cache acts as a write buffer.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cache.pagecache import PageCache
from .params import (BITMAP_BYTES, CHUNK_BYTES, FlashParams,
                     OPEN_OVERHEAD_BYTES, PAGE_BYTES)


@dataclasses.dataclass
class EnergyAccount:
    """NAND-chip-side energy only (paper's Fig 13 accounting)."""
    sense_pj: float = 0.0
    program_pj: float = 0.0
    bus_pj: float = 0.0
    match_pj: float = 0.0

    @property
    def total_pj(self) -> float:
        return self.sense_pj + self.program_pj + self.bus_pj + self.match_pj


@dataclasses.dataclass
class SimStats:
    reads: int = 0
    writes: int = 0
    scans: int = 0
    senses: int = 0
    programs: int = 0
    matches: int = 0
    full_page_reads: int = 0
    internal_bytes: int = 0
    pcie_bytes: int = 0
    batched_searches: int = 0
    open_page_hits: int = 0


class SSDSim:
    # Linux vm.dirty_ratio default: the kernel page cache throttles writers
    # once ~20 % of it is dirty.  SiM's application-managed write buffer has
    # no such cap (reads never enter it) — see PageCache docstring.
    BASELINE_DIRTY_FRACTION = 0.20

    def __init__(self, params: FlashParams, *, n_index_pages: int,
                 cache_pages: int, system: str,
                 power_budget_ma: float | None = None, seed: int = 0):
        assert system in ("baseline", "sim")
        self.p = params
        self.system = system
        self.n_index_pages = n_index_pages
        self.cache = PageCache(
            cache_pages,
            max_dirty_fraction=(self.BASELINE_DIRTY_FRACTION
                                if system == "baseline" else 1.0))
        self.energy = EnergyAccount()
        self.stats = SimStats()
        self.read_latencies: list[float] = []
        self.write_latencies: list[float] = []
        self.scan_latencies: list[float] = []
        self._rng = np.random.default_rng(seed)

        n_dies = params.n_dies
        # Two timelines per die: senses (reads) run with read priority /
        # program-suspend (standard in modern controllers), programs queue
        # separately and only contend with each other.
        self.die_sense_free = np.zeros(n_dies)
        self.die_prog_free = np.zeros(n_dies)
        self.chan_free = np.zeros(params.channels)
        self.pcie_free = 0.0
        # Async write-back backpressure: a client stalls only when the
        # victim die's program backlog exceeds this window.
        self.prog_backlog_ns = 4 * params.t_program_ns
        # Match-mode page-buffer state (§IV-B): the page latched per die.  A
        # search/gather that targets the open page skips the array sense and
        # the open-verification transfer — the latch-pipelining reuse the
        # batch-matching of §IV-E also exploits.  Storage-mode ops clobber
        # the latches (programs and full-page reads invalidate).
        self.open_page = np.full(n_dies, -1, dtype=np.int64)
        # §II-B peak-current pool for bus transfers (None = unconstrained)
        if power_budget_ma is not None:
            slots_storage = max(1, int(power_budget_ma
                                       / params.bus_peak_ma_storage))
            slots_match = max(1, int(power_budget_ma
                                     / params.bus_peak_ma_match))
            self._pool_storage = np.zeros(slots_storage)
            self._pool_match = np.zeros(slots_match)
        else:
            self._pool_storage = self._pool_match = None

    # ----------------------------------------------------------- resources
    def _die_of(self, page: int) -> int:
        return page % self.p.n_dies

    def _chan_of(self, die: int) -> int:
        return die % self.p.channels

    def _sense(self, page: int, ready: float) -> float:
        die = self._die_of(page)
        start = max(ready, self.die_sense_free[die])
        end = start + self.p.t_read_ns
        self.die_sense_free[die] = end
        self.stats.senses += 1
        self.energy.sense_pj += self.p.e_sense_pj()
        return end

    def _program(self, page: int, ready: float) -> float:
        die = self._die_of(page)
        start = max(ready, self.die_prog_free[die])
        end = start + self.p.t_program_ns
        self.die_prog_free[die] = end
        self.open_page[die] = -1          # program clobbers the page buffer
        self.stats.programs += 1
        self.energy.program_pj += self.p.e_program_pj()
        return end

    def _bus(self, page: int, ready: float, n_bytes: int,
             match_mode: bool) -> float:
        chan = self._chan_of(self._die_of(page))
        start = max(ready, self.chan_free[chan])
        if self._pool_storage is not None:
            pool = self._pool_match if match_mode else self._pool_storage
            slot = int(np.argmin(pool))
            start = max(start, pool[slot])
        dur = self.p.bus_time_ns(n_bytes, match_mode)
        end = start + dur
        self.chan_free[chan] = end
        if self._pool_storage is not None:
            pool[slot] = end
        self.stats.internal_bytes += n_bytes
        self.energy.bus_pj += self.p.e_bus_pj(n_bytes, match_mode)
        return end

    def _pcie(self, ready: float, n_bytes: int) -> float:
        start = max(ready, self.pcie_free)
        end = start + self.p.pcie_time_ns(n_bytes)
        self.pcie_free = end
        self.stats.pcie_bytes += n_bytes
        return end

    def _match(self, ready: float, n_queries: int = 1) -> float:
        self.stats.matches += n_queries
        self.energy.match_pj += self.p.e_match_pj() * n_queries
        return ready + self.p.t_match_ns * n_queries

    # ------------------------------------------------------ fault scheduling
    # Device-fault stalls (repro.reliability.device_faults) are scheduled
    # directly onto the resource timelines: a blocked die/channel simply has
    # its free-time pushed past the stall window, so every later phase
    # queues behind it through the ordinary max(ready, free) discipline —
    # no special-case latency paths.
    def block_die(self, die: int, until: float) -> None:
        """Hold both of a die's timelines (sense + program) to ``until``."""
        self.die_sense_free[die] = max(self.die_sense_free[die], until)
        self.die_prog_free[die] = max(self.die_prog_free[die], until)

    def block_channel(self, chan: int, until: float) -> None:
        """Hold a channel's internal bus timeline to ``until``."""
        self.chan_free[chan] = max(self.chan_free[chan], until)

    # -------------------------------------------------------- page fetches
    def _fetch_full_page(self, page: int, now: float) -> float:
        """Storage-mode full page to host: sense -> bus -> PCIe -> kernel."""
        t = self._sense(page, now)
        self.open_page[self._die_of(page)] = -1   # storage-mode read clobbers
        t = self._bus(page, t, PAGE_BYTES, match_mode=False)
        t = self._pcie(t, PAGE_BYTES)
        self.stats.full_page_reads += 1
        return t + self.p.host_io_overhead_ns

    def _writeback(self, victim: int, now: float) -> float:
        """Full write I/O for a dirty victim: PCIe + internal bus + program.

        The kernel-path overhead applies to the baseline only (SiM's write
        buffer is flushed by the application through the same MMIO command
        path as its reads).
        """
        t = now + (self.p.host_io_overhead_ns if self.system == "baseline"
                   else 0.0)
        t = self._pcie(t, PAGE_BYTES)
        t = self._bus(victim, t, PAGE_BYTES, match_mode=False)
        return self._program(victim, t)

    def _evict_sync(self, evicted: list[tuple[int, bool]],
                    now: float) -> float:
        """Baseline semantics: the evicting thread performs the write-back
        inline (direct reclaim / vm.dirty_ratio writer throttling) and waits
        for it — the §VII-A/C read-behind-write-back stall."""
        done = now
        for victim, was_dirty in evicted:
            if was_dirty:
                done = max(done, self._writeback(victim, now))
        return done

    def _evict_async(self, evicted: list[tuple[int, bool]],
                     now: float) -> float:
        """SiM semantics: the application-managed write buffer flushes in the
        background; the client stalls only when the victim die's program
        backlog exceeds the queue window (the §VII-D sporadic-peak tail)."""
        done = now
        for victim, was_dirty in evicted:
            if not was_dirty:
                continue
            end = self._writeback(victim, now)
            stall_until = end - self.prog_backlog_ns
            if stall_until > now:
                done = max(done, stall_until)
        return done

    # ------------------------------------------------------------- queries
    def read_baseline(self, key_page: int, value_page: int,
                      now: float) -> float:
        hit_k = self.cache.lookup(key_page)
        hit_v = self.cache.lookup(value_page)
        if hit_k and hit_v:
            return now + self.p.dram_hit_ns + self.p.cpu_search_ns
        done = now
        for page, hit in ((key_page, hit_k), (value_page, hit_v)):
            if hit:
                continue
            t = self._fetch_full_page(page, now)      # fetches run parallel
            t = self._evict_sync(self.cache.insert(page, dirty=False), t)
            done = max(done, t)
        return done + self.p.cpu_search_ns

    def _open_for_match(self, page: int, now: float) -> float:
        """page_open in match mode: skip the sense + verification transfer
        when the page is already latched in the die's buffer (§IV-B)."""
        die = self._die_of(page)
        if self.open_page[die] == page:
            self.stats.open_page_hits += 1
            return now
        t = self._sense(page, now)
        t = self._bus(page, t, OPEN_OVERHEAD_BYTES, match_mode=True)
        self.open_page[die] = page
        return t

    def read_sim(self, key_page: int, value_page: int, now: float,
                 batch_extra: int = 0) -> float:
        """search(key page) + pipelined gather(value page) (§V-A).

        ``batch_extra`` > 0 models additional queued searches sharing this
        page sense (deadline scheduler, §IV-E).
        """
        # key page: open (sense + verification transfer) + match + bitmap out
        t = self._open_for_match(key_page, now)
        t = self._match(t, 1 + batch_extra)
        if batch_extra:
            self.stats.batched_searches += batch_extra
        t = self._bus(key_page, t, BITMAP_BYTES * (1 + batch_extra),
                      match_mode=True)
        t_bitmap = self._pcie(t, BITMAP_BYTES)
        # value page: opened speculatively in parallel with the key search,
        # gather transfer once both the open and the bitmap are ready.
        t_open_v = self._open_for_match(value_page, now)
        t = self._bus(value_page, max(t_open_v, t_bitmap), CHUNK_BYTES,
                      match_mode=True)
        t = self._pcie(t, CHUNK_BYTES)
        return t + self.p.mmio_ns

    def write(self, key_page: int, value_page: int, now: float) -> float:
        """Index update: buffer both pages dirty (write-back on eviction)."""
        if self.cache.capacity == 0:
            t1 = self._writeback(key_page, now)
            t2 = self._writeback(value_page, now)
            return max(t1, t2)
        evict = (self._evict_sync if self.system == "baseline"
                 else self._evict_async)
        done = now + self.p.dram_hit_ns
        for page in (key_page, value_page):
            done = max(done, evict(self.cache.insert(page, dirty=True), now))
        return done

    def read(self, key_page: int, value_page: int, now: float,
             force_full_page: bool = False, batch_extra: int = 0) -> float:
        self.stats.reads += 1
        if self.system == "baseline":
            end = self.read_baseline(key_page, value_page, now)
        elif force_full_page:
            # SiM system doing a legitimate full-page read (§VII-F, e.g. LSM
            # compaction or an analytic scan).  These are storage-mode reads
            # on the *conventional* I/O path — they stream through the
            # kernel page cache and therefore compete with the write buffer,
            # which is exactly why Fig 18's effect is strongest in
            # write-dominant workloads.
            end = now
            for page in (key_page, value_page):
                t = self._fetch_full_page(page, now)
                t = self._evict_sync(self.cache.insert(page, dirty=False), t)
                end = max(end, t)
            end += self.p.cpu_search_ns
        else:
            end = self.read_sim(key_page, value_page, now,
                                batch_extra=batch_extra)
        self.read_latencies.append(end - now)
        return end

    def submit_write(self, key_page: int, value_page: int,
                     now: float) -> float:
        self.stats.writes += 1
        end = self.write(key_page, value_page, now)
        self.write_latencies.append(end - now)
        return end

    # --------------------------------------------------------------- scans
    def scan(self, key_pages: list[int], now: float) -> float:
        """YCSB-E range scan over the key pages the range touches (§V-C).

        ``sim`` system: a match-mode multi-page read — per page, one
        ``_open_for_match`` (skipped when the page is already latched), one
        match op (the fused Op.PLAN evaluates every decomposition pass
        in-latch, so only the combined 64 B bitmap crosses the bus and the
        PCIe link per page).  Scans are *reads*: they never dirty the cache
        and never program — the timing executor used to funnel them into
        ``submit_write``, corrupting QPS/latency/energy and ``programs``
        for any scan-bearing workload.

        ``baseline`` system: conventional full-page reads of each touched
        page through the OS page cache + a host-side scan of the page.
        """
        self.stats.scans += 1
        end = now
        if self.system == "baseline":
            for page in key_pages:
                if self.cache.lookup(page):
                    t = now + self.p.dram_hit_ns
                else:
                    t = self._fetch_full_page(page, now)
                    t = self._evict_sync(
                        self.cache.insert(page, dirty=False), t)
                end = max(end, t)
            end += self.p.cpu_search_ns
        else:
            for page in key_pages:
                t = self._open_for_match(page, now)
                t = self._match(t)
                t = self._bus(page, t, BITMAP_BYTES, match_mode=True)
                t = self._pcie(t, BITMAP_BYTES)
                end = max(end, t)
            end += self.p.mmio_ns
        self.scan_latencies.append(end - now)
        return end
