"""hymba-1.5b [hybrid] — parallel attention + mamba heads per block;
sliding-window attention with a global layer every 11 (3 global layers of
32), ssm_state=16.  [arXiv:2411.13676; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, sliding_window=1024, global_attn_every=11,
)
