"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (pattern 3:1), no FFN (d_ff=0).
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_every=4, mlstm_heads=4,
)
