"""kimi-k2-1t-a32b [moe] — trillion-param MoE: 384 experts top-8 + 1 shared
expert, d_ff(expert)=2048 (paper-table entry).  [arXiv:2501.kimi2; unverified]

Memory note (DESIGN.md §5): ~1.03e12 params.  bf16 params + bf16 Adam
moments = ~6 TB of state; at 512 chips that is ~11.7 GB/chip and fits v5e
only with FSDP over the full (pod, data) product and bf16 moments —
optimizer_dtype below records that choice; the roofline table quantifies it.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    optimizer_dtype="bfloat16",
)
