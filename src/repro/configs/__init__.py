"""Architecture registry: the 10 assigned configs + reduced smoke variants.

``get_config("kimi-k2-1t-a32b")`` returns the full paper-table config;
``reduced_config(cfg)`` shrinks it to a CPU-runnable smoke config of the
same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import SHAPES, InputShape, ModelConfig

from .granite_3_8b import CONFIG as granite_3_8b
from .qwen3_4b import CONFIG as qwen3_4b
from .olmo_1b import CONFIG as olmo_1b
from .starcoder2_7b import CONFIG as starcoder2_7b
from .internvl2_26b import CONFIG as internvl2_26b
from .whisper_medium import CONFIG as whisper_medium
from .kimi_k2_1t_a32b import CONFIG as kimi_k2_1t_a32b
from .mixtral_8x22b import CONFIG as mixtral_8x22b
from .xlstm_350m import CONFIG as xlstm_350m
from .hymba_1_5b import CONFIG as hymba_1_5b

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        granite_3_8b, qwen3_4b, olmo_1b, starcoder2_7b, internvl2_26b,
        whisper_medium, kimi_k2_1t_a32b, mixtral_8x22b, xlstm_350m,
        hymba_1_5b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Same family/code paths, laptop-sized dims for smoke tests."""
    kv = 4 if cfg.n_kv_heads == cfg.n_heads else 2
    upd = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=kv, head_dim=16,
        d_ff=128, vocab_size=256,
    )
    if cfg.is_moe:
        upd.update(n_experts=4, top_k=2, moe_d_ff=32,
                   n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.family == "ssm":
        upd.update(slstm_every=2, mlstm_heads=2)
    if cfg.family == "hybrid":
        upd.update(ssm_state=4, sliding_window=8, global_attn_every=2)
    elif cfg.sliding_window is not None:
        upd.update(sliding_window=8)
    if cfg.encoder_layers:
        upd.update(encoder_layers=2, encoder_seq=16)
    if cfg.frontend_tokens:
        upd.update(frontend_tokens=8)
    return dataclasses.replace(cfg, **upd)


def shape_cells(cfg: ModelConfig) -> dict[str, InputShape | None]:
    """The 4 assigned shape cells for an arch; None marks a documented skip
    (long_500k on pure full-attention archs — DESIGN.md §4)."""
    cells: dict[str, InputShape | None] = {}
    for name, shape in SHAPES.items():
        if name == "long_500k" and not cfg.supports_long_context:
            cells[name] = None
        else:
            cells[name] = shape
    return cells


__all__ = ["ARCHS", "get_config", "reduced_config", "shape_cells",
           "SHAPES", "ModelConfig", "InputShape"]
