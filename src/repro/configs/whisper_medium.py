"""whisper-medium [audio] — enc-dec; conv frontend STUBBED (input_specs
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    encoder_layers=24, encoder_seq=1500, cross_attention=True,
    frontend="audio_stub",
)
