"""YCSB-like workload generation (paper §VI-A4/A5).

Key popularity follows a (scrambled) Zipf over key ranks with parameter
alpha in {0 (uniform), 0.5 (skewed), 0.9 (very skewed)}; read ratio and
cache-coverage grids mirror the paper's figures.  Keys map to (key page,
value page) pairs of the generic index of Fig 11: 504 keys per 4 KiB page,
key and value pages disjoint halves of the page space.
"""
from __future__ import annotations

import dataclasses

import numpy as np

KEYS_PER_PAGE = 504


def value_page_of(key_page, n_key_pages: int):
    """§V-A leaf placement: value page of key page i, second half of the
    address space rotated by one so the pair lands on two different dies."""
    return n_key_pages + (key_page + 1) % n_key_pages


def zipf_probs(n: int, alpha: float) -> np.ndarray:
    if alpha <= 0.0:
        return np.full(n, 1.0 / n)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    return w / w.sum()


def concentration_table(n: int, alpha: float, top: int = 4) -> np.ndarray:
    """Fraction of queries landing on the top-k keys (paper Table III)."""
    return zipf_probs(n, alpha)[:top]


@dataclasses.dataclass
class Workload:
    ops: np.ndarray          # (N,) uint8: 0 = read, 1 = write, 2 = scan
    key_pages: np.ndarray    # (N,) int32
    value_pages: np.ndarray  # (N,) int32
    alpha: float
    read_ratio: float
    n_index_pages: int
    # Concrete key ids (rank-scrambled), one per op — lets the functional
    # executor (repro.frontend.replay) replay the stream against real pages.
    keys: np.ndarray | None = None
    # YCSB-E: scan lengths, one per op (used where ops == 2).  A scan
    # starting at key k covers [k, k + len) and replays as ONE Op.PLAN
    # range plan per key page through the backend's fused in-latch path.
    scan_lens: np.ndarray | None = None


def generate(n_queries: int, *, n_key_pages: int, read_ratio: float,
             alpha: float, seed: int = 0, scramble: bool = True,
             scan_ratio: float = 0.0, max_scan_len: int = 64) -> Workload:
    """Generate a closed-loop query stream.

    ``n_key_pages`` pages of keys; each key page i pairs with value page
    ``n_key_pages + i`` (the §V-A two-page leaf layout).  With ``scramble``
    the popularity ranks are permuted across the keyspace so rank-adjacent
    hot keys do not collapse onto one page (YCSB's scrambled zipfian).
    ``scan_ratio`` carves YCSB-E range scans (op 2, uniform lengths in
    [1, max_scan_len]) out of the top of the op-probability space; the
    default 0 leaves the historical read/write stream bit-identical.
    """
    if scan_ratio > 0.0 and read_ratio + scan_ratio > 1.0:
        # Scans carve the top of the probability space [1-scan_ratio, 1),
        # which must fit inside the write band [read_ratio, 1) — otherwise
        # scans would silently swallow the requested writes (and reads).
        raise ValueError(f"read_ratio {read_ratio} + scan_ratio "
                         f"{scan_ratio} > 1: no probability mass left "
                         "for the write band")
    rng = np.random.default_rng(seed)
    n_keys = n_key_pages * KEYS_PER_PAGE
    probs = zipf_probs(n_keys, alpha)
    ranks = rng.choice(n_keys, size=n_queries, p=probs)
    if scramble:
        perm = rng.permutation(n_keys)
        keys = perm[ranks]
    else:
        keys = ranks
    key_pages = (keys // KEYS_PER_PAGE).astype(np.int32)
    # The rotated pairing keeps both page buffers latched for hot leaves and
    # makes the chip-internal search->gather pipelining effective.
    value_pages = value_page_of(key_pages, n_key_pages)
    r = rng.random(n_queries)
    ops = (r >= read_ratio).astype(np.uint8)
    scan_lens = None
    if scan_ratio > 0.0:
        ops[r >= 1.0 - scan_ratio] = 2
        scan_lens = rng.integers(1, max_scan_len + 1, n_queries,
                                 dtype=np.int32)
    return Workload(ops=ops, key_pages=key_pages,
                    value_pages=value_pages.astype(np.int32), alpha=alpha,
                    read_ratio=read_ratio, n_index_pages=2 * n_key_pages,
                    keys=keys.astype(np.int64), scan_lens=scan_lens)
