"""Closed-loop workload executor + metrics (QPS, latency percentiles, energy).

Mirrors the paper's measurement protocol (§VI-A4, footnote 6): statistics
start after a 30 % warmup; QPS = measured queries / measured makespan.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.scheduler import DeadlineScheduler
from repro.flash.params import FlashParams
from repro.flash.ssd import SSDSim
from .ycsb import Workload

WARMUP_FRACTION = 0.30


@dataclasses.dataclass
class RunResult:
    qps: float
    read_median_ns: float
    read_p25_ns: float
    read_p75_ns: float
    read_p99_ns: float
    energy_pj: float
    programs: int
    senses: int
    internal_bytes: int
    pcie_bytes: int
    cache_hit_rate: float
    absorbed_writes: int
    batched_searches: int
    makespan_ns: float


def run(workload: Workload, *, params: FlashParams, system: str,
        cache_coverage: float, clients: int = 16,
        full_page_read_ratio: float = 0.0,
        batch_deadline_ns: float | None = None,
        power_budget_ma: float | None = None, seed: int = 0) -> RunResult:
    """Execute a workload closed-loop on one simulated SSD."""
    cache_pages = int(round(cache_coverage * workload.n_index_pages))
    sim = SSDSim(params, n_index_pages=workload.n_index_pages,
                 cache_pages=cache_pages, system=system,
                 power_budget_ma=power_budget_ma, seed=seed)
    rng = np.random.default_rng(seed + 17)

    n = len(workload.ops)
    warmup = int(n * WARMUP_FRACTION)
    # Closed loop: heap of (ready_time, client, next_query_index).
    heap = [(0.0, c) for c in range(clients)]
    heapq.heapify(heap)
    next_q = 0
    warmup_end_t = None
    energy_at_warmup = 0.0
    stats_mark = None
    lat_mark = 0

    # Deadline batching (§IV-E): queries wait up to deadline for same-page
    # peers.  Approximated by counting same-page arrivals within the window
    # using a small pending map keyed by page.
    pending_same_page: dict[int, list[float]] = {}

    while next_q < n:
        now, client = heapq.heappop(heap)
        op = workload.ops[next_q]
        kp = int(workload.key_pages[next_q])
        vp = int(workload.value_pages[next_q])

        if next_q == warmup:
            warmup_end_t = now
            energy_at_warmup = sim.energy.total_pj
            stats_mark = dataclasses.replace(sim.stats)
            lat_mark = len(sim.read_latencies)

        if op == 0:
            batch_extra = 0
            if batch_deadline_ns is not None and system == "sim":
                window = pending_same_page.setdefault(kp, [])
                window[:] = [t for t in window if t >= now - batch_deadline_ns]
                batch_extra = len(window)
                window.append(now)
                # queries joining a batch pay the residual wait
                now = now + (batch_deadline_ns if batch_extra == 0 else 0.0)
            full = (system == "sim"
                    and rng.random() < full_page_read_ratio)
            end = sim.read(kp, vp, now, force_full_page=full,
                           batch_extra=batch_extra)
        else:
            end = sim.submit_write(kp, vp, now)
        heapq.heappush(heap, (end, client))
        next_q += 1

    makespan = max(t for t, _ in heap) - (warmup_end_t or 0.0)
    lats = np.array(sim.read_latencies[lat_mark:]) if sim.read_latencies \
        else np.array([0.0])
    measured = n - warmup
    s, m = sim.stats, stats_mark
    return RunResult(
        qps=measured / (makespan / 1e9) if makespan > 0 else 0.0,
        read_median_ns=float(np.median(lats)),
        read_p25_ns=float(np.percentile(lats, 25)),
        read_p75_ns=float(np.percentile(lats, 75)),
        read_p99_ns=float(np.percentile(lats, 99)),
        energy_pj=sim.energy.total_pj - energy_at_warmup,
        programs=s.programs - (m.programs if m else 0),
        senses=s.senses - (m.senses if m else 0),
        internal_bytes=s.internal_bytes - (m.internal_bytes if m else 0),
        pcie_bytes=s.pcie_bytes - (m.pcie_bytes if m else 0),
        cache_hit_rate=sim.cache.stats.hit_rate,
        absorbed_writes=sim.cache.stats.absorbed_writes,
        batched_searches=s.batched_searches - (m.batched_searches if m else 0),
        makespan_ns=makespan,
    )
