"""Closed-loop analytic executor (the timing half of the repro).

Mirrors the paper's measurement protocol (§VI-A4, footnote 6): statistics
start after a 30 % warmup; QPS = measured queries / measured makespan.

``run`` is the *timing* simulation on SSDSim (latency/energy, no real
data).  Reads are match-mode search+gather pairs, writes are buffered
page programs, and YCSB-E scans (``ops == 2``) are match-mode multi-page
READS over the key pages the range touches — never writes.  Returns a
:class:`repro.frontend.RunReport` (source ``"analytic"``).

The *functional* execution of the op stream against real programmed
pages lives in :func:`repro.frontend.replay`, configured by a
:class:`repro.frontend.RunConfig` (the ``run_functional`` shim that used
to forward there served its one promised deprecation cycle and is gone).

``RunResult`` and ``FunctionalRunResult`` are now aliases of
``RunReport`` — the one result schema of every executor — whose legacy
flat attributes (``qps``, ``n_reads``, ``sim_makespan_ns``, ...) remain
readable properties over the nested sections.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.flash.params import FlashParams
from repro.flash.ssd import SSDSim
from repro.frontend import RunReport
from .ycsb import KEYS_PER_PAGE, Workload

WARMUP_FRACTION = 0.30
FULL_MASK = 0xFFFFFFFFFFFFFFFF

# Legacy names: both executor result schemas unified into RunReport.
RunResult = RunReport
FunctionalRunResult = RunReport


def run(workload: Workload, *, params: FlashParams, system: str,
        cache_coverage: float, clients: int = 16,
        full_page_read_ratio: float = 0.0,
        batch_deadline_ns: float | None = None,
        power_budget_ma: float | None = None, seed: int = 0) -> RunReport:
    """Execute a workload closed-loop on one simulated SSD."""
    cache_pages = int(round(cache_coverage * workload.n_index_pages))
    sim = SSDSim(params, n_index_pages=workload.n_index_pages,
                 cache_pages=cache_pages, system=system,
                 power_budget_ma=power_budget_ma, seed=seed)
    rng = np.random.default_rng(seed + 17)

    n = len(workload.ops)
    warmup = int(n * WARMUP_FRACTION)
    # Closed loop: heap of (ready_time, client, next_query_index).
    heap = [(0.0, c) for c in range(clients)]
    heapq.heapify(heap)
    next_q = 0
    warmup_end_t = None
    energy_at_warmup = 0.0
    stats_mark = None
    lat_mark = 0

    # Deadline batching (§IV-E): queries wait up to deadline for same-page
    # peers.  Approximated by counting same-page arrivals within the window
    # using a small pending map keyed by page.
    pending_same_page: dict[int, list[float]] = {}

    n_key_pages = workload.n_index_pages // 2
    n_keys = n_key_pages * KEYS_PER_PAGE

    def scan_pages(qi: int) -> list[int]:
        """Key pages a YCSB-E scan touches — same placement arithmetic as
        the functional executor's scan path, so both executors model an
        identical page footprint for one op stream."""
        if workload.keys is None or workload.scan_lens is None:
            return [int(workload.key_pages[qi])]
        lo = int(workload.keys[qi]) + 1          # stored key of id k is k+1
        hi = min(lo + int(workload.scan_lens[qi]), n_keys + 1)
        if lo >= hi:
            return []
        p0 = (lo - 1) // KEYS_PER_PAGE
        p1 = (hi - 2) // KEYS_PER_PAGE
        return list(range(p0, min(p1, n_key_pages - 1) + 1))

    while next_q < n:
        now, client = heapq.heappop(heap)
        op = workload.ops[next_q]
        kp = int(workload.key_pages[next_q])
        vp = int(workload.value_pages[next_q])

        if next_q == warmup:
            warmup_end_t = now
            energy_at_warmup = sim.energy.total_pj
            stats_mark = dataclasses.replace(sim.stats)
            lat_mark = len(sim.read_latencies)

        if op == 0:
            batch_extra = 0
            if batch_deadline_ns is not None and system == "sim":
                window = pending_same_page.setdefault(kp, [])
                window[:] = [t for t in window if t >= now - batch_deadline_ns]
                batch_extra = len(window)
                window.append(now)
                # queries joining a batch pay the residual wait
                now = now + (batch_deadline_ns if batch_extra == 0 else 0.0)
            full = (system == "sim"
                    and rng.random() < full_page_read_ratio)
            end = sim.read(kp, vp, now, force_full_page=full,
                           batch_extra=batch_extra)
        elif op == 2:
            # YCSB-E scan: a match-mode multi-page READ.  This used to fall
            # into the write branch below, counting every scan as a page
            # write (wrong QPS/latency/energy, phantom programs on any
            # scan_ratio > 0 workload).
            end = sim.scan(scan_pages(next_q), now)
        else:
            end = sim.submit_write(kp, vp, now)
        heapq.heappush(heap, (end, client))
        next_q += 1

    makespan = max(t for t, _ in heap) - (warmup_end_t or 0.0)
    lats = np.array(sim.read_latencies[lat_mark:]) if sim.read_latencies \
        else np.array([0.0])
    measured = n - warmup
    s, m = sim.stats, stats_mark
    return RunReport.from_analytic(
        qps=measured / (makespan / 1e9) if makespan > 0 else 0.0,
        read_median_ns=float(np.median(lats)),
        read_p25_ns=float(np.percentile(lats, 25)),
        read_p75_ns=float(np.percentile(lats, 75)),
        read_p99_ns=float(np.percentile(lats, 99)),
        energy_pj=sim.energy.total_pj - energy_at_warmup,
        programs=s.programs - (m.programs if m else 0),
        senses=s.senses - (m.senses if m else 0),
        internal_bytes=s.internal_bytes - (m.internal_bytes if m else 0),
        pcie_bytes=s.pcie_bytes - (m.pcie_bytes if m else 0),
        cache_hit_rate=sim.cache.stats.hit_rate,
        absorbed_writes=sim.cache.stats.absorbed_writes,
        batched_searches=s.batched_searches - (m.batched_searches if m else 0),
        makespan_ns=makespan,
        reads=s.reads - (m.reads if m else 0),
        writes=s.writes - (m.writes if m else 0),
        scans=s.scans - (m.scans if m else 0),
    )
