"""Closed-loop workload executor + metrics (QPS, latency percentiles, energy).

Mirrors the paper's measurement protocol (§VI-A4, footnote 6): statistics
start after a 30 % warmup; QPS = measured queries / measured makespan.

Two executors live here:

  * ``run``            — the *timing* simulation on SSDSim (latency/energy,
                         no real data).  Reads are match-mode
                         search+gather pairs, writes are buffered page
                         programs, and YCSB-E scans (``ops == 2``) are
                         match-mode multi-page READS over the key pages
                         the range touches — never writes;
  * ``run_functional`` — the *functional* execution of the same op stream
                         against real programmed pages through a
                         MatchBackend, batching read bursts.  With
                         ``fused=False`` each burst is one search launch +
                         one gather launch on the kernel backend (§IV-E);
                         with ``fused=True`` the burst goes through
                         ``submit_lookup`` and resolves in ONE fused
                         launch — match, slot select and value gather all
                         on-device, the §III-B in-buffer pipelining.  All
                         backend/mode combinations must return identical
                         read values (tests/test_backend_parity).

``run_functional`` on a timeline-coupled ``ShardedSsdBackend`` closes the
loop between the two executors: the functional replay reports each flush's
per-chip batch sizes to ``flash/timeline.py``, which advances the same
die/channel/PCIe resource timelines ``run`` uses — so the result carries
bit-exact values *and* a simulated per-burst latency distribution + energy
account (fig14/15-style) from one execution.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.backend import as_backend
from repro.buffer.writebuffer import WriteBuffer
from repro.core.bits import SLOTS_PER_CHUNK, unpack_bitmap
from repro.core.commands import Command
from repro.core.page import mask_header_slots
from repro.core.range_query import evaluate_plan_on_pages, exact_range
from repro.flash.params import FlashParams
from repro.flash.ssd import SSDSim
from repro.reliability import UncorrectableReadError, require_clean
from .ycsb import KEYS_PER_PAGE, Workload, value_page_of

WARMUP_FRACTION = 0.30
FULL_MASK = 0xFFFFFFFFFFFFFFFF


@dataclasses.dataclass
class RunResult:
    qps: float
    read_median_ns: float
    read_p25_ns: float
    read_p75_ns: float
    read_p99_ns: float
    energy_pj: float
    programs: int
    senses: int
    internal_bytes: int
    pcie_bytes: int
    cache_hit_rate: float
    absorbed_writes: int
    batched_searches: int
    makespan_ns: float
    writes: int = 0           # write ops simulated (scan ops excluded)
    scans: int = 0            # YCSB-E scan ops simulated as multi-page reads


@dataclasses.dataclass
class FunctionalRunResult:
    read_values: np.ndarray   # (N,) uint64: full value read (0 where no hit)
    read_hits: np.ndarray     # (N,) bool: True where a read op found its key
    n_reads: int
    n_writes: int
    flushes: int              # backend flushes issued by the executor
    kernel_launches: int      # device launches (0 on the scalar backend)
    staged_bytes: int = 0     # host->device page bytes (0 on scalar)
    result_bytes: int = 0     # exact device->host result payload bytes
    # Write path.  Unbuffered, every write reprograms its value page
    # synchronously: programs == n_writes.  Through the §VI DRAM write
    # buffer, hot-page writes coalesce and dirty pages flush in grouped
    # deferred-program bursts: programs < n_writes on any skewed stream,
    # and reads of buffered pages are DRAM hits (buffer_read_hits) that
    # never queue a device command.
    programs: int = 0         # value-page programs issued during the replay
    write_flushes: int = 0    # write-buffer group flushes (0 unbuffered)
    buffer_read_hits: int = 0  # reads served from the write-buffer overlay
    # YCSB-E scans (op 2): matched-key count per scan op, 0 elsewhere.
    # Each scan replays as one Op.PLAN per key page (fused in-latch range
    # evaluation) and must be bit-identical across backends.
    scan_counts: np.ndarray | None = None
    n_scans: int = 0
    # Timeline coupling (sharded backend with a BurstTimeline attached):
    # simulated SSD time/energy for the replayed op stream, so fig14/15-
    # style latency distributions come out of the *functional* run too.
    burst_latencies_ns: np.ndarray | None = None   # one entry per flush
    write_latencies_ns: np.ndarray | None = None   # one entry per program
    sim_makespan_ns: float = 0.0
    sim_energy_pj: float = 0.0
    # Reliability tier (run with ``reliability=ReliabilityState(...)``):
    # per-op error outcomes.  A read/scan whose page fails outer-code
    # decode surfaces here as a typed per-op error — never as a silently
    # wrong value — and pages the open burst marked stale are refreshed
    # (rewritten through the deferred-program path) at end of replay.
    read_errors: np.ndarray | None = None   # (N,) bool: UncorrectableReadError
    n_read_errors: int = 0
    refreshes: int = 0                      # stale pages rewritten at drain
    reliability_stats: object | None = None  # ReliabilityStats snapshot


def run_functional(workload: Workload, backend, *, burst: int = 64,
                   fused: bool = False,
                   write_buffer: "WriteBuffer | bool" = False,
                   write_high_water: int = 16,
                   reliability=None) -> FunctionalRunResult:
    """Execute the op stream against real pages through a MatchBackend.

    Key id ``k`` lives on key page ``k // 504`` at entry ``k % 504`` with
    stored key ``k + 1`` (nonzero, distinct from the vacant-slot sentinel);
    its value sits at the same entry of the §V-A paired value page.  Reads
    accumulate into bursts of up to ``burst`` queries.  With
    ``fused=False`` the burst's searches flush as one batch, then its value
    gathers as a second — two kernel launches on the batched backend.  With
    ``fused=True`` every read becomes a ``submit_lookup`` and the whole
    burst resolves in one fused launch, no host bitmap decode in between;
    lazy tickets keep each burst's outputs device-resident until the NEXT
    burst has been flushed, so host staging and device compute of adjacent
    bursts overlap (the depth-1 pipeline — results are position-tagged, so
    replay stays bit-identical).
    Writes, unbuffered (default): a write flushes the open burst first
    (read-your-writes), updates the host mirror and reprograms the value
    page through the backend — which invalidates exactly that page's row
    in the device-resident plane store.  One program + one forced burst
    split per write: the eager reference.
    Writes, buffered (``write_buffer=True`` or a ``WriteBuffer``): the §VI
    DRAM write-buffer configuration.  A write *absorbs* into the buffer —
    no forced ``resolve_burst``, no program; repeated writes to a hot page
    coalesce last-wins.  Reads of a buffered page are served from the DRAM
    overlay (read-your-writes without a device command); reads of clean
    pages queue as usual, and stay correct because the on-flash image only
    changes at a buffer flush, which resolves the open burst first.  Dirty
    pages drain at the ``write_high_water`` mark (and at end of stream) as
    ONE deferred-program group per flush — grouped plane-store staging,
    async program-line accounting on a timeline-coupled backend — so
    ``programs`` comes out *below* ``n_writes`` on any skewed stream while
    read values stay bit-identical to the unbuffered eager replay.
    A scan op (YCSB-E, ``ops == 2``) replays as ONE ``Op.PLAN`` per key
    page the scanned range touches: the §V-C exact-range decomposition
    evaluates fused in-latch and 64 B per page crosses back, regardless
    of the plan's pass count.
    With ``reliability=ReliabilityState(...)`` the replay runs against
    fault-injected pages: the state installs on the backend after the
    bulk load (so the fault model corrupts the loaded images), every op's
    result passes through :func:`repro.reliability.require_clean`, pages
    that fail outer-code decode mark ``read_errors[qi]`` instead of
    returning a wrong value, and pages flagged CLEAN_NEEDS_REFRESH are
    rewritten (fresh timestamp, errors cleared) through the deferred
    Op.PROGRAM path at end of replay (``refreshes``).
    """
    if workload.keys is None:
        raise ValueError("workload has no key stream "
                         "(regenerate with ycsb.generate)")
    backend = as_backend(backend)
    n_key_pages = workload.n_index_pages // 2
    n_keys = n_key_pages * KEYS_PER_PAGE
    stored_keys = np.arange(1, n_keys + 1, dtype=np.uint64)
    # Deterministic initial values (odd, so never the vacant sentinel).
    values = (stored_keys * np.uint64(0x9E3779B97F4A7C15)) | np.uint64(1)

    for p in range(n_key_pages):
        s = p * KEYS_PER_PAGE
        backend.program_entries(p, stored_keys[s:s + KEYS_PER_PAGE])
        backend.program_entries(value_page_of(p, n_key_pages),
                                values[s:s + KEYS_PER_PAGE])

    # Fault injection corrupts the images loaded above (install also
    # switches every later flush onto the reliability path).
    if reliability is not None:
        reliability.install(backend)

    # Timeline-coupled backends (sharded + BurstTimeline) measure the
    # replayed op stream only — the bulk load above is setup, not workload.
    timeline = getattr(backend, "timeline", None)
    if timeline is not None:
        timeline.reset()

    if write_buffer is True:
        write_buffer = WriteBuffer(high_water=write_high_water)
    wb: WriteBuffer | None = write_buffer or None

    n = len(workload.ops)
    out = np.zeros(n, dtype=np.uint64)
    hits = np.zeros(n, dtype=bool)
    read_errors = np.zeros(n, dtype=bool)
    scan_counts = np.zeros(n, dtype=np.int64)
    flushes = 0
    n_scans = 0
    pending: list[int] = []                 # op indices of queued reads
    inflight: list[list] = []               # flushed, not-yet-drained bursts

    def drain(lookups) -> None:
        for qi, t in lookups:
            try:
                r = require_clean(t.result())
            except UncorrectableReadError:
                read_errors[qi] = True
                continue
            if r.value_slot is None:
                continue
            out[qi] = int.from_bytes(r.value, "little")
            hits[qi] = True

    def drain_inflight() -> None:
        while inflight:
            drain(inflight.pop(0))

    def resolve_burst_fused() -> None:
        """One submit_lookup per read: the whole burst is ONE launch.

        With lazy tickets the flush only *dispatches* the launch; this
        burst's host tail is deferred until the NEXT burst has been
        flushed (depth-1 pipeline), so staging of burst k+1 overlaps
        device compute of burst k.  Results are position-tagged, so the
        deferred drain is order-independent and bit-identical.
        """
        nonlocal flushes
        if not pending:
            return
        lookups = [(qi, backend.submit_lookup(Command.lookup(
            int(workload.key_pages[qi]), int(workload.value_pages[qi]),
            int(stored_keys[workload.keys[qi]]), FULL_MASK)))
            for qi in pending]
        pending.clear()
        backend.flush()
        flushes += 1
        inflight.append(lookups)
        while len(inflight) > 1:
            drain(inflight.pop(0))

    def resolve_burst_split() -> None:
        """Search launch, host bitmap decode, then gather launch."""
        nonlocal flushes
        if not pending:
            return
        # Page routing comes from the workload's own placement fields so the
        # timing executor (run) and this one always model the same layout.
        searches = [(qi, backend.submit_search(Command.search(
            int(workload.key_pages[qi]),
            int(stored_keys[workload.keys[qi]]), FULL_MASK)))
            for qi in pending]
        pending.clear()
        backend.flush()
        flushes += 1
        gathers = []
        for qi, t in searches:
            try:
                bitmap = mask_header_slots(
                    require_clean(t.result()).bitmap_words)
            except UncorrectableReadError:
                read_errors[qi] = True
                continue
            slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
            if slots.size == 0:
                continue
            value_slot = int(slots[0])      # same entry on the value page
            gathers.append((qi, value_slot, backend.submit_gather(
                Command.gather(int(workload.value_pages[qi]),
                               1 << (value_slot // SLOTS_PER_CHUNK)))))
        backend.flush()
        flushes += 1
        for qi, value_slot, g in gathers:
            off = (value_slot % SLOTS_PER_CHUNK) * 8
            try:
                r = require_clean(g.result())
            except UncorrectableReadError:
                read_errors[qi] = True
                continue
            out[qi] = int.from_bytes(bytes(r.chunks[0][off:off + 8]),
                                     "little")
            hits[qi] = True

    resolve_burst = resolve_burst_fused if fused else resolve_burst_split

    def run_scan(qi: int) -> None:
        """YCSB-E scan: ONE Op.PLAN per touched key page, fused in-latch.

        Scans key ids [k, k + len); stored key of id k is k + 1, and ids
        are laid out contiguously (page p holds ids [p*504, (p+1)*504)),
        so the plan only needs the pages overlapping the stored-key range
        [lo, hi) — at most ceil(len/504) + 1 of them.  Key pages are
        never reprogrammed, so a scan needs no ordering against the write
        stream — only the open read burst is resolved first so the plan
        flush stays a dedicated launch.
        """
        nonlocal flushes, n_scans
        resolve_burst()
        k = int(workload.keys[qi])
        lo = k + 1
        hi = min(lo + int(workload.scan_lens[qi]), n_keys + 1)
        if lo >= hi:
            return
        p0 = (lo - 1) // KEYS_PER_PAGE     # page of stored key lo
        p1 = (hi - 2) // KEYS_PER_PAGE     # page of stored key hi - 1
        try:
            bitmaps = evaluate_plan_on_pages(
                backend, exact_range(lo, hi, width=64),
                list(range(p0, min(p1, n_key_pages - 1) + 1)))
        except UncorrectableReadError:
            # Any touched page failing outer-code decode voids the whole
            # scan — a partial count would be a silently wrong result.
            read_errors[qi] = True
            flushes += 1
            n_scans += 1
            return
        flushes += 1
        total = 0
        for bm in bitmaps:
            bits = unpack_bitmap(mask_header_slots(bm), 512)
            total += int(bits.sum())
        scan_counts[qi] = total
        n_scans += 1

    n_reads = n_writes = programs = write_flushes = 0
    for qi in range(n):
        if workload.ops[qi] == 0:
            n_reads += 1
            if wb is not None:
                # Read-your-writes from DRAM: a dirty value page serves the
                # read straight from the buffered image — no device command.
                # (Key pages are never written, so a buffered value page
                # always implies the key exists on its key page.)
                overlay = wb.get(int(workload.value_pages[qi]))
                if overlay is not None:
                    k = int(workload.keys[qi])
                    out[qi] = overlay[k % KEYS_PER_PAGE]
                    hits[qi] = True
                    continue
            pending.append(qi)
            if len(pending) >= burst:
                resolve_burst()
        elif workload.ops[qi] == 2:
            run_scan(qi)
        else:
            n_writes += 1
            k = int(workload.keys[qi])
            values[k] = np.uint64(qi * 2 + 1)   # tagged by op index, odd
            p = k // KEYS_PER_PAGE
            s = p * KEYS_PER_PAGE
            if wb is not None:
                # Absorb into the DRAM buffer; the on-flash image stays as
                # queued reads expect it until the grouped flush below.
                wb.put(value_page_of(p, n_key_pages),
                       values[s:s + KEYS_PER_PAGE])
                if wb.should_flush:
                    resolve_burst()     # queued reads precede the programs
                    if reliability is not None:
                        drain_inflight()
                    programs += wb.flush(backend)
                    write_flushes += 1
            else:
                resolve_burst()             # read-your-writes ordering
                if reliability is not None:
                    # The reliability finalize verifies hits against the
                    # on-flash image at RESOLVE time (selective
                    # verification is a re-read, not a kernel output), so
                    # the image must not change under an in-flight burst:
                    # drain the depth-1 pipeline before reprogramming.
                    drain_inflight()
                backend.program_entries(value_page_of(p, n_key_pages),
                                        values[s:s + KEYS_PER_PAGE])
                programs += 1
    resolve_burst()
    if wb is not None and wb.n_dirty:
        if reliability is not None:
            drain_inflight()    # resolve-time verification, see write path
        programs += wb.flush(backend)
        write_flushes += 1
    drain_inflight()
    refreshes = 0
    if reliability is not None:
        refreshes = _drain_refreshes(backend, reliability)
    result = FunctionalRunResult(
        read_values=out, read_hits=hits, n_reads=n_reads, n_writes=n_writes,
        flushes=flushes,
        kernel_launches=backend.stats.kernel_launches,
        staged_bytes=backend.stats.staged_bytes,
        result_bytes=backend.stats.result_bytes,
        programs=programs, write_flushes=write_flushes,
        buffer_read_hits=wb.stats.read_hits if wb is not None else 0,
        scan_counts=scan_counts if n_scans else None, n_scans=n_scans,
        read_errors=read_errors if reliability is not None else None,
        n_read_errors=int(read_errors.sum()), refreshes=refreshes,
        reliability_stats=reliability.stats if reliability is not None
        else None)
    if timeline is not None:
        result.burst_latencies_ns = np.asarray(timeline.burst_latencies)
        result.write_latencies_ns = np.asarray(timeline.write_latencies)
        result.sim_makespan_ns = timeline.now
        result.sim_energy_pj = timeline.energy_pj
    return result


def _drain_refreshes(backend, reliability) -> int:
    """Rewrite every page the open bursts flagged CLEAN_NEEDS_REFRESH.

    A refresh is read-through-ECC then reprogram: sub-threshold raw errors
    are corrected (the simulator's ``_repair`` restores the clean image),
    the entries are re-extracted and ride the deferred ``Op.PROGRAM`` path
    with a fresh timestamp — so the rewrite groups and coalesces exactly
    like workload writes and later opens see a young, error-free page.
    Pages whose raw error count exceeds the outer-code budget cannot be
    refreshed (the data is gone); they stay marked and keep surfacing as
    typed errors.
    """
    from repro.core.page import entries_from_plain
    chips = backend.chips
    tickets = []
    for addr in sorted(reliability.refresh_due):
        chip, local = chips.route(addr)
        sp = chip.pages.get(local)
        if sp is None:
            continue
        if sp.injected_error_bits > reliability.policy.ecc.t_correctable:
            continue                       # beyond refresh: uncorrectable
        if sp.injected_error_bits:
            reliability.stats.corrected_bits += sp.injected_error_bits
            chip._repair(sp, local)
        plain = chip._derandomize_page(sp, local)
        entries = entries_from_plain(plain, sp.n_entries)
        tickets.append(backend.submit_program(
            addr, entries, timestamp_ns=reliability.now_ns))
    if tickets:
        backend.flush()
    reliability.refresh_due.clear()
    reliability.stats.refreshes += len(tickets)
    return len(tickets)


def run(workload: Workload, *, params: FlashParams, system: str,
        cache_coverage: float, clients: int = 16,
        full_page_read_ratio: float = 0.0,
        batch_deadline_ns: float | None = None,
        power_budget_ma: float | None = None, seed: int = 0) -> RunResult:
    """Execute a workload closed-loop on one simulated SSD."""
    cache_pages = int(round(cache_coverage * workload.n_index_pages))
    sim = SSDSim(params, n_index_pages=workload.n_index_pages,
                 cache_pages=cache_pages, system=system,
                 power_budget_ma=power_budget_ma, seed=seed)
    rng = np.random.default_rng(seed + 17)

    n = len(workload.ops)
    warmup = int(n * WARMUP_FRACTION)
    # Closed loop: heap of (ready_time, client, next_query_index).
    heap = [(0.0, c) for c in range(clients)]
    heapq.heapify(heap)
    next_q = 0
    warmup_end_t = None
    energy_at_warmup = 0.0
    stats_mark = None
    lat_mark = 0

    # Deadline batching (§IV-E): queries wait up to deadline for same-page
    # peers.  Approximated by counting same-page arrivals within the window
    # using a small pending map keyed by page.
    pending_same_page: dict[int, list[float]] = {}

    n_key_pages = workload.n_index_pages // 2
    n_keys = n_key_pages * KEYS_PER_PAGE

    def scan_pages(qi: int) -> list[int]:
        """Key pages a YCSB-E scan touches — same placement arithmetic as
        the functional executor's ``run_scan``, so both executors model an
        identical page footprint for one op stream."""
        if workload.keys is None or workload.scan_lens is None:
            return [int(workload.key_pages[qi])]
        lo = int(workload.keys[qi]) + 1          # stored key of id k is k+1
        hi = min(lo + int(workload.scan_lens[qi]), n_keys + 1)
        if lo >= hi:
            return []
        p0 = (lo - 1) // KEYS_PER_PAGE
        p1 = (hi - 2) // KEYS_PER_PAGE
        return list(range(p0, min(p1, n_key_pages - 1) + 1))

    while next_q < n:
        now, client = heapq.heappop(heap)
        op = workload.ops[next_q]
        kp = int(workload.key_pages[next_q])
        vp = int(workload.value_pages[next_q])

        if next_q == warmup:
            warmup_end_t = now
            energy_at_warmup = sim.energy.total_pj
            stats_mark = dataclasses.replace(sim.stats)
            lat_mark = len(sim.read_latencies)

        if op == 0:
            batch_extra = 0
            if batch_deadline_ns is not None and system == "sim":
                window = pending_same_page.setdefault(kp, [])
                window[:] = [t for t in window if t >= now - batch_deadline_ns]
                batch_extra = len(window)
                window.append(now)
                # queries joining a batch pay the residual wait
                now = now + (batch_deadline_ns if batch_extra == 0 else 0.0)
            full = (system == "sim"
                    and rng.random() < full_page_read_ratio)
            end = sim.read(kp, vp, now, force_full_page=full,
                           batch_extra=batch_extra)
        elif op == 2:
            # YCSB-E scan: a match-mode multi-page READ.  This used to fall
            # into the write branch below, counting every scan as a page
            # write (wrong QPS/latency/energy, phantom programs on any
            # scan_ratio > 0 workload).
            end = sim.scan(scan_pages(next_q), now)
        else:
            end = sim.submit_write(kp, vp, now)
        heapq.heappush(heap, (end, client))
        next_q += 1

    makespan = max(t for t, _ in heap) - (warmup_end_t or 0.0)
    lats = np.array(sim.read_latencies[lat_mark:]) if sim.read_latencies \
        else np.array([0.0])
    measured = n - warmup
    s, m = sim.stats, stats_mark
    return RunResult(
        qps=measured / (makespan / 1e9) if makespan > 0 else 0.0,
        read_median_ns=float(np.median(lats)),
        read_p25_ns=float(np.percentile(lats, 25)),
        read_p75_ns=float(np.percentile(lats, 75)),
        read_p99_ns=float(np.percentile(lats, 99)),
        energy_pj=sim.energy.total_pj - energy_at_warmup,
        programs=s.programs - (m.programs if m else 0),
        senses=s.senses - (m.senses if m else 0),
        internal_bytes=s.internal_bytes - (m.internal_bytes if m else 0),
        pcie_bytes=s.pcie_bytes - (m.pcie_bytes if m else 0),
        cache_hit_rate=sim.cache.stats.hit_rate,
        absorbed_writes=sim.cache.stats.absorbed_writes,
        batched_searches=s.batched_searches - (m.batched_searches if m else 0),
        makespan_ns=makespan,
        writes=s.writes - (m.writes if m else 0),
        scans=s.scans - (m.scans if m else 0),
    )
