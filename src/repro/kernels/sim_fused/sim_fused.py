"""Pallas TPU kernels: fused SiM search + gather.

The paper notes a search is commonly followed immediately by a gather on the
same page, and the chip pipelines them because the page already sits in the
page buffers (§III-B, §V-A).  The TPU analogue is fusion: one VMEM residency
of the page tile feeds both the match and the compaction matmul, halving HBM
page reads for the search->gather pattern that dominates B+Tree lookups.

Two kernels live here:

  * ``sim_fused_kernel`` — the cross-product form: Q queries against N
    pages, each (query, page) cell returning its packed bitmap plus the
    matching chunks compacted from the *same* page.  Pages carry per-row
    flash addresses and device seeds (same operand scheme as ``sim_search``)
    so one launch batches pages from different chips.
  * ``sim_lookup_kernel`` — the paired form the index/workload read burst
    produces: row i matches query i against *key* page i, selects the first
    matching user slot in-kernel (header chunk masked), and gathers the
    slot's 64 B chunk from the paired *value* page i — search + slot select
    + value gather in ONE launch, no bitmap round trip through the host.

Gathered chunks come back *randomized* when the store is randomized (the
gather bus payload is the raw latch content); the controller/host
de-randomizes per chunk — tests cover the round trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bits import mix2_32
from repro.core.randomize import _HI_SALT, _LO_SALT

SLOTS = 512
CHUNKS = 64
WORDS = 16
BITMAP_WORDS = 16
SLOTS_PER_CHUNK = 8
NO_SLOT = SLOTS          # first-match sentinel: no user slot matched


def _match_bits(lo, hi, q_lo, q_hi, m_lo, m_hi, page, seed, *,
                shape, randomized: bool):
    """Masked XOR match with in-VMEM stream regeneration (§IV-C1).

    ``page``/``seed`` are (PB, 1) uint32 per-page operands; the stream
    counter for slot s of page p is ``(page[p] * 512 + s) ^ seed[p]`` —
    identical to core/randomize.py, so one launch spans chips.
    """
    if randomized:
        slot = jax.lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 1)
        ctr = (page * jnp.uint32(SLOTS) + slot) ^ seed
        q_lo = q_lo ^ mix2_32(ctr, _LO_SALT, jnp)
        q_hi = q_hi ^ mix2_32(ctr, _HI_SALT, jnp)
    mismatch = ((lo ^ q_lo) & m_lo) | ((hi ^ q_hi) & m_hi)
    return (mismatch == 0).astype(jnp.uint32)


def _pack_bits(bits, lead_shape):
    """(..., 512) {0,1} -> (..., 16) uint32 packed bitmap, in VMEM."""
    b = bits.reshape(*lead_shape, BITMAP_WORDS, 32)
    sh = jax.lax.broadcasted_iota(jnp.uint32, b.shape, b.ndim - 1)
    return (b << sh).sum(axis=-1).astype(jnp.uint32)


def _split16_select(sel_f32, lo, hi, page_block: int):
    """One-hot chunk selection via the split-16 exact MXU matmul.

    sel_f32: (PB, M, 64) or (PB, 64) one-hot rows; lo/hi: (PB, 512) planes.
    Returns the selected chunk words, uint32, front-packed along M.
    """
    lo_c = lo.reshape(page_block, CHUNKS, SLOTS_PER_CHUNK)
    hi_c = hi.reshape(page_block, CHUNKS, SLOTS_PER_CHUNK)
    chunks = jnp.stack([lo_c, hi_c], axis=-1).reshape(
        page_block, CHUNKS, WORDS)                 # interleaved words
    c_lo = (chunks & jnp.uint32(0xFFFF)).astype(jnp.float32)
    c_hi = (chunks >> jnp.uint32(16)).astype(jnp.float32)
    contract = sel_f32.ndim - 1
    dn = (((contract,), (1,)), ((0,), (0,)))
    g_lo = jax.lax.dot_general(sel_f32, c_lo, dn,
                               preferred_element_type=jnp.float32)
    g_hi = jax.lax.dot_general(sel_f32, c_hi, dn,
                               preferred_element_type=jnp.float32)
    return g_lo.astype(jnp.uint32) | (g_hi.astype(jnp.uint32)
                                      << jnp.uint32(16))


# ---------------------------------------------------------------------------
# Cross-product fused kernel: Q queries x N pages, same-page chunk gather.
# ---------------------------------------------------------------------------

def _fused_kernel(lo_ref, hi_ref, q_ref, m_ref, page_ref, seed_ref, bm_ref,
                  out_ref, cnt_ref, *, page_block: int, max_out: int,
                  randomized: bool):
    lo = lo_ref[...]                                   # (PB, 512)
    hi = hi_ref[...]
    q = q_ref[...]                                     # (1, 2): query j
    m = m_ref[...]
    bits = _match_bits(lo, hi, q[0, 0], q[0, 1], m[0, 0], m[0, 1],
                       page_ref[...], seed_ref[...],
                       shape=(page_block, SLOTS), randomized=randomized)

    # --- search output: packed 64 B bitmap per page
    bm_ref[...] = _pack_bits(bits, (page_block,))[None]

    # --- gather phase, reusing the resident planes
    chunk_bits = (bits.reshape(page_block, CHUNKS, SLOTS_PER_CHUNK
                               ).sum(axis=2) > 0).astype(jnp.uint32)
    pos = jnp.cumsum(chunk_bits, axis=1, dtype=jnp.uint32) - chunk_bits
    m_ids = jax.lax.broadcasted_iota(jnp.uint32,
                                     (page_block, max_out, CHUNKS), 1)
    sel = ((pos[:, None, :] == m_ids) & (chunk_bits[:, None, :] == 1)
           ).astype(jnp.float32)
    out_ref[...] = _split16_select(sel, lo, hi, page_block)[None]
    cnt_ref[...] = chunk_bits.sum(axis=1, dtype=jnp.int32)[None]


@functools.partial(jax.jit, static_argnames=("page_block", "max_out",
                                             "randomized", "interpret"))
def sim_fused_kernel(lo, hi, queries, masks, page_ids, page_seeds, *,
                     page_block: int = 16, max_out: int = 16,
                     randomized: bool = False, interpret: bool = True):
    """Fused multi-query search+gather.

    lo, hi:      (N, 512) uint32 planes, N a multiple of ``page_block``
    queries:     (Q, 2) uint32;  masks: (Q, 2) uint32
    page_ids:    (N,) uint32 per-page flash addresses
    page_seeds:  (N,) uint32 per-page device seeds
    returns:     (bitmaps (Q, N, 16) uint32,
                  gathered (Q, N, max_out, 16) uint32,
                  counts (Q, N) int32)
    """
    n = lo.shape[0]
    n_q = queries.shape[0]
    assert n % page_block == 0, (n, page_block)
    kernel = functools.partial(_fused_kernel, page_block=page_block,
                               max_out=max_out, randomized=randomized)
    return pl.pallas_call(
        kernel,
        grid=(n // page_block, n_q),
        in_specs=[
            pl.BlockSpec((page_block, SLOTS), lambda i, j: (i, 0)),
            pl.BlockSpec((page_block, SLOTS), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((page_block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((page_block, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, page_block, BITMAP_WORDS),
                         lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, page_block, max_out, WORDS),
                         lambda i, j: (j, i, 0, 0)),
            pl.BlockSpec((1, page_block), lambda i, j: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_q, n, BITMAP_WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((n_q, n, max_out, WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((n_q, n), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(lo, jnp.uint32), jnp.asarray(hi, jnp.uint32),
      jnp.asarray(queries, jnp.uint32), jnp.asarray(masks, jnp.uint32),
      jnp.asarray(page_ids, jnp.uint32).reshape(-1, 1),
      jnp.asarray(page_seeds, jnp.uint32).reshape(-1, 1))


# ---------------------------------------------------------------------------
# Paired lookup kernel: query i -> key page i -> value page i, one launch.
# ---------------------------------------------------------------------------

def _lookup_kernel(klo_ref, khi_ref, vlo_ref, vhi_ref, q_ref, m_ref,
                   kid_ref, kseed_ref, bm_ref, val_ref, slot_ref, *,
                   row_block: int, randomized: bool):
    klo = klo_ref[...]                                 # (RB, 512) key planes
    khi = khi_ref[...]
    q = q_ref[...]                                     # (RB, 2) per-row query
    m = m_ref[...]
    bits = _match_bits(klo, khi, q[:, 0:1], q[:, 1:2], m[:, 0:1], m[:, 1:2],
                       kid_ref[...], kseed_ref[...],
                       shape=(row_block, SLOTS), randomized=randomized)

    # Raw packed bitmap (bit-identical to a search command's bus payload).
    bm_ref[...] = _pack_bits(bits, (row_block,))

    # First matching *user* slot: the header chunk (slots 0..7) never holds
    # entries — index software strips it host-side; here the strip happens
    # in-VMEM so the whole match->gather hop needs no host round trip.
    slot = jax.lax.broadcasted_iota(jnp.uint32, (row_block, SLOTS), 1)
    user = jnp.where(slot >= jnp.uint32(SLOTS_PER_CHUNK), bits,
                     jnp.uint32(0))
    first = jnp.where(user == 1, slot, jnp.uint32(NO_SLOT)).min(axis=1)
    found = first < NO_SLOT                            # (RB,)
    slot_ref[...] = first.astype(jnp.int32)[:, None]

    # Gather the matched slot's chunk from the paired VALUE page row.
    chunk = jnp.minimum(first >> jnp.uint32(3), jnp.uint32(CHUNKS - 1))
    cidx = jax.lax.broadcasted_iota(jnp.uint32, (row_block, CHUNKS), 1)
    sel = ((cidx == chunk[:, None]) & found[:, None]).astype(jnp.float32)
    val_ref[...] = _split16_select(sel, vlo_ref[...], vhi_ref[...],
                                   row_block)


@functools.partial(jax.jit, static_argnames=("row_block", "randomized",
                                             "interpret"))
def sim_lookup_kernel(klo, khi, vlo, vhi, queries, masks, key_ids, key_seeds,
                      *, row_block: int = 8, randomized: bool = False,
                      interpret: bool = True):
    """Paired search->slot-select->value-gather, one launch for B lookups.

    klo, khi:   (B, 512) uint32 key-page planes (row i serves lookup i)
    vlo, vhi:   (B, 512) uint32 value-page planes, paired per row
    queries:    (B, 2) uint32 per-row queries;  masks: (B, 2) uint32
    key_ids:    (B,) uint32 key-page flash addresses (stream regeneration)
    key_seeds:  (B,) uint32 key-page device seeds
    returns:    (bitmaps (B, 16) uint32 — raw key-page match bitmaps,
                 value_words (B, 16) uint32 — the matched slot's 64 B value
                 chunk, randomized as stored,
                 slots (B,) int32 — first matching user slot, 512 if none)
    """
    b = klo.shape[0]
    assert b % row_block == 0, (b, row_block)
    kernel = functools.partial(_lookup_kernel, row_block=row_block,
                               randomized=randomized)
    bm, val, slot = pl.pallas_call(
        kernel,
        grid=(b // row_block,),
        in_specs=[
            pl.BlockSpec((row_block, SLOTS), lambda i: (i, 0)),
            pl.BlockSpec((row_block, SLOTS), lambda i: (i, 0)),
            pl.BlockSpec((row_block, SLOTS), lambda i: (i, 0)),
            pl.BlockSpec((row_block, SLOTS), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 2), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 2), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 1), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((row_block, BITMAP_WORDS), lambda i: (i, 0)),
            pl.BlockSpec((row_block, WORDS), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, BITMAP_WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((b, WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(klo, jnp.uint32), jnp.asarray(khi, jnp.uint32),
      jnp.asarray(vlo, jnp.uint32), jnp.asarray(vhi, jnp.uint32),
      jnp.asarray(queries, jnp.uint32), jnp.asarray(masks, jnp.uint32),
      jnp.asarray(key_ids, jnp.uint32).reshape(-1, 1),
      jnp.asarray(key_seeds, jnp.uint32).reshape(-1, 1))
    return bm, val, slot[:, 0]
