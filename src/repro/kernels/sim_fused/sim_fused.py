"""Pallas TPU kernel: fused SiM search + gather (single query).

The paper notes a search is commonly followed immediately by a gather on the
same page, and the chip pipelines them because the page already sits in the
page buffers (§III-B, §V-A).  The TPU analogue is fusion: one VMEM residency
of the page tile feeds both the match and the compaction matmul, halving HBM
page reads for the search->gather pattern that dominates B+Tree lookups.

Gathered chunks come back *randomized* when the store is randomized (the
gather bus payload is the raw latch content); the controller/host
de-randomizes per chunk — tests cover the round trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bits import mix2_32
from repro.core.randomize import _HI_SALT, _LO_SALT

SLOTS = 512
CHUNKS = 64
WORDS = 16
BITMAP_WORDS = 16


def _fused_kernel(lo_ref, hi_ref, q_ref, m_ref, base_ref, bm_ref, out_ref,
                  cnt_ref, *, page_block: int, max_out: int,
                  randomized: bool, device_seed: int):
    lo = lo_ref[...]                                   # (PB, 512)
    hi = hi_ref[...]
    q = q_ref[...]                                     # (1, 2)
    m = m_ref[...]
    q_lo, q_hi = q[0, 0], q[0, 1]
    m_lo, m_hi = m[0, 0], m[0, 1]

    if randomized:
        tile = pl.program_id(0).astype(jnp.uint32)
        page_in_tile = jax.lax.broadcasted_iota(jnp.uint32,
                                                (page_block, SLOTS), 0)
        slot = jax.lax.broadcasted_iota(jnp.uint32, (page_block, SLOTS), 1)
        page = base_ref[0, 0] + tile * jnp.uint32(page_block) + page_in_tile
        ctr = (page * jnp.uint32(SLOTS) + slot) ^ jnp.uint32(
            device_seed & 0xFFFFFFFF)
        q_lo = q_lo ^ mix2_32(ctr, _LO_SALT, jnp)
        q_hi = q_hi ^ mix2_32(ctr, _HI_SALT, jnp)

    mismatch = ((lo ^ q_lo) & m_lo) | ((hi ^ q_hi) & m_hi)
    bits = (mismatch == 0).astype(jnp.uint32)          # (PB, 512)

    # --- search output: packed 64 B bitmap per page
    b = bits.reshape(page_block, BITMAP_WORDS, 32)
    sh = jax.lax.broadcasted_iota(jnp.uint32,
                                  (page_block, BITMAP_WORDS, 32), 2)
    bm_ref[...] = (b << sh).sum(axis=2).astype(jnp.uint32)

    # --- gather phase, reusing the resident planes
    chunk_bits = (bits.reshape(page_block, CHUNKS, 8).sum(axis=2)
                  > 0).astype(jnp.uint32)              # (PB, 64)
    pos = jnp.cumsum(chunk_bits, axis=1, dtype=jnp.uint32) - chunk_bits
    m_ids = jax.lax.broadcasted_iota(jnp.uint32,
                                     (page_block, max_out, CHUNKS), 1)
    sel = ((pos[:, None, :] == m_ids) & (chunk_bits[:, None, :] == 1)
           ).astype(jnp.float32)

    lo_c = lo.reshape(page_block, CHUNKS, 8)
    hi_c = hi.reshape(page_block, CHUNKS, 8)
    chunks = jnp.stack([lo_c, hi_c], axis=-1).reshape(
        page_block, CHUNKS, WORDS)                     # interleaved words
    c_lo = (chunks & jnp.uint32(0xFFFF)).astype(jnp.float32)
    c_hi = (chunks >> jnp.uint32(16)).astype(jnp.float32)
    dn = (((2,), (1,)), ((0,), (0,)))
    g_lo = jax.lax.dot_general(sel, c_lo, dn,
                               preferred_element_type=jnp.float32)
    g_hi = jax.lax.dot_general(sel, c_hi, dn,
                               preferred_element_type=jnp.float32)
    out_ref[...] = (g_lo.astype(jnp.uint32)
                    | (g_hi.astype(jnp.uint32) << jnp.uint32(16)))
    cnt_ref[...] = chunk_bits.sum(axis=1, dtype=jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("page_block", "max_out",
                                             "randomized", "device_seed",
                                             "interpret"))
def sim_fused_kernel(lo, hi, query, mask, page_base, *, page_block: int = 16,
                     max_out: int = 16, randomized: bool = False,
                     device_seed: int = 0, interpret: bool = True):
    n = lo.shape[0]
    assert n % page_block == 0
    kernel = functools.partial(_fused_kernel, page_block=page_block,
                               max_out=max_out, randomized=randomized,
                               device_seed=device_seed)
    return pl.pallas_call(
        kernel,
        grid=(n // page_block,),
        in_specs=[
            pl.BlockSpec((page_block, SLOTS), lambda i: (i, 0)),
            pl.BlockSpec((page_block, SLOTS), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((page_block, BITMAP_WORDS), lambda i: (i, 0)),
            pl.BlockSpec((page_block, max_out, WORDS), lambda i: (i, 0, 0)),
            pl.BlockSpec((page_block, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, BITMAP_WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((n, max_out, WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(lo, jnp.uint32), jnp.asarray(hi, jnp.uint32),
      jnp.asarray(query, jnp.uint32).reshape(1, 2),
      jnp.asarray(mask, jnp.uint32).reshape(1, 2),
      jnp.asarray(page_base, jnp.uint32).reshape(1, 1))
