"""Public wrappers for the fused search+gather kernels: layout, padding,
fallback, and the single-query compatibility squeeze."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import default_interpret
from .ref import sim_fused_ref, sim_lookup_ref
from .sim_fused import sim_fused_kernel, sim_lookup_kernel


def _resolve_pages(n, page_base, device_seed, page_ids, page_seeds):
    if page_ids is None:
        page_ids = jnp.uint32(page_base) + jnp.arange(n, dtype=jnp.uint32)
    if page_seeds is None:
        page_seeds = jnp.full(n, device_seed & 0xFFFFFFFF, jnp.uint32)
    return page_ids, page_seeds


def sim_fused(lo, hi, queries, masks, *, max_out: int = 16,
              page_block: int = 16, page_base: int = 0,
              randomized: bool = False, device_seed: int = 0,
              interpret: bool | None = None, use_kernel: bool = True,
              page_ids=None, page_seeds=None):
    """Fused multi-query search+gather over page planes.

    queries/masks may be (2,) (single query — outputs lose the leading Q
    axis, the historical API) or (Q, 2).  ``page_ids``/``page_seeds`` give
    each staged page its own flash address and device seed, so one launch
    batches pages from different chips (same scheme as ``sim_search``).

    Returns (slot_bitmaps (Q, N, 16), gathered (Q, N, max_out, 16),
    counts (Q, N)) — without the Q axis for a single 1-D query.
    """
    queries = jnp.asarray(queries, jnp.uint32)
    masks = jnp.asarray(masks, jnp.uint32)
    single = queries.ndim == 1
    queries = jnp.atleast_2d(queries)
    masks = jnp.atleast_2d(masks)
    lo = jnp.asarray(lo, jnp.uint32)
    hi = jnp.asarray(hi, jnp.uint32)
    if not use_kernel:
        bm, out, cnt = sim_fused_ref(
            lo, hi, queries, masks, max_out=max_out, randomized=randomized,
            page_base=page_base, device_seed=device_seed,
            page_ids=page_ids, page_seeds=page_seeds)
    else:
        interpret = default_interpret() if interpret is None else interpret
        n = lo.shape[0]
        page_ids, page_seeds = _resolve_pages(n, page_base, device_seed,
                                              page_ids, page_seeds)
        pad = (-n) % page_block
        if pad:
            lo = jnp.pad(lo, ((0, pad), (0, 0)))
            hi = jnp.pad(hi, ((0, pad), (0, 0)))
            page_ids = jnp.pad(jnp.asarray(page_ids, jnp.uint32), (0, pad))
            page_seeds = jnp.pad(jnp.asarray(page_seeds, jnp.uint32),
                                 (0, pad))
        bm, out, cnt = sim_fused_kernel(
            lo, hi, queries, masks, page_ids, page_seeds,
            page_block=page_block, max_out=max_out, randomized=randomized,
            interpret=interpret)
        bm, out, cnt = bm[:, :n], out[:, :n], cnt[:, :n]
    if single:
        return bm[0], out[0], cnt[0]
    return bm, out, cnt


def sim_fused_lookup(klo, khi, vlo, vhi, queries, masks, *,
                     row_block: int = 8, randomized: bool = False,
                     page_base: int = 0, device_seed: int = 0,
                     interpret: bool | None = None, use_kernel: bool = True,
                     key_ids=None, key_seeds=None):
    """Paired lookup burst: search key row i, gather value row i — 1 launch.

    Returns (bitmaps (B, 16), value_words (B, 16) — randomized as stored,
    slots (B,) int32 with 512 meaning "no user slot matched").
    """
    klo = jnp.asarray(klo, jnp.uint32)
    khi = jnp.asarray(khi, jnp.uint32)
    vlo = jnp.asarray(vlo, jnp.uint32)
    vhi = jnp.asarray(vhi, jnp.uint32)
    queries = jnp.atleast_2d(jnp.asarray(queries, jnp.uint32))
    masks = jnp.atleast_2d(jnp.asarray(masks, jnp.uint32))
    if not use_kernel:
        return sim_lookup_ref(klo, khi, vlo, vhi, queries, masks,
                              randomized=randomized, page_base=page_base,
                              device_seed=device_seed, key_ids=key_ids,
                              key_seeds=key_seeds)
    interpret = default_interpret() if interpret is None else interpret
    b = klo.shape[0]
    key_ids, key_seeds = _resolve_pages(b, page_base, device_seed,
                                        key_ids, key_seeds)
    pad = (-b) % row_block
    if pad:
        p2 = ((0, pad), (0, 0))
        klo, khi = jnp.pad(klo, p2), jnp.pad(khi, p2)
        vlo, vhi = jnp.pad(vlo, p2), jnp.pad(vhi, p2)
        queries = jnp.pad(queries, p2)
        masks = jnp.pad(masks, p2)
        key_ids = jnp.pad(jnp.asarray(key_ids, jnp.uint32), (0, pad))
        key_seeds = jnp.pad(jnp.asarray(key_seeds, jnp.uint32), (0, pad))
    bm, val, slot = sim_lookup_kernel(
        klo, khi, vlo, vhi, queries, masks, key_ids, key_seeds,
        row_block=row_block, randomized=randomized, interpret=interpret)
    return bm[:b], val[:b], slot[:b]


def sim_fused_pages(pages_bytes: np.ndarray, queries_u64, masks_u64, **kw):
    """Convenience: raw (N, 4096) uint8 pages + uint64 queries/masks."""
    from repro.core.bits import u64_array_to_pairs
    from repro.kernels.layout import pages_to_planes
    lo, hi = pages_to_planes(pages_bytes)
    q = u64_array_to_pairs(np.atleast_1d(np.asarray(queries_u64,
                                                    dtype=np.uint64)))
    m = u64_array_to_pairs(np.atleast_1d(np.asarray(masks_u64,
                                                    dtype=np.uint64)))
    return sim_fused(lo, hi, q, m, **kw)
