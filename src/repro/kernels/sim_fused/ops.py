"""Public wrapper for the fused search+gather kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import default_interpret
from .ref import sim_fused_ref
from .sim_fused import sim_fused_kernel


def sim_fused(lo, hi, query, mask, *, max_out: int = 16,
              page_block: int = 16, page_base: int = 0,
              randomized: bool = False, device_seed: int = 0,
              interpret: bool | None = None, use_kernel: bool = True):
    """Fused single-query search+gather over page planes.

    Returns (slot_bitmap (N, 16), gathered (N, max_out, 16), counts (N,)).
    """
    lo = jnp.asarray(lo, jnp.uint32)
    hi = jnp.asarray(hi, jnp.uint32)
    if not use_kernel:
        return sim_fused_ref(lo, hi, query, mask, max_out=max_out,
                             randomized=randomized, page_base=page_base,
                             device_seed=device_seed)
    interpret = default_interpret() if interpret is None else interpret
    n = lo.shape[0]
    pad = (-n) % page_block
    if pad:
        lo = jnp.pad(lo, ((0, pad), (0, 0)))
        hi = jnp.pad(hi, ((0, pad), (0, 0)))
    bm, out, cnt = sim_fused_kernel(
        lo, hi, jnp.asarray(query, jnp.uint32), jnp.asarray(mask, jnp.uint32),
        page_base, page_block=page_block, max_out=max_out,
        randomized=randomized, device_seed=device_seed, interpret=interpret)
    return bm[:n], out[:n], cnt[:n, 0]
