"""Pure-jnp oracles for the fused search+gather kernels."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bits import pack_bitmap
from repro.kernels.layout import planes_to_chunk_words_xp
from repro.kernels.sim_search.ref import stream_planes

NO_SLOT = 512


def sim_fused_ref(lo, hi, queries, masks, *, max_out: int,
                  randomized: bool = False, page_base: int = 0,
                  device_seed: int = 0, page_ids=None, page_seeds=None):
    """Multi-query search -> chunk-select -> gather, one logical page pass.

    lo, hi: (N, 512) uint32 planes;  queries, masks: (Q, 2) uint32
    Returns (slot_bitmaps (Q, N, 16) uint32,
             gathered (Q, N, max_out, 16) uint32,
             counts (Q, N) int32) — counts are *chunk* counts.
    """
    lo = jnp.asarray(lo, jnp.uint32)
    hi = jnp.asarray(hi, jnp.uint32)
    q = jnp.atleast_2d(jnp.asarray(queries, jnp.uint32))
    m = jnp.atleast_2d(jnp.asarray(masks, jnp.uint32))
    n = lo.shape[0]
    if randomized:
        s_lo, s_hi = stream_planes(page_base, n, device_seed,
                                   page_ids=page_ids, page_seeds=page_seeds)
        q_lo = q[:, None, None, 0] ^ s_lo[None]        # (Q, N, 512)
        q_hi = q[:, None, None, 1] ^ s_hi[None]
    else:
        q_lo = q[:, None, None, 0]
        q_hi = q[:, None, None, 1]
    mm = ((lo[None] ^ q_lo) & m[:, None, None, 0]) | (
        (hi[None] ^ q_hi) & m[:, None, None, 1])
    bits = (mm == 0).astype(jnp.uint32)                # (Q, N, 512)
    slot_bitmap = pack_bitmap(bits, xp=jnp)            # (Q, N, 16)

    n_q = q.shape[0]
    chunk_bits = (bits.reshape(n_q, n, 64, 8).sum(axis=3) > 0
                  ).astype(jnp.uint32)                 # (Q, N, 64)
    pos = jnp.cumsum(chunk_bits, axis=2, dtype=jnp.uint32) - chunk_bits
    sel = ((pos[:, :, None, :]
            == jnp.arange(max_out, dtype=jnp.uint32)[None, None, :, None])
           & (chunk_bits[:, :, None, :] == 1)).astype(jnp.uint32)
    chunks = planes_to_chunk_words_xp(lo, hi, jnp)     # (N, 64, 16)
    gathered = jnp.einsum("qnmj,njw->qnmw", sel, chunks).astype(jnp.uint32)
    counts = chunk_bits.sum(axis=2).astype(jnp.int32)
    return slot_bitmap, gathered, counts


def sim_lookup_ref(klo, khi, vlo, vhi, queries, masks, *,
                   randomized: bool = False, page_base: int = 0,
                   device_seed: int = 0, key_ids=None, key_seeds=None):
    """Paired lookup oracle: query i vs key row i, value gather from row i.

    Returns (bitmaps (B, 16) uint32, value_words (B, 16) uint32,
             slots (B,) int32 — first matching user slot, 512 if none).
    """
    klo = jnp.asarray(klo, jnp.uint32)
    khi = jnp.asarray(khi, jnp.uint32)
    q = jnp.asarray(queries, jnp.uint32)
    m = jnp.asarray(masks, jnp.uint32)
    b = klo.shape[0]
    if randomized:
        s_lo, s_hi = stream_planes(page_base, b, device_seed,
                                   page_ids=key_ids, page_seeds=key_seeds)
        q_lo = q[:, 0:1] ^ s_lo                        # (B, 512)
        q_hi = q[:, 1:2] ^ s_hi
    else:
        q_lo, q_hi = q[:, 0:1], q[:, 1:2]
    mm = ((klo ^ q_lo) & m[:, 0:1]) | ((khi ^ q_hi) & m[:, 1:2])
    bits = (mm == 0).astype(jnp.uint32)                # (B, 512)
    bitmap = pack_bitmap(bits, xp=jnp)                 # (B, 16)

    slot = jnp.arange(512, dtype=jnp.uint32)[None, :]
    user = jnp.where(slot >= 8, bits, jnp.uint32(0))
    first = jnp.where(user == 1, slot, jnp.uint32(NO_SLOT)).min(axis=1)
    found = first < NO_SLOT
    chunk = jnp.minimum(first >> jnp.uint32(3), jnp.uint32(63))
    sel = ((jnp.arange(64, dtype=jnp.uint32)[None, :] == chunk[:, None])
           & found[:, None]).astype(jnp.uint32)        # (B, 64)
    vchunks = planes_to_chunk_words_xp(jnp.asarray(vlo, jnp.uint32),
                                       jnp.asarray(vhi, jnp.uint32), jnp)
    value = jnp.einsum("bj,bjw->bw", sel, vchunks).astype(jnp.uint32)
    return bitmap, value, first.astype(jnp.int32)
