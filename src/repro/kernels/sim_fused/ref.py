"""Pure-jnp oracle for the fused search+gather kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bits import pack_bitmap
from repro.kernels.layout import planes_to_chunk_words_xp
from repro.kernels.sim_search.ref import stream_planes


def sim_fused_ref(lo, hi, query, mask, *, max_out: int,
                  randomized: bool = False, page_base: int = 0,
                  device_seed: int = 0):
    """Single-query search -> chunk-select -> gather, one logical page pass.

    lo, hi: (N, 512) uint32 planes;  query, mask: (2,) uint32
    Returns (slot_bitmap (N, 16) uint32, gathered (N, max_out, 16) uint32,
             counts (N,) int32) — counts are *chunk* counts.
    """
    lo = jnp.asarray(lo, jnp.uint32)
    hi = jnp.asarray(hi, jnp.uint32)
    q = jnp.asarray(query, jnp.uint32)
    m = jnp.asarray(mask, jnp.uint32)
    n = lo.shape[0]
    if randomized:
        s_lo, s_hi = stream_planes(page_base, n, device_seed)
        q_lo, q_hi = q[0] ^ s_lo, q[1] ^ s_hi
    else:
        q_lo, q_hi = q[0], q[1]
    mm = ((lo ^ q_lo) & m[0]) | ((hi ^ q_hi) & m[1])
    bits = (mm == 0).astype(jnp.uint32)                    # (N, 512)
    slot_bitmap = pack_bitmap(bits, xp=jnp)                # (N, 16)

    chunk_bits = (bits.reshape(n, 64, 8).sum(axis=2) > 0).astype(jnp.uint32)
    pos = jnp.cumsum(chunk_bits, axis=1, dtype=jnp.uint32) - chunk_bits
    sel = ((pos[:, None, :] == jnp.arange(max_out,
                                          dtype=jnp.uint32)[None, :, None])
           & (chunk_bits[:, None, :] == 1)).astype(jnp.uint32)
    chunks = planes_to_chunk_words_xp(lo, hi, jnp)         # (N, 64, 16)
    gathered = jnp.einsum("nmj,njw->nmw", sel, chunks).astype(jnp.uint32)
    counts = chunk_bits.sum(axis=1).astype(jnp.int32)
    return slot_bitmap, gathered, counts
