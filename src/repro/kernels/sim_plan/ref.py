"""Pure-jnp oracle for the sim_plan kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.bits import pack_bitmap
from repro.kernels.sim_search.ref import stream_planes

from .sim_plan import PASS_EXCLUDE, PASS_INCLUDE


def sim_plan_ref(lo, hi, queries, masks, flags, *, randomized: bool = False,
                 page_base: int = 0, device_seed: int = 0,
                 page_ids=None, page_seeds=None) -> jnp.ndarray:
    """Reference fused range-plan evaluation.

    lo, hi:   (N, 512) uint32 slot-word planes (possibly randomized)
    queries:  (G, P, 2) uint32 pass rows;  masks: (G, P, 2) uint32
    flags:    (G, P) uint32 — PASS_INCLUDE / PASS_EXCLUDE / PASS_PAD
    returns:  (G, N, 16) uint32 combined bitmaps (OR includes, AND-NOT
              excludes — paper Fig 10)
    """
    lo = jnp.asarray(lo, dtype=jnp.uint32)
    hi = jnp.asarray(hi, dtype=jnp.uint32)
    q = jnp.asarray(queries, dtype=jnp.uint32)       # (G, P, 2)
    m = jnp.asarray(masks, dtype=jnp.uint32)
    f = jnp.asarray(flags, dtype=jnp.uint32)         # (G, P)
    if randomized:
        s_lo, s_hi = stream_planes(page_base, lo.shape[0], device_seed,
                                   page_ids=page_ids, page_seeds=page_seeds)
        q_lo = q[..., 0][:, :, None, None] ^ s_lo[None, None]  # (G, P, N, 512)
        q_hi = q[..., 1][:, :, None, None] ^ s_hi[None, None]
    else:
        q_lo = q[..., 0][:, :, None, None]
        q_hi = q[..., 1][:, :, None, None]
    mm = ((lo[None, None] ^ q_lo) & m[..., 0][:, :, None, None]) | (
        (hi[None, None] ^ q_hi) & m[..., 1][:, :, None, None])
    bits = (mm == 0).astype(jnp.uint32)              # (G, P, N, 512)
    is_inc = (f == PASS_INCLUDE).astype(jnp.uint32)[..., None, None]
    is_exc = (f == PASS_EXCLUDE).astype(jnp.uint32)[..., None, None]
    inc = (bits & is_inc).max(axis=1)                # (G, N, 512)
    exc = (bits & is_exc).max(axis=1)
    return pack_bitmap(inc & ~exc, xp=jnp)           # (G, N, 16)


def sim_plan_ref_np(lo, hi, queries, masks, flags, **kw) -> np.ndarray:
    return np.asarray(sim_plan_ref(lo, hi, queries, masks, flags, **kw))
