"""Public wrapper for the SiM fused plan kernel: layout, padding, fallback."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.sim_search.ops import _pad_pages

from .ref import sim_plan_ref
from .sim_plan import PASS_EXCLUDE, PASS_INCLUDE, sim_plan_kernel


def plan_pass_rows(include, exclude, n_passes: int):
    """Dense (P, 2)/(P, 2)/(P,) pass operands from a plan's pass pairs.

    ``include``/``exclude`` are sequences of ``((q_lo, q_hi), (m_lo, m_hi))``
    uint32 pair tuples (the ``Command.plan`` wire format); rows past the
    real passes are PASS_PAD and contribute to neither accumulator.
    """
    if n_passes < len(include) + len(exclude):
        raise ValueError((n_passes, len(include), len(exclude)))
    q = np.zeros((n_passes, 2), dtype=np.uint32)
    m = np.zeros_like(q)
    f = np.zeros(n_passes, dtype=np.uint32)
    for i, (qp, mp) in enumerate(include):
        q[i], m[i], f[i] = qp, mp, PASS_INCLUDE
    base = len(include)
    for i, (qp, mp) in enumerate(exclude):
        q[base + i], m[base + i], f[base + i] = qp, mp, PASS_EXCLUDE
    return q, m, f


def sim_plan(lo, hi, queries, masks, flags, *, page_block: int = 8,
             randomized: bool = False, device_seed: int = 0,
             page_base: int = 0, interpret: bool | None = None,
             use_kernel: bool = True, page_ids=None, page_seeds=None):
    """Fused multi-pass plan evaluation -> (G, N, 16) combined bitmaps.

    One launch evaluates G plan groups (each up to P passes, include OR /
    exclude AND-NOT accumulated in-VMEM, paper Fig 10) against N pages and
    returns ONE combined bitmap per (group, page) — the result payload
    shrinks by the pass count versus per-pass ``sim_search``.
    ``use_kernel=False`` routes through the jnp oracle.
    """
    queries = jnp.asarray(queries, jnp.uint32)
    masks = jnp.asarray(masks, jnp.uint32)
    flags = jnp.asarray(flags, jnp.uint32)
    if queries.ndim == 2:                  # single plan group convenience
        queries, masks, flags = queries[None], masks[None], flags[None]
    if not use_kernel:
        return sim_plan_ref(lo, hi, queries, masks, flags,
                            randomized=randomized, page_base=page_base,
                            device_seed=device_seed, page_ids=page_ids,
                            page_seeds=page_seeds)
    interpret = default_interpret() if interpret is None else interpret
    lo, hi, page_ids, page_seeds, n = _pad_pages(
        jnp.asarray(lo, jnp.uint32), jnp.asarray(hi, jnp.uint32), page_block,
        page_ids, page_seeds)
    out = sim_plan_kernel(lo, hi, queries, masks, flags,
                          page_block=page_block, randomized=randomized,
                          device_seed=device_seed, page_base=page_base,
                          interpret=interpret, page_ids=page_ids,
                          page_seeds=page_seeds)
    return out[:, :n]
