"""Pallas TPU kernel: fused multi-pass range-plan evaluation (paper Fig 10).

A SiM range plan decomposes ``lo <= k < hi`` into P masked-equality passes
(core/range_query.py).  The chip evaluates them *in-latch*: each pass's
match bits are OR-accumulated (include passes) or AND-NOT-accumulated
(exclude passes) into the SDC latch, and only the final combined 512-bit
bitmap — 64 B — crosses the bus.  Per-pass bitmaps never leave the chip.

This kernel is the TPU analogue of that dataflow.  One grid step stages a
tile of ``page_block`` pages into VMEM and sweeps ALL P pass rows of one
plan group against the resident tile: per-pass match bits are reduced with
a masked OR into an include accumulator and an exclude accumulator while
still in VMEM, the AND-NOT combine happens in-register, and only the packed
(PB, 16) combined bitmap is written back to HBM.  Device->host result
traffic therefore shrinks by the pass count versus the per-pass
``sim_search`` path (exact 64-bit plans reach >100 passes), exactly like
the chip's bus.

Operand scheme matches ``sim_search``: each staged page carries its own
flash address and device seed on the sublane axis, so the §IV-C1
randomization stream regenerates in-kernel and one launch batches pages
from different chips.  Plans ride a *group* axis: the grid is
(page tiles, plan groups), each group owning (P, 2) query/mask rows plus a
(P,) flags row marking every pass include / exclude / padding.

VMEM per step ~= 2 * PB * 2 KiB (planes) + P * PB * 2 KiB (pass-match
intermediate); the default PB=8 keeps a 128-pass plan at ~2 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bits import mix2_32
from repro.core.randomize import _HI_SALT, _LO_SALT

SLOTS = 512
BITMAP_WORDS = 16

# Pass flags: how a pass row enters the in-latch accumulation.
PASS_PAD = 0        # padding row — contributes to neither accumulator
PASS_INCLUDE = 1    # OR into the include accumulator
PASS_EXCLUDE = 2    # OR into the exclude accumulator (AND-NOT at the end)


def _plan_kernel(lo_ref, hi_ref, q_ref, m_ref, f_ref, page_ref, seed_ref,
                 out_ref, *, page_block: int, randomized: bool):
    lo = lo_ref[...]                       # (PB, 512) uint32
    hi = hi_ref[...]
    q = q_ref[...][0]                      # (P, 2): this group's pass rows
    m = m_ref[...][0]
    f = f_ref[...][0]                      # (P,) uint32 pass flags

    q_lo = q[:, 0][:, None, None]          # (P, 1, 1)
    q_hi = q[:, 1][:, None, None]
    m_lo = m[:, 0][:, None, None]
    m_hi = m[:, 1][:, None, None]
    if randomized:
        # Deserializer: regenerate the slot-address-counter stream in VMEM
        # from each staged page's own flash address and device seed.
        page = page_ref[...]               # (PB, 1) uint32
        seed = seed_ref[...]
        slot = jax.lax.broadcasted_iota(
            jnp.uint32, (page_block, SLOTS), 1)
        ctr = (page * jnp.uint32(SLOTS) + slot) ^ seed
        q_lo = q_lo ^ mix2_32(ctr, _LO_SALT, jnp)[None]
        q_hi = q_hi ^ mix2_32(ctr, _HI_SALT, jnp)[None]

    mismatch = ((lo[None] ^ q_lo) & m_lo) | ((hi[None] ^ q_hi) & m_hi)
    bits = (mismatch == 0).astype(jnp.uint32)      # (P, PB, 512)

    # In-latch accumulation (Fig 10): masked OR over the include passes,
    # masked OR over the exclude passes, one AND-NOT combine — all while
    # the per-pass bits are still resident in VMEM.
    is_inc = (f == jnp.uint32(PASS_INCLUDE)).astype(jnp.uint32)[:, None, None]
    is_exc = (f == jnp.uint32(PASS_EXCLUDE)).astype(jnp.uint32)[:, None, None]
    inc = (bits & is_inc).max(axis=0)              # (PB, 512) 0/1
    exc = (bits & is_exc).max(axis=0)
    acc = inc & ~exc          # bits are 0/1: ~0 keeps inc, ~1 clears it

    # Only the combined bitmap leaves VMEM: 512 bits -> 16 uint32 (64 B).
    b = acc.reshape(page_block, BITMAP_WORDS, 32)
    sh = jax.lax.broadcasted_iota(
        jnp.uint32, (page_block, BITMAP_WORDS, 32), 2)
    out_ref[...] = ((b << sh).sum(axis=2).astype(jnp.uint32))[None]


@functools.partial(
    jax.jit,
    static_argnames=("page_block", "randomized", "interpret"))
def _sim_plan_call(lo, hi, queries, masks, flags, page_ids, page_seeds, *,
                   page_block: int, randomized: bool, interpret: bool):
    n_pages = lo.shape[0]
    n_groups, n_passes, _ = queries.shape
    assert n_pages % page_block == 0, (n_pages, page_block)
    grid = (n_pages // page_block, n_groups)

    kernel = functools.partial(
        _plan_kernel, page_block=page_block, randomized=randomized)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((page_block, SLOTS), lambda i, j: (i, 0)),
            pl.BlockSpec((page_block, SLOTS), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n_passes, 2), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, n_passes, 2), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, n_passes), lambda i, j: (j, 0)),
            pl.BlockSpec((page_block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((page_block, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, page_block, BITMAP_WORDS),
                               lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, n_pages, BITMAP_WORDS),
                                       jnp.uint32),
        interpret=interpret,
    )(jnp.asarray(lo, jnp.uint32), jnp.asarray(hi, jnp.uint32),
      jnp.asarray(queries, jnp.uint32), jnp.asarray(masks, jnp.uint32),
      jnp.asarray(flags, jnp.uint32),
      jnp.asarray(page_ids, jnp.uint32).reshape(-1, 1),
      jnp.asarray(page_seeds, jnp.uint32).reshape(-1, 1))


def sim_plan_kernel(lo, hi, queries, masks, flags, *, page_block: int = 8,
                    randomized: bool = False, device_seed: int = 0,
                    page_base: int = 0, interpret: bool = True,
                    page_ids=None, page_seeds=None):
    """Run the fused plan kernel.

    lo, hi:     (N, 512) uint32 planes, N a multiple of ``page_block``
                (ops.py pads)
    queries:    (G, P, 2) uint32 pass rows;  masks: (G, P, 2) uint32
    flags:      (G, P) uint32 — PASS_INCLUDE / PASS_EXCLUDE / PASS_PAD
    page_ids:   optional (N,) uint32 per-page flash addresses (defaults to
                the contiguous ``page_base + arange(N)``)
    page_seeds: optional (N,) uint32 per-page device seeds (default: the
                scalar ``device_seed`` for every page)
    returns:    (G, N, 16) uint32 combined match bitmaps — ONE per
                (plan group, page), not one per pass
    """
    n_pages = lo.shape[0]
    if page_ids is None:
        page_ids = jnp.uint32(page_base) + jnp.arange(n_pages,
                                                      dtype=jnp.uint32)
    if page_seeds is None:
        page_seeds = jnp.full(n_pages, device_seed & 0xFFFFFFFF, jnp.uint32)
    return _sim_plan_call(lo, hi, queries, masks, flags, page_ids,
                          page_seeds, page_block=page_block,
                          randomized=randomized, interpret=interpret)
