"""Pure-jnp oracle for the sim_gather kernel."""
from __future__ import annotations

import jax.numpy as jnp


def sim_gather_ref(chunks, bitmap_words, max_out: int):
    """Order-preserving chunk compaction per page.

    chunks:       (N, 64, 16) uint32 chunk-major page words
    bitmap_words: (N, 2) uint32 — 64-bit chunk-select bitmap per page
    returns (gathered (N, max_out, 16) uint32, counts (N,) int32).
    Selected chunks pack to the front in chunk order; tail is zero.
    Chunks beyond ``max_out`` selections are dropped (counts still reports
    the true total, so the host can re-issue a follow-up gather).
    """
    chunks = jnp.asarray(chunks, jnp.uint32)
    bm = jnp.asarray(bitmap_words, jnp.uint32)
    j = jnp.arange(64, dtype=jnp.uint32)[None, :]                # (1, 64)
    word = jnp.where(j < 32, bm[:, 0:1], bm[:, 1:2])             # (N, 64)
    bit = (word >> (j % 32)) & jnp.uint32(1)                     # (N, 64)
    pos = jnp.cumsum(bit, axis=1, dtype=jnp.uint32) - bit        # (N, 64)
    sel = ((pos[:, None, :] == jnp.arange(max_out,
                                          dtype=jnp.uint32)[None, :, None])
           & (bit[:, None, :] == 1))                             # (N, M, 64)
    gathered = jnp.einsum("nmj,njw->nmw", sel.astype(jnp.uint32), chunks)
    counts = bit.sum(axis=1).astype(jnp.int32)
    return gathered.astype(jnp.uint32), counts
