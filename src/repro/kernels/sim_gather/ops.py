"""Public wrapper for the SiM gather kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import default_interpret
from repro.kernels.layout import pages_to_chunk_words
from .ref import sim_gather_ref
from .sim_gather import sim_gather_kernel


def sim_gather(chunks, bitmap_words, *, max_out: int = 16,
               page_block: int = 16, interpret: bool | None = None,
               use_kernel: bool = True):
    """Gather selected chunks per page -> ((N, max_out, 16), (N,) counts)."""
    chunks = jnp.asarray(chunks, jnp.uint32)
    bm = jnp.asarray(bitmap_words, jnp.uint32)
    if not use_kernel:
        return sim_gather_ref(chunks, bm, max_out)
    interpret = default_interpret() if interpret is None else interpret
    n = chunks.shape[0]
    pad = (-n) % page_block
    if pad:
        chunks = jnp.pad(chunks, ((0, pad), (0, 0), (0, 0)))
        bm = jnp.pad(bm, ((0, pad), (0, 0)))
    out, cnt = sim_gather_kernel(chunks, bm, page_block=page_block,
                                 max_out=max_out, interpret=interpret)
    return out[:n], cnt[:n, 0]


def sim_gather_pages(pages_bytes: np.ndarray, chunk_bitmaps_u64, **kw):
    """Raw (N, 4096) uint8 pages + per-page uint64 chunk bitmaps."""
    from repro.core.bits import u64_array_to_pairs
    cw = pages_to_chunk_words(pages_bytes)
    bm = u64_array_to_pairs(np.atleast_1d(
        np.asarray(chunk_bitmaps_u64, dtype=np.uint64)))
    return sim_gather(cw, bm, **kw)
