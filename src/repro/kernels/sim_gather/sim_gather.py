"""Pallas TPU kernel: SiM gather — bitmap-selected chunk compaction.

Hardware mapping (DESIGN.md §2): the chip's column decoder walks the 64-bit
chunk-select bitmap and streams selected 64 B chunks onto the bus.  The TPU
analogue of a selection tree is a *one-hot matmul on the MXU*: the prefix sum
of the select bits defines a (max_out, 64) compaction permutation which,
multiplied against the page's (64, 16) chunk words, emits the selected chunks
front-packed and in order.

uint32 words cannot ride the MXU directly; each word is split into two
16-bit halves lifted to f32 (exact: one-hot rows sum at most one value
< 2^16), multiplied, and recombined — so the kernel is exact for arbitrary
bit patterns while the heavy lifting stays on the systolic array.

Block geometry: per grid step — chunks tile (PB, 64, 16) uint32 (PB pages,
4 KiB each), bitmap tile (PB, 2), output (PB, M, 16).  The one-hot tensor is
(PB, M, 64) f32 in VMEM; with PB=16, M=16 that is ~64 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNKS = 64
WORDS = 16


def _gather_kernel(chunk_ref, bm_ref, out_ref, cnt_ref, *, page_block: int,
                   max_out: int):
    chunks = chunk_ref[...]                           # (PB, 64, 16) uint32
    bm = bm_ref[...]                                  # (PB, 2) uint32

    j = jax.lax.broadcasted_iota(jnp.uint32, (page_block, CHUNKS), 1)
    word = jnp.where(j < 32, bm[:, 0:1], bm[:, 1:2])  # (PB, 64)
    bit = (word >> (j % 32)) & jnp.uint32(1)
    pos = jnp.cumsum(bit, axis=1, dtype=jnp.uint32) - bit

    m_ids = jax.lax.broadcasted_iota(jnp.uint32, (page_block, max_out, CHUNKS), 1)
    sel = ((pos[:, None, :] == m_ids) & (bit[:, None, :] == 1)
           ).astype(jnp.float32)                      # (PB, M, 64)

    # Split-16 exact integer matmul on the MXU.
    c_lo = (chunks & jnp.uint32(0xFFFF)).astype(jnp.float32)
    c_hi = (chunks >> jnp.uint32(16)).astype(jnp.float32)
    dn = (((2,), (1,)), ((0,), (0,)))                 # batch PB, contract 64
    out_lo = jax.lax.dot_general(sel, c_lo, dn,
                                 preferred_element_type=jnp.float32)
    out_hi = jax.lax.dot_general(sel, c_hi, dn,
                                 preferred_element_type=jnp.float32)
    out_ref[...] = (out_lo.astype(jnp.uint32)
                    | (out_hi.astype(jnp.uint32) << jnp.uint32(16)))
    cnt_ref[...] = bit.sum(axis=1, dtype=jnp.int32)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("page_block", "max_out", "interpret"))
def sim_gather_kernel(chunks, bitmap_words, *, page_block: int = 16,
                      max_out: int = 16, interpret: bool = True):
    """chunks (N, 64, 16) uint32, bitmap (N, 2) uint32 ->
    (gathered (N, max_out, 16) uint32, counts (N, 1) int32)."""
    n = chunks.shape[0]
    assert n % page_block == 0, (n, page_block)
    grid = (n // page_block,)
    kernel = functools.partial(_gather_kernel, page_block=page_block,
                               max_out=max_out)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((page_block, CHUNKS, WORDS), lambda i: (i, 0, 0)),
            pl.BlockSpec((page_block, 2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((page_block, max_out, WORDS), lambda i: (i, 0, 0)),
            pl.BlockSpec((page_block, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, max_out, WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(chunks, jnp.uint32), jnp.asarray(bitmap_words, jnp.uint32))
