"""Host-side layout conversions between SiM page bytes and kernel operands.

TPU lane tiling wants the trailing axis to be a multiple of 128.  The
interleaved on-flash slot layout ``(N, 512, 2)`` puts 2 in the lanes, which
is hostile; we de-interleave pages into two word *planes* of shape
``(N, 512)`` (lo words, hi words) — 512 lanes = 4 x 128.  This mirrors the
chip, where the two words of a slot live on different bitline groups anyway.
"""
from __future__ import annotations

import numpy as np

from repro.core.bits import bytes_to_slot_words, slot_words_to_bytes

SLOTS = 512
CHUNKS = 64
WORDS_PER_CHUNK = 16   # 64 B / 4 B


def pages_to_planes(pages_bytes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(N, 4096) uint8 -> ((N, 512) lo, (N, 512) hi) uint32 planes."""
    words = bytes_to_slot_words(np.asarray(pages_bytes, dtype=np.uint8))
    return np.ascontiguousarray(words[..., 0]), np.ascontiguousarray(
        words[..., 1])


def planes_to_pages(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    words = np.stack([lo, hi], axis=-1).astype(np.uint32)
    return slot_words_to_bytes(words)


def pages_to_chunk_words(pages_bytes: np.ndarray) -> np.ndarray:
    """(N, 4096) uint8 -> (N, 64, 16) uint32 chunk-major word view."""
    b = np.ascontiguousarray(np.asarray(pages_bytes, dtype=np.uint8))
    return b.view('<u4').reshape(*b.shape[:-1], CHUNKS, WORDS_PER_CHUNK)


def chunk_words_to_pages(cw: np.ndarray) -> np.ndarray:
    c = np.ascontiguousarray(cw, dtype=np.uint32)
    return c.view(np.uint8).reshape(*c.shape[:-2], c.shape[-2] * 64)


def planes_to_chunk_words_xp(lo, hi, xp):
    """Device-side (B, 512)+(B, 512) planes -> (B, 64, 16) chunk words.

    Chunk j holds slots 8j..8j+7; its 16 words interleave lo/hi per slot.
    """
    B = lo.shape[0]
    lo_c = lo.reshape(B, CHUNKS, 8)
    hi_c = hi.reshape(B, CHUNKS, 8)
    return xp.stack([lo_c, hi_c], axis=-1).reshape(B, CHUNKS, WORDS_PER_CHUNK)
