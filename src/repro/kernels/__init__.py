"""Pallas TPU kernels for the SiM hot paths.

Every kernel directory ships three files:
  <name>.py — the pl.pallas_call kernel with explicit BlockSpec tiling
  ops.py    — the jit'd public wrapper (padding, layout, interpret flag)
  ref.py    — the pure-jnp oracle the kernel is validated against

On this CPU-only container kernels execute with ``interpret=True`` (the
kernel body runs step-by-step under the Pallas interpreter); on a real TPU
the same code lowers to Mosaic.  ``default_interpret()`` picks automatically.
"""
import jax


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"
