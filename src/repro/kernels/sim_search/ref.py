"""Pure-jnp oracle for the sim_search kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.bits import mix2_32, pack_bitmap
from repro.core.randomize import _HI_SALT, _LO_SALT


def stream_planes(page_base: int, n_pages: int, device_seed: int, xp=jnp,
                  page_ids=None, page_seeds=None):
    """Randomization stream for pages [page_base, page_base+n) as planes.

    ``page_ids``/``page_seeds`` (each (N,) uint32) override the contiguous
    single-seed default — the per-page addressing the batched backend uses.
    """
    if page_ids is None:
        page = (xp.arange(n_pages, dtype=xp.uint32)[:, None]
                + xp.uint32(page_base))
    else:
        page = xp.asarray(page_ids, dtype=xp.uint32)[:, None]
    if page_seeds is None:
        seed = xp.uint32(device_seed & 0xFFFFFFFF)
    else:
        seed = xp.asarray(page_seeds, dtype=xp.uint32)[:, None]
    slot = xp.arange(512, dtype=xp.uint32)[None, :]
    ctr = (page * xp.uint32(512) + slot).astype(xp.uint32)
    ctr = ctr ^ seed
    return mix2_32(ctr, _LO_SALT, xp), mix2_32(ctr, _HI_SALT, xp)


def sim_search_ref(lo, hi, queries, masks, *, randomized: bool = False,
                   page_base: int = 0, device_seed: int = 0,
                   page_ids=None, page_seeds=None) -> jnp.ndarray:
    """Reference masked multi-query search.

    lo, hi:   (N, 512) uint32 slot-word planes (possibly randomized)
    queries:  (Q, 2) uint32
    masks:    (Q, 2) uint32
    returns:  (Q, N, 16) uint32 packed match bitmaps
    """
    lo = jnp.asarray(lo, dtype=jnp.uint32)
    hi = jnp.asarray(hi, dtype=jnp.uint32)
    q = jnp.asarray(queries, dtype=jnp.uint32)
    m = jnp.asarray(masks, dtype=jnp.uint32)
    if randomized:
        s_lo, s_hi = stream_planes(page_base, lo.shape[0], device_seed,
                                   page_ids=page_ids, page_seeds=page_seeds)
        q_lo = q[:, None, None, 0] ^ s_lo[None]      # (Q, N, 512)
        q_hi = q[:, None, None, 1] ^ s_hi[None]
    else:
        q_lo = q[:, None, None, 0]
        q_hi = q[:, None, None, 1]
    mm = ((lo[None] ^ q_lo) & m[:, None, None, 0]) | (
        (hi[None] ^ q_hi) & m[:, None, None, 1])
    bits = (mm == 0).astype(jnp.uint32)              # (Q, N, 512)
    return pack_bitmap(bits, xp=jnp)                 # (Q, N, 16)


def sim_search_ref_np(lo, hi, queries, masks, **kw) -> np.ndarray:
    return np.asarray(sim_search_ref(lo, hi, queries, masks, **kw))
