"""Pallas TPU kernel: SiM search — masked multi-query match -> packed bitmap.

Hardware mapping (DESIGN.md §2):
  * one grid step stages a tile of ``page_block`` pages (two (PB, 512) uint32
    word planes, 4 KiB/page) from HBM into VMEM — the analogue of the NAND
    array sense into the page buffers;
  * the VPU evaluates the masked XOR match for *all Q queries* against the
    resident tile — the analogue of §IV-E batch matching, amortizing the
    page sense across queries and raising arithmetic intensity by Q;
  * when ``randomized=True`` the kernel regenerates the per-slot
    randomization stream *in-kernel* (two fmix32 rounds on a slot-address
    counter) and XORs it into the broadcast query — the deserializer of
    §IV-C1; stored pages never need de-randomizing for a search;
  * the 512 match bits per page are packed to 16 uint32 words before leaving
    VMEM, so HBM write traffic is 64 B/page — the same 64:1 reduction the
    chip achieves on its bus.

Page addressing: each staged page carries its own 32-bit flash address and
device seed as (N, 1) uint32 operands riding the sublane axis next to the
planes.  The stream counter for slot ``s`` of page ``p`` is
``(addr[p] * 512 + s) ^ seed[p]`` — identical to core/randomize.py — so a
single launch can batch pages from *different* chips (different local
addresses and device seeds), which is what the MatchBackend's deferred
submission queue relies on (§IV-E cross-page multi-query batching).

Block geometry: the trailing axis of both planes is 512 = 4 x 128 lanes;
``page_block`` rides the sublane axis (multiples of 8 keep the uint32 tile
(8, 128)-aligned).  VMEM per step ~= 2 * PB * 2 KiB + Q * PB * 2 KiB
(match-bit intermediate), e.g. PB=32, Q=16 -> ~1.3 MiB, well under the
~16 MiB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bits import mix2_32
from repro.core.randomize import _HI_SALT, _LO_SALT

SLOTS = 512
BITMAP_WORDS = 16


def _search_kernel(lo_ref, hi_ref, q_ref, m_ref, page_ref, seed_ref, out_ref,
                   *, page_block: int, n_queries: int, randomized: bool):
    lo = lo_ref[...]                       # (PB, 512) uint32
    hi = hi_ref[...]
    q = q_ref[...]                         # (Q, 2) uint32
    m = m_ref[...]
    q_lo = q[:, 0][:, None, None]          # (Q, 1, 1)
    q_hi = q[:, 1][:, None, None]
    m_lo = m[:, 0][:, None, None]
    m_hi = m[:, 1][:, None, None]

    if randomized:
        # Deserializer: regenerate the slot-address-counter stream in VMEM
        # from each staged page's own flash address and device seed.
        page = page_ref[...]               # (PB, 1) uint32
        seed = seed_ref[...]               # (PB, 1) uint32
        slot = jax.lax.broadcasted_iota(
            jnp.uint32, (page_block, SLOTS), 1)
        ctr = (page * jnp.uint32(SLOTS) + slot) ^ seed
        s_lo = mix2_32(ctr, _LO_SALT, jnp)         # (PB, 512)
        s_hi = mix2_32(ctr, _HI_SALT, jnp)
        q_lo = q_lo ^ s_lo[None]
        q_hi = q_hi ^ s_hi[None]

    mismatch = ((lo[None] ^ q_lo) & m_lo) | ((hi[None] ^ q_hi) & m_hi)
    bits = (mismatch == 0).astype(jnp.uint32)      # (Q, PB, 512)

    # In-VMEM bitmap packing: 512 bits -> 16 uint32 (the 64 B bus payload).
    b = bits.reshape(n_queries, page_block, BITMAP_WORDS, 32)
    sh = jax.lax.broadcasted_iota(
        jnp.uint32, (n_queries, page_block, BITMAP_WORDS, 32), 3)
    out_ref[...] = (b << sh).sum(axis=3).astype(jnp.uint32)


@functools.partial(
    jax.jit,
    static_argnames=("page_block", "randomized", "interpret"))
def _sim_search_call(lo, hi, queries, masks, page_ids, page_seeds, *,
                     page_block: int, randomized: bool, interpret: bool):
    n_pages = lo.shape[0]
    n_queries = queries.shape[0]
    assert n_pages % page_block == 0, (n_pages, page_block)
    grid = (n_pages // page_block,)

    kernel = functools.partial(
        _search_kernel, page_block=page_block, n_queries=n_queries,
        randomized=randomized)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((page_block, SLOTS), lambda i: (i, 0)),
            pl.BlockSpec((page_block, SLOTS), lambda i: (i, 0)),
            pl.BlockSpec((n_queries, 2), lambda i: (0, 0)),
            pl.BlockSpec((n_queries, 2), lambda i: (0, 0)),
            pl.BlockSpec((page_block, 1), lambda i: (i, 0)),
            pl.BlockSpec((page_block, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_queries, page_block, BITMAP_WORDS),
                               lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_queries, n_pages, BITMAP_WORDS),
                                       jnp.uint32),
        interpret=interpret,
    )(jnp.asarray(lo, jnp.uint32), jnp.asarray(hi, jnp.uint32),
      jnp.asarray(queries, jnp.uint32), jnp.asarray(masks, jnp.uint32),
      jnp.asarray(page_ids, jnp.uint32).reshape(-1, 1),
      jnp.asarray(page_seeds, jnp.uint32).reshape(-1, 1))


def sim_search_kernel(lo, hi, queries, masks, page_base, *,
                      page_block: int = 32, randomized: bool = False,
                      device_seed: int = 0, interpret: bool = True,
                      page_ids=None, page_seeds=None):
    """Run the search kernel.

    lo, hi:     (N, 512) uint32 planes, N a multiple of ``page_block``
                (ops.py pads)
    queries:    (Q, 2) uint32;  masks: (Q, 2) uint32
    page_base:  scalar — global index of page 0 (randomization seed) when
                ``page_ids`` is not given
    page_ids:   optional (N,) uint32 per-page flash addresses (overrides the
                contiguous ``page_base + arange(N)`` default)
    page_seeds: optional (N,) uint32 per-page device seeds (default: the
                scalar ``device_seed`` for every page)
    returns:    (Q, N, 16) uint32 packed match bitmaps
    """
    n_pages = lo.shape[0]
    if page_ids is None:
        page_ids = jnp.uint32(page_base) + jnp.arange(n_pages,
                                                      dtype=jnp.uint32)
    if page_seeds is None:
        page_seeds = jnp.full(n_pages, device_seed & 0xFFFFFFFF, jnp.uint32)
    return _sim_search_call(lo, hi, queries, masks, page_ids, page_seeds,
                            page_block=page_block, randomized=randomized,
                            interpret=interpret)
