"""Public wrapper for the SiM search kernel: layout, padding, fallback."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import default_interpret
from repro.kernels.layout import pages_to_planes
from .ref import sim_search_ref
from .sim_search import sim_search_kernel


def _pad_pages(lo, hi, page_block, page_ids=None, page_seeds=None):
    n = lo.shape[0]
    pad = (-n) % page_block
    if pad:
        lo = jnp.pad(lo, ((0, pad), (0, 0)))
        hi = jnp.pad(hi, ((0, pad), (0, 0)))
        if page_ids is not None:
            page_ids = jnp.pad(jnp.asarray(page_ids, jnp.uint32), (0, pad))
        if page_seeds is not None:
            page_seeds = jnp.pad(jnp.asarray(page_seeds, jnp.uint32),
                                 (0, pad))
    return lo, hi, page_ids, page_seeds, n


def sim_search(lo, hi, queries, masks, *, page_base: int = 0,
               page_block: int = 32, randomized: bool = False,
               device_seed: int = 0, interpret: bool | None = None,
               use_kernel: bool = True, page_ids=None, page_seeds=None):
    """Masked multi-query search over page planes -> (Q, N, 16) bitmaps.

    ``use_kernel=False`` routes through the jnp oracle (the path the XLA
    dry-run models lower; identical semantics, validated in tests).
    ``page_ids``/``page_seeds`` give each staged page its own flash address
    and device seed for the randomized-stream regeneration, so one launch
    can batch pages from different chips (the MatchBackend fast path).
    """
    queries = jnp.atleast_2d(jnp.asarray(queries, jnp.uint32))
    masks = jnp.atleast_2d(jnp.asarray(masks, jnp.uint32))
    if not use_kernel:
        return sim_search_ref(lo, hi, queries, masks, randomized=randomized,
                              page_base=page_base, device_seed=device_seed,
                              page_ids=page_ids, page_seeds=page_seeds)
    interpret = default_interpret() if interpret is None else interpret
    lo, hi, page_ids, page_seeds, n = _pad_pages(
        jnp.asarray(lo, jnp.uint32), jnp.asarray(hi, jnp.uint32), page_block,
        page_ids, page_seeds)
    out = sim_search_kernel(lo, hi, queries, masks, page_base,
                            page_block=page_block, randomized=randomized,
                            device_seed=device_seed, interpret=interpret,
                            page_ids=page_ids, page_seeds=page_seeds)
    return out[:, :n]


def sim_search_pages(pages_bytes: np.ndarray, queries_u64, masks_u64,
                     **kw):
    """Convenience: raw (N, 4096) uint8 pages + uint64 queries/masks."""
    from repro.core.bits import u64_array_to_pairs
    lo, hi = pages_to_planes(pages_bytes)
    q = u64_array_to_pairs(np.atleast_1d(np.asarray(queries_u64,
                                                    dtype=np.uint64)))
    m = u64_array_to_pairs(np.atleast_1d(np.asarray(masks_u64,
                                                    dtype=np.uint64)))
    return sim_search(lo, hi, q, m, **kw)
