"""Pallas TPU kernel: tiled online-softmax (flash) attention with GQA.

This is the compute hot spot sitting directly above the SiM-paged KV cache
in the serving path, and the prefill/training attention for the dense LM
configs.  Standard construction:

  grid = (B*H, Sq/block_q, Sk/block_k), innermost axis sequential;
  per (bh, iq): VMEM scratch carries the running (acc, m, l) across k tiles;
  causal + sliding-window tiles that are fully masked are skipped with
  pl.when (no VPU/MXU work, no HBM reads for k/v of skipped tiles beyond the
  pipelined prefetch);
  GQA is folded into the k/v BlockSpec index maps (q head -> kv head), so kv
  tiles are fetched once per group, not repeated per q head.

Stats scratches are kept (block_q, 128)-shaped (lane-aligned) with the value
replicated across lanes — the usual Mosaic-friendly layout for row stats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int | None,
                 block_q: int, block_k: int, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window is not None:
        relevant &= k_start + block_k > q_start - window + 1

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (block_q, D)
        k = k_ref[0].astype(jnp.float32)          # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        row = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                 (block_q, block_k), 0)
        col = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                 (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask &= col <= row
        if window is not None:
            mask &= col > row - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                     # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = s.max(axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                    # (bq, bk)
        corr = jnp.exp(m_prev - m_new)            # (bq, 1)
        l_new = corr * l_prev + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = corr * acc_ref[...] + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           scale: float | None = None, block_q: int = 128,
                           block_k: int = 128, interpret: bool = True):
    """q: (BH, S, D), k/v: (BHkv, S, D) flattened head-major; returns like q.

    BH = B*H and BHkv = B*Hkv must describe the same B (the wrapper in
    ops.py flattens and maps q-heads onto kv-heads).
    """
    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    assert bh % bhkv == 0
    group = bh // bhkv          # q heads per kv head (within a batch slice)
    scale = (d ** -0.5) if scale is None else scale
    n_q, n_k = sq // block_q, sk // block_k

    kernel = functools.partial(_attn_kernel, scale=scale, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, n_k=n_k)
    grid = (bh, n_q, n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),    # acc
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running denom
        ],
        interpret=interpret,
    )(q, k, v)
