"""Public wrapper for flash attention: (B, S, H, D) layout, GQA flattening."""
from __future__ import annotations

from repro.kernels import default_interpret
from .flash_attention import flash_attention_kernel
from .ref import attention_ref


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None, use_kernel: bool = True):
    """Multi-head attention with optional causal / sliding-window masking.

    q: (B, Sq, H, D);  k, v: (B, Sk, Hkv, D).  Falls back to the dense
    reference when shapes don't tile (decode steps, ragged tails) or when
    ``use_kernel=False`` (the XLA path the dry-run lowers).
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    tiles_ok = (sq % block_q == 0) and (sk % block_k == 0) and sq == sk
    if not use_kernel or not tiles_ok:
        return attention_ref(q, k, v, causal=causal, window=window,
                             scale=scale)
    interpret = default_interpret() if interpret is None else interpret
    # (B, S, H, D) -> (B*H, S, D); kv -> (B*Hkv, S, D).  The kernel maps
    # flat q index bh -> kv index bh // (H // Hkv); that requires the head
    # axis to be *outer* so that q heads of one kv group are contiguous:
    # flatten as (B, H, S, D) -> (B*H, S, D).
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    of = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                                scale=scale, block_q=block_q,
                                block_k=block_k, interpret=interpret)
    return of.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
