"""Pure-jnp oracle for the flash attention kernel (dense softmax attention)."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None, logits_dtype=jnp.float32):
    """Dense reference attention with GQA + causal / sliding-window masks.

    q: (B, Sq, H, D);  k, v: (B, Sk, Hkv, D) with H % Hkv == 0.
    ``window`` w keeps keys with  row - w < col <= row  (w most recent).
    Returns (B, Sq, H, D) in q.dtype.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    group = h // hkv
    scale = (d ** -0.5) if scale is None else scale

    kx = jnp.repeat(k, group, axis=2)
    vx = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(logits_dtype),
                   kx.astype(logits_dtype)) * scale
    row = jnp.arange(sq)[:, None] + (sk - sq)   # align ends (decode-style)
    col = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= col <= row
    if window is not None:
        mask &= col > row - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx.astype(logits_dtype))
    return out.astype(q.dtype)
