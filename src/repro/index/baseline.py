"""CPU-centric baseline index (paper §VI-A3): full-page reads + host search.

Functionally equivalent to the SiM indexes — used by tests to prove result
equality and by benchmarks to count the I/O both architectures move.
"""
from __future__ import annotations

import bisect

import numpy as np

from repro.core.engine import SimChipArray
from repro.core.page import entries_from_plain

LEAF_CAPACITY = 504


class BaselineBTree:
    """Same layout as SimBTree but lookups read entire pages."""

    def __init__(self, chips: SimChipArray, *, leaf_fill: int = 404):
        self.chips = chips
        self.leaf_fill = min(leaf_fill, LEAF_CAPACITY)
        self.leaves: list[tuple[int, int, int, int]] = []  # kp, vp, n, low
        self._separators: list[int] = []
        self._next_page = 0
        self.pages_read = 0
        self.bytes_read = 0

    def bulk_load(self, keys: np.ndarray, values: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        values = np.asarray(values, dtype=np.uint64)
        order = np.argsort(keys, kind="stable")
        keys, values = keys[order], values[order]
        for start in range(0, len(keys), self.leaf_fill):
            k = keys[start:start + self.leaf_fill]
            v = values[start:start + self.leaf_fill]
            kp, vp = self._next_page, self._next_page + 1
            self._next_page += 2
            self.chips.program_entries(kp, k)
            self.chips.program_entries(vp, v)
            self.leaves.append((kp, vp, len(k), int(k[0])))
            self._separators.append(int(k[0]))

    def _read_entries(self, page: int, n: int) -> np.ndarray:
        plain = self.chips.read_full(page).plain
        self.pages_read += 1
        self.bytes_read += 4096
        return entries_from_plain(plain, n)

    def lookup(self, key: int) -> int | None:
        i = bisect.bisect_right(self._separators, int(key)) - 1
        if i < 0:
            return None
        kp, vp, n, _ = self.leaves[i]
        keys = self._read_entries(kp, n)           # full 4 KiB page
        pos = np.searchsorted(keys, np.uint64(key))
        if pos >= n or keys[pos] != np.uint64(key):
            return None
        values = self._read_entries(vp, n)          # second full page
        return int(values[pos])

    def range_query(self, lo: int, hi: int) -> list[tuple[int, int]]:
        out = []
        i0 = max(bisect.bisect_right(self._separators, int(lo)) - 1, 0)
        for kp, vp, n, low in self.leaves[i0:]:
            if low >= hi:
                break
            keys = self._read_entries(kp, n)
            sel = (keys >= lo) & (keys < hi)
            if not sel.any():
                continue
            values = self._read_entries(vp, n)
            out.extend((int(k), int(v)) for k, v in zip(keys[sel],
                                                        values[sel]))
        return out
