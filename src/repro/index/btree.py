"""B+Tree primary index with SiM leaf pages (paper §V-A, Fig 8).

Internal nodes live in host memory (sorted separator arrays); leaf nodes are
pairs of SiM pages — a key page and a value page on different chips/dies —
searched with `search` and fetched with `gather`.  A lookup therefore ships
one 8-byte query down and gets 64 B of bitmap + 64 B of chunk back instead
of two 4 KiB pages.

All device traffic flows through a MatchBackend.  Point lookups use the
fused LOOKUP primitive — key-page search, first-slot selection and
value-page chunk gather in one command — so a ``lookup_batch`` burst is a
single device launch on the kernel backend.  ``range_query`` enqueues every
search (and then every gather) before flushing, so a whole scan executes as
one batched launch per phase (§IV-E).

The host-side B+Tree logic is deliberately ordinary; everything interesting
happens in how little data crosses the bus.

Page addressing goes through the backend's namespace: on a
``ShardedSsdBackend`` the sequentially-allocated leaf pages stripe across
channels x dies (``backend/sharded.py::decompose``), so a leaf's key and
value page land on *different* chips — the §V-A cross-die pairing — and a
``lookup_batch``/``range_query`` burst fans out over every chip while
still resolving in one stacked launch per phase.
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.backend import MatchBackend, as_backend
from repro.core.bits import (SLOTS_PER_CHUNK, chunk_bitmap_from_slot_bitmap,
                             pair_to_u64, unpack_bitmap)
from repro.core.commands import Command
from repro.core.page import mask_header_slots
from repro.core.range_query import evaluate_plan_on_pages, exact_range
from repro.reliability import require_clean

FULL_MASK = 0xFFFFFFFFFFFFFFFF
LEAF_CAPACITY = 504


@dataclasses.dataclass
class Leaf:
    key_page: int
    value_page: int
    n_entries: int
    low_key: int         # smallest key (separator)


@dataclasses.dataclass
class LookupStats:
    searches: int = 0
    gathers: int = 0
    bitmap_bytes: int = 0
    chunk_bytes: int = 0


class SimBTree:
    """Bulk-loaded B+Tree over (uint64 key -> uint64 value).

    ``backend`` accepts either a MatchBackend or a raw SimChipArray (which
    is adapted to the scalar reference backend).
    """

    def __init__(self, backend, *, leaf_fill: int = 404):
        self.backend: MatchBackend = as_backend(backend)
        self.leaf_fill = min(leaf_fill, LEAF_CAPACITY)
        self.leaves: list[Leaf] = []
        self._separators: list[int] = []     # low key of each leaf
        self._next_page = 0
        self.stats = LookupStats()

    @property
    def chips(self):
        return self.backend.chips

    # ------------------------------------------------------------- loading
    def bulk_load(self, keys: np.ndarray, values: np.ndarray,
                  timestamp_ns: int = 0) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        values = np.asarray(values, dtype=np.uint64)
        order = np.argsort(keys, kind="stable")
        keys, values = keys[order], values[order]
        if keys.size and np.any(keys[:-1] == keys[1:]):
            raise ValueError("duplicate keys in primary index")
        for start in range(0, len(keys), self.leaf_fill):
            k = keys[start:start + self.leaf_fill]
            v = values[start:start + self.leaf_fill]
            kp, vp = self._next_page, self._next_page + 1
            self._next_page += 2
            self.backend.program_entries(kp, k, timestamp_ns=timestamp_ns)
            self.backend.program_entries(vp, v, timestamp_ns=timestamp_ns)
            self.leaves.append(Leaf(kp, vp, len(k), int(k[0])))
            self._separators.append(int(k[0]))

    # -------------------------------------------------------------- lookup
    def _leaf_for(self, key: int) -> Leaf | None:
        i = bisect.bisect_right(self._separators, int(key)) - 1
        return self.leaves[i] if i >= 0 else None

    def lookup(self, key: int) -> int | None:
        """Point query: fused search+gather on the leaf's paired pages
        (pipelined on-chip, §III-B — one command, one launch)."""
        return self.lookup_batch([key])[0]

    def lookup_batch(self, keys) -> list[int | None]:
        """Batched point queries through ``submit_lookup``: the whole burst
        is ONE fused launch on the kernel backend — the key-page match, the
        first-slot selection and the value-page chunk gather never leave
        the device."""
        leaves = [self._leaf_for(int(k)) for k in keys]
        tickets = []
        for k, leaf in zip(keys, leaves):
            if leaf is None:
                tickets.append(None)
                continue
            tickets.append(self.backend.submit_lookup(
                Command.lookup(leaf.key_page, leaf.value_page, int(k),
                               FULL_MASK)))
            self.stats.searches += 1
            self.stats.bitmap_bytes += 64
        self.backend.flush()

        out: list[int | None] = []
        for t in tickets:
            if t is None:
                out.append(None)
                continue
            resp = require_clean(t.result())
            if resp.value_slot is None:
                out.append(None)
                continue
            self.stats.gathers += 1
            self.stats.chunk_bytes += 64
            out.append(int.from_bytes(resp.value, "little"))
        return out

    # --------------------------------------------------------------- range
    def range_query(self, lo: int, hi: int) -> list[tuple[int, int]]:
        """lo <= key < hi via the §V-C masked-equality decomposition: one
        ``Op.PLAN`` per touched leaf flushes as one batch (the passes
        accumulate in-latch, 64 B/leaf on the bus), then all key/value-page
        gathers flush as a second batch."""
        plan = exact_range(int(lo), int(hi), width=64)
        i0 = max(bisect.bisect_right(self._separators, int(lo)) - 1, 0)
        leaves = [leaf for leaf in self.leaves[i0:] if leaf.low_key < hi]
        if not leaves:
            return []
        bitmaps = evaluate_plan_on_pages(
            self.backend, plan, [leaf.key_page for leaf in leaves])
        self.stats.searches += plan.n_passes * len(leaves)  # on-chip matches
        self.stats.bitmap_bytes += 64 * len(leaves)         # combined bitmaps

        hits = []                      # (leaf, slots, key ticket, val ticket)
        for leaf, acc in zip(leaves, bitmaps):
            acc = mask_header_slots(acc)
            slots = np.nonzero(unpack_bitmap(acc, 512))[0]
            if slots.size == 0:
                continue
            # gather matched key chunks + the aligned value chunks
            kb = int(pair_to_u64(*chunk_bitmap_from_slot_bitmap(acc)))
            gk = self.backend.submit_gather(Command.gather(leaf.key_page, kb))
            gv = self.backend.submit_gather(Command.gather(leaf.value_page,
                                                           kb))
            self.stats.gathers += 2
            hits.append((leaf, slots, gk, gv))
        self.backend.flush()

        out: list[tuple[int, int]] = []
        for _leaf, slots, gk, gv in hits:
            rk, rv = require_clean(gk.result()), require_clean(gv.result())
            self.stats.chunk_bytes += 64 * (len(rk.chunk_ids)
                                            + len(rv.chunk_ids))
            chunk_pos = {int(c): j for j, c in enumerate(rk.chunk_ids)}
            for s in slots:
                c, off = s // SLOTS_PER_CHUNK, (s % SLOTS_PER_CHUNK) * 8
                j = chunk_pos[int(c)]
                k = int.from_bytes(bytes(rk.chunks[j][off:off + 8]), "little")
                v = int.from_bytes(bytes(rv.chunks[j][off:off + 8]), "little")
                out.append((k, v))
        return out
