"""B+Tree primary index with SiM leaf pages (paper §V-A, Fig 8).

Internal nodes live in host memory (sorted separator arrays); leaf nodes are
pairs of SiM pages — a key page and a value page on different chips/dies —
searched with `search` and fetched with `gather`.  A lookup therefore ships
one 8-byte query down and gets 64 B of bitmap + 64 B of chunk back instead
of two 4 KiB pages.

The host-side B+Tree logic is deliberately ordinary; everything interesting
happens in how little data crosses the bus.
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.core.bits import (SLOTS_PER_CHUNK, chunk_bitmap_from_slot_bitmap,
                             pair_to_u64, unpack_bitmap)
from repro.core.commands import Command
from repro.core.engine import SimChipArray
from repro.core.page import mask_header_slots
from repro.core.range_query import exact_range

FULL_MASK = 0xFFFFFFFFFFFFFFFF
LEAF_CAPACITY = 504


@dataclasses.dataclass
class Leaf:
    key_page: int
    value_page: int
    n_entries: int
    low_key: int         # smallest key (separator)


@dataclasses.dataclass
class LookupStats:
    searches: int = 0
    gathers: int = 0
    bitmap_bytes: int = 0
    chunk_bytes: int = 0


class SimBTree:
    """Bulk-loaded B+Tree over (uint64 key -> uint64 value)."""

    def __init__(self, chips: SimChipArray, *, leaf_fill: int = 404):
        self.chips = chips
        self.leaf_fill = min(leaf_fill, LEAF_CAPACITY)
        self.leaves: list[Leaf] = []
        self._separators: list[int] = []     # low key of each leaf
        self._next_page = 0
        self.stats = LookupStats()

    # ------------------------------------------------------------- loading
    def bulk_load(self, keys: np.ndarray, values: np.ndarray,
                  timestamp_ns: int = 0) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        values = np.asarray(values, dtype=np.uint64)
        order = np.argsort(keys, kind="stable")
        keys, values = keys[order], values[order]
        if keys.size and np.any(keys[:-1] == keys[1:]):
            raise ValueError("duplicate keys in primary index")
        for start in range(0, len(keys), self.leaf_fill):
            k = keys[start:start + self.leaf_fill]
            v = values[start:start + self.leaf_fill]
            kp, vp = self._next_page, self._next_page + 1
            self._next_page += 2
            self.chips.program_entries(kp, k, timestamp_ns=timestamp_ns)
            self.chips.program_entries(vp, v, timestamp_ns=timestamp_ns)
            self.leaves.append(Leaf(kp, vp, len(k), int(k[0])))
            self._separators.append(int(k[0]))

    # -------------------------------------------------------------- lookup
    def _leaf_for(self, key: int) -> Leaf | None:
        i = bisect.bisect_right(self._separators, int(key)) - 1
        return self.leaves[i] if i >= 0 else None

    def lookup(self, key: int) -> int | None:
        """Point query: search command on the key page, gather on the value
        page (pipelined on-chip; we issue them back to back)."""
        leaf = self._leaf_for(key)
        if leaf is None:
            return None
        resp = self.chips.search(Command.search(leaf.key_page, int(key),
                                                FULL_MASK))
        self.stats.searches += 1
        self.stats.bitmap_bytes += 64
        bitmap = mask_header_slots(resp.bitmap_words)
        slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
        if slots.size == 0:
            return None
        # value sits at the same entry index in the value page
        entry = int(slots[0]) - SLOTS_PER_CHUNK
        value_slot = SLOTS_PER_CHUNK + entry
        cb = 1 << (value_slot // SLOTS_PER_CHUNK)
        g = self.chips.gather(Command.gather(leaf.value_page, cb))
        self.stats.gathers += 1
        self.stats.chunk_bytes += 64 * len(g.chunk_ids)
        off = (value_slot % SLOTS_PER_CHUNK) * 8
        return int.from_bytes(bytes(g.chunks[0][off:off + 8]), "little")

    # --------------------------------------------------------------- range
    def range_query(self, lo: int, hi: int) -> list[tuple[int, int]]:
        """lo <= key < hi via the §V-C masked-equality decomposition,
        evaluated leaf by leaf with bitmap OR accumulation."""
        plan = exact_range(int(lo), int(hi), width=64)
        out: list[tuple[int, int]] = []
        i0 = max(bisect.bisect_right(self._separators, int(lo)) - 1, 0)
        for leaf in self.leaves[i0:]:
            if leaf.low_key >= hi:
                break
            acc = np.zeros(16, dtype=np.uint32)
            for mq in plan.include:
                resp = self.chips.search(
                    Command.search(leaf.key_page, mq.query, mq.mask))
                self.stats.searches += 1
                self.stats.bitmap_bytes += 64
                acc |= resp.bitmap_words
            acc = mask_header_slots(acc)
            slots = np.nonzero(unpack_bitmap(acc, 512))[0]
            if slots.size == 0:
                continue
            # gather matched key chunks + the aligned value chunks
            kb = int(pair_to_u64(*chunk_bitmap_from_slot_bitmap(acc)))
            gk = self.chips.gather(Command.gather(leaf.key_page, kb))
            gv = self.chips.gather(Command.gather(leaf.value_page, kb))
            self.stats.gathers += 2
            self.stats.chunk_bytes += 64 * (len(gk.chunk_ids)
                                            + len(gv.chunk_ids))
            chunk_pos = {int(c): j for j, c in enumerate(gk.chunk_ids)}
            for s in slots:
                c, off = s // SLOTS_PER_CHUNK, (s % SLOTS_PER_CHUNK) * 8
                j = chunk_pos[int(c)]
                k = int.from_bytes(bytes(gk.chunks[j][off:off + 8]), "little")
                v = int.from_bytes(bytes(gv.chunks[j][off:off + 8]), "little")
                out.append((k, v))
        return out
