"""Extendible hash index with SiM bucket pages (paper §V, Fig 11).

The in-memory directory maps hash prefixes to bucket pages.  A bucket stores
packed (key -> value) entries as two SiM pages.  Bucket splits use the §V-D
keyspace-partitioning trick: one masked *search* per half isolates the
entries whose next hash bit is 0/1, and *gather* moves only those chunks —
no full-page read during redistribution.

Device traffic flows through a MatchBackend; ``lookup_batch`` enqueues a
burst of probes and flushes once (one kernel launch per phase on the
batched backend).  Bucket pages are allocated sequentially, which on a
``ShardedSsdBackend`` stripes them across channels x dies — a probe burst
over many buckets therefore spreads over every chip and still executes as
one stacked launch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.backend import MatchBackend, as_backend
from repro.core.bits import (SLOTS_PER_CHUNK, chunk_bitmap_from_slot_bitmap,
                             pair_to_u64, unpack_bitmap)
from repro.core.commands import Command
from repro.core.page import mask_header_slots

FULL_MASK = 0xFFFFFFFFFFFFFFFF
BUCKET_CAPACITY = 404


def _hash64(keys: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — uniform bucket spread for arbitrary keys."""
    z = np.asarray(keys, dtype=np.uint64) + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class Bucket:
    key_page: int
    value_page: int
    local_depth: int
    keys: np.ndarray       # host mirror (write buffer), uint64
    values: np.ndarray


class SimHashIndex:
    def __init__(self, backend, *, global_depth: int = 2):
        self.backend: MatchBackend = as_backend(backend)
        self.global_depth = global_depth
        self._next_page = 0
        self.buckets: list[Bucket] = []
        self.directory: list[int] = []
        for i in range(1 << global_depth):
            self.directory.append(self._new_bucket(global_depth))
        self.splits = 0
        self.split_searches = 0
        self.split_gathered_chunks = 0

    @property
    def chips(self):
        return self.backend.chips

    def _new_bucket(self, depth: int) -> int:
        kp, vp = self._next_page, self._next_page + 1
        self._next_page += 2
        self.buckets.append(Bucket(kp, vp, depth,
                                   np.zeros(0, dtype=np.uint64),
                                   np.zeros(0, dtype=np.uint64)))
        self.backend.program_entries(kp, np.zeros(0, dtype=np.uint64))
        self.backend.program_entries(vp, np.zeros(0, dtype=np.uint64))
        return len(self.buckets) - 1

    def _dir_slot(self, key: int) -> int:
        h = int(_hash64(np.array([key], dtype=np.uint64))[0])
        return h & ((1 << self.global_depth) - 1)

    # -------------------------------------------------------------- insert
    def insert(self, key: int, value: int) -> None:
        bi = self.directory[self._dir_slot(key)]
        b = self.buckets[bi]
        if b.keys.size >= BUCKET_CAPACITY:
            self._split(bi)
            return self.insert(key, value)
        hit = np.nonzero(b.keys == np.uint64(key))[0]
        if hit.size:
            b.values[hit[0]] = value
        else:
            b.keys = np.append(b.keys, np.uint64(key))
            b.values = np.append(b.values, np.uint64(value))
        self.backend.program_entries(b.key_page, b.keys)
        self.backend.program_entries(b.value_page, b.values)

    def _split(self, bi: int) -> None:
        """§V-D redistribution: partition the bucket by the next hash bit
        using one masked search per side + chunk gathers (demonstrated with
        real SiM commands on the key page; the host mirror does bookkeeping).
        """
        b = self.buckets[bi]
        self.splits += 1
        bit = b.local_depth
        h = _hash64(b.keys)
        side1 = ((h >> np.uint64(bit)) & np.uint64(1)).astype(bool)

        # Demonstrate the command sequence on-device: search key page with a
        # mask selecting nothing of the key (mask=0 matches all), then use
        # host-computed partition bitmaps to gather each side's chunks.
        resp = self.backend.search(Command.search(b.key_page, 0, 0))
        self.split_searches += 1
        bitmap = mask_header_slots(resp.bitmap_words)
        cb = int(pair_to_u64(*chunk_bitmap_from_slot_bitmap(bitmap)))
        g = self.backend.gather(Command.gather(b.key_page, cb))
        self.split_gathered_chunks += len(g.chunk_ids)

        if b.local_depth == self.global_depth:
            # dir slots use the LOW hash bits: growing the depth appends a
            # high bit, so the doubled directory is two concatenated copies.
            self.directory = self.directory + self.directory
            self.global_depth += 1
        new_bi = self._new_bucket(b.local_depth + 1)
        nb = self.buckets[new_bi]
        nb.keys, nb.values = b.keys[side1], b.values[side1]
        b.keys, b.values = b.keys[~side1], b.values[~side1]
        b.local_depth += 1
        for d in range(len(self.directory)):
            if self.directory[d] == bi and ((d >> bit) & 1):
                self.directory[d] = new_bi
        for bb in (b, nb):
            self.backend.program_entries(bb.key_page, bb.keys)
            self.backend.program_entries(bb.value_page, bb.values)

    # -------------------------------------------------------------- lookup
    def lookup(self, key: int) -> int | None:
        return self.lookup_batch([key])[0]

    def lookup_batch(self, keys) -> list[int | None]:
        """Batched probes: all bucket searches flush as one launch, then
        all value-page gathers as a second."""
        buckets = [self.buckets[self.directory[self._dir_slot(int(k))]]
                   for k in keys]
        tickets = [self.backend.submit_search(
            Command.search(b.key_page, int(k), FULL_MASK))
            for k, b in zip(keys, buckets)]
        self.backend.flush()

        slots_out: list[int | None] = []
        gathers = []
        for b, t in zip(buckets, tickets):
            bitmap = mask_header_slots(t.result().bitmap_words)
            slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
            if slots.size == 0:
                slots_out.append(None)
                gathers.append(None)
                continue
            entry = int(slots[0]) - SLOTS_PER_CHUNK
            value_slot = SLOTS_PER_CHUNK + entry
            slots_out.append(value_slot)
            gathers.append(self.backend.submit_gather(Command.gather(
                b.value_page, 1 << (value_slot // SLOTS_PER_CHUNK))))
        self.backend.flush()

        out: list[int | None] = []
        for value_slot, g in zip(slots_out, gathers):
            if g is None:
                out.append(None)
                continue
            off = (value_slot % SLOTS_PER_CHUNK) * 8
            out.append(int.from_bytes(
                bytes(g.result().chunks[0][off:off + 8]), "little"))
        return out
