"""Extendible hash index with SiM bucket pages (paper §V, Fig 11).

The in-memory directory maps hash prefixes to bucket pages.  A bucket stores
packed (key -> value) entries as two SiM pages.  Bucket splits use the §V-D
keyspace-partitioning trick: one masked *search* per half isolates the
entries whose next hash bit is 0/1, and *gather* moves only those chunks —
no full-page read during redistribution.

Device traffic flows through a MatchBackend; ``lookup_batch`` enqueues a
burst of probes and flushes once (one kernel launch per phase on the
batched backend).  Bucket pages are allocated sequentially, which on a
``ShardedSsdBackend`` stripes them across channels x dies — a probe burst
over many buckets therefore spreads over every chip and still executes as
one stacked launch.

Write path.  Inserts do NOT reprogram the bucket's two pages per call
anymore — bucket mutations land in host-mirror arrays with amortized
(doubling) growth and the dirty pages sit in a coalescing ``WriteBuffer``
(repro.buffer): consecutive inserts into one bucket collapse to ONE
deferred ``submit_program`` per page at the next flush point (a lookup, a
split, or an explicit ``flush_writes()``), which the kernel backends stage
as one grouped plane-store update.  Lookups flush first, so read-your-
writes and the lookup parity tests hold unchanged.

Splits are *iterative*: a full bucket splits until the target fits, and a
degenerate split — every key on one side because the keys share a hash
prefix — no longer recurses without bound.  ``depth_cap`` bounds the local
depth (and with it the directory, which doubles per global split); a
bucket that is still full at the cap overflows in place instead, bounded
by the page's 504 user slots.
"""
from __future__ import annotations

import numpy as np

from repro.backend import MatchBackend, as_backend
from repro.buffer.writebuffer import WriteBuffer
from repro.core.bits import (SLOTS_PER_CHUNK, chunk_bitmap_from_slot_bitmap,
                             pair_to_u64, unpack_bitmap)
from repro.core.commands import Command
from repro.core.page import USER_SLOTS, mask_header_slots
from repro.reliability import require_clean

FULL_MASK = 0xFFFFFFFFFFFFFFFF
BUCKET_CAPACITY = 404
DEPTH_CAP = 20     # bounds degenerate split chains AND the directory (2^cap)


def _hash64(keys: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — uniform bucket spread for arbitrary keys."""
    z = np.asarray(keys, dtype=np.uint64) + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


class Bucket:
    """Host mirror of one bucket's two pages, with amortized append.

    Entries live in capacity arrays that double on demand — an insert is
    O(1) amortized instead of the O(n) ``np.append`` reallocation per call
    the old dataclass paid twice per insert.  ``keys``/``values`` expose
    zero-copy views of the live prefix.
    """

    __slots__ = ("key_page", "value_page", "local_depth", "n",
                 "_keys", "_vals")

    def __init__(self, key_page: int, value_page: int, local_depth: int,
                 capacity: int = 64):
        self.key_page = key_page
        self.value_page = value_page
        self.local_depth = local_depth
        self.n = 0
        self._keys = np.empty(capacity, dtype=np.uint64)
        self._vals = np.empty(capacity, dtype=np.uint64)

    @property
    def keys(self) -> np.ndarray:
        return self._keys[:self.n]

    @property
    def values(self) -> np.ndarray:
        return self._vals[:self.n]

    def _grow_to(self, need: int) -> None:
        if need <= self._keys.size:
            return
        cap = max(self._keys.size * 2, need)
        self._keys = np.resize(self._keys, cap)
        self._vals = np.resize(self._vals, cap)

    def append(self, key: int, value: int) -> None:
        self._grow_to(self.n + 1)
        self._keys[self.n] = key
        self._vals[self.n] = value
        self.n += 1

    def set_entries(self, keys: np.ndarray, values: np.ndarray) -> None:
        self._grow_to(keys.size)
        self._keys[:keys.size] = keys
        self._vals[:values.size] = values
        self.n = int(keys.size)


class SimHashIndex:
    def __init__(self, backend, *, global_depth: int = 2,
                 depth_cap: int = DEPTH_CAP, write_high_water: int = 16):
        if not (0 < depth_cap <= 63):
            raise ValueError(f"depth_cap must be in (0, 63], got {depth_cap}")
        self.backend: MatchBackend = as_backend(backend)
        self.global_depth = global_depth
        self.depth_cap = max(depth_cap, global_depth)
        self.write_buffer = WriteBuffer(high_water=write_high_water)
        self._next_page = 0
        self.buckets: list[Bucket] = []
        self.directory: list[int] = []
        for _ in range(1 << global_depth):
            self.directory.append(self._new_bucket(global_depth))
        self.splits = 0
        self.split_searches = 0
        self.split_gathered_chunks = 0

    @property
    def chips(self):
        return self.backend.chips

    def _new_bucket(self, depth: int) -> int:
        kp, vp = self._next_page, self._next_page + 1
        self._next_page += 2
        self.buckets.append(Bucket(kp, vp, depth))
        # Structural page allocation is eager (pages must exist before any
        # device command routes to them); data updates go through the
        # write buffer.
        self.backend.program_entries(kp, np.zeros(0, dtype=np.uint64))
        self.backend.program_entries(vp, np.zeros(0, dtype=np.uint64))
        return len(self.buckets) - 1

    def _dir_slot(self, key: int) -> int:
        h = int(_hash64(np.array([key], dtype=np.uint64))[0])
        return h & ((1 << self.global_depth) - 1)

    # ----------------------------------------------------------- write path
    def _put_bucket(self, b: Bucket) -> None:
        """Mark both of the bucket's pages dirty in the coalescing buffer;
        consecutive inserts into one bucket collapse to one program per
        page at the next flush point."""
        self.write_buffer.put(b.key_page, b.keys)
        self.write_buffer.put(b.value_page, b.values)
        if self.write_buffer.should_flush:
            self.flush_writes()

    def flush_writes(self) -> int:
        """Drain dirty bucket pages as one deferred-program group."""
        return self.write_buffer.flush(self.backend)

    # -------------------------------------------------------------- insert
    def insert(self, key: int, value: int) -> None:
        bi = self.directory[self._dir_slot(key)]
        b = self.buckets[bi]
        # Iterative split-until-fits: a degenerate split (every key on one
        # side) just deepens the bucket, so the loop terminates at
        # depth_cap instead of recursing without bound.  At the cap the
        # bucket overflows in place (bounded by the page's user slots).
        while b.n >= BUCKET_CAPACITY and b.local_depth < self.depth_cap:
            self._split(bi)
            bi = self.directory[self._dir_slot(key)]
            b = self.buckets[bi]
        hit = np.nonzero(b.keys == np.uint64(key))[0]
        if hit.size:                   # updates need no new slot, so they
            b._vals[hit[0]] = value    # succeed even at a full capped bucket
        elif b.n >= USER_SLOTS:
            raise RuntimeError(
                f"bucket at depth cap {self.depth_cap} overflowed the page "
                f"({b.n} entries): degenerate key set")
        else:
            b.append(key, value)
        self._put_bucket(b)

    def _split(self, bi: int) -> None:
        """§V-D redistribution: partition the bucket by the next hash bit
        using one masked search per side + chunk gathers (demonstrated with
        real SiM commands on the key page; the host mirror does bookkeeping).
        """
        # The on-device demonstration reads the bucket's key page, so the
        # buffered image must be programmed first.
        self.flush_writes()
        b = self.buckets[bi]
        self.splits += 1
        bit = b.local_depth
        h = _hash64(b.keys)
        side1 = ((h >> np.uint64(bit)) & np.uint64(1)).astype(bool)

        # Demonstrate the command sequence on-device: search key page with a
        # mask selecting nothing of the key (mask=0 matches all), then use
        # host-computed partition bitmaps to gather each side's chunks.
        resp = require_clean(self.backend.search(
            Command.search(b.key_page, 0, 0)))
        self.split_searches += 1
        bitmap = mask_header_slots(resp.bitmap_words)
        cb = int(pair_to_u64(*chunk_bitmap_from_slot_bitmap(bitmap)))
        g = self.backend.gather(Command.gather(b.key_page, cb))
        self.split_gathered_chunks += len(g.chunk_ids)

        if b.local_depth == self.global_depth:
            # dir slots use the LOW hash bits: growing the depth appends a
            # high bit, so the doubled directory is two concatenated copies.
            self.directory = self.directory + self.directory
            self.global_depth += 1
        new_bi = self._new_bucket(b.local_depth + 1)
        nb = self.buckets[new_bi]
        keys, vals = b.keys.copy(), b.values.copy()
        nb.set_entries(keys[side1], vals[side1])
        b.set_entries(keys[~side1], vals[~side1])
        b.local_depth += 1
        for d in range(len(self.directory)):
            if self.directory[d] == bi and ((d >> bit) & 1):
                self.directory[d] = new_bi
        for bb in (b, nb):
            self.write_buffer.put(bb.key_page, bb.keys)
            self.write_buffer.put(bb.value_page, bb.values)

    # -------------------------------------------------------------- lookup
    def lookup(self, key: int) -> int | None:
        return self.lookup_batch([key])[0]

    def lookup_batch(self, keys) -> list[int | None]:
        """Batched probes: all bucket searches flush as one launch, then
        all value-page gathers as a second.  Dirty buffered pages program
        first (read-your-writes)."""
        self.flush_writes()
        buckets = [self.buckets[self.directory[self._dir_slot(int(k))]]
                   for k in keys]
        tickets = [self.backend.submit_search(
            Command.search(b.key_page, int(k), FULL_MASK))
            for k, b in zip(keys, buckets)]
        self.backend.flush()

        slots_out: list[int | None] = []
        gathers = []
        for b, t in zip(buckets, tickets):
            bitmap = mask_header_slots(require_clean(t.result()).bitmap_words)
            slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
            if slots.size == 0:
                slots_out.append(None)
                gathers.append(None)
                continue
            entry = int(slots[0]) - SLOTS_PER_CHUNK
            value_slot = SLOTS_PER_CHUNK + entry
            slots_out.append(value_slot)
            gathers.append(self.backend.submit_gather(Command.gather(
                b.value_page, 1 << (value_slot // SLOTS_PER_CHUNK))))
        self.backend.flush()

        out: list[int | None] = []
        for value_slot, g in zip(slots_out, gathers):
            if g is None:
                out.append(None)
                continue
            off = (value_slot % SLOTS_PER_CHUNK) * 8
            out.append(int.from_bytes(
                bytes(require_clean(g.result()).chunks[0][off:off + 8]),
                "little"))
        return out
