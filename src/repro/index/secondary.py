"""Secondary index with BitWeaving-encoded rows on SiM pages (§V-B/C).

Rows are packed into 8-byte keys by a RowCodec (column -> bit range).  A
column predicate becomes one masked search per page (point) or the §V-C
range plan (range); gather returns only the matching encoded rows, from
which the host decodes e.g. the user id.

Predicates execute through a MatchBackend: every page's plan command is
enqueued and flushed together, so a table scan is one batched launch (and
one follow-up gather launch) on the kernel backend instead of a per-page
command loop.  Range predicates ride ``Op.PLAN`` — the multi-pass §V-C
decomposition accumulates OR/AND-NOT in-latch (Fig 10) and only the
combined 64 B bitmap per page crosses the bus, independent of pass count.
Sequential page allocation stripes the table across a
``ShardedSsdBackend``'s channels x dies, so a full-table predicate is the
best case for the stacked launch: every chip matches its own shard of the
table in parallel within ONE device dispatch.
"""
from __future__ import annotations

import numpy as np

from repro.backend import MatchBackend, as_backend
from repro.core.bits import (SLOTS_PER_CHUNK, chunk_bitmap_from_slot_bitmap,
                             pair_to_u64, unpack_bitmap)
from repro.core.bitweaving import RowCodec
from repro.core.commands import Command
from repro.core.page import mask_header_slots
from repro.core.range_query import RangePlan, evaluate_plan_on_pages
from repro.reliability import require_clean

ROWS_PER_PAGE = 504


class SimSecondaryIndex:
    def __init__(self, backend, codec: RowCodec, *, first_page: int = 0):
        self.backend: MatchBackend = as_backend(backend)
        self.codec = codec
        self.first_page = first_page
        self.n_pages = 0
        self.n_rows = 0
        self.io_bitmap_bytes = 0
        self.io_chunk_bytes = 0

    @property
    def chips(self):
        return self.backend.chips

    def load_rows(self, rows: dict[str, np.ndarray]) -> None:
        keys = self.codec.encode_rows(rows)
        self.n_rows = len(keys)
        self._rows_in_page: list[int] = []
        for start in range(0, len(keys), ROWS_PER_PAGE):
            page = self.first_page + self.n_pages
            chunk = keys[start:start + ROWS_PER_PAGE]
            self.backend.program_entries(page, chunk)
            self._rows_in_page.append(len(chunk))
            self.n_pages += 1

    # ---------------------------------------------------------- predicates
    def _page_addrs(self) -> list[int]:
        return [self.first_page + p for p in range(self.n_pages)]

    def _collect_pages(self, bitmaps: np.ndarray) -> np.ndarray:
        """Gather matching rows of all pages -> decoded uint64 keys.

        Slots past a page's row count are vacant (all-ones sentinel) and
        can alias masked predicates (e.g. any column test with all-set
        bits), so the host strips them — the same software-side
        responsibility as the header-chunk mask.  All gathers are enqueued
        before one flush.
        """
        pending = []                       # (slots, ticket)
        for p, bitmap_words in enumerate(bitmaps):
            page = self.first_page + p
            bitmap = mask_header_slots(bitmap_words)
            slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
            slots = slots[slots < SLOTS_PER_CHUNK + self._rows_in_page[p]]
            if slots.size == 0:
                continue
            cb = int(pair_to_u64(*chunk_bitmap_from_slot_bitmap(bitmap)))
            pending.append((slots, self.backend.submit_gather(
                Command.gather(page, cb))))
        self.backend.flush()

        rows = []
        for slots, ticket in pending:
            g = require_clean(ticket.result())
            self.io_chunk_bytes += 64 * len(g.chunk_ids)
            chunk_pos = {int(c): j for j, c in enumerate(g.chunk_ids)}
            out = np.zeros(slots.size, dtype=np.uint64)
            for i, s in enumerate(slots):
                c, off = int(s) // SLOTS_PER_CHUNK, \
                    (int(s) % SLOTS_PER_CHUNK) * 8
                out[i] = int.from_bytes(
                    bytes(g.chunks[chunk_pos[c]][off:off + 8]), "little")
            rows.append(out)
        return (np.concatenate(rows) if rows
                else np.zeros(0, dtype=np.uint64))

    def select_equals(self, column: str, value: int) -> np.ndarray:
        """Fig 9: e.g. all rows with gender == female -> encoded rows."""
        mq = self.codec.equals(column, value)
        plan = RangePlan(include=(mq,))
        bitmaps = evaluate_plan_on_pages(self.backend, plan,
                                         self._page_addrs())
        self.io_bitmap_bytes += 64 * self.n_pages
        return self._collect_pages(bitmaps)

    def select_range(self, column: str, lo: int, hi: int, *,
                     exact: bool = True) -> np.ndarray:
        """Fig 10: lo <= column < hi via the masked-equality range plan.

        The whole predicate is ONE ``Op.PLAN`` per page: all passes
        accumulate in-latch and 64 B per page crosses the bus, no matter
        how many passes the decomposition needs.  With ``exact=False``
        the one-pass-per-bound approximate plan is used and the
        (superset) result is refined on the host — the workflow the
        paper proposes for analytical scans.
        """
        plan: RangePlan = self.codec.range(column, lo, hi, exact=exact)
        bitmaps = evaluate_plan_on_pages(self.backend, plan,
                                         self._page_addrs())
        self.io_bitmap_bytes += 64 * self.n_pages   # combined, pass-free
        got = self._collect_pages(bitmaps)
        if not exact and got.size:
            vals = self.codec.decode_rows(got, column)
            got = got[(vals >= lo) & (vals < hi)]   # host-side refinement
        return got
