"""Secondary index with BitWeaving-encoded rows on SiM pages (§V-B/C).

Rows are packed into 8-byte keys by a RowCodec (column -> bit range).  A
column predicate becomes one masked search per page (point) or the §V-C
range plan (range); gather returns only the matching encoded rows, from
which the host decodes e.g. the user id.
"""
from __future__ import annotations

import numpy as np

from repro.core.bits import (SLOTS_PER_CHUNK, chunk_bitmap_from_slot_bitmap,
                             pair_to_u64, unpack_bitmap)
from repro.core.bitweaving import RowCodec
from repro.core.commands import Command
from repro.core.engine import SimChipArray
from repro.core.page import mask_header_slots
from repro.core.range_query import RangePlan

ROWS_PER_PAGE = 504


class SimSecondaryIndex:
    def __init__(self, chips: SimChipArray, codec: RowCodec,
                 *, first_page: int = 0):
        self.chips = chips
        self.codec = codec
        self.first_page = first_page
        self.n_pages = 0
        self.n_rows = 0
        self.io_bitmap_bytes = 0
        self.io_chunk_bytes = 0

    def load_rows(self, rows: dict[str, np.ndarray]) -> None:
        keys = self.codec.encode_rows(rows)
        self.n_rows = len(keys)
        self._rows_in_page: list[int] = []
        for start in range(0, len(keys), ROWS_PER_PAGE):
            page = self.first_page + self.n_pages
            chunk = keys[start:start + ROWS_PER_PAGE]
            self.chips.program_entries(page, chunk)
            self._rows_in_page.append(len(chunk))
            self.n_pages += 1

    # ---------------------------------------------------------- predicates
    def _collect(self, page: int, bitmap_words: np.ndarray) -> np.ndarray:
        """Gather matching rows of one page -> decoded uint64 keys.

        Slots past the page's row count are vacant (all-ones sentinel) and
        can alias masked predicates (e.g. any column test with all-set bits),
        so the host strips them — the same software-side responsibility as
        the header-chunk mask.
        """
        bitmap = mask_header_slots(bitmap_words)
        slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
        n_rows = self._rows_in_page[page - self.first_page]
        slots = slots[slots < SLOTS_PER_CHUNK + n_rows]
        if slots.size == 0:
            return np.zeros(0, dtype=np.uint64)
        cb = int(pair_to_u64(*chunk_bitmap_from_slot_bitmap(bitmap)))
        g = self.chips.gather(Command.gather(page, cb))
        self.io_chunk_bytes += 64 * len(g.chunk_ids)
        chunk_pos = {int(c): j for j, c in enumerate(g.chunk_ids)}
        out = np.zeros(slots.size, dtype=np.uint64)
        for i, s in enumerate(slots):
            c, off = int(s) // SLOTS_PER_CHUNK, (int(s) % SLOTS_PER_CHUNK) * 8
            out[i] = int.from_bytes(
                bytes(g.chunks[chunk_pos[c]][off:off + 8]), "little")
        return out

    def select_equals(self, column: str, value: int) -> np.ndarray:
        """Fig 9: e.g. all rows with gender == female -> encoded rows."""
        mq = self.codec.equals(column, value)
        rows = []
        for p in range(self.n_pages):
            page = self.first_page + p
            resp = self.chips.search(Command.search(page, mq.query, mq.mask))
            self.io_bitmap_bytes += 64
            rows.append(self._collect(page, resp.bitmap_words))
        return np.concatenate(rows) if rows else np.zeros(0, dtype=np.uint64)

    def select_range(self, column: str, lo: int, hi: int, *,
                     exact: bool = True) -> np.ndarray:
        """Fig 10: lo <= column < hi via the masked-equality range plan.

        With ``exact=False`` the one-pass-per-bound approximate plan is used
        and the (superset) result is refined on the host — the workflow the
        paper proposes for analytical scans.
        """
        plan: RangePlan = self.codec.range(column, lo, hi, exact=exact)
        rows = []
        for p in range(self.n_pages):
            page = self.first_page + p
            acc = np.zeros(16, dtype=np.uint32)
            for mq in plan.include:
                resp = self.chips.search(Command.search(page, mq.query,
                                                        mq.mask))
                self.io_bitmap_bytes += 64
                acc |= resp.bitmap_words
            for mq in plan.exclude:
                resp = self.chips.search(Command.search(page, mq.query,
                                                        mq.mask))
                self.io_bitmap_bytes += 64
                acc &= ~resp.bitmap_words
            rows.append(self._collect(page, acc))
        got = np.concatenate(rows) if rows else np.zeros(0, dtype=np.uint64)
        if not exact and got.size:
            vals = self.codec.decode_rows(got, column)
            got = got[(vals >= lo) & (vals < hi)]   # host-side refinement
        return got
