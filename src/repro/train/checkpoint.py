"""Sharded checkpointing with bitwise resume and elastic resharding.

Format: one .npz per "process" (this container is single-process; the file
layout keys every leaf by its pytree path, so a multi-host deployment writes
per-host shards of the same schema) + a JSON manifest (step, config name,
mesh shape, leaf tree structure).  Restore onto a *different* mesh works by
device_put-ing each leaf with the new sharding (elastic scaling).

Atomicity: writes go to <dir>.tmp then os.replace — a crash mid-save leaves
the previous checkpoint intact (exercised by the failure-injection test).
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":     # npz cannot round-trip bf16
            arr = arr.astype(np.float32)     # widening cast is lossless
        out[key] = arr
    return out


def save_checkpoint(ckpt_dir: str | Path, step: int, params, opt_state,
                    *, config_name: str = "", extra: dict | None = None):
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    np.savez(tmp / "params.npz", **_flatten_with_paths(params))
    np.savez(tmp / "opt_state.npz", **_flatten_with_paths(opt_state))
    manifest = {"step": int(step), "config": config_name,
                "extra": extra or {}}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if ckpt_dir.exists():
        shutil.rmtree(ckpt_dir)
    os.replace(tmp, ckpt_dir)


def _unflatten_like(template, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        # jnp handles bf16 targets that numpy cannot cast to
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_checkpoint(ckpt_dir: str | Path, params_template, opt_template,
                    *, shardings=None, opt_shardings=None):
    """Restore (step, params, opt_state).

    ``shardings``/``opt_shardings``: optional NamedSharding trees for the
    *target* mesh — passing trees built for a different mesh than the one
    that saved the checkpoint is the elastic-rescale path (tested).
    """
    ckpt_dir = Path(ckpt_dir)
    manifest = json.loads((ckpt_dir / "manifest.json").read_text())
    with np.load(ckpt_dir / "params.npz") as z:
        params = _unflatten_like(params_template, dict(z))
    with np.load(ckpt_dir / "opt_state.npz") as z:
        opt_state = _unflatten_like(opt_template, dict(z))
    if shardings is not None:
        params = jax.device_put(params, shardings)
    if opt_shardings is not None:
        opt_state = jax.device_put(opt_state, opt_shardings)
    return manifest["step"], params, opt_state


def latest_step(root: str | Path) -> Path | None:
    root = Path(root)
    if not root.exists():
        return None
    steps = sorted((int(p.name.split("_")[-1]), p)
                   for p in root.glob("step_*") if p.is_dir())
    return steps[-1][1] if steps else None
