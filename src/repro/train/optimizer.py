"""AdamW with dtype-configurable moments (bf16 moments fit kimi-k2 on 512
chips — see configs/kimi_k2_1t_a32b.py) and decoupled weight decay.

Functional: state is a pytree mirroring params; its sharding reuses the
parameter logical axes, so FSDP shards moments exactly like weights.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / (1 - cfg.b1 ** step)
        vhat = v32 / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
