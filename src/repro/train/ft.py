"""Fault tolerance: straggler watchdog + failure injection hooks.

At 1000+ nodes the common failures are (a) a slow chip/host dragging every
synchronous step (stragglers), (b) hard node loss.  The framework handles
them with:

  * StragglerWatchdog — per-step wall-time tracking against a rolling
    median; a step slower than ``threshold x median`` raises a flag the
    driver acts on (log, re-dispatch, or — with a real fleet — hot-spare
    swap).  On this container the "straggler" is simulated by the test
    injecting sleep into a step.
  * checkpoint/restart — train.py checkpoints every N steps and resumes
    from the latest durable checkpoint after a crash; bitwise equality with
    an uninterrupted run is asserted in tests (deterministic data pipeline
    + stateless-by-step optimizer make this exact).
  * elastic rescale — the checkpoint loader reshards onto whatever mesh the
    restarted job has (see checkpoint.load_checkpoint).
"""
from __future__ import annotations

import dataclasses
import statistics
import time


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float

    @property
    def slowdown(self) -> float:
        return self.duration_s / self.median_s if self.median_s else 0.0


class StragglerWatchdog:
    """Rolling-median step timer; flags steps slower than threshold x median."""

    def __init__(self, threshold: float = 3.0, window: int = 32,
                 warmup_steps: int = 3):
        self.threshold = threshold
        self.window = window
        self.warmup_steps = warmup_steps
        self._durations: list[float] = []
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.perf_counter()

    def end_step(self) -> StragglerEvent | None:
        assert self._t0 is not None, "start_step not called"
        dur = time.perf_counter() - self._t0
        self._t0 = None
        history = self._durations[-self.window:]
        self._durations.append(dur)
        if len(history) < self.warmup_steps:
            return None
        med = statistics.median(history)
        if med > 0 and dur > self.threshold * med:
            ev = StragglerEvent(self._step, dur, med)
            self.events.append(ev)
            return ev
        return None


class FailureInjector:
    """Deterministic crash injection for restart tests: raises at a chosen
    step, once."""

    def __init__(self, crash_at_step: int | None = None):
        self.crash_at_step = crash_at_step
        self.fired = False

    def maybe_crash(self, step: int) -> None:
        if (self.crash_at_step is not None and not self.fired
                and step == self.crash_at_step):
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")
