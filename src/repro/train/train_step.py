"""Training step: causal LM loss + AdamW, with optional microbatch gradient
accumulation and cross-pod int8 gradient compression.

The step function is a pure (params, opt_state, batch) -> (params,
opt_state, metrics) map; pjit distributes it given the sharding trees from
parallel/sharding.py.  The batch is sharded over (pod, data); XLA inserts
the gradient all-reduce.  When ``compress_pods`` is on, the cross-pod leg of
that reduction is replaced by an explicit int8 error-feedback stage
(parallel/compression.py) under shard_map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import train_logits
from .optimizer import AdamWConfig, adamw_update

AUX_WEIGHT = 0.01
IGNORE = -1


def lm_loss(params, cfg: ModelConfig, tokens, labels, frontend_embeds=None,
            block_specs=None, act_spec=None):
    """Next-token cross entropy; positions with label == IGNORE are masked."""
    logits, aux = train_logits(params, cfg, tokens,
                               frontend_embeds=frontend_embeds,
                               block_specs=block_specs, act_spec=act_spec)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    mask = (labels != IGNORE).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + AUX_WEIGHT * aux, (loss, aux)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, grad_transform=None,
                    block_specs=None, act_spec=None):
    """Build the jittable train step.

    ``grad_transform(grads) -> grads`` hook: the compression stage (or any
    distributed-optimization trick) plugs in here.
    """

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        fe = batch.get("frontend")

        if microbatches == 1:
            grad_fn = jax.value_and_grad(lm_loss, has_aux=True)
            (_, (loss, aux)), grads = grad_fn(params, cfg, tokens, labels,
                                              fe, block_specs, act_spec)
        else:
            b = tokens.shape[0]
            assert b % microbatches == 0
            mb = b // microbatches

            def one(i, carry):
                g_acc, l_acc, a_acc = carry
                sl = lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0)
                grad_fn = jax.value_and_grad(lm_loss, has_aux=True)
                (_, (l, a)), g = grad_fn(params, cfg, sl(tokens), sl(labels),
                                         sl(fe) if fe is not None else None,
                                         block_specs, act_spec)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return g_acc, l_acc + l, a_acc + a

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            grads, loss, aux = jax.lax.fori_loop(
                0, microbatches, one, (g0, jnp.float32(0), jnp.float32(0)))
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss, aux = loss / microbatches, aux / microbatches

        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = adamw_update(grads, opt_state,
                                                      params, opt_cfg)
        metrics = {"loss": loss, "aux_loss": aux, **opt_metrics}
        return params, opt_state, metrics

    return train_step
