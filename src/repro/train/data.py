"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step): a crashed-and-restarted run
regenerates exactly the stream it would have seen, which is what makes the
bitwise-resume test meaningful.  The generator is a Markov-ish mixture so
the LM loss actually decreases (unlike uniform noise) — examples/train_lm.py
shows a real loss curve on it.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patterns: int = 64        # latent pattern count (learnable structure)


def batch_at_step(cfg: DataConfig, step: int) -> dict:
    """{tokens, labels} for one step — stateless in ``step``."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # each sequence follows one of n_patterns affine token recurrences
    pat = rng.integers(0, cfg.n_patterns, size=(b, 1))
    mult = 1 + 2 * (pat % 37)
    add = 7 + pat % 23
    t0 = rng.integers(0, v, size=(b, 1))
    idx = np.arange(s)[None, :]
    tokens = ((t0 + add * idx) * mult) % v
    noise = rng.random((b, s)) < 0.02
    tokens = np.where(noise, rng.integers(0, v, size=(b, s)), tokens)
    labels = np.roll(tokens, -1, axis=1).copy()
    labels[:, -1] = -1                       # IGNORE tail position
    return {"tokens": jnp.asarray(tokens, jnp.int32),
            "labels": jnp.asarray(labels, jnp.int32)}


def batches(cfg: DataConfig, start_step: int = 0):
    step = start_step
    while True:
        yield step, batch_at_step(cfg, step)
        step += 1
