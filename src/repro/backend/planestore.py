"""Device-resident page-plane store for the batched kernel backend.

The SiM chip's entire advantage is that stored pages never cross the bus —
only queries and 64 B bitmaps move (paper §III-B).  The TPU analogue: keep
every staged page's word planes *resident on the device* so a steady-state
flush ships only the (Q, 2) query operands, not 4 KiB per page per flush.

The store is a block-aligned arena of persistent JAX arrays:

    _lo, _hi    : (cap, 512) uint32   — the de-interleaved word planes
    _ids        : (cap, 1)   uint32   — chip-local flash address per row
    _seeds      : (cap, 1)   uint32   — device seed per row

Rows are assigned lazily the first time a flush references a page and are
re-staged *incrementally*: the store subscribes to the write path of its
``SimChipArray`` (``add_observer``), so a ``program_entries`` — or a bit-error
injection or ECC repair, anything that mutates the stored image — marks only
that page's row dirty.  The next flush that touches the page ships exactly
one 4 KiB row host->device; untouched pages ship zero bytes.  The arena
capacity grows by power-of-two blocks and existing rows are carried over
with a device-side copy, so growth never re-ships resident pages.

``staged_bytes``/``staged_rows`` count actual host->device page-plane
traffic; the kernel-micro benchmark asserts they stop growing once the
working set is warm (the zero-restage claim of the ROADMAP's hot-path
mandate).
"""
from __future__ import annotations

import weakref

import jax.numpy as jnp
import numpy as np

from repro.core.bits import PAGE_BYTES, SLOTS_PER_PAGE
from repro.core.engine import SimChipArray
from repro.kernels.layout import pages_to_planes


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (and >= 1)."""
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


def padded_rows(n: int, block: int) -> int:
    """Pad a row count to a power-of-two multiple of ``block``.

    Both flush paths use this geometry so repeated bursts of *similar* (not
    identical) size reuse the same compiled kernel instead of retracing on
    every distinct burst size.
    """
    return block * next_pow2(-(-n // block))


class PlaneStore:
    """Arena of device-resident page planes, invalidated by the write path."""

    def __init__(self, chips: SimChipArray, *, block: int = 32,
                 log_staging: bool = False):
        self.chips = chips
        self.block = block
        self.log_staging = log_staging
        self._row: dict[int, int] = {}      # global page addr -> arena row
        self._addrs: list[int] = []         # arena row -> global page addr
        self._dirty: set[int] = set()
        self._cap = 0
        self._lo = self._hi = None          # (cap, 512) uint32
        self._ids = self._seeds = None      # (cap, 1) uint32
        self.staged_rows = 0                # rows shipped host->device, ever
        self.staged_bytes = 0               # page-plane bytes shipped, ever
        # With ``log_staging``: addresses whose *dirty* planes restaged
        # since the log was last drained — the sharded backend groups
        # these per chip to charge write-back bytes on the right
        # channel-bus timeline (see flash/timeline.py).  Cold first-touch
        # staging is deliberately not logged, and the log is off by
        # default so backends that never drain it don't accumulate it.
        self.staged_log: list[int] = []
        # Subscribe through a weakref so an abandoned store (and its device
        # arena) stays collectable — the chip array outlives backends.
        ref = weakref.ref(self)
        chips.add_observer(lambda addr, _r=ref: (
            _r()._on_write(addr) if _r() is not None else None))

    # ------------------------------------------------------------ bookkeeping
    @property
    def resident_rows(self) -> int:
        return len(self._addrs)

    def _on_write(self, page_addr: int) -> None:
        if page_addr in self._row:
            self._dirty.add(page_addr)

    def _grow(self, need: int) -> None:
        cap = max(self._cap, self.block)
        while cap < need:
            cap *= 2
        if cap == self._cap:
            return
        pad = ((0, cap - self._cap), (0, 0))
        if self._lo is None:
            self._lo = jnp.zeros((cap, SLOTS_PER_PAGE), jnp.uint32)
            self._hi = jnp.zeros((cap, SLOTS_PER_PAGE), jnp.uint32)
            self._ids = jnp.zeros((cap, 1), jnp.uint32)
            self._seeds = jnp.zeros((cap, 1), jnp.uint32)
        else:
            # Device-side copy: growth never re-ships resident pages.
            self._lo = jnp.pad(self._lo, pad)
            self._hi = jnp.pad(self._hi, pad)
            self._ids = jnp.pad(self._ids, pad)
            self._seeds = jnp.pad(self._seeds, pad)
        self._cap = cap

    # ---------------------------------------------------------------- staging
    def rows_for(self, page_addrs) -> np.ndarray:
        """Arena rows for global page addresses, staging new + dirty pages.

        Raises KeyError (via the chip model) on unprogrammed pages, like the
        per-flush staging it replaces.  Returns (len(page_addrs),) int32.
        """
        rows = np.empty(len(page_addrs), np.int32)
        stage: list[int] = []
        dirty_staged: list[int] = []
        queued = set()
        for i, a in enumerate(page_addrs):
            a = int(a)
            r = self._row.get(a)
            if r is None:
                chip, local = self.chips.route(a)
                chip._get(local)            # KeyError on unprogrammed
                r = len(self._addrs)
                self._row[a] = r
                self._addrs.append(a)
                if a not in queued:
                    stage.append(a)
                    queued.add(a)
            elif a in self._dirty and a not in queued:
                stage.append(a)
                dirty_staged.append(a)
                queued.add(a)
            rows[i] = r
        if len(self._addrs) > self._cap:
            self._grow(len(self._addrs))
        if stage:
            self._stage(stage)
            if self.log_staging:
                # Only *dirty* restages enter the log: cold first-touch
                # staging is arena population (a TPU-residency artifact),
                # not write-caused channel traffic (see flash/timeline.py).
                self.staged_log.extend(dirty_staged)
        return rows

    def stage_group(self, page_addrs) -> int:
        """Re-stage a group of just-programmed pages in ONE device update.

        The deferred write path (``MatchBackend.submit_program``) calls this
        right after its grouped chip programs: every listed page that is
        resident-and-dirty, or not yet resident, ships in a single
        ``_stage`` scatter — N programs cost one ``.at[idx].set`` per plane
        instead of N per-page invalidate-then-restage round trips through
        later ``rows_for`` calls.  Clean resident pages are skipped, and
        dirty restages enter ``staged_log``, both exactly as in
        ``rows_for`` — which does all the work here; this entry point only
        discards the row indices.  Returns the number of rows staged.
        """
        before = self.staged_rows
        self.rows_for([int(a) for a in page_addrs])
        return self.staged_rows - before

    def _stage(self, addrs: list[int]) -> None:
        """Ship the listed pages' planes host->device (the only page bytes
        that ever cross after warm-up: new rows and dirty rows)."""
        idx = jnp.asarray(np.array([self._row[a] for a in addrs], np.int32))
        raws, ids, seeds = [], [], []
        for a in addrs:
            chip, local = self.chips.route(a)
            raws.append(chip.pages[local].raw)
            ids.append(local)
            seeds.append(chip.device_seed & 0xFFFFFFFF)
        lo, hi = pages_to_planes(np.stack(raws))
        self._lo = self._lo.at[idx].set(jnp.asarray(lo))
        self._hi = self._hi.at[idx].set(jnp.asarray(hi))
        self._ids = self._ids.at[idx].set(
            jnp.asarray(np.asarray(ids, np.uint32)[:, None]))
        self._seeds = self._seeds.at[idx].set(
            jnp.asarray(np.asarray(seeds, np.uint32)[:, None]))
        self._dirty.difference_update(addrs)
        self.staged_rows += len(addrs)
        self.staged_bytes += len(addrs) * PAGE_BYTES

    # ----------------------------------------------------------------- access
    def take(self, rows: np.ndarray, pad_to: int):
        """Device-side row gather, padded to ``pad_to`` rows (repeats row 0).

        Returns (lo (P, 512), hi (P, 512), ids (P,), seeds (P,)) as device
        arrays — no page bytes cross the bus here, only the row indices.
        """
        r = np.zeros(pad_to, np.int32)
        r[:len(rows)] = rows
        ridx = jnp.asarray(r)
        return (self._lo[ridx], self._hi[ridx],
                self._ids[ridx, 0], self._seeds[ridx, 0])

    def take2d(self, rows: np.ndarray):
        """Row gather for a (C, R) index matrix, in four device ops total.

        Returns (lo (C, R, 512), hi (C, R, 512), ids (C, R), seeds (C, R)).
        This is how the sharded backend stacks every chip's operand rows
        for its single vmapped launch without a per-chip gather+stack
        cascade (device dispatch on the interpret path is the bottleneck).
        """
        ridx = jnp.asarray(np.asarray(rows, np.int32))
        return (self._lo[ridx], self._hi[ridx],
                self._ids[ridx, 0], self._seeds[ridx, 0])
