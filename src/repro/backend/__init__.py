"""Interchangeable execution backends for the SiM search/gather/lookup
contract.

See base.py for the contract, scalar.py for the per-page reference path,
batched.py for the single-launch Pallas fast path and planestore.py for the
device-resident page-plane arena behind it.
"""
from .base import (BackendStats, MatchBackend, Ticket, as_backend,
                   make_backend)
from .batched import BatchedKernelBackend
from .planestore import PlaneStore
from .scalar import ScalarBackend

__all__ = ["BackendStats", "MatchBackend", "PlaneStore", "Ticket",
           "as_backend", "make_backend", "ScalarBackend",
           "BatchedKernelBackend"]
