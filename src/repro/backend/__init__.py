"""Interchangeable execution backends for the SiM search/gather contract.

See base.py for the contract, scalar.py for the per-page reference path and
batched.py for the single-launch Pallas fast path.
"""
from .base import (BackendStats, MatchBackend, Ticket, as_backend,
                   make_backend)
from .batched import BatchedKernelBackend
from .scalar import ScalarBackend

__all__ = ["BackendStats", "MatchBackend", "Ticket", "as_backend",
           "make_backend", "ScalarBackend", "BatchedKernelBackend"]
