"""Interchangeable execution backends for the SiM search/gather/lookup
contract.

See base.py for the contract, scalar.py for the per-page reference path,
batched.py for the single-launch Pallas fast path, planestore.py for the
device-resident page-plane arena behind it, and sharded.py for the
channels x dies multi-chip SSD backend (per-chip arenas, one stacked
launch per burst, optional flash/ssd.py timeline coupling).
"""
from .base import (BackendStats, MatchBackend, Ticket, as_backend,
                   make_backend)
from .batched import BatchedKernelBackend
from .planestore import PlaneStore
from .scalar import ScalarBackend
from .sharded import ShardedSsdBackend

__all__ = ["BackendStats", "MatchBackend", "PlaneStore", "Ticket",
           "as_backend", "make_backend", "ScalarBackend",
           "BatchedKernelBackend", "ShardedSsdBackend"]
