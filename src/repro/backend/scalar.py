"""Reference MatchBackend: queued commands execute one page at a time.

This is the existing numpy ``SimChip`` path behind the deferred-submission
interface.  Every queued command walks the full functional model — latch
pipeline, optimistic-open verdicts, ECC fallback — so it remains the
bit-exact oracle the batched backend is validated against, and the only
backend that models damaged pages end to end.
A queued LOOKUP executes as the paper's §V-A command pair — a key-page
search followed by a gather of the first matching user slot's chunk on the
paired value page — through the same chip model, so it is the bit-exact
oracle for the batched backend's fused single-launch lookup path.
A queued PLAN executes as the per-pass split: one chip search per
include/exclude pass, OR/AND-NOT combined on the controller — the
bit-exact reference for the fused in-latch ``sim_plan`` kernel.
``BackendStats.result_bytes`` still counts only the combined 64 B bitmap
per plan (what a SiM chip would transmit), not the per-pass payloads.
"""
from __future__ import annotations

import numpy as np

from repro.core.bits import (SLOTS_PER_CHUNK, popcount_words, unpack_bitmap)
from repro.core.commands import (Command, LookupResponse, Op,
                                 SearchResponse)
from repro.core.ecc import OpenVerdict
from repro.core.engine import SimChipArray
from repro.core.page import mask_header_slots

from .base import MatchBackend, Ticket


class ScalarBackend(MatchBackend):
    def __init__(self, chips: SimChipArray):
        super().__init__(chips)
        self._queue: list[tuple[str, Command, Ticket]] = []

    def submit_search(self, cmd: Command) -> Ticket:
        t = Ticket(self)
        self._queue.append(("search", cmd, t))
        return t

    def submit_gather(self, cmd: Command) -> Ticket:
        t = Ticket(self)
        self._queue.append(("gather", cmd, t))
        return t

    def submit_lookup(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.LOOKUP or cmd.value_page is None:
            raise ValueError(f"not a lookup command: {cmd}")
        t = Ticket(self)
        self._queue.append(("lookup", cmd, t))
        return t

    def submit_plan(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.PLAN or cmd.plan_include is None:
            raise ValueError(f"not a plan command: {cmd}")
        t = Ticket(self)
        self._queue.append(("plan", cmd, t))
        return t

    @property
    def pending(self) -> int:
        return len(self._queue) + self.pending_programs

    def flush(self) -> None:
        # Deferred programs run first (coalesced last-wins per page), so
        # commands flushed alongside them match against the new images —
        # identical ordering to the kernel backends' grouped program phase.
        programs = self._execute_programs()
        queue, self._queue = self._queue, []
        if not queue:
            if programs:
                self.stats.flushes += 1
            return
        self.stats.flushes += 1
        if self.reliability is not None:
            self._flush_reliable(queue)
            return
        for kind, cmd, ticket in queue:
            if kind == "search":
                ticket._resolve(self.chips.search(cmd))
                self.stats.searches += 1
                self.stats.result_bytes += 64
            elif kind == "lookup":
                resp = self._lookup(cmd)
                ticket._resolve(resp)
                self.stats.lookups += 1
                self.stats.result_bytes += 64 + (64 if resp.value_slot
                                                 is not None else 0)
            elif kind == "plan":
                ticket._resolve(self._plan(cmd))
                self.stats.plans += 1
                self.stats.result_bytes += 64      # the combined bitmap only
            else:
                resp = self.chips.gather(cmd)
                ticket._resolve(resp)
                self.stats.gathers += 1
                self.stats.result_bytes += 64 * len(resp.chunk_ids)

    def _flush_reliable(self, queue) -> None:
        """Reliability-tier flush: ONE optimistic open per unique page (the
        same staged-open discipline as the kernel backends), raw execution
        against the possibly open-repaired images, then the shared
        vote/verify/fallback finalize per response.

        Raw execution runs for the WHOLE burst before any finalize step, so
        resolve-time repairs (verification failures, lookup-miss
        escalations) cannot retroactively change a burst peer's raw bitmap
        — exactly the ordering a single kernel launch imposes.
        """
        from repro.reliability import UncorrectableReadError
        rel = self.reliability
        addrs = set()
        for _, cmd, _ in queue:
            addrs.add(cmd.page_addr)
            if cmd.value_page is not None:
                addrs.add(cmd.value_page)
        opens = rel.open_burst(self.chips, addrs)

        def dead(cmd):
            if opens[cmd.page_addr].verdict is OpenVerdict.UNCORRECTABLE:
                return cmd.page_addr
            if cmd.value_page is not None and \
                    opens[cmd.value_page].verdict is OpenVerdict.UNCORRECTABLE:
                return cmd.value_page
            return None

        raws = []
        for kind, cmd, _ in queue:
            if dead(cmd) is not None:
                raws.append(None)
            elif kind == "search":
                raws.append(self.chips.search(cmd).bitmap_words)
            elif kind == "lookup":
                raws.append(self.chips.search(Command(
                    Op.SEARCH, cmd.page_addr, query=cmd.query,
                    mask=cmd.mask)).bitmap_words)
            elif kind == "plan":
                raws.append(self._plan(cmd).bitmap_words)
            else:
                raws.append(self.chips.gather(cmd))

        for (kind, cmd, ticket), raw in zip(queue, raws):
            try:
                if raw is None:
                    raise UncorrectableReadError(dead(cmd))
                if kind == "search":
                    resp = rel.finalize_search(self.chips, cmd, raw, opens)
                    ticket._resolve(resp)
                    self.stats.result_bytes += 64
                elif kind == "lookup":
                    resp = rel.finalize_lookup(self.chips, cmd, raw, opens)
                    ticket._resolve(resp)
                    self.stats.result_bytes += 64 + (
                        64 if resp.value_slot is not None else 0)
                elif kind == "plan":
                    resp = rel.finalize_plan(self.chips, cmd, raw, opens)
                    ticket._resolve(resp)
                    self.stats.result_bytes += 64
                else:
                    resp = rel.finalize_gather(self.chips, cmd, raw, opens)
                    ticket._resolve(resp)
                    self.stats.result_bytes += 64 * len(resp.chunk_ids)
            except UncorrectableReadError as e:
                ticket._fail(e)
            if kind == "search":
                self.stats.searches += 1
            elif kind == "lookup":
                self.stats.lookups += 1
            elif kind == "plan":
                self.stats.plans += 1
            else:
                self.stats.gathers += 1

    # Open-verdict severity, worst-wins across a plan's passes.
    _VERDICT_RANK = {v.value: i for i, v in enumerate((
        OpenVerdict.CLEAN, OpenVerdict.CLEAN_NEEDS_REFRESH,
        OpenVerdict.FALLBACK_ECC, OpenVerdict.UNCORRECTABLE))}

    def _plan(self, cmd: Command) -> SearchResponse:
        """Per-pass split reference for Op.PLAN: one full chip search per
        include/exclude pass, combined OR-then-AND-NOT exactly as the
        latch accumulation would (paper Fig 10).  Reports the worst
        (most severe) open verdict any pass saw."""
        acc = np.zeros(16, dtype=np.uint32)
        verdict = OpenVerdict.CLEAN.value
        for q, mk in cmd.plan_include:
            r = self.chips.search(Command(Op.SEARCH, cmd.page_addr,
                                          query=q, mask=mk))
            acc |= r.bitmap_words
            verdict = max(verdict, r.open_verdict,
                          key=self._VERDICT_RANK.__getitem__)
        for q, mk in cmd.plan_exclude:
            r = self.chips.search(Command(Op.SEARCH, cmd.page_addr,
                                          query=q, mask=mk))
            acc &= ~r.bitmap_words
            verdict = max(verdict, r.open_verdict,
                          key=self._VERDICT_RANK.__getitem__)
        return SearchResponse(bitmap_words=acc,
                              match_count=int(popcount_words(acc).sum()),
                              open_verdict=verdict)

    def _lookup(self, cmd: Command) -> LookupResponse:
        resp = self.chips.search(Command(Op.SEARCH, cmd.page_addr,
                                         query=cmd.query, mask=cmd.mask))
        bitmap = mask_header_slots(resp.bitmap_words)
        slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
        if slots.size == 0:
            return LookupResponse(search=resp, value_slot=None, value=None)
        slot = int(slots[0])
        g = self.chips.gather(Command.gather(cmd.value_page,
                                             1 << (slot // SLOTS_PER_CHUNK)))
        off = (slot % SLOTS_PER_CHUNK) * 8
        return LookupResponse(search=resp, value_slot=slot,
                              value=bytes(g.chunks[0][off:off + 8]),
                              parity_ok=bool(g.parity_ok[0]))
