"""Reference MatchBackend: queued commands execute one page at a time.

This is the existing numpy ``SimChip`` path behind the deferred-submission
interface.  Every queued command walks the full functional model — latch
pipeline, optimistic-open verdicts, ECC fallback — so it remains the
bit-exact oracle the batched backend is validated against, and the only
backend that models damaged pages end to end.
"""
from __future__ import annotations

from repro.core.commands import Command
from repro.core.engine import SimChipArray

from .base import MatchBackend, Ticket


class ScalarBackend(MatchBackend):
    def __init__(self, chips: SimChipArray):
        super().__init__(chips)
        self._queue: list[tuple[str, Command, Ticket]] = []

    def submit_search(self, cmd: Command) -> Ticket:
        t = Ticket(self)
        self._queue.append(("search", cmd, t))
        return t

    def submit_gather(self, cmd: Command) -> Ticket:
        t = Ticket(self)
        self._queue.append(("gather", cmd, t))
        return t

    @property
    def pending(self) -> int:
        return len(self._queue)

    def flush(self) -> None:
        queue, self._queue = self._queue, []
        if not queue:
            return
        self.stats.flushes += 1
        for kind, cmd, ticket in queue:
            if kind == "search":
                ticket._resolve(self.chips.search(cmd))
                self.stats.searches += 1
            else:
                ticket._resolve(self.chips.gather(cmd))
                self.stats.gathers += 1
