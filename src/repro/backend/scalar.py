"""Reference MatchBackend: queued commands execute one page at a time.

This is the existing numpy ``SimChip`` path behind the deferred-submission
interface.  Every queued command walks the full functional model — latch
pipeline, optimistic-open verdicts, ECC fallback — so it remains the
bit-exact oracle the batched backend is validated against, and the only
backend that models damaged pages end to end.
A queued LOOKUP executes as the paper's §V-A command pair — a key-page
search followed by a gather of the first matching user slot's chunk on the
paired value page — through the same chip model, so it is the bit-exact
oracle for the batched backend's fused single-launch lookup path.
"""
from __future__ import annotations

import numpy as np

from repro.core.bits import SLOTS_PER_CHUNK, unpack_bitmap
from repro.core.commands import Command, LookupResponse, Op
from repro.core.engine import SimChipArray
from repro.core.page import mask_header_slots

from .base import MatchBackend, Ticket


class ScalarBackend(MatchBackend):
    def __init__(self, chips: SimChipArray):
        super().__init__(chips)
        self._queue: list[tuple[str, Command, Ticket]] = []

    def submit_search(self, cmd: Command) -> Ticket:
        t = Ticket(self)
        self._queue.append(("search", cmd, t))
        return t

    def submit_gather(self, cmd: Command) -> Ticket:
        t = Ticket(self)
        self._queue.append(("gather", cmd, t))
        return t

    def submit_lookup(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.LOOKUP or cmd.value_page is None:
            raise ValueError(f"not a lookup command: {cmd}")
        t = Ticket(self)
        self._queue.append(("lookup", cmd, t))
        return t

    @property
    def pending(self) -> int:
        return len(self._queue)

    def flush(self) -> None:
        queue, self._queue = self._queue, []
        if not queue:
            return
        self.stats.flushes += 1
        for kind, cmd, ticket in queue:
            if kind == "search":
                ticket._resolve(self.chips.search(cmd))
                self.stats.searches += 1
            elif kind == "lookup":
                ticket._resolve(self._lookup(cmd))
                self.stats.lookups += 1
            else:
                ticket._resolve(self.chips.gather(cmd))
                self.stats.gathers += 1

    def _lookup(self, cmd: Command) -> LookupResponse:
        resp = self.chips.search(Command(Op.SEARCH, cmd.page_addr,
                                         query=cmd.query, mask=cmd.mask))
        bitmap = mask_header_slots(resp.bitmap_words)
        slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
        if slots.size == 0:
            return LookupResponse(search=resp, value_slot=None, value=None)
        slot = int(slots[0])
        g = self.chips.gather(Command.gather(cmd.value_page,
                                             1 << (slot // SLOTS_PER_CHUNK)))
        off = (slot % SLOTS_PER_CHUNK) * 8
        return LookupResponse(search=resp, value_slot=slot,
                              value=bytes(g.chunks[0][off:off + 8]),
                              parity_ok=bool(g.parity_ok[0]))
