"""MatchBackend: the batched search/gather contract, defined once.

core/match.py specifies *what* a search and a gather compute; this module
specifies *how* callers drive them at scale.  Index structures and workload
runners never talk to a chip directly — they enqueue commands against a
backend and flush, which is what turns a B+Tree range scan or a YCSB read
burst into one device operation instead of a per-page command storm
(paper §IV-E batch matching).

Two interchangeable implementations ship today:

  * ``ScalarBackend`` (scalar.py) — the numpy ``SimChip``/``SimChipArray``
    functional model, executing queued commands one page at a time.  This is
    the bit-exact reference, with the full latch/ECC machinery.
  * ``BatchedKernelBackend`` (batched.py) — keeps stored pages *device
    resident* in a ``PlaneStore`` arena (planestore.py) and executes queued
    searches in a single ``sim_search`` Pallas launch, queued gathers in a
    single ``sim_gather`` launch, and queued lookups in a single fused
    ``sim_fused_lookup`` launch, with the per-page randomization stream
    regenerated in-kernel.  After warm-up only (Q, 2) query operands cross
    host->device per flush; ``program_entries`` invalidates exactly the
    rewritten page's arena row through the engine's write observers.

Besides search/gather, backends implement ``submit_lookup`` — the fused
point-lookup primitive (key-page search + first-matching-slot value gather,
the §V-A paired-page pattern) that a YCSB read burst or a B+Tree
``lookup_batch`` resolves in ONE device launch instead of a search launch,
a Python bitmap decode, and a gather launch — and ``submit_plan``, the
fused multi-pass range-plan primitive (Op.PLAN): every include/exclude
pass of a §V-C range decomposition evaluates on-device and the OR/AND-NOT
combine happens in-latch (paper Fig 10), so ONE 64 B bitmap per page comes
back instead of one per pass (``BackendStats.result_bytes`` counts the
difference).

The write path is deferred too: ``submit_program`` queues an ``Op.PROGRAM``
(a full-page entry image) instead of reprogramming the chip inline.
Repeated programs of one page within a burst coalesce last-wins — every
ticket of the page resolves to the final image's ``BuiltPage`` and only ONE
chip program executes (``BackendStats.programs`` /
``programs_coalesced``).  At ``flush()`` the queued programs run *first*
(so commands flushed alongside them see the new images), and the kernel
backends re-stage every programmed page's device-resident plane row in ONE
grouped scatter (``PlaneStore.stage_group``) instead of the per-page
invalidate-then-restage round trip the eager ``program_entries`` path
causes.  This is the backend half of the §VI "whole cache acts as a write
buffer" configuration; the host half (coalescing across bursts, overlay
reads) lives in ``repro.buffer.writebuffer``.

Result delivery is *lazy* on the kernel backends: ``flush()`` dispatches
the launches and attaches a ``LazyResultBatch`` to each ticket; the
device->host transfer and host tail run at the first ``result()`` call of
a burst, so JAX async dispatch overlaps staging of burst k+1 with device
compute of burst k.

A third implementation, ``ShardedSsdBackend`` (sharded.py), scales the
same contract to a whole SSD: ``channels x dies_per_channel`` chips, each
with its own plane-store arena and pending queue, drained in ONE stacked
launch per burst (vmap over the chip axis) with optional coupling to the
flash/ssd.py resource timelines for per-burst latency/energy accounting.
The scalar and batched backends are its degenerate 1x1 cases and its
bit-exactness references.

Future backends the ROADMAP names (async, replicated) implement the same
six methods: ``submit_search``, ``submit_gather``, ``submit_lookup``,
``submit_plan``, ``submit_program`` (inherited), ``flush``.

Protocol invariants (statically enforced by ``repro.analysis``; rule IDs
in brackets — see README "Static gates"):

  I1 [SIM001, SIM009]  Ticket discipline.  Every ``submit_*`` return
      value is kept (SIM001), and a ``.result()`` on tickets submitted in
      the same function is dominated by a ``flush()`` when more than one
      command is pending (SIM009, interprocedural: helper submits and
      flushes are summarized through the call graph).  Violations
      silently degrade to the eager one-command-per-launch path (§IV-E
      anti-pattern) or lean on a *later* burst's flush.  The eager
      ``search``/``gather``/``lookup``/``plan`` wrappers above are the
      documented immediate mode — a single straight-line submit whose
      ``Ticket.result()`` auto-flushes by contract — which the dataflow
      analysis proves clean (no baseline pin needed).

  I2 [SIM002]  Observer completeness.  Every mutation of a stored page
      image (``SimChip.pages``/``raw``) notifies the write observers, and
      every arena-plane mutation (``PlaneStore._lo``/``_hi``/...) updates
      the dirty/staging bookkeeping — otherwise a kernel backend matches
      against a stale device-resident row.

  I3 [SIM003]  No host sync in the hot path.  ``flush``/``_flush_*``/
      ``_dispatch*``/``_stacked*`` bodies and the kernel ``ops.py``
      wrappers never force a device->host transfer (``np.asarray``,
      ``int()``, ``.block_until_ready()`` on launch outputs); the host
      tail lives in the deferred closures ``LazyResultBatch`` runs.

  I4 [SIM004]  Counter integrity.  ``BackendStats`` fields move only
      inside the accounting helpers (flush phases, submit/resolve paths,
      deferred tails) — the staged/result byte exactness the launch audit
      (SIM101..SIM105) reconciles against the traced jaxpr depends on it.

  I5 [SIM007]  Unit-suffix convention.  Every name that carries a
      physical quantity declares its dimension by suffix: ``_ns`` for
      time, ``_pj`` for energy, ``_bytes`` for payload sizes, ``_prob``
      (or ``_probs``) for probabilities — and a value only flows between
      names of the same dimension.  Adding, subtracting or comparing two
      different declared dimensions (a latency landing in an energy
      field two calls away) is a lint finding; products and ratios are
      deliberately unconstrained so unit conversions (``ms * MS_NS``)
      and rates (``bytes / ns``) stay idiomatic.

  I6 [SIM008]  Seed provenance.  Every RNG construction
      (``default_rng``, ``SeedSequence``, ``PRNGKey``, ...) traces to a
      literal or an explicitly seed-named value (``seed``, ``*_seed``,
      ``entropy``) — through assignments, entropy-list mixing, helper
      returns, and every call site when the seed arrives as a parameter.
      Wall-clock or OS entropy anywhere in the chain breaks replay
      determinism and the seeded fault-injection tier with it.
"""
from __future__ import annotations

import abc
import dataclasses

import numpy as np

from repro.core.commands import (Command, GatherResponse, LookupResponse,
                                 ReadFullResponse, SearchResponse)
from repro.core.engine import SimChipArray


@dataclasses.dataclass
class BackendStats:
    searches: int = 0          # search commands resolved
    gathers: int = 0           # gather commands resolved
    lookups: int = 0           # fused lookup commands resolved
    plans: int = 0             # fused multi-pass plan commands resolved
    flushes: int = 0           # non-empty flush() calls
    kernel_launches: int = 0   # device launches (batched backend only)
    staged_pages: int = 0      # page rows referenced across launches
    staged_queries: int = 0    # query rows staged across launches
    staged_bytes: int = 0      # page-plane bytes shipped host->device; with
                               # the device-resident store this stops growing
                               # once the working set is warm (only new or
                               # reprogrammed pages ever re-ship)
    batched_searches: int = 0  # searches that shared a launch with >= 1 peer
    programs: int = 0          # deferred Op.PROGRAM commands executed
    programs_coalesced: int = 0  # queued programs absorbed by a later
                               # program of the same page before the flush
                               # (last-wins; the page is programmed once)
    result_bytes: int = 0      # exact device->host result payload: 64 B per
                               # search/plan bitmap (per unique launch cell
                               # on kernel backends — dedup'd commands share
                               # one transfer), 64 B per gathered chunk,
                               # 64 B bitmap + 64 B value chunk (on hit) per
                               # lookup.  A fused PLAN pays 64 B/page where
                               # the per-pass path pays 64 B/pass/page.


class LazyResultBatch:
    """Deferred host tail of one flushed launch.

    The kernel backends resolve tickets *lazily*: ``flush()`` dispatches
    the launch and keeps its outputs as device arrays, attaching one of
    these to every ticket of the burst; the first ``result()`` call runs
    the host tail (device->host transfer, de-randomize/verify, ticket
    resolution) for the whole burst at once.  Until then JAX's async
    dispatch lets host staging of burst k+1 overlap device compute of
    burst k.  ``run()`` is idempotent — later tickets find themselves
    already resolved.
    """

    __slots__ = ("_fn", "_exc")

    def __init__(self, fn):
        self._fn = fn
        self._exc = None

    def run(self) -> None:
        if self._exc is not None:
            # A previous drain attempt failed: re-raise the ROOT cause on
            # every later ticket of the burst instead of degenerating into
            # the misleading "ticket unresolved" bookkeeping error.
            raise self._exc
        fn, self._fn = self._fn, None
        if fn is not None:
            try:
                fn()
            except BaseException as e:
                self._exc = e
                raise


class Ticket:
    """Deferred response handle returned by ``submit_*``.

    ``result()`` on an unresolved ticket flushes the owning backend first,
    so eager callers never deadlock; batch-aware callers submit many
    tickets and flush once.  On the kernel backends a flush attaches a
    :class:`LazyResultBatch` instead of a value — the launch output stays
    on-device until the first ``result()`` of the burst triggers the host
    transfer (``done`` reads True either way: the result is available
    without another flush).
    """

    __slots__ = ("_backend", "_value", "_batch", "_exc")

    def __init__(self, backend: "MatchBackend"):
        self._backend = backend
        self._value = None
        self._batch = None
        self._exc = None

    def _resolve(self, value) -> None:
        self._value = value
        self._batch = None

    def _fail(self, exc: BaseException) -> None:
        """Resolve the ticket to a typed per-command error (e.g. an
        UncorrectableReadError from the reliability tier): ``result()``
        raises it instead of returning a wrong response."""
        self._exc = exc
        self._batch = None

    def _defer(self, batch: LazyResultBatch) -> None:
        self._batch = batch

    @property
    def done(self) -> bool:
        return (self._value is not None or self._batch is not None
                or self._exc is not None)

    def result(self):
        if self._value is None and self._exc is None and self._batch is None:
            self._backend.flush()
        if self._value is None and self._exc is None \
                and self._batch is not None:
            self._batch.run()
        if self._exc is not None:
            raise self._exc
        if self._value is None:
            raise RuntimeError("flush() left a submitted ticket unresolved")
        return self._value


class MatchBackend(abc.ABC):
    """Batched search/gather execution over a SimChipArray's stored pages."""

    def __init__(self, chips: SimChipArray):
        self.chips = chips
        self.stats = BackendStats()
        # Reliability tier (repro.reliability.ReliabilityState) or None.
        # When attached, flush() runs an optimistic open burst over every
        # touched page and routes responses through the vote/verify/
        # fallback finalize paths; uncorrectable pages fail their tickets
        # with a typed error instead of resolving a wrong bitmap.
        self.reliability = None
        # Deferred Op.PROGRAM queue: page addr -> [entries, kwargs, tickets].
        # A dict so repeated programs of one page coalesce last-wins before
        # anything touches the chip (insertion order = program order).
        self._program_queue: dict[int, list] = {}

    def enable_reliability(self, state) -> None:
        """Attach a reliability tier to this backend's flush path.  Usually
        called through ``ReliabilityState.install`` /
        ``replay(..., RunConfig.reliable(...))``."""
        self.reliability = state

    def _open_reliability(self, page_addrs) -> dict:
        """Flush-time ECC-aware open burst over the flush's unique pages;
        {} when no reliability tier is attached.  Must run before kernel
        backends stage plane rows so open-time repairs ship corrected
        rows in the same flush."""
        if self.reliability is None:
            return {}
        return self.reliability.open_burst(self.chips, page_addrs)

    # ------------------------------------------------------------- storage
    # Programming and full-page reads are storage-mode operations; both
    # backends route them through the functional chip model so the stored
    # (randomized) images — the ground truth searches run against — are
    # identical regardless of backend choice.
    def program_entries(self, page_addr: int, entries, **kw):
        return self._program_page(page_addr, entries, kw)

    def _program_page(self, page_addr: int, entries, kw):
        """Program one page on the chip model.  Fault-aware backends
        (sharded) override this to fan writes out to replicas and remap
        grown bad blocks; the page keeps its *logical* address — callers
        and counters never see the physical placement."""
        return self.chips.program_entries(page_addr, entries, **kw)

    def submit_program(self, page_addr: int, entries, **kw) -> Ticket:
        """Queue a deferred page program (Op.PROGRAM).

        The entry image is copied at submit time (callers keep mutating
        their host mirrors).  Programs of the same page coalesce last-wins:
        one chip program executes at flush and every ticket of the page
        resolves to the final image's ``BuiltPage``.  Backends run queued
        programs *before* the burst's other commands and re-stage the
        programmed pages' plane rows in one grouped update.
        """
        t = Ticket(self)
        arr = np.array(entries, dtype=np.uint64, copy=True)
        entry = self._program_queue.get(int(page_addr))
        if entry is None:
            self._program_queue[int(page_addr)] = [arr, kw, [t]]
        else:
            entry[0], entry[1] = arr, kw
            entry[2].append(t)
            self.stats.programs_coalesced += 1
        return t

    @property
    def pending_programs(self) -> int:
        """Queued (post-coalescing) deferred programs."""
        return len(self._program_queue)

    def _execute_programs(self) -> list[int]:
        """Run the queued programs against the chip model, in submit order.

        Resolves every ticket and returns the programmed page addresses so
        kernel backends can re-stage them as ONE group (and timeline-coupled
        backends can report the program group).  Called by ``flush()``
        before any queued command executes — commands flushed in the same
        burst match against the new images, exactly like the eager path.
        """
        if not self._program_queue:
            return []
        queue, self._program_queue = self._program_queue, {}
        addrs: list[int] = []
        for page_addr, (entries, kw, tickets) in queue.items():
            built = self._program_page(page_addr, entries, kw)
            self.stats.programs += 1
            for t in tickets:
                t._resolve(built)
            addrs.append(page_addr)
        return addrs

    def read_full(self, page_addr: int) -> ReadFullResponse:
        return self.chips.read_full(page_addr)

    # ----------------------------------------------------------- immediate
    def search(self, cmd: Command) -> SearchResponse:
        return self.submit_search(cmd).result()

    def gather(self, cmd: Command) -> GatherResponse:
        return self.submit_gather(cmd).result()

    def lookup(self, cmd: Command) -> LookupResponse:
        return self.submit_lookup(cmd).result()

    def plan(self, cmd: Command) -> SearchResponse:
        return self.submit_plan(cmd).result()

    def _defer_all(self, tickets, tail) -> None:
        """Attach one lazy host tail to a burst's (cmd, ticket) pairs: the
        launch outputs stay device-resident until the first result()."""
        batch = LazyResultBatch(tail)
        for _, t in tickets:
            t._defer(batch)

    # ------------------------------------------------------------ deferred
    @abc.abstractmethod
    def submit_search(self, cmd: Command) -> Ticket:
        """Queue a search; the ticket resolves at the next flush()."""

    @abc.abstractmethod
    def submit_gather(self, cmd: Command) -> Ticket:
        """Queue a gather; the ticket resolves at the next flush()."""

    @abc.abstractmethod
    def submit_lookup(self, cmd: Command) -> Ticket:
        """Queue a fused point lookup (Op.LOOKUP): search the key page,
        select the first matching user slot, gather that slot's chunk from
        the paired value page.  Resolves to a LookupResponse at flush()."""

    @abc.abstractmethod
    def submit_plan(self, cmd: Command) -> Ticket:
        """Queue a fused multi-pass range plan (Op.PLAN): evaluate every
        include/exclude pass against the page and accumulate OR / AND-NOT
        in-latch (paper Fig 10).  Resolves to a SearchResponse holding the
        ONE combined bitmap — 64 B crosses per page, not per pass."""

    @abc.abstractmethod
    def flush(self) -> None:
        """Execute every queued command and resolve its ticket."""

    @property
    @abc.abstractmethod
    def pending(self) -> int:
        """Number of queued, unresolved commands."""


def as_backend(chips_or_backend) -> MatchBackend:
    """Adapt a raw SimChipArray to the reference backend (API compat)."""
    if isinstance(chips_or_backend, MatchBackend):
        return chips_or_backend
    from .scalar import ScalarBackend
    return ScalarBackend(chips_or_backend)


def make_backend(name: str, chips: SimChipArray, **kw) -> MatchBackend:
    """Factory: ``scalar`` (reference), ``batched`` (single-arena Pallas
    fast path) or ``sharded`` (channels x dies multi-chip SSD)."""
    from .batched import BatchedKernelBackend
    from .scalar import ScalarBackend
    from .sharded import ShardedSsdBackend
    backends = {"scalar": ScalarBackend, "batched": BatchedKernelBackend,
                "sharded": ShardedSsdBackend}
    if name not in backends:
        raise ValueError(f"unknown backend {name!r}; pick from "
                         f"{sorted(backends)}")
    return backends[name](chips, **kw)
