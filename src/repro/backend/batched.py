"""Batched MatchBackend: queued commands execute as one Pallas launch.

The deferred submission queue is staged into dense device operands at
flush time:

  * every *unique* page touched by a queued search becomes one row of the
    (N, 512) lo/hi word planes, carrying its chip-local flash address and
    per-chip device seed so the kernel regenerates the §IV-C1 randomization
    stream in-VMEM (stored images are staged as-is, bit errors included);
  * every *unique* (query, mask) pair becomes one row of the (Q, 2) query
    operands — Q queries match against N pages in a single ``sim_search``
    launch, the §IV-E cross-page multi-query batch that amortizes one
    staging pass over the whole burst;
  * queued gathers stage per-command (page chunk words, chunk bitmap) rows
    and compact through one ``sim_gather`` launch; de-randomization and
    inner-code verification of the selected chunks happen host-side, as on
    the controller.

Results are bit-identical to ``ScalarBackend`` for every programmed page
(damaged or not): both paths match against the same stored image with the
same stream.  What this backend does *not* model is the per-page-open
control machinery — optimistic-open verdicts, ECC fallback repair, latch
pipelining — so ``SearchResponse.open_verdict`` always reads CLEAN here.
Workloads that need open verdicts (error-injection studies) use the scalar
backend; see tests/test_backend_parity.py for the exact contract.

Query rows are padded to the next power of two and page rows to a multiple
of ``page_block``, so repeated flushes of similar-size bursts reuse the
same compiled kernel instead of retracing.
"""
from __future__ import annotations

import numpy as np

from repro.core import ecc
from repro.core.bits import CHUNK_BYTES, CHUNKS_PER_PAGE, popcount_words, \
    slot_words_to_bytes, unpack_bitmap
from repro.core.commands import Command, GatherResponse, Op, SearchResponse
from repro.core.ecc import OpenVerdict
from repro.core.engine import SimChip, SimChipArray
from repro.core.randomize import chunk_stream_words
from repro.kernels.layout import pages_to_chunk_words, pages_to_planes
from repro.kernels.sim_gather.ops import sim_gather
from repro.kernels.sim_search.ops import sim_search

from .base import MatchBackend, Ticket


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


class BatchedKernelBackend(MatchBackend):
    def __init__(self, chips: SimChipArray, *, page_block: int = 32,
                 use_kernel: bool = True, interpret: bool | None = None):
        super().__init__(chips)
        self.page_block = page_block
        self.use_kernel = use_kernel
        self.interpret = interpret
        self._searches: list[tuple[Command, Ticket]] = []
        self._gathers: list[tuple[Command, Ticket]] = []

    # ------------------------------------------------------------ deferred
    def submit_search(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.SEARCH or cmd.query is None or cmd.mask is None:
            raise ValueError(f"not a search command: {cmd}")
        t = Ticket(self)
        self._searches.append((cmd, t))
        return t

    def submit_gather(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.GATHER or cmd.chunk_bitmap is None:
            raise ValueError(f"not a gather command: {cmd}")
        t = Ticket(self)
        self._gathers.append((cmd, t))
        return t

    @property
    def pending(self) -> int:
        return len(self._searches) + len(self._gathers)

    def flush(self) -> None:
        if not self._searches and not self._gathers:
            return
        self.stats.flushes += 1
        searches, self._searches = self._searches, []
        gathers, self._gathers = self._gathers, []
        if searches:
            self._flush_searches(searches)
        if gathers:
            self._flush_gathers(gathers)

    # ------------------------------------------------------------- staging
    def _stored(self, page_addr: int) -> tuple[SimChip, int]:
        chip, local = self.chips.route(page_addr)
        chip._get(local)                       # KeyError on unprogrammed
        return chip, local

    def _flush_searches(self, searches) -> None:
        # Stage unique pages and unique (query, mask) operand pairs.
        page_rows: dict[int, int] = {}
        query_rows: dict[tuple, int] = {}
        raws, page_ids, page_seeds, chip_rows = [], [], [], []
        q_pairs, m_pairs = [], []
        placements = []                        # (qi, pi) per command
        for cmd, _ in searches:
            if cmd.page_addr not in page_rows:
                chip, local = self._stored(cmd.page_addr)
                page_rows[cmd.page_addr] = len(raws)
                raws.append(chip.pages[local].raw)
                page_ids.append(local)
                page_seeds.append(chip.device_seed & 0xFFFFFFFF)
                chip_rows.append(chip)
            key = (cmd.query, cmd.mask)
            if key not in query_rows:
                query_rows[key] = len(q_pairs)
                q_pairs.append(cmd.query)
                m_pairs.append(cmd.mask)
            placements.append((query_rows[key], page_rows[cmd.page_addr]))

        # One staged sense per unique page, amortized over all queries.
        for chip in chip_rows:
            chip.counters.array_reads += 1

        lo, hi = pages_to_planes(np.stack(raws))
        n_queries = len(q_pairs)
        q = np.zeros((_next_pow2(n_queries), 2), dtype=np.uint32)
        m = np.zeros_like(q)
        q[:n_queries] = np.asarray(q_pairs, dtype=np.uint32)
        m[:n_queries] = np.asarray(m_pairs, dtype=np.uint32)

        out = np.asarray(sim_search(
            lo, hi, q, m, randomized=True,
            page_ids=np.asarray(page_ids, dtype=np.uint32),
            page_seeds=np.asarray(page_seeds, dtype=np.uint32),
            page_block=self.page_block, use_kernel=self.use_kernel,
            interpret=self.interpret))        # (Qpad, N, 16)

        self.stats.kernel_launches += 1
        self.stats.staged_pages += len(raws)
        self.stats.staged_queries += n_queries
        self.stats.searches += len(searches)
        if len(searches) > 1:
            self.stats.batched_searches += len(searches)

        for (cmd, ticket), (qi, pi) in zip(searches, placements):
            bitmap = out[qi, pi].copy()
            chip, _ = self.chips.route(cmd.page_addr)
            chip.counters.searches += 1
            ticket._resolve(SearchResponse(
                bitmap_words=bitmap,
                match_count=int(popcount_words(bitmap).sum()),
                open_verdict=OpenVerdict.CLEAN.value))

    def _flush_gathers(self, gathers) -> None:
        rows, bitmaps, owners = [], [], []
        for cmd, _ in gathers:
            chip, local = self._stored(cmd.page_addr)
            rows.append(chip.pages[local].raw)
            bitmaps.append(cmd.chunk_bitmap)
            owners.append((chip, local))
        chunk_words = pages_to_chunk_words(np.stack(rows))
        bm = np.asarray(bitmaps, dtype=np.uint32)
        out, _counts = sim_gather(chunk_words, bm,
                                  max_out=CHUNKS_PER_PAGE,
                                  interpret=self.interpret,
                                  use_kernel=self.use_kernel)
        out = np.asarray(out)                  # (R, 64, 16) uint32
        self.stats.kernel_launches += 1
        self.stats.gathers += len(gathers)

        for r, (cmd, ticket) in enumerate(gathers):
            chip, local = owners[r]
            sp = chip.pages[local]
            bits = unpack_bitmap(bm[r], n_bits=CHUNKS_PER_PAGE)
            chunk_ids = np.nonzero(bits)[0]
            k = int(chunk_ids.size)
            if k:
                # Controller side: de-randomize the compacted chunks with
                # their chunk-addressed streams, then verify inner codes.
                words = out[r, :k].reshape(k, 8, 2)
                streams = np.stack([
                    chunk_stream_words(local, int(c), chip.device_seed)
                    for c in chunk_ids])
                plain = slot_words_to_bytes(words ^ streams)
                parity_ok = (ecc.crc32_rows(plain)
                             == sp.chunk_parities[chunk_ids])
            else:
                plain = np.zeros((0, CHUNK_BYTES), dtype=np.uint8)
                parity_ok = np.zeros(0, dtype=bool)
            chip.counters.array_reads += 1
            chip.counters.gathers += 1
            chip.counters.chunks_gathered += k
            ticket._resolve(GatherResponse(chunks=plain, chunk_ids=chunk_ids,
                                           parity_ok=parity_ok))
