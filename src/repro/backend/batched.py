"""Batched MatchBackend: queued commands execute as one Pallas launch over
device-resident page planes.

Stored pages live in a ``PlaneStore`` arena (planestore.py): persistent JAX
device arrays holding each staged page's lo/hi word planes plus its
chip-local flash address and device seed.  Pages are populated lazily the
first time a flush references them and invalidated incrementally through
the engine's write observers, so a steady-state flush ships **zero page
bytes** host->device — only the (Q, 2) query operands move, the TPU
analogue of the chip keeping operands in-array while only queries and 64 B
bitmaps cross the bus (paper §III-B).

At flush time the deferred queues stage into dense device operands:

  * every *unique* page touched by a queued search becomes one arena-row
    reference; the kernel regenerates the §IV-C1 randomization stream
    in-VMEM from the row's address/seed operands (stored images are staged
    as-is, bit errors included);
  * every *unique* (query, mask) pair becomes one row of the (Q, 2) query
    operands — Q queries match against N pages in a single ``sim_search``
    launch, the §IV-E cross-page multi-query batch;
  * queued gathers reference per-command arena rows and compact through one
    ``sim_gather`` launch; de-randomization and inner-code verification of
    the selected chunks happen host-side, batched over the whole burst;
  * queued lookups (Op.LOOKUP) run the fused ``sim_fused_lookup`` kernel:
    key-page search, first-matching-user-slot selection, and the paired
    value page's same-slot chunk gather all happen in ONE launch — no
    bitmap round trip through Python between search and gather;
  * queued plans (Op.PLAN) run the fused ``sim_plan`` kernel: every
    include/exclude pass of a §V-C range decomposition matches in-VMEM and
    the OR/AND-NOT combine (paper Fig 10) happens before anything leaves
    the device — ONE 64 B bitmap per (plan, page) instead of one per pass.
    Unique (include, exclude) tuples dedup to plan groups the way unique
    (query, mask) pairs dedup to query rows.

Ticket resolution is *lazy*: each flush phase dispatches its launch and
attaches a ``LazyResultBatch`` holding the device-array outputs; the host
transfer, de-randomization and CRC verification run at the first
``result()`` call of the burst.  JAX async dispatch therefore overlaps
staging of burst k+1 with device compute of burst k, and
``BackendStats.result_bytes`` counts exactly what crossed device->host.

Results are bit-identical to ``ScalarBackend`` for every programmed page
(damaged or not): both paths match against the same stored image with the
same stream.  Without a reliability tier attached,
``SearchResponse.open_verdict`` always reads CLEAN here (no per-page-open
control machinery runs).  With ``enable_reliability`` the flush performs
the same optimistic open burst as the scalar reference — verdicts, ECC
fallback repairs, voting and selective verification included — and
uncorrectable pages fail their tickets with a typed error; see
tests/test_backend_parity.py and tests/test_reliability.py for the exact
contracts.

Query rows are padded to the next power of two and page/gather/lookup rows
to a power-of-two multiple of the block size (``padded_rows``), so repeated
flushes of similar-size bursts reuse the same compiled kernel instead of
retracing on every distinct burst size.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ecc
from repro.core.bits import CHUNK_BYTES, CHUNKS_PER_PAGE, SLOTS_PER_CHUNK, \
    popcount_words, slot_words_to_bytes, unpack_bitmap
from repro.core.commands import (Command, GatherResponse, LookupResponse,
                                 Op, SearchResponse)
from repro.core.ecc import OpenVerdict
from repro.core.engine import SimChipArray
from repro.core.randomize import chunk_stream_words_batch
from repro.kernels.layout import planes_to_chunk_words_xp
from repro.kernels.sim_fused.ops import sim_fused_lookup
from repro.kernels.sim_fused.sim_fused import NO_SLOT
from repro.kernels.sim_gather.ops import sim_gather
from repro.kernels.sim_plan.ops import plan_pass_rows, sim_plan
from repro.kernels.sim_search.ops import sim_search

from .base import MatchBackend, Ticket
from .planestore import PlaneStore, next_pow2, padded_rows


# ---------------------------------------------------------------------------
# Host-tail resolvers, shared by every kernel-launching backend (batched's
# single-chip launches and sharded's stacked multi-chip launches): given the
# launch outputs as numpy arrays, de-randomize / verify on the controller
# side, bump the owning chips' functional counters and resolve the tickets.
# Each returns the exact device->host result payload in bytes (the
# ``BackendStats.result_bytes`` contract); with lazy tickets they run at the
# first ``result()`` call of a burst, not at flush.
# ---------------------------------------------------------------------------

def _resolve_bitmap_responses(chips, cmds, placements, out, matches_of,
                              reliability=None, opens=None,
                              is_plan=False) -> int:
    """Resolve bitmap-shaped (search / plan) tickets from launch output.

    ``placements[i]`` is the index tuple of command i's bitmap in ``out``
    (e.g. ``(qi, pi)`` for a single-chip launch, ``(ci, qi, pi)`` for a
    chip-stacked one).  Commands that dedup'd into the same launch cell
    share ONE host copy of the bitmap (and its popcount) — one copy per
    unique placement, detached from ``out`` so later mutation of the
    launch buffer can never alias into a response.  ``matches_of(cmd)``
    is the on-chip match-op count the command's chip executed (1 for a
    search, ``n_passes`` for a plan).  Returns result bytes: 64 B per
    unique placement (shared cells cross the link once).

    With a reliability tier attached, each unique cell's raw bitmap runs
    the vote/verify/fallback finalize against the flush's captured page
    opens; uncorrectable pages fail every ticket of the cell with the
    typed error instead of resolving.
    """
    from repro.reliability import UncorrectableReadError
    cache: dict[tuple, tuple] = {}
    n_ok = 0
    for (cmd, ticket), idx in zip(cmds, placements):
        entry = cache.get(idx)
        if entry is None:
            raw = np.array(out[idx], copy=True)
            if reliability is None:
                entry = ("ok", SearchResponse(
                    bitmap_words=raw,
                    match_count=int(popcount_words(raw).sum()),
                    open_verdict=OpenVerdict.CLEAN.value))
            else:
                try:
                    fin = (reliability.finalize_plan if is_plan
                           else reliability.finalize_search)
                    entry = ("ok", fin(chips, cmd, raw, opens))
                except UncorrectableReadError as e:
                    entry = ("err", e)
            cache[idx] = entry
            if entry[0] == "ok":
                n_ok += 1
        chip, _ = chips.route(cmd.page_addr)
        chip.counters.searches += matches_of(cmd)
        if entry[0] == "ok":
            ticket._resolve(entry[1])
        else:
            ticket._fail(entry[1])
    return 64 * n_ok


def resolve_search_responses(chips, searches, placements, out,
                             reliability=None, opens=None) -> int:
    return _resolve_bitmap_responses(chips, searches, placements, out,
                                     lambda cmd: 1, reliability, opens)


def resolve_plan_responses(chips, plans, placements, out,
                           reliability=None, opens=None) -> int:
    """A PLAN's chip executed ``n_passes`` match ops, but only the one
    combined 64 B bitmap per unique cell crossed — the Fig 10 win."""
    return _resolve_bitmap_responses(chips, plans, placements, out,
                                     lambda cmd: cmd.n_passes, reliability,
                                     opens, is_plan=True)


def snapshot_parities(chips, addrs) -> dict:
    """Flush-time copy of each page's inner-code parities.

    Lazy host tails verify CRCs at drain time, which may be AFTER a
    reprogram of one of the burst's pages; the launch itself captured the
    pre-write plane snapshot, so the verification must compare against
    the parities as of flush, not whatever the chip holds at drain.
    """
    snap = {}
    for a in set(addrs):
        chip, local = chips.route(a)
        snap[int(a)] = chip.pages[local].chunk_parities.copy()
    return snap


def resolve_lookup_responses(chips, lookups, bm, val, slots,
                             parity_snap, reliability=None,
                             opens=None) -> int:
    """Fused-lookup host tail: batched de-randomize + inner-code verify of
    every hit's value chunk, then ticket resolution.

    ``bm`` (n, 16), ``val`` (n, 16), ``slots`` (n,) are the launch outputs
    trimmed to the burst length; ``parity_snap`` maps each value page to
    its flush-time ``snapshot_parities`` row.

    With a reliability tier attached the on-device slot select and value
    gather are advisory only: the finalize path re-derives the slot from
    the voted/verified key bitmap and host-reads the value chunk from the
    current image, so every backend serves byte-identical values under a
    fault seed.
    """
    if reliability is not None:
        return _resolve_lookups_reliable(chips, lookups, bm, reliability,
                                         opens)
    n = len(lookups)
    key_addrs = [cmd.page_addr for cmd, _ in lookups]
    val_addrs = [cmd.value_page for cmd, _ in lookups]
    counts = popcount_words(bm)                # (n,) per-row match totals

    for a in set(key_addrs):
        chip, _ = chips.route(a)
        chip.counters.array_reads += 1

    hit = slots < NO_SLOT
    hit_idx = np.nonzero(hit)[0]
    values = [None] * n
    parity = np.ones(n, dtype=bool)
    if hit_idx.size:
        v_locals, v_seeds, parities = [], [], []
        chunks = slots[hit_idx] // SLOTS_PER_CHUNK
        for i, c in zip(hit_idx, chunks):
            chip, local = chips.route(val_addrs[int(i)])
            v_locals.append(local)
            v_seeds.append(chip.device_seed & 0xFFFFFFFF)
            parities.append(parity_snap[int(val_addrs[int(i)])][int(c)])
            chip.counters.array_reads += 1
            chip.counters.gathers += 1
            chip.counters.chunks_gathered += 1
        streams = chunk_stream_words_batch(v_locals, chunks, v_seeds)
        words = val[hit_idx].reshape(-1, SLOTS_PER_CHUNK, 2)
        plain = slot_words_to_bytes(words ^ streams)       # (K, 64) bytes
        parity[hit_idx] = (ecc.crc32_rows(plain)
                           == np.asarray(parities, np.uint32))
        offs = (slots[hit_idx] % SLOTS_PER_CHUNK) * 8
        for j, i in enumerate(hit_idx):
            values[int(i)] = bytes(plain[j, offs[j]:offs[j] + 8])

    for i, (cmd, ticket) in enumerate(lookups):
        chip, _ = chips.route(cmd.page_addr)
        chip.counters.searches += 1
        resp = SearchResponse(bitmap_words=bm[i].copy(),
                              match_count=int(counts[i]),
                              open_verdict=OpenVerdict.CLEAN.value)
        ticket._resolve(LookupResponse(
            search=resp,
            value_slot=int(slots[i]) if hit[i] else None,
            value=values[i], parity_ok=bool(parity[i])))
    return 64 * n + 64 * int(hit_idx.size)


def _resolve_lookups_reliable(chips, lookups, bm, reliability, opens) -> int:
    """Reliability tail for a lookup burst: finalize each key bitmap
    (vote + selective verification + miss fallback) and serve the value
    through the inner-code-checked host read."""
    from repro.reliability import UncorrectableReadError
    nbytes = 0
    for a in {cmd.page_addr for cmd, _ in lookups}:
        chip, _ = chips.route(a)
        chip.counters.array_reads += 1
    for i, (cmd, ticket) in enumerate(lookups):
        chip, _ = chips.route(cmd.page_addr)
        chip.counters.searches += 1
        try:
            resp = reliability.finalize_lookup(
                chips, cmd, np.array(bm[i], copy=True), opens)
        except UncorrectableReadError as e:
            ticket._fail(e)
            continue
        ticket._resolve(resp)
        nbytes += 64 + (64 if resp.value_slot is not None else 0)
    return nbytes


def resolve_gather_responses(chips, gathers, out, parity_snap,
                             reliability=None, opens=None) -> int:
    """Gather host tail: one stream regeneration + one CRC pass for every
    selected chunk of the whole burst.  ``parity_snap`` holds each page's
    flush-time ``snapshot_parities`` row.  Returns result bytes (64 B per
    gathered chunk)."""
    owners, all_locals, all_chunks, all_seeds, all_parities = \
        [], [], [], [], []
    chunk_ids_per = []
    for cmd, _ in gathers:
        chip, local = chips.route(cmd.page_addr)
        owners.append((chip, local))
        bits = unpack_bitmap(np.asarray(cmd.chunk_bitmap, np.uint32),
                             n_bits=CHUNKS_PER_PAGE)
        chunk_ids = np.nonzero(bits)[0]
        chunk_ids_per.append(chunk_ids)
        all_locals.extend([local] * chunk_ids.size)
        all_chunks.extend(chunk_ids.tolist())
        all_seeds.extend([chip.device_seed & 0xFFFFFFFF]
                         * chunk_ids.size)
        all_parities.append(parity_snap[int(cmd.page_addr)][chunk_ids])

    k_total = len(all_chunks)
    if k_total:
        words = np.concatenate([
            out[r, :ids.size] for r, ids in enumerate(chunk_ids_per)
            if ids.size]).reshape(k_total, SLOTS_PER_CHUNK, 2)
        streams = chunk_stream_words_batch(all_locals, all_chunks,
                                           all_seeds)
        plain_all = slot_words_to_bytes(words ^ streams)
        parity_all = (ecc.crc32_rows(plain_all)
                      == np.concatenate(all_parities))
    else:
        plain_all = np.zeros((0, CHUNK_BYTES), dtype=np.uint8)
        parity_all = np.zeros(0, dtype=bool)

    from repro.reliability import UncorrectableReadError
    pos = 0
    for r, (cmd, ticket) in enumerate(gathers):
        chip, local = owners[r]
        chunk_ids = chunk_ids_per[r]
        k = int(chunk_ids.size)
        plain = plain_all[pos:pos + k]
        parity_ok = parity_all[pos:pos + k]
        pos += k
        chip.counters.array_reads += 1
        chip.counters.gathers += 1
        chip.counters.chunks_gathered += k
        resp = GatherResponse(chunks=plain, chunk_ids=chunk_ids,
                              parity_ok=parity_ok)
        if reliability is not None:
            try:
                resp = reliability.finalize_gather(chips, cmd, resp, opens)
            except UncorrectableReadError as e:
                ticket._fail(e)
                continue
        ticket._resolve(resp)
    return 64 * k_total


class BatchedKernelBackend(MatchBackend):
    def __init__(self, chips: SimChipArray, *, page_block: int = 32,
                 lookup_block: int = 8, use_kernel: bool = True,
                 interpret: bool | None = None):
        super().__init__(chips)
        self.page_block = page_block
        self.lookup_block = lookup_block
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.store = PlaneStore(chips, block=page_block)
        self._searches: list[tuple[Command, Ticket]] = []
        self._gathers: list[tuple[Command, Ticket]] = []
        self._lookups: list[tuple[Command, Ticket]] = []
        self._plans: list[tuple[Command, Ticket]] = []

    # ------------------------------------------------------------ deferred
    def submit_search(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.SEARCH or cmd.query is None or cmd.mask is None:
            raise ValueError(f"not a search command: {cmd}")
        t = Ticket(self)
        self._searches.append((cmd, t))
        return t

    def submit_gather(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.GATHER or cmd.chunk_bitmap is None:
            raise ValueError(f"not a gather command: {cmd}")
        t = Ticket(self)
        self._gathers.append((cmd, t))
        return t

    def submit_lookup(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.LOOKUP or cmd.value_page is None:
            raise ValueError(f"not a lookup command: {cmd}")
        t = Ticket(self)
        self._lookups.append((cmd, t))
        return t

    def submit_plan(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.PLAN or cmd.plan_include is None:
            raise ValueError(f"not a plan command: {cmd}")
        t = Ticket(self)
        self._plans.append((cmd, t))
        return t

    @property
    def pending(self) -> int:
        return (len(self._searches) + len(self._gathers)
                + len(self._lookups) + len(self._plans)
                + self.pending_programs)

    def flush(self) -> None:
        # Deferred programs first: one grouped chip-program pass, then ONE
        # plane-store scatter re-stages every programmed row — the burst's
        # other phases (and any later flush) see current arena rows without
        # per-page invalidate/restage round trips.
        programs = self._execute_programs()
        if programs:
            self.store.stage_group(programs)
            self.stats.staged_bytes = self.store.staged_bytes
        if not (self._searches or self._gathers or self._lookups
                or self._plans):
            if programs:
                self.stats.flushes += 1
            return
        self.stats.flushes += 1
        searches, self._searches = self._searches, []
        lookups, self._lookups = self._lookups, []
        gathers, self._gathers = self._gathers, []
        plans, self._plans = self._plans, []
        # Reliability open burst BEFORE any staging: open-time ECC repairs
        # mark their plane rows dirty, so rows_for re-stages the corrected
        # images in this same flush.  The verdict dict is captured into the
        # phase tails — later flushes may re-open these pages before the
        # lazy tails run.
        opens = self._open_reliability(
            {c.page_addr for c, _ in searches}
            | {c.page_addr for c, _ in plans}
            | {c.page_addr for c, _ in gathers}
            | {c.page_addr for c, _ in lookups}
            | {c.value_page for c, _ in lookups})
        if searches:
            self._flush_searches(searches, opens)
        if plans:
            self._flush_plans(plans, opens)
        if lookups:
            self._flush_lookups(lookups, opens)
        if gathers:
            self._flush_gathers(gathers, opens)
        # The plane store is the only source of host->device page traffic.
        self.stats.staged_bytes = self.store.staged_bytes

    # ------------------------------------------------------------- staging
    def _flush_searches(self, searches, opens=None) -> None:
        # Unique pages -> arena rows; unique (query, mask) -> operand rows.
        page_rows: dict[int, int] = {}
        query_rows: dict[tuple, int] = {}
        addrs: list[int] = []
        q_pairs, m_pairs = [], []
        placements = []                        # (qi, pi) per command
        for cmd, _ in searches:
            if cmd.page_addr not in page_rows:
                page_rows[cmd.page_addr] = len(addrs)
                addrs.append(cmd.page_addr)
            key = (cmd.query, cmd.mask)
            if key not in query_rows:
                query_rows[key] = len(q_pairs)
                q_pairs.append(cmd.query)
                m_pairs.append(cmd.mask)
            placements.append((query_rows[key], page_rows[cmd.page_addr]))

        rows = self.store.rows_for(addrs)      # stages new + dirty only
        # One staged sense per unique page, amortized over all queries.
        for a in addrs:
            chip, _ = self.chips.route(a)
            chip.counters.array_reads += 1

        n_pages = padded_rows(len(addrs), self.page_block)
        lo, hi, page_ids, page_seeds = self.store.take(rows, n_pages)
        n_queries = len(q_pairs)
        q = np.zeros((next_pow2(n_queries), 2), dtype=np.uint32)
        m = np.zeros_like(q)
        q[:n_queries] = np.asarray(q_pairs, dtype=np.uint32)
        m[:n_queries] = np.asarray(m_pairs, dtype=np.uint32)

        out = sim_search(
            lo, hi, q, m, randomized=True,
            page_ids=page_ids, page_seeds=page_seeds,
            page_block=self.page_block, use_kernel=self.use_kernel,
            interpret=self.interpret)          # (Qpad, Npad, 16) on device

        self.stats.kernel_launches += 1
        self.stats.staged_pages += len(addrs)
        self.stats.staged_queries += n_queries
        self.stats.searches += len(searches)
        if len(searches) > 1:
            self.stats.batched_searches += len(searches)

        def tail(out=out, searches=searches, placements=placements,
                 rel=self.reliability, opens=opens):
            self.stats.result_bytes += resolve_search_responses(
                self.chips, searches, placements, np.asarray(out),
                rel, opens)
        self._defer_all(searches, tail)

    # ---------------------------------------------------------------- plans
    def _flush_plans(self, plans, opens=None) -> None:
        """Fused multi-pass range plans: one launch, one 64 B bitmap/page.

        Unique pages dedup to arena rows exactly like searches; unique
        (include, exclude) pass tuples dedup to plan *groups* (the Fig 10
        dataflow runs once per group x page, commands sharing both land on
        the same launch cell).  Pass rows pad to a power of two and groups
        to a power of two so repeated plan bursts reuse compiled kernels.
        """
        page_rows: dict[int, int] = {}
        group_rows: dict[tuple, int] = {}
        addrs: list[int] = []
        groups: list[tuple] = []
        placements = []                        # (gi, pi) per command
        for cmd, _ in plans:
            if cmd.page_addr not in page_rows:
                page_rows[cmd.page_addr] = len(addrs)
                addrs.append(cmd.page_addr)
            key = (cmd.plan_include, cmd.plan_exclude)
            if key not in group_rows:
                group_rows[key] = len(groups)
                groups.append(key)
            placements.append((group_rows[key], page_rows[cmd.page_addr]))

        rows = self.store.rows_for(addrs)
        for a in addrs:                        # one staged sense per page,
            chip, _ = self.chips.route(a)      # amortized over every pass
            chip.counters.array_reads += 1

        n_pages = padded_rows(len(addrs), self.page_block)
        lo, hi, page_ids, page_seeds = self.store.take(rows, n_pages)
        p_pad = next_pow2(max(max(len(i) + len(e) for i, e in groups), 1))
        g_pad = next_pow2(len(groups))
        q = np.zeros((g_pad, p_pad, 2), dtype=np.uint32)
        m = np.zeros_like(q)
        f = np.zeros((g_pad, p_pad), dtype=np.uint32)
        for gi, (inc, exc) in enumerate(groups):
            q[gi], m[gi], f[gi] = plan_pass_rows(inc, exc, p_pad)

        out = sim_plan(
            lo, hi, q, m, f, randomized=True,
            page_ids=page_ids, page_seeds=page_seeds,
            page_block=self.page_block, use_kernel=self.use_kernel,
            interpret=self.interpret)          # (Gpad, Npad, 16) on device

        self.stats.kernel_launches += 1
        self.stats.staged_pages += len(addrs)
        self.stats.staged_queries += sum(len(i) + len(e)
                                         for i, e in groups)
        self.stats.plans += len(plans)

        def tail(out=out, plans=plans, placements=placements,
                 rel=self.reliability, opens=opens):
            self.stats.result_bytes += resolve_plan_responses(
                self.chips, plans, placements, np.asarray(out), rel, opens)
        self._defer_all(plans, tail)

    # -------------------------------------------------------------- lookups
    def _flush_lookups(self, lookups, opens=None) -> None:
        """Fused read burst: search + slot select + value gather, 1 launch."""
        key_addrs = [cmd.page_addr for cmd, _ in lookups]
        val_addrs = [cmd.value_page for cmd, _ in lookups]
        k_rows = self.store.rows_for(key_addrs)
        v_rows = self.store.rows_for(val_addrs)

        n = len(lookups)
        n_pad = padded_rows(n, self.lookup_block)
        klo, khi, kids, kseeds = self.store.take(k_rows, n_pad)
        vlo, vhi, _, _ = self.store.take(v_rows, n_pad)
        q = np.zeros((n_pad, 2), dtype=np.uint32)
        m = np.full((n_pad, 2), 0xFFFFFFFF, dtype=np.uint32)  # pad rows miss
        q[:n] = np.asarray([cmd.query for cmd, _ in lookups], np.uint32)
        m[:n] = np.asarray([cmd.mask for cmd, _ in lookups], np.uint32)

        bm, val, slots = sim_fused_lookup(
            klo, khi, vlo, vhi, q, m, randomized=True,
            key_ids=kids, key_seeds=kseeds, row_block=self.lookup_block,
            use_kernel=self.use_kernel, interpret=self.interpret)

        self.stats.kernel_launches += 1
        self.stats.lookups += n
        self.stats.staged_pages += len(set(key_addrs) | set(val_addrs))
        self.stats.staged_queries += n
        snap = snapshot_parities(self.chips, val_addrs)

        def tail(bm=bm, val=val, slots=slots, lookups=lookups, n=n,
                 snap=snap, rel=self.reliability, opens=opens):
            self.stats.result_bytes += resolve_lookup_responses(
                self.chips, lookups, np.asarray(bm)[:n],
                np.asarray(val)[:n], np.asarray(slots)[:n], snap,
                rel, opens)
        self._defer_all(lookups, tail)

    # -------------------------------------------------------------- gathers
    def _flush_gathers(self, gathers, opens=None) -> None:
        addrs = [cmd.page_addr for cmd, _ in gathers]
        rows = self.store.rows_for(addrs)
        n = len(gathers)
        n_pad = padded_rows(n, self.page_block)
        lo, hi, _, _ = self.store.take(rows, n_pad)
        chunk_words = planes_to_chunk_words_xp(lo, hi, jnp)
        bm = np.zeros((n_pad, 2), dtype=np.uint32)
        bm[:n] = np.asarray([cmd.chunk_bitmap for cmd, _ in gathers],
                            np.uint32)
        out, _counts = sim_gather(chunk_words, bm,
                                  max_out=CHUNKS_PER_PAGE,
                                  page_block=self.page_block,
                                  interpret=self.interpret,
                                  use_kernel=self.use_kernel)
        self.stats.kernel_launches += 1
        self.stats.gathers += n
        snap = snapshot_parities(self.chips, addrs)

        def tail(out=out, gathers=gathers, n=n, snap=snap,
                 rel=self.reliability, opens=opens):
            self.stats.result_bytes += resolve_gather_responses(
                self.chips, gathers, np.asarray(out)[:n], snap, rel, opens)
        self._defer_all(gathers, tail)
