"""Sharded multi-chip SSD backend: channels x dies chips, one launch/burst.

The scalar and batched backends drive what is effectively ONE chip's worth
of device state; only the analytic timeline model (flash/ssd.py) knew the
SSD has more than one die.  This backend is the refactor that turns "a chip
model with fast kernels" into "an SSD": it owns ``channels x dies_per_channel``
chips behind the same four-method ``MatchBackend`` contract and exploits
their parallelism the way the paper's controller does (§VI-A, TCAM-SSD's
channel-level framework).

Address space.  A global page address stripes across chips exactly like
``SimChipArray.route`` — ``chip = addr % n_chips``, ``local = addr // n_chips``
(:func:`decompose` / :func:`compose`) — so stored images, and therefore
every response, are bit-identical to the scalar/batched references over the
same array.  The single-chip backends are the degenerate 1x1 case.

Per-chip state.  Every chip gets its own pending command queue and its own
plane-arena namespace — per-chip row maps, dirty tracking and staged-byte
accounting — carved out of ONE block-aligned backing ``PlaneStore``
allocation, so that draining all chips stages with a single (chips, rows)
device gather instead of a per-chip gather+stack cascade (device dispatch,
not compute, dominates the interpret path).  ``flush()`` drains every chip
in a single device dispatch per phase:

  * searches — each chip's unique local pages and unique (query, mask)
    rows pad to the common pow2-of-block geometry and stack into
    (chips, rows, ...) operands for ONE ``jax.vmap``-ed ``sim_search``
    launch over the chip axis.  Sharding also shrinks the work: a chip's
    queries match only its own resident pages, so the cross product is
    ~1/chips of the single-arena launch — the kernel analogue of
    per-channel match engines, and where the >= 2x-at-16-chips throughput
    gate in benchmarks/kernel_micro.py comes from.
  * lookups — the paired ``sim_fused_lookup`` kernel is row-parallel
    (row i searches key page i, gathers value page i), so rows from every
    chip ride one row-stacked launch; the key and value page of one lookup
    may live on different chips (the §V-A cross-die pairing).
  * gathers — same row stacking through one ``sim_gather`` launch.
  * plans (Op.PLAN) — each chip's unique pages and unique
    (include, exclude) pass tuples dedup per chip (plan dedup, mirroring
    the query dedup) and stack into ONE vmapped ``sim_plan`` launch; the
    OR/AND-NOT combine happens in-kernel (the in-latch Fig 10 dataflow),
    so the timeline charges ``n_passes`` match ops but only 64 B of
    match-mode bus payload per page — not 64 B per pass per page.

Ticket resolution is lazy (see base.py/batched.py): every flush phase
keeps its launch outputs device-resident and the host tail runs at the
first ``result()`` of the burst, overlapping staging of the next burst
with device compute of this one.  Timeline accounting stays at flush time
— simulated SSD time is independent of when the host drains results.

Timeline coupling.  Pass ``timeline=`` (or ``timeline=True``) to attach a
``flash.timeline.BurstTimeline``: every flush reports per-chip batch sizes
and restaged bytes as ``ChipBurst`` records, which the adapter replays on
flash/ssd.py's die/channel/PCIe timelines — ``frontend.replay`` then
returns
measured-bit-exact results plus a simulated latency/energy distribution
(fig14/15-style) from the functional backend itself.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bits import (CHUNKS_PER_PAGE, SLOTS_PER_CHUNK,
                             popcount_words, unpack_bitmap)
from repro.core.commands import Command, LookupResponse, Op, SearchResponse
from repro.core.ecc import OpenVerdict
from repro.core.page import mask_header_slots
from repro.core.engine import SimChipArray
from repro.flash.params import (BITMAP_BYTES, CHUNK_BYTES, FlashParams,
                                OPEN_OVERHEAD_BYTES, PAGE_BYTES)
from repro.flash.timeline import BurstTimeline, ChipBurst
from repro.kernels.layout import planes_to_chunk_words_xp
from repro.kernels.sim_fused.ops import sim_fused_lookup
from repro.kernels.sim_gather.ops import sim_gather
from repro.kernels.sim_plan.ops import plan_pass_rows
from repro.kernels.sim_plan.ref import sim_plan_ref
from repro.kernels.sim_plan.sim_plan import sim_plan_kernel
from repro.kernels.sim_search.ref import sim_search_ref
from repro.kernels.sim_search.sim_search import sim_search_kernel

from .base import MatchBackend, Ticket
from .batched import (resolve_gather_responses, resolve_lookup_responses,
                      resolve_plan_responses, resolve_search_responses,
                      snapshot_parities)
from .planestore import PlaneStore, next_pow2, padded_rows

QUERY_BYTES = 16               # (query, mask) uint32 pairs shipped per search


def decompose(page_addr: int, n_chips: int) -> tuple[int, int]:
    """Global page -> (chip, local page), striped across the chip array."""
    return page_addr % n_chips, page_addr // n_chips


def compose(chip: int, local: int, n_chips: int) -> int:
    """(chip, local page) -> global page; inverse of :func:`decompose`."""
    return local * n_chips + chip


@functools.partial(jax.jit, static_argnames=("page_block", "use_kernel",
                                             "interpret"))
def _stacked_search(lo, hi, q, m, ids, seeds, *, page_block: int,
                    use_kernel: bool, interpret: bool):
    """One vmapped launch over the chip axis: (C, N, 512) planes x
    (C, Q, 2) queries -> (C, Q, N, 16) packed bitmaps."""
    if use_kernel:
        def one_chip(lo, hi, q, m, ids, seeds):
            return sim_search_kernel(lo, hi, q, m, 0, page_block=page_block,
                                     randomized=True, interpret=interpret,
                                     page_ids=ids, page_seeds=seeds)
    else:
        def one_chip(lo, hi, q, m, ids, seeds):
            return sim_search_ref(lo, hi, q, m, randomized=True,
                                  page_ids=ids, page_seeds=seeds)
    return jax.vmap(one_chip)(lo, hi, q, m, ids, seeds)


@functools.partial(jax.jit, static_argnames=("page_block", "use_kernel",
                                             "interpret"))
def _stacked_plan(lo, hi, q, m, f, ids, seeds, *, page_block: int,
                  use_kernel: bool, interpret: bool):
    """One vmapped fused-plan launch over the chip axis: (C, N, 512)
    planes x (C, G, P, 2) pass rows -> (C, G, N, 16) combined bitmaps."""
    if use_kernel:
        def one_chip(lo, hi, q, m, f, ids, seeds):
            return sim_plan_kernel(lo, hi, q, m, f, page_block=page_block,
                                   randomized=True, interpret=interpret,
                                   page_ids=ids, page_seeds=seeds)
    else:
        def one_chip(lo, hi, q, m, f, ids, seeds):
            return sim_plan_ref(lo, hi, q, m, f, randomized=True,
                                page_ids=ids, page_seeds=seeds)
    return jax.vmap(one_chip)(lo, hi, q, m, f, ids, seeds)


class ShardedSsdBackend(MatchBackend):
    """channels x dies chips, per-chip queues, one stacked launch per burst.

    ``chips`` must hold ``channels * dies_per_channel`` chips (geometry
    defaults to one channel per chip).  Results are bit-identical to the
    scalar/batched backends over the same array; like the batched backend
    it reports ``open_verdict`` CLEAN unless a reliability tier is
    attached (``enable_reliability``), in which case the flush runs the
    full optimistic open burst and charges read-retries and full-page ECC
    fallback reads on the flash timelines.
    """

    # Bounded program retry budget: a seeded program-failure draw relocates
    # the page to a spare and retries at most this many times (the SIM006
    # discipline — no unbounded, unseeded retry loops in the backend).
    MAX_PROGRAM_ATTEMPTS = 8

    def __init__(self, chips: SimChipArray, *, channels: int | None = None,
                 dies_per_channel: int | None = None, page_block: int = 8,
                 lookup_block: int = 8, use_kernel: bool = True,
                 interpret: bool | None = None,
                 timeline: BurstTimeline | bool | None = None,
                 replicas: int = 1):
        super().__init__(chips)
        n_chips = len(chips.chips)
        if channels is None:
            channels = n_chips if dies_per_channel is None else \
                n_chips // dies_per_channel
        if dies_per_channel is None:
            dies_per_channel = n_chips // channels
        if channels * dies_per_channel != n_chips:
            raise ValueError(
                f"geometry {channels}x{dies_per_channel} != {n_chips} chips")
        self.channels = channels
        self.dies_per_channel = dies_per_channel
        self.page_block = page_block
        self.lookup_block = lookup_block
        self.use_kernel = use_kernel
        self.interpret = interpret
        if timeline is True:
            timeline = BurstTimeline(FlashParams(
                channels=channels, dies_per_channel=dies_per_channel))
        if timeline is not None and timeline is not False \
                and timeline.n_chips != n_chips:
            raise ValueError(f"timeline models {timeline.n_chips} dies, "
                             f"backend has {n_chips} chips")
        self.timeline: BurstTimeline | None = timeline or None
        # One backing arena, addressed by global page; per-chip rows are
        # grouped at flush time (see module docstring).
        self.store = PlaneStore(chips, block=page_block, log_staging=True)
        # Per-chip pending queues — the sharded command namespace.
        self._pending: list[list[tuple[str, Command, Ticket]]] = [
            [] for _ in chips.chips]
        # Fault tolerance: k-replica page striping plus bad-block remap.
        # replicas=1 keeps exactly today's single-copy behaviour; with
        # replicas=k every program fans out to k-1 extra copies on the
        # next chips round-robin, allocated from the TOP of each chip's
        # local address space (primary data grows from the bottom).
        if not 1 <= replicas <= len(chips.chips):
            raise ValueError(f"replicas={replicas} needs 1..{len(chips.chips)}")
        self.replicas = replicas
        self._replica_of: dict[int, tuple[int, ...]] = {}
        self._spare_next: list[int] = [chips.pages_per_chip - 1
                                       for _ in chips.chips]
        # DeviceFaultState (repro.reliability.device_faults) or None.
        self.faults = None

    # ------------------------------------------------------------ geometry
    @classmethod
    def from_geometry(cls, *, channels: int, dies_per_channel: int = 1,
                      pages_per_chip: int = 512, device_seed: int = 0,
                      **kw) -> "ShardedSsdBackend":
        """Build the chip array from SSD geometry (FlashParams convention:
        ``channels x dies_per_channel`` chips)."""
        arr = SimChipArray(n_chips=channels * dies_per_channel,
                           pages_per_chip=pages_per_chip,
                           device_seed=device_seed)
        return cls(arr, channels=channels,
                   dies_per_channel=dies_per_channel, **kw)

    @property
    def n_chips(self) -> int:
        return len(self.chips.chips)

    def decompose(self, page_addr: int) -> tuple[int, int]:
        return decompose(page_addr, self.n_chips)

    # ------------------------------------------------------------- storage
    def program_entries(self, page_addr: int, entries, **kw):
        built = self._program_page(page_addr, entries, kw)
        if self.timeline is not None:
            for c in self._program_chips(page_addr):
                self.timeline.observe_program(c)
        return built

    def _program_chips(self, page_addr: int) -> list[int]:
        """Chips a logical program lands on: the (possibly remapped)
        primary plus every replica — replica fan-out is charged on the
        timelines like any other program."""
        chips = [self._mapped(page_addr) % self.n_chips]
        chips += [self._mapped(r) % self.n_chips
                  for r in self._replica_of.get(page_addr, ())]
        return chips

    # --------------------------------------------------- fault-aware placing
    def enable_device_faults(self, state) -> None:
        """Attach a DeviceFaultState: programs draw seeded failures (grown
        bad blocks remap to spares), reads consult the outage set at flush
        and fail over to replicas, and the attached timeline schedules
        stall windows onto its resource lines."""
        self.faults = state
        if self.timeline is not None:
            self.timeline.attach_faults(state)

    def _alloc_spare(self, chip: int) -> int:
        """Carve one spare page off the top of a chip's local space."""
        local = self._spare_next[chip]
        programmed = self.chips.chips[chip].pages
        while local >= 0 and local in programmed:
            local -= 1
        if local < 0:
            raise RuntimeError(
                f"chip {chip}: out of spare pages (replicas/bad-block "
                "remap exhausted the local address space)")
        self._spare_next[chip] = local - 1
        return compose(chip, local, self.n_chips)

    def _next_live_chip(self, chip: int) -> int:
        """First chip after ``chip`` (round-robin) not in the outage set."""
        for off in range(1, self.n_chips + 1):
            c = (chip + off) % self.n_chips
            if not self.faults.chip_dead(c):
                return c
        return chip                        # whole array dead: nowhere left

    def _mapped(self, addr: int) -> int:
        """Follow the bad-block remap chain to the live physical page."""
        if self.faults is None:
            return addr
        remap = self.faults.remap
        for _ in range(len(remap)):
            nxt = remap.get(addr)
            if nxt is None:
                break
            addr = nxt
        return addr

    def _replica_addrs(self, addr: int) -> tuple[int, ...]:
        """The k-1 replica pages of a primary (allocated at first program,
        striped across the next chips round-robin)."""
        if self.replicas <= 1:
            return ()
        reps = self._replica_of.get(addr)
        if reps is None:
            chip = addr % self.n_chips
            reps = tuple(self._alloc_spare((chip + r) % self.n_chips)
                         for r in range(1, self.replicas))
            self._replica_of[addr] = reps
        return reps

    def _program_page(self, page_addr: int, entries, kw):
        """Fault-aware program: primary (with bad-block remap and bounded
        seeded retry) plus every replica.  The logical address never
        changes — only the physical placement does."""
        built = self._program_physical(page_addr, entries, kw)
        if self.faults is not None:
            for rep in self._replica_addrs(page_addr):
                self._program_physical(rep, entries, kw)
                self.faults.stats.replica_programs += 1
        else:
            for rep in self._replica_addrs(page_addr):
                self._program_physical(rep, entries, kw)
        return built

    def _program_physical(self, addr: int, entries, kw):
        """Program one physical page, relocating off dead chips and around
        seeded program failures (grown bad blocks) with a bounded retry."""
        target = self._mapped(addr)
        if self.faults is not None:
            chip = target % self.n_chips
            if self.faults.chip_dead(chip):
                # The owning chip is offline: relocate to a spare on the
                # next live chip so writes survive the outage.
                spare = self._alloc_spare(self._next_live_chip(chip))
                self.faults.mark_bad(target, spare)
                target = spare
            for attempt in range(self.MAX_PROGRAM_ATTEMPTS):
                if not self.faults.program_fails(target, attempt):
                    break
                spare = self._alloc_spare(target % self.n_chips)
                self.faults.mark_bad(target, spare)
                target = spare
        return self.chips.program_entries(target, entries, **kw)

    # ------------------------------------------------------------ deferred
    def _submit(self, kind: str, cmd: Command) -> Ticket:
        t = Ticket(self)
        chip, _ = self.decompose(cmd.page_addr)
        self._pending[chip].append((kind, cmd, t))
        return t

    def submit_search(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.SEARCH or cmd.query is None or cmd.mask is None:
            raise ValueError(f"not a search command: {cmd}")
        return self._submit("search", cmd)

    def submit_gather(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.GATHER or cmd.chunk_bitmap is None:
            raise ValueError(f"not a gather command: {cmd}")
        return self._submit("gather", cmd)

    def submit_lookup(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.LOOKUP or cmd.value_page is None:
            raise ValueError(f"not a lookup command: {cmd}")
        return self._submit("lookup", cmd)

    def submit_plan(self, cmd: Command) -> Ticket:
        if cmd.op is not Op.PLAN or cmd.plan_include is None:
            raise ValueError(f"not a plan command: {cmd}")
        return self._submit("plan", cmd)

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._pending) + self.pending_programs

    # --------------------------------------------------------------- flush
    def flush(self) -> None:
        # Deferred write path first: one grouped chip-program pass, ONE
        # plane-store scatter for every programmed row, and one program-
        # group report to the timeline (programs queue async on each die's
        # program line; restaged dirty planes charge the storage-mode
        # channel bus — the client clock does not advance).
        programs = self._execute_programs()
        if programs:
            self.store.stage_group(programs)
            if self.timeline is not None:
                staged, self.store.staged_log = self.store.staged_log, []
                self.timeline.observe_program_group(
                    [c for a in programs for c in self._program_chips(a)],
                    restage_chips=[self.decompose(a)[0] for a in staged])
            self.stats.staged_bytes = self.store.staged_bytes
        if not any(self._pending):
            if programs:
                self.stats.flushes += 1
            return
        self.stats.flushes += 1
        searches, lookups, gathers, plans = [], [], [], []
        for queue in self._pending:
            for kind, cmd, t in queue:
                if self.faults is not None and self.faults.remap:
                    cmd = self._remap_cmd(cmd)
                {"search": searches, "lookup": lookups,
                 "gather": gathers, "plan": plans}[kind].append((cmd, t))
            queue.clear()
        bursts: dict[int, ChipBurst] = {}
        # Device-fault failover: commands whose chip is offline at the
        # fault clock leave the kernel path here and are served host-side
        # from a replica (or fail typed) — see _serve_degraded.
        if self.faults is not None:
            dead = self.faults.dead_chips()
            if dead:
                searches = self._failover("search", searches, dead, bursts)
                lookups = self._failover("lookup", lookups, dead, bursts)
                gathers = self._failover("gather", gathers, dead, bursts)
                plans = self._failover("plan", plans, dead, bursts)
        # Reliability open burst before staging (open-time ECC repairs
        # restage corrected rows in this flush); retries and full-page
        # fallback reads charge the owning die's timeline record.
        opens = self._open_reliability(
            {c.page_addr for c, _ in searches}
            | {c.page_addr for c, _ in plans}
            | {c.page_addr for c, _ in gathers}
            | {c.page_addr for c, _ in lookups}
            | {c.value_page for c, _ in lookups})
        if opens and self.timeline is not None:
            for a, po in opens.items():
                c, _ = self.decompose(a)
                b = self._burst(bursts, c)
                b.retry_senses += po.result.retries_used
                if po.verdict is OpenVerdict.FALLBACK_ECC:
                    b.fallback_reads += 1
        if searches:
            self._flush_searches(searches, bursts, opens)
        if plans:
            self._flush_plans(plans, bursts, opens)
        if lookups:
            self._flush_lookups(lookups, bursts, opens)
        if gathers:
            self._flush_gathers(gathers, bursts, opens)
        self.stats.staged_bytes = self.store.staged_bytes
        staged, self.store.staged_log = self.store.staged_log, []
        if self.timeline is not None:
            for a in staged:   # dirty/new planes restage in storage mode
                c, _ = self.decompose(a)
                self._burst(bursts, c).bus_storage_bytes += PAGE_BYTES
            self.timeline.observe_flush(
                [bursts[c] for c in sorted(bursts)])

    def _burst(self, bursts: dict[int, ChipBurst], chip: int) -> ChipBurst:
        return bursts.setdefault(chip, ChipBurst(chip))

    # ---------------------------------------------------- degraded failover
    def _remap_cmd(self, cmd: Command) -> Command:
        """Follow grown-bad-block remaps; spares hold the same entries and
        responses are derandomized (address-independent), so the remapped
        read is bit-identical to the original."""
        mapped = self._mapped(cmd.page_addr)
        vmapped = (self._mapped(cmd.value_page)
                   if cmd.value_page is not None else None)
        if mapped == cmd.page_addr and vmapped == cmd.value_page:
            return cmd
        return dataclasses.replace(cmd, page_addr=mapped,
                                   value_page=vmapped)

    def _failover(self, kind: str, items, dead: set[int], bursts):
        """Split one flush list: commands touching a dead chip are served
        host-side (degraded) right now; the rest stay on the kernel path."""
        if not items:
            return items
        keep = []
        for cmd, ticket in items:
            touched = [cmd.page_addr]
            if cmd.value_page is not None:
                touched.append(cmd.value_page)
            if any(a % self.n_chips in dead for a in touched):
                self._serve_degraded(kind, cmd, ticket, dead, bursts)
            else:
                keep.append((cmd, ticket))
        return keep

    def _live_addr(self, addr: int, dead: set[int], bursts) -> int:
        """A live physical address for ``addr``: the page itself when its
        chip is up, else the first replica on a live chip (charged as one
        degraded full-page read).  Raises DegradedReadError when neither
        survives."""
        from repro.reliability import DegradedReadError
        if addr % self.n_chips not in dead:
            return self._mapped(addr)
        for rep in self._replica_of.get(addr, ()):
            rep = self._mapped(rep)
            chip = rep % self.n_chips
            if chip not in dead:
                self.faults.stats.failovers += 1
                b = self._burst(bursts, chip)
                b.degraded_reads += 1
                b.pcie_bytes += PAGE_BYTES
                return rep
        raise DegradedReadError(addr)

    def _serve_degraded(self, kind: str, cmd: Command, ticket: Ticket,
                        dead: set[int], bursts) -> None:
        """Graceful degradation: execute one command host-side against the
        scalar reference path on a surviving replica.  The replica holds
        the same entries, and search/gather responses are derandomized, so
        the result is bit-identical to the healthy read — faults surface
        only as latency (the degraded full-page reads charged in
        ``bursts``) or as a typed DegradedReadError, never as wrong data.
        """
        from repro.reliability import DegradedReadError
        try:
            addr = self._live_addr(cmd.page_addr, dead, bursts)
            vaddr = (self._live_addr(cmd.value_page, dead, bursts)
                     if cmd.value_page is not None else None)
        except DegradedReadError as e:
            ticket._fail(e)
            return
        self.faults.stats.degraded_ops += 1
        if kind == "search":
            ticket._resolve(self.chips.search(
                dataclasses.replace(cmd, page_addr=addr)))
        elif kind == "gather":
            ticket._resolve(self.chips.gather(
                dataclasses.replace(cmd, page_addr=addr)))
        elif kind == "plan":
            ticket._resolve(self._plan_host(
                dataclasses.replace(cmd, page_addr=addr)))
        else:                              # lookup: the §V-A command pair
            resp = self.chips.search(Command(
                Op.SEARCH, addr, query=cmd.query, mask=cmd.mask))
            bitmap = mask_header_slots(resp.bitmap_words)
            slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
            if slots.size == 0:
                ticket._resolve(LookupResponse(search=resp,
                                               value_slot=None, value=None))
                return
            slot = int(slots[0])
            g = self.chips.gather(Command.gather(
                vaddr, 1 << (slot // SLOTS_PER_CHUNK)))
            off = (slot % SLOTS_PER_CHUNK) * 8
            ticket._resolve(LookupResponse(
                search=resp, value_slot=slot,
                value=bytes(g.chunks[0][off:off + 8]),
                parity_ok=bool(g.parity_ok[0])))

    # Open-verdict severity, worst-wins across a degraded plan's passes
    # (mirrors ScalarBackend._VERDICT_RANK).
    _VERDICT_RANK = {v.value: i for i, v in enumerate((
        OpenVerdict.CLEAN, OpenVerdict.CLEAN_NEEDS_REFRESH,
        OpenVerdict.FALLBACK_ECC, OpenVerdict.UNCORRECTABLE))}

    def _plan_host(self, cmd: Command) -> SearchResponse:
        """Per-pass split reference for a degraded Op.PLAN (scalar recipe)."""
        acc = np.zeros(16, dtype=np.uint32)
        verdict = OpenVerdict.CLEAN.value
        for q, mk in cmd.plan_include:
            r = self.chips.search(Command(Op.SEARCH, cmd.page_addr,
                                          query=q, mask=mk))
            acc |= r.bitmap_words
            verdict = max(verdict, r.open_verdict,
                          key=self._VERDICT_RANK.__getitem__)
        for q, mk in cmd.plan_exclude:
            r = self.chips.search(Command(Op.SEARCH, cmd.page_addr,
                                          query=q, mask=mk))
            acc &= ~r.bitmap_words
            verdict = max(verdict, r.open_verdict,
                          key=self._VERDICT_RANK.__getitem__)
        return SearchResponse(bitmap_words=acc,
                              match_count=int(popcount_words(acc).sum()),
                              open_verdict=verdict)

    # ------------------------------------------------------------- searches
    def _flush_searches(self, searches, bursts, opens=None) -> None:
        # Per chip: unique pages -> arena rows; unique (query, mask) ->
        # operand rows; every command lands at one (chip, qi, pi) cell.
        # Approximate-match voting re-senses each page vote_k times; the
        # majority accumulates in-latch so still ONE bitmap crosses per
        # command (mirrors the plan path's in-latch accumulation).
        vf = self.reliability.vote_factor if self.reliability is not None \
            else 1
        n = self.n_chips
        addrs: list[list[int]] = [[] for _ in range(n)]
        page_rows: list[dict[int, int]] = [{} for _ in range(n)]
        query_rows: list[dict[tuple, int]] = [{} for _ in range(n)]
        q_pairs: list[list] = [[] for _ in range(n)]
        m_pairs: list[list] = [[] for _ in range(n)]
        placements = []                        # (chip, qi, pi)
        for cmd, _ in searches:
            c, _local = self.decompose(cmd.page_addr)
            if cmd.page_addr not in page_rows[c]:
                page_rows[c][cmd.page_addr] = len(addrs[c])
                addrs[c].append(cmd.page_addr)
            key = (cmd.query, cmd.mask)
            if key not in query_rows[c]:
                query_rows[c][key] = len(q_pairs[c])
                q_pairs[c].append(cmd.query)
                m_pairs[c].append(cmd.mask)
            placements.append((c, query_rows[c][key],
                               page_rows[c][cmd.page_addr]))

        active = [c for c in range(n) if addrs[c]]
        slot_of = {c: i for i, c in enumerate(active)}
        n_pad = max(padded_rows(len(addrs[c]), self.page_block)
                    for c in active)
        q_pad = max(next_pow2(len(q_pairs[c])) for c in active)
        c_pad = next_pow2(len(active))

        # One staging pass over every chip's pages, then one (C, N) gather.
        flat = [a for c in active for a in addrs[c]]
        rows = self.store.rows_for(flat)
        idx2d = np.zeros((c_pad, n_pad), np.int32)
        off = 0
        for i, c in enumerate(active):
            k = len(addrs[c])
            idx2d[i, :k] = rows[off:off + k]
            off += k
            chip = self.chips.chips[c]
            chip.counters.array_reads += k     # one staged sense per page
            b = self._burst(bursts, c)
            b.senses += k * vf
            b.bus_match_bytes += OPEN_OVERHEAD_BYTES * k
        lo, hi, ids, seeds = self.store.take2d(idx2d)
        q = np.zeros((c_pad, q_pad, 2), dtype=np.uint32)
        m = np.zeros_like(q)
        for i, c in enumerate(active):
            q[i, :len(q_pairs[c])] = np.asarray(q_pairs[c], np.uint32)
            m[i, :len(m_pairs[c])] = np.asarray(m_pairs[c], np.uint32)

        interp = self.interpret
        if interp is None:
            from repro.kernels import default_interpret
            interp = default_interpret()
        out = _stacked_search(
            lo, hi, q, m, ids, seeds, page_block=self.page_block,
            use_kernel=self.use_kernel, interpret=interp)

        self.stats.kernel_launches += 1
        self.stats.staged_pages += len(flat)
        self.stats.staged_queries += sum(len(q_pairs[c]) for c in active)
        self.stats.searches += len(searches)
        if len(searches) > 1:
            self.stats.batched_searches += len(searches)
        for cmd, _ in searches:
            c, _local = self.decompose(cmd.page_addr)
            b = self._burst(bursts, c)
            b.matches += vf
            b.bus_match_bytes += BITMAP_BYTES
            b.pcie_bytes += BITMAP_BYTES + QUERY_BYTES

        stacked = [(slot_of[c], qi, pi) for c, qi, pi in placements]

        def tail(out=out, searches=searches, stacked=stacked,
                 rel=self.reliability, opens=opens):
            self.stats.result_bytes += resolve_search_responses(
                self.chips, searches, stacked, np.asarray(out),
                reliability=rel, opens=opens)
        self._defer_all(searches, tail)

    # --------------------------------------------------------------- plans
    def _flush_plans(self, plans, bursts, opens=None) -> None:
        """Fused range plans, stacked across chips like searches.

        Per chip: unique pages -> arena rows, unique (include, exclude)
        pass tuples -> plan groups (the per-chip plan dedup mirroring the
        query dedup).  ONE vmapped ``sim_plan`` launch evaluates every
        chip's groups against its own resident pages.  On the simulated
        bus a plan costs ``n_passes`` match ops but only ONE 64 B bitmap
        per page — the in-latch accumulation (Fig 10) — where the per-pass
        split path would cross 64 B per pass per page.
        """
        n = self.n_chips
        addrs: list[list[int]] = [[] for _ in range(n)]
        page_rows: list[dict[int, int]] = [{} for _ in range(n)]
        group_rows: list[dict[tuple, int]] = [{} for _ in range(n)]
        groups: list[list[tuple]] = [[] for _ in range(n)]
        vf = self.reliability.vote_factor if self.reliability is not None \
            else 1
        placements = []                        # (chip, gi, pi)
        for cmd, _ in plans:
            c, _local = self.decompose(cmd.page_addr)
            if cmd.page_addr not in page_rows[c]:
                page_rows[c][cmd.page_addr] = len(addrs[c])
                addrs[c].append(cmd.page_addr)
            key = (cmd.plan_include, cmd.plan_exclude)
            if key not in group_rows[c]:
                group_rows[c][key] = len(groups[c])
                groups[c].append(key)
            placements.append((c, group_rows[c][key],
                               page_rows[c][cmd.page_addr]))

        active = [c for c in range(n) if addrs[c]]
        slot_of = {c: i for i, c in enumerate(active)}
        n_pad = max(padded_rows(len(addrs[c]), self.page_block)
                    for c in active)
        g_pad = max(next_pow2(len(groups[c])) for c in active)
        p_pad = next_pow2(max(max((len(i) + len(e) for i, e in groups[c]),
                                  default=1) for c in active))
        c_pad = next_pow2(len(active))

        flat = [a for c in active for a in addrs[c]]
        rows = self.store.rows_for(flat)
        idx2d = np.zeros((c_pad, n_pad), np.int32)
        off = 0
        for i, c in enumerate(active):
            k = len(addrs[c])
            idx2d[i, :k] = rows[off:off + k]
            off += k
            chip = self.chips.chips[c]
            chip.counters.array_reads += k     # one staged sense per page
            b = self._burst(bursts, c)
            b.senses += k * vf
            b.bus_match_bytes += OPEN_OVERHEAD_BYTES * k
        lo, hi, ids, seeds = self.store.take2d(idx2d)
        q = np.zeros((c_pad, g_pad, p_pad, 2), dtype=np.uint32)
        m = np.zeros_like(q)
        f = np.zeros((c_pad, g_pad, p_pad), dtype=np.uint32)
        for i, c in enumerate(active):
            for gi, (inc, exc) in enumerate(groups[c]):
                q[i, gi], m[i, gi], f[i, gi] = plan_pass_rows(inc, exc,
                                                              p_pad)

        interp = self.interpret
        if interp is None:
            from repro.kernels import default_interpret
            interp = default_interpret()
        out = _stacked_plan(
            lo, hi, q, m, f, ids, seeds, page_block=self.page_block,
            use_kernel=self.use_kernel, interpret=interp)

        self.stats.kernel_launches += 1
        self.stats.staged_pages += len(flat)
        self.stats.staged_queries += sum(len(i) + len(e)
                                         for c in active
                                         for i, e in groups[c])
        self.stats.plans += len(plans)
        for cmd, _ in plans:
            c, _local = self.decompose(cmd.page_addr)
            b = self._burst(bursts, c)
            b.matches += cmd.n_passes * vf     # every pass matches on-die
            b.bus_match_bytes += BITMAP_BYTES  # ...but ONE bitmap crosses
            b.pcie_bytes += BITMAP_BYTES + QUERY_BYTES * cmd.n_passes

        stacked = [(slot_of[c], gi, pi) for c, gi, pi in placements]

        def tail(out=out, plans=plans, stacked=stacked,
                 rel=self.reliability, opens=opens):
            self.stats.result_bytes += resolve_plan_responses(
                self.chips, plans, stacked, np.asarray(out),
                reliability=rel, opens=opens)
        self._defer_all(plans, tail)

    # -------------------------------------------------------------- lookups
    def _flush_lookups(self, lookups, bursts, opens=None) -> None:
        """Row-stacked fused burst across every chip: ONE launch."""
        vf = self.reliability.vote_factor if self.reliability is not None \
            else 1
        key_addrs = [cmd.page_addr for cmd, _ in lookups]
        val_addrs = [cmd.value_page for cmd, _ in lookups]
        k_rows = self.store.rows_for(key_addrs)
        v_rows = self.store.rows_for(val_addrs)
        n = len(lookups)
        n_pad = padded_rows(n, self.lookup_block)
        klo, khi, kids, kseeds = self.store.take(k_rows, n_pad)
        vlo, vhi, _, _ = self.store.take(v_rows, n_pad)
        q = np.zeros((n_pad, 2), dtype=np.uint32)
        m = np.full((n_pad, 2), 0xFFFFFFFF, dtype=np.uint32)  # pad rows miss
        q[:n] = np.asarray([cmd.query for cmd, _ in lookups], np.uint32)
        m[:n] = np.asarray([cmd.mask for cmd, _ in lookups], np.uint32)

        bm, val, slots = sim_fused_lookup(
            klo, khi, vlo, vhi, q, m, randomized=True,
            key_ids=kids, key_seeds=kseeds, row_block=self.lookup_block,
            use_kernel=self.use_kernel, interpret=self.interpret)
        self.stats.kernel_launches += 1
        self.stats.lookups += n
        self.stats.staged_pages += len(set(key_addrs) | set(val_addrs))
        self.stats.staged_queries += n
        # Key pages re-sense vote_k times for majority voting; value pages
        # sense once (the chunk read is verified by parity, not by vote).
        for addrs, senses in ((set(key_addrs), vf), (set(val_addrs), 1)):
            for a in addrs:                    # one open per unique page
                c, _ = self.decompose(a)
                b = self._burst(bursts, c)
                b.senses += senses
                b.bus_match_bytes += OPEN_OVERHEAD_BYTES
        for cmd, _ in lookups:
            kc, _ = self.decompose(cmd.page_addr)
            vc, _ = self.decompose(cmd.value_page)
            kb = self._burst(bursts, kc)
            kb.matches += vf
            kb.bus_match_bytes += BITMAP_BYTES
            kb.pcie_bytes += BITMAP_BYTES + QUERY_BYTES
            vb = self._burst(bursts, vc)
            vb.bus_match_bytes += CHUNK_BYTES
            vb.pcie_bytes += CHUNK_BYTES

        snap = snapshot_parities(self.chips, val_addrs)

        def tail(bm=bm, val=val, slots=slots, lookups=lookups, n=n,
                 snap=snap, rel=self.reliability, opens=opens):
            self.stats.result_bytes += resolve_lookup_responses(
                self.chips, lookups, np.asarray(bm)[:n],
                np.asarray(val)[:n], np.asarray(slots)[:n], snap,
                reliability=rel, opens=opens)
        self._defer_all(lookups, tail)

    # -------------------------------------------------------------- gathers
    def _flush_gathers(self, gathers, bursts, opens=None) -> None:
        addrs = [cmd.page_addr for cmd, _ in gathers]
        rows = self.store.rows_for(addrs)
        n = len(gathers)
        n_pad = padded_rows(n, self.page_block)
        lo, hi, _, _ = self.store.take(rows, n_pad)
        chunk_words = planes_to_chunk_words_xp(lo, hi, jnp)
        bm = np.zeros((n_pad, 2), dtype=np.uint32)
        bm[:n] = np.asarray([cmd.chunk_bitmap for cmd, _ in gathers],
                            np.uint32)
        out, _counts = sim_gather(chunk_words, bm,
                                  max_out=CHUNKS_PER_PAGE,
                                  page_block=self.page_block,
                                  interpret=self.interpret,
                                  use_kernel=self.use_kernel)
        self.stats.kernel_launches += 1
        self.stats.gathers += n
        snap = snapshot_parities(self.chips, addrs)

        def tail(out=out, gathers=gathers, n=n, snap=snap,
                 rel=self.reliability, opens=opens):
            self.stats.result_bytes += resolve_gather_responses(
                self.chips, gathers, np.asarray(out)[:n], snap,
                reliability=rel, opens=opens)
        self._defer_all(gathers, tail)
        for cmd, _ in gathers:
            c, _local = self.decompose(cmd.page_addr)
            k = int(popcount_words(
                np.asarray(cmd.chunk_bitmap, np.uint32)).sum())
            b = self._burst(bursts, c)
            b.senses += 1
            b.bus_match_bytes += CHUNK_BYTES * k
            b.pcie_bytes += CHUNK_BYTES * k
