"""Continuous-batching serving engine.

Slots hold independent sequences with their own caches and positions;
finished sequences retire and waiting requests admit without draining the
batch.  Slots step through ``decode_step`` per slot (a real deployment vmaps
slots onto the batch dim; the per-slot loop keeps this engine simple and
exactly matches the batched math — asserted in tests).

When constructed with a SimPagedKVCache the engine additionally mirrors
every generated token's KV into SiM-indexed pages and serves attention from
gathered pages — the end-to-end paper-technique path used by
examples/serve_lm.py.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import decode_step, prefill


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_token: int | None = None


@dataclasses.dataclass
class Completion:
    req_id: int
    tokens: list[int]
    prefill_s: float
    decode_s: float


@dataclasses.dataclass
class _Slot:
    request: Request
    caches: dict
    position: int
    generated: list[int]
    t_prefill: float


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_slots: int = 4,
                 cache_len: int = 256, paged_cache=None):
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.paged = paged_cache
        self.queue: deque[Request] = deque()
        self.slots: dict[int, _Slot] = {}
        self.completed: list[Completion] = []
        self.steps = 0

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    # ----------------------------------------------------------- internals
    def _admit(self) -> None:
        while self.queue and len(self.slots) < self.max_slots:
            req = self.queue.popleft()
            t0 = time.perf_counter()
            tokens = jnp.asarray([req.prompt], jnp.int32)
            logits, caches = prefill(self.params, self.cfg, tokens,
                                     self.cache_len)
            dt = time.perf_counter() - t0
            first = int(jnp.argmax(logits, -1)[0])
            slot = _Slot(request=req, caches=caches,
                         position=len(req.prompt), generated=[first],
                         t_prefill=dt)
            if self.paged is not None:
                self._mirror_prompt_kv(req, caches)
            self.slots[req.req_id] = slot

    def _mirror_prompt_kv(self, req: Request, caches: dict) -> None:
        """Mirror prefilled KV into the SiM-paged pool (per token)."""
        ck, cv = caches["kv"]
        for pos in range(len(req.prompt)):
            self.paged.write_token(req.req_id, pos,
                                   ck[:, 0, pos], cv[:, 0, pos])

    def _retire(self, req_id: int, decode_s: float) -> None:
        slot = self.slots.pop(req_id)
        if self.paged is not None:
            self.paged.free_sequence(req_id)
        self.completed.append(Completion(
            req_id=req_id, tokens=slot.generated,
            prefill_s=slot.t_prefill, decode_s=decode_s))

    def step(self) -> int:
        """One engine tick: admit + one decode step per active slot."""
        self._admit()
        done = []
        t0 = time.perf_counter()
        for req_id, slot in self.slots.items():
            tok = jnp.asarray([[slot.generated[-1]]], jnp.int32)
            logits, slot.caches = decode_step(
                self.params, self.cfg, tok, slot.caches, slot.position,
                enc_out=slot.caches.get("enc_out"))
            nxt = int(jnp.argmax(logits, -1)[0])
            slot.generated.append(nxt)
            if self.paged is not None:
                ck, cv = slot.caches["kv"]
                self.paged.write_token(req_id, slot.position,
                                       ck[:, 0, slot.position],
                                       cv[:, 0, slot.position])
            slot.position += 1
            req = slot.request
            if (len(slot.generated) >= req.max_new_tokens
                    or (req.eos_token is not None
                        and nxt == req.eos_token)):
                done.append(req_id)
        dt = time.perf_counter() - t0
        for rid in done:
            self._retire(rid, dt)
        self.steps += 1
        return len(self.slots)

    def run(self) -> list[Completion]:
        while self.queue or self.slots:
            self.step()
        return self.completed
