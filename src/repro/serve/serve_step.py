"""Serving steps: prefill and decode as jittable pure functions.

``decode_32k`` / ``long_500k`` dry-run cells lower ``serve_decode_step`` —
one new token against a resident cache (contiguous, ring for sliding-window
archs, or recurrent state for ssm/hybrid).  The SiM-paged cache variant
(serve/kvcache.py) is exercised by examples/serve_lm.py and tests.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import decode_step, prefill


def serve_prefill(params, cfg: ModelConfig, tokens, cache_len: int, *,
                  frontend_embeds=None, block_specs=None, act_spec=None):
    return prefill(params, cfg, tokens, cache_len,
                   frontend_embeds=frontend_embeds, block_specs=block_specs,
                   act_spec=act_spec)


def serve_decode_step(params, cfg: ModelConfig, token, caches, index, *,
                      enc_out=None, block_specs=None, act_spec=None):
    """token (B,1) int32; index: absolute position scalar.  Greedy-samples
    the next token so the serving loop is self-contained."""
    logits, caches = decode_step(params, cfg, token, caches, index,
                                 enc_out=enc_out, block_specs=block_specs,
                                 act_spec=act_spec)
    next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return next_token, logits, caches
