"""SiM-paged KV cache: the paper's technique as a first-class serving
feature (DESIGN.md §2, last row of the mapping table).

A vLLM-style paged KV cache needs a *block table*: (sequence, logical
block) -> physical page.  That table is exactly the kind of index the paper
accelerates — fixed-width keys, masked point lookups, high fan-out — so
here it lives on SiM flash pages and is queried with real ``search`` /
``gather`` commands through the functional chip engine:

    key slot (8 B, BitWeaving):  [seq_id:24 | logical_block:20 | phys:20]

A lookup masks out the ``phys`` field and matches on (seq_id, block); the
matching slot's own bits carry the physical page id (single-page lookup =
one search command, no gather needed — cheaper than the generic two-page
schema of §V-A).  De-allocation and sequence eviction reuse the §V-D
keyspace-partition trick: one masked search per sequence isolates all its
table entries.

The KV payload pool is an ordinary jax array (HBM); only the *index* rides
SiM — mirroring the paper's data/metadata separation (Fig 4).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.bits import unpack_bitmap
from repro.core.bitweaving import Column, RowCodec
from repro.core.commands import Command
from repro.core.engine import SimChipArray
from repro.core.page import USER_SLOTS, mask_header_slots
from repro.models.config import ModelConfig
from repro.reliability import require_clean

TABLE_CODEC = RowCodec([Column("seq", 24), Column("block", 20),
                        Column("phys", 20)])


@dataclasses.dataclass
class PagedStats:
    searches: int = 0
    programs: int = 0
    pages_allocated: int = 0
    pages_freed: int = 0


class SimPagedKVCache:
    """Physical KV page pool + SiM-resident block table (single layer-stack
    pool; layers index the same physical pages at different strides)."""

    def __init__(self, cfg: ModelConfig, *, n_pages: int,
                 page_tokens: int = 16, table_pages: int = 8,
                 n_chips: int = 4):
        self.cfg = cfg
        self.page_tokens = page_tokens
        self.n_pages = n_pages
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.n_layers, n_pages, page_tokens, cfg.n_kv_heads,
                 cfg.head_dim)
        self.pool_k = jnp.zeros(shape, dt)
        self.pool_v = jnp.zeros(shape, dt)
        self.chips = SimChipArray(n_chips=n_chips,
                                  pages_per_chip=table_pages)
        self.table_pages = table_pages
        self._entries: list[int] = [[] for _ in range(table_pages)]
        self._entries = {p: [] for p in range(table_pages)}
        self._free = list(range(n_pages - 1, -1, -1))
        self._seq_blocks: dict[int, int] = {}     # seq -> #blocks
        self.stats = PagedStats()
        for p in range(table_pages):
            self.chips.program_entries(p, np.zeros(0, dtype=np.uint64))

    # ------------------------------------------------------------ table io
    def _table_page_of(self, seq_id: int) -> int:
        return seq_id % self.table_pages

    def _reprogram(self, page: int) -> None:
        self.chips.program_entries(
            page, np.array(self._entries[page], dtype=np.uint64))
        self.stats.programs += 1

    def allocate(self, seq_id: int, logical_block: int) -> int:
        if not self._free:
            raise RuntimeError("KV pool exhausted")
        phys = self._free.pop()
        key = TABLE_CODEC.encode(seq=seq_id, block=logical_block, phys=phys)
        page = self._table_page_of(seq_id)
        if len(self._entries[page]) >= USER_SLOTS:
            raise RuntimeError("block-table page full")
        self._entries[page].append(key)
        self._reprogram(page)
        self._seq_blocks[seq_id] = max(self._seq_blocks.get(seq_id, 0),
                                       logical_block + 1)
        self.stats.pages_allocated += 1
        return phys

    def lookup(self, seq_id: int, logical_block: int) -> int | None:
        """One masked search command -> physical page id."""
        mq_seq = TABLE_CODEC.equals("seq", seq_id)
        mq_blk = TABLE_CODEC.equals("block", logical_block)
        query = mq_seq.query | mq_blk.query
        mask = mq_seq.mask | mq_blk.mask          # phys field = don't care
        page = self._table_page_of(seq_id)
        resp = require_clean(self.chips.search(Command.search(page, query,
                                                              mask)))
        self.stats.searches += 1
        bitmap = mask_header_slots(resp.bitmap_words)
        slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
        slots = slots[slots - 8 < len(self._entries[page])]
        if slots.size == 0:
            return None
        entry = self._entries[page][int(slots[0]) - 8]
        return TABLE_CODEC.decode(entry, "phys")

    def free_sequence(self, seq_id: int) -> int:
        """§V-D partition-style eviction: one masked search isolates every
        entry of the sequence, freed in one sweep."""
        mq = TABLE_CODEC.equals("seq", seq_id)
        page = self._table_page_of(seq_id)
        resp = require_clean(self.chips.search(Command.search(page, mq.query,
                                                              mq.mask)))
        self.stats.searches += 1
        bitmap = mask_header_slots(resp.bitmap_words)
        slots = np.nonzero(unpack_bitmap(bitmap, 512))[0]
        freed = 0
        keep = []
        for key in self._entries[page]:
            if TABLE_CODEC.decode(key, "seq") == seq_id:
                self._free.append(TABLE_CODEC.decode(key, "phys"))
                freed += 1
            else:
                keep.append(key)
        assert freed == int((slots - 8 < len(self._entries[page])).sum())
        self._entries[page] = keep
        self._reprogram(page)
        self._seq_blocks.pop(seq_id, None)
        self.stats.pages_freed += freed
        return freed

    # ----------------------------------------------------------- kv access
    def write_token(self, seq_id: int, position: int, k, v) -> None:
        """k, v: (L, Kh, hd) for one token."""
        block, off = divmod(position, self.page_tokens)
        phys = self.lookup(seq_id, block)
        if phys is None:
            phys = self.allocate(seq_id, block)
        self.pool_k = self.pool_k.at[:, phys, off].set(k)
        self.pool_v = self.pool_v.at[:, phys, off].set(v)

    def gather_sequence(self, seq_id: int, length: int):
        """Contiguous (L, length, Kh, hd) view for attention."""
        n_blocks = -(-length // self.page_tokens)
        phys = [self.lookup(seq_id, b) for b in range(n_blocks)]
        assert all(p is not None for p in phys), "missing KV page"
        k = jnp.concatenate([self.pool_k[:, p] for p in phys], axis=1)
        v = jnp.concatenate([self.pool_v[:, p] for p in phys], axis=1)
        return k[:, :length], v[:, :length]
