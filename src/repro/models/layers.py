"""Core layers: norms, RoPE, GQA attention (train/prefill/decode), SwiGLU.

Functional style: ``init_*`` returns ``(params, axes)`` where ``axes`` is a
matching pytree of logical-axis tuples consumed by parallel/sharding.py.
Layer-stacked weights carry a leading ``layers`` axis and are consumed by
``jax.lax.scan`` so compile time is depth-independent.

dtype policy: parameters in cfg.dtype (bf16 by default); norms, softmax,
router logits and losses in float32.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .config import ModelConfig


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------- init

def _dense_init(key, shape, fan_in: int, dtype):
    """Truncated-normal init scaled by 1/sqrt(fan_in)."""
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def head_pad_mask(cfg: ModelConfig, xp=jnp):
    """(padded_heads,) 1/0 mask — real vs zero-padded q heads, laid out
    per KV group (see ModelConfig.padded_heads)."""
    h, kv, hp = cfg.n_heads, cfg.n_kv_heads, cfg.padded_heads
    g, g_pad = h // kv, hp // kv
    pos = xp.arange(hp) % g_pad
    return (pos < g).astype(xp.float32)


def init_attention(key, cfg: ModelConfig, n_layers: int):
    d, h, k, hd = cfg.d_model, cfg.padded_heads, cfg.n_kv_heads, cfg.head_dim
    dt = pdtype(cfg)
    keys = jax.random.split(key, 4)
    L = (n_layers,)
    mask = head_pad_mask(cfg, jnp).astype(dt)
    params = {
        "wq": _dense_init(keys[0], L + (d, h, hd), d, dt)
        * mask[None, None, :, None],
        "wk": _dense_init(keys[1], L + (d, k, hd), d, dt),
        "wv": _dense_init(keys[2], L + (d, k, hd), d, dt),
        "wo": _dense_init(keys[3], L + (h, hd, d), cfg.n_heads * hd, dt)
        * mask[None, :, None, None],
    }
    axes = {
        "wq": ("layers", "embed", "heads", "head_dim"),
        "wk": ("layers", "embed", "kv_heads", "head_dim"),
        "wv": ("layers", "embed", "kv_heads", "head_dim"),
        "wo": ("layers", "heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((n_layers, hd), dt)
        params["k_norm"] = jnp.ones((n_layers, hd), dt)
        axes["q_norm"] = axes["k_norm"] = ("layers", "head_dim")
    return params, axes


def init_mlp(key, cfg: ModelConfig, n_layers: int, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = pdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "wi_gate": _dense_init(k1, (n_layers, d, f), d, dt),
        "wi_up": _dense_init(k2, (n_layers, d, f), d, dt),
        "wo": _dense_init(k3, (n_layers, f, d), f, dt),
    }
    axes = {
        "wi_gate": ("layers", "embed", "mlp"),
        "wi_up": ("layers", "embed", "mlp"),
        "wo": ("layers", "mlp", "embed"),
    }
    return params, axes


def init_norms(cfg: ModelConfig, n_layers: int, n_norms: int = 2):
    if cfg.nonparametric_norm:
        return {}, {}
    dt = pdtype(cfg)
    params = {f"norm_{i}": jnp.ones((n_layers, cfg.d_model), dt)
              for i in range(n_norms)}
    axes = {f"norm_{i}": ("layers", "embed") for i in range(n_norms)}
    return params, axes


# -------------------------------------------------------------------- norms

def rms_norm(x, weight=None, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(dt)


def layer_norm_nonparametric(x, eps: float = 1e-5):
    """olmo: LN without scale/bias parameters."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def block_norm(x, params, idx: int, cfg: ModelConfig):
    if cfg.nonparametric_norm:
        return layer_norm_nonparametric(x, cfg.norm_eps)
    return rms_norm(x, params[f"norm_{idx}"], cfg.norm_eps)


# --------------------------------------------------------------------- rope

def rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) or (S,) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # (..., S, 1, half): broadcast positions over heads and frequencies
    angles = positions.astype(jnp.float32)[..., None, None] * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention

@dataclasses.dataclass
class KVCache:
    """Contiguous decode cache for one layer stack: (L, B, C, Kh, hd)."""
    k: jax.Array
    v: jax.Array
    length: jax.Array          # scalar int32 — tokens already cached


def _attend(q, k, v, mask_bias, cfg: ModelConfig):
    """q: (B, Sq, H, hd); k/v: (B, Sk, Kh, hd); mask_bias: (B|1, 1, Sq, Sk).

    GQA is evaluated by repeating KV heads up to H *before* the einsums so
    the ``heads`` axis survives intact through every contraction — folding
    q to (B, Sq, Kh, G, hd) instead reshapes the sharded head axis, which
    GSPMD cannot propagate and silently replicates attention over the
    model axis (observed 16x FLOP blow-up in the 256-chip dry run).
    KV stays un-repeated at rest (cache memory unchanged); the repeat is a
    broadcast the compiler fuses into the matmul operand.
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.astype(jnp.float32)
    scores = jnp.einsum("bqhd,bshd->bhqs", qf, k.astype(jnp.float32)) \
        * (hd ** -0.5)
    scores = scores + mask_bias                      # (B,H,Sq,Sk)
    # softmax in f32 (stability), probs stored/multiplied in the param
    # dtype: halves the (B,H,S,S) materialization and runs PV on the MXU
    # bf16 path (§Perf iteration 5).
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return out.astype(q.dtype)


def causal_mask_bias(sq: int, sk: int, window: int | None,
                     q_offset) -> jax.Array:
    """(1, 1, Sq, Sk) additive f32 bias.  ``q_offset`` aligns decode steps:
    absolute query position = q_offset + row."""
    row = q_offset + jnp.arange(sq)[:, None]
    col = jnp.arange(sk)[None, :]
    keep = col <= row
    if window is not None:
        keep &= col > row - window
    return jnp.where(keep, 0.0, -1e30).astype(jnp.float32)[None, None]


def apply_attention(p, x, cfg: ModelConfig, *, positions, mask_bias,
                    kv_cache: tuple[jax.Array, jax.Array] | None = None,
                    cache_index=None, causal: bool = True):
    """One attention layer (single-layer slices of the stacked params).

    Returns (out, (new_k_cache, new_v_cache) | None).
    With a cache: x is the new token(s); k/v are written at cache_index.
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if causal:   # rope only on self-attention (whisper cross-attn skips it)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(
            ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        k, v = ck, cv
        new_cache = (ck, cv)
    out = _attend(q, k, v, mask_bias, cfg)
    if cfg.padded_heads != cfg.n_heads:
        # zero the padded heads' outputs so (a) they contribute nothing and
        # (b) wo's pad rows receive exactly-zero gradients (stay frozen).
        out = out * head_pad_mask(cfg, jnp).astype(out.dtype)[None, None, :,
                                                              None]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache


# -------------------------------------------------------------------- mlp

def apply_mlp(p, x):
    gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
    up = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])
