"""Mixture-of-Experts block: group-limited token-choice routing with
capacity, expert-parallel over the ``model`` mesh axis.

Design (DESIGN.md §5): routing is confined to each sequence (the "group"),
so no token ever crosses the data axis — the only collective the MoE layer
adds beyond dense TP is the combine-side reduction over the expert axis,
which XLA emits as the same all-reduce a dense FFN needs.  Dispatch uses
per-expert top-C token gathers (capacity C = ceil(cf * k * S / E)), i.e.
Switch/GShard-style dropping semantics without the (T, E, C) one-hot blowup.

Covers mixtral (8e top-2) and kimi-k2 (384e top-8 + 1 shared expert).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense_init, pdtype


def capacity(cfg: ModelConfig, seq_len: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * seq_len / cfg.n_experts) + 1
    return max(1, min(c, seq_len))


def init_moe(key, cfg: ModelConfig, n_layers: int):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(key, 5)
    L = (n_layers,)
    params = {
        "router": _dense_init(ks[0], L + (d, e), d, jnp.float32),
        "w_gate": _dense_init(ks[1], L + (e, d, f), d, dt),
        "w_up": _dense_init(ks[2], L + (e, d, f), d, dt),
        "w_down": _dense_init(ks[3], L + (e, f, d), f, dt),
    }
    emlp = "mlp" if cfg.moe_tp else "expert_mlp"
    eax = None if cfg.moe_tp else "expert"
    axes = {
        "router": ("layers", "embed", "expert"),
        "w_gate": ("layers", eax, "embed", emlp),
        "w_up": ("layers", eax, "embed", emlp),
        "w_down": ("layers", eax, emlp, "embed"),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        params["shared_gate"] = _dense_init(ks[4], L + (d, fs), d, dt)
        params["shared_up"] = _dense_init(
            jax.random.fold_in(ks[4], 1), L + (d, fs), d, dt)
        params["shared_down"] = _dense_init(
            jax.random.fold_in(ks[4], 2), L + (fs, d), fs, dt)
        axes["shared_gate"] = ("layers", "embed", "mlp")
        axes["shared_up"] = ("layers", "embed", "mlp")
        axes["shared_down"] = ("layers", "mlp", "embed")
    return params, axes


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D).  Per-sequence group routing."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                   # (B,S,E) f32
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (B,S,K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # token -> expert weight matrix, then per-expert top-C token selection
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)   # (B,S,K,E)
    weights = (gate_vals[..., None] * onehot).sum(axis=2)     # (B,S,E)
    expert_scores = weights.transpose(0, 2, 1)                # (B,E,S)
    top_c_w, top_c_idx = jax.lax.top_k(expert_scores, c)      # (B,E,C)

    # dispatch: gather the chosen tokens per expert
    xg = jnp.take_along_axis(x[:, None], top_c_idx[..., None],
                             axis=2)                          # (B,E,C,D)
    gate = jnp.einsum("becd,edf->becf", xg, p["w_gate"])
    up = jnp.einsum("becd,edf->becf", xg, p["w_up"])
    # silu stays in the param dtype: upcasting to f32 here drags the whole
    # dispatch-gradient chain (and its (B,E,C,D) cross-model all-reduces)
    # into f32 — 2x the collective bytes for no routing benefit (the
    # router, where precision matters, is f32 above).  §Perf iteration 3.
    h = jax.nn.silu(gate) * up
    y = jnp.einsum("becf,efd->becd", h, p["w_down"])          # (B,E,C,D)
    y = y * top_c_w[..., None].astype(y.dtype)                # combine gates

    # Combine: one-hot matmul instead of scatter-add.  GSPMD partitions a
    # scatter over a model-sharded expert dim by replicating the (B,S,D)
    # operand globally and all-reducing it in f32 — observed as 75 % of
    # kimi-k2's collective bytes (§Perf iteration 4).  The one-hot
    # contraction keeps experts local, costs one extra MXU einsum
    # (~2.4e12 FLOPs/layer, ~12 us at peak) and leaves exactly the dense-TP
    # bf16 partial-sum all-reduce of (B,S,D).
    onehot = jax.lax.stop_gradient(
        (top_c_idx[..., None] == jnp.arange(s)[None, None, None]
         ).astype(y.dtype))                                   # (B,E,C,S)
    out = jnp.einsum("becs,becd->bsd", onehot, y)

    if cfg.n_shared_experts:
        sg = jnp.einsum("bsd,df->bsf", x, p["shared_gate"])
        su = jnp.einsum("bsd,df->bsf", x, p["shared_up"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        out = out + jnp.einsum("bsf,fd->bsd", sh, p["shared_down"])

    aux = router_aux_loss(probs, gate_idx, cfg)
    return out, aux


def router_aux_loss(probs, gate_idx, cfg: ModelConfig):
    """Switch-style load-balancing loss (mean over groups)."""
    e = cfg.n_experts
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)   # (B,S,K,E)
    frac_tokens = onehot.sum(axis=2).mean(axis=1)             # (B,E)
    frac_probs = probs.mean(axis=1)                           # (B,E)
    return (e * (frac_tokens * frac_probs).sum(axis=-1)).mean()
