"""Recurrent blocks: Mamba-style selective SSM (hymba heads) and the
xLSTM pair (mLSTM matrix memory + sLSTM scalar memory).

All three expose a sequence form (used by train/prefill: jax.lax.scan over
time) and a single-step form (used by decode: O(1) state update — this is
what makes the ssm/hybrid archs runnable at long_500k where attention KV
would not fit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense_init, pdtype


# =========================================================== selective SSM

def init_mamba(key, cfg: ModelConfig, n_layers: int):
    d, n = cfg.d_model, cfg.ssm_state
    dt = pdtype(cfg)
    ks = jax.random.split(key, 6)
    L = (n_layers,)
    params = {
        "in_proj": _dense_init(ks[0], L + (d, 2 * d), d, dt),
        "conv_w": _dense_init(ks[1], L + (cfg.ssm_conv, d), cfg.ssm_conv, dt),
        "x_proj": _dense_init(ks[2], L + (d, 2 * n + 1), d, dt),
        "a_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
            L + (d, n)).copy(),
        "d_skip": jnp.ones(L + (d,), jnp.float32),
        "out_proj": _dense_init(ks[3], L + (d, d), d, dt),
    }
    axes = {
        "in_proj": ("layers", "embed", "mlp"),
        "conv_w": ("layers", None, "mlp"),
        "x_proj": ("layers", "embed", None),
        "a_log": ("layers", "mlp", None),
        "d_skip": ("layers", "mlp"),
        "out_proj": ("layers", "mlp", "embed"),
    }
    return params, axes


def _mamba_scan(u, delta, a, bmat, cmat, d_skip, h0):
    """u: (B,S,D); delta: (B,S,D); a: (D,N); bmat/cmat: (B,S,N).

    h_t = exp(delta a) h_{t-1} + delta * b_t * u_t ;  y_t = c_t . h_t
    Returns (y (B,S,D), h_final (B,D,N)).
    """
    da = jnp.einsum("bsd,dn->bsdn", delta, a)          # (B,S,D,N)
    decay = jnp.exp(da)
    drive = jnp.einsum("bsd,bsn->bsdn", delta * u, bmat)

    def step(h, inputs):
        dec, drv, c = inputs                           # (B,D,N),(B,D,N),(B,N)
        h = dec * h + drv
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    xs = (decay.transpose(1, 0, 2, 3), drive.transpose(1, 0, 2, 3),
          cmat.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + u * d_skip             # (B,S,D)
    return y, h_final


def apply_mamba(p, x, cfg: ModelConfig, *, state=None, conv_state=None,
                single_step: bool = False):
    """x: (B,S,D).  Returns (y, (ssm_state, conv_state)).

    state: (B, D, N) SSM state; conv_state: (B, K-1, D) conv tail.
    """
    b, s, d = x.shape
    n = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)                   # (B,S,D) each

    kconv = cfg.ssm_conv
    if conv_state is None:
        conv_state = jnp.zeros((b, kconv - 1, d), u.dtype)
    upad = jnp.concatenate([conv_state, u], axis=1)    # (B, S+K-1, D)
    # depthwise causal conv along seq
    u = sum(upad[:, i:i + s] * p["conv_w"][i] for i in range(kconv))
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    new_conv_state = upad[:, -(kconv - 1):] if kconv > 1 else conv_state

    proj = jnp.einsum("bsd,de->bse", u, p["x_proj"]).astype(jnp.float32)
    bmat, cmat, dt_raw = (proj[..., :n], proj[..., n:2 * n],
                          proj[..., 2 * n:])
    delta = jax.nn.softplus(dt_raw)                    # (B,S,1)
    delta = jnp.broadcast_to(delta, (b, s, d))
    a = -jnp.exp(p["a_log"])                           # (D,N), negative

    if state is None:
        state = jnp.zeros((b, d, n), jnp.float32)
    if single_step:
        # one token: closed-form update, no scan
        dec = jnp.exp(jnp.einsum("bd,dn->bdn", delta[:, 0], a))
        drv = jnp.einsum("bd,bn->bdn",
                         (delta[:, 0] * u[:, 0].astype(jnp.float32)),
                         bmat[:, 0])
        state = dec * state + drv
        y = jnp.einsum("bdn,bn->bd", state, cmat[:, 0])[:, None]
        y = y + u.astype(jnp.float32) * p["d_skip"]
    else:
        y, state = _mamba_scan(u.astype(jnp.float32), delta, a, bmat, cmat,
                               p["d_skip"], state)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return out, (state, new_conv_state)


# ================================================================== mLSTM

def init_mlstm(key, cfg: ModelConfig, n_layers: int):
    d, h = cfg.d_model, cfg.mlstm_heads
    dt = pdtype(cfg)
    ks = jax.random.split(key, 3)
    L = (n_layers,)
    params = {
        "wqkv": _dense_init(ks[0], L + (d, 3, h, d // h), d, dt),
        "wgates": _dense_init(ks[1], L + (d, 2, h), d, jnp.float32),
        "wo": _dense_init(ks[2], L + (h, d // h, d), d, dt),
    }
    axes = {
        "wqkv": ("layers", "embed", None, "heads", "head_dim"),
        "wgates": ("layers", "embed", None, "heads"),
        "wo": ("layers", "heads", "head_dim", "embed"),
    }
    return params, axes


def apply_mlstm(p, x, cfg: ModelConfig, *, state=None,
                single_step: bool = False):
    """Stabilized mLSTM (xLSTM §mLSTM).  state = (C, n, m):
    C (B,H,hd,hd) matrix memory, n (B,H,hd) normalizer, m (B,H) stabilizer.
    """
    b, s, d = x.shape
    h = cfg.mlstm_heads
    hd = d // h
    qkv = jnp.einsum("bsd,dthk->btshk", x, p["wqkv"])   # (B,3,S,H,hd)
    q, k, v = qkv[:, 0], qkv[:, 1], qkv[:, 2]
    k = k * (hd ** -0.5)
    gates = jnp.einsum("bsd,dgh->bgsh", x.astype(jnp.float32),
                       p["wgates"])                     # (B,2,S,H)
    i_log, f_log = gates[:, 0], jax.nn.log_sigmoid(gates[:, 1])

    if state is None:
        state = (jnp.zeros((b, h, hd, hd), jnp.float32),
                 jnp.zeros((b, h, hd), jnp.float32),
                 jnp.full((b, h), -1e30, jnp.float32))

    def step(carry, inputs):
        C, n, m = carry
        qt, kt, vt, it, ft = inputs                     # (B,H,hd)...(B,H)
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)[..., None]            # (B,H,1)
        f_s = jnp.exp(ft + m - m_new)[..., None]
        C = f_s[..., None] * C + i_s[..., None] * jnp.einsum(
            "bhv,bhk->bhvk", vt, kt)
        n = f_s * n + i_s * kt
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)[..., None]
        ht = jnp.einsum("bhvk,bhk->bhv", C, qt) / denom
        return (C, n, m_new), ht

    xs = (q.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          i_log.transpose(1, 0, 2), f_log.transpose(1, 0, 2))
    state, hs = jax.lax.scan(step, state, xs)
    out = hs.transpose(1, 0, 2, 3).astype(x.dtype)      # (B,S,H,hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), state


# ================================================================== sLSTM

def init_slstm(key, cfg: ModelConfig, n_layers: int):
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    L = (n_layers,)
    params = {
        "wx": _dense_init(ks[0], L + (d, 4, d), d, jnp.float32),
        "wr": _dense_init(ks[1], L + (d, 4, d), d, jnp.float32),
    }
    axes = {"wx": ("layers", "embed", None, "mlp"),
            "wr": ("layers", "embed", None, "mlp")}
    return params, axes


def apply_slstm(p, x, cfg: ModelConfig, *, state=None):
    """sLSTM with exponential input gate and normalizer state.

    state = (c, n, h, m): each (B, D) f32.  Sequential by construction
    (the recurrent R connection is the whole point of sLSTM).
    """
    b, s, d = x.shape
    gx = jnp.einsum("bsd,dge->bsge", x.astype(jnp.float32), p["wx"])
    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        state = (z, z, z, jnp.full((b, d), -1e30, jnp.float32))

    def step(carry, gxt):
        c, n, h, m = carry
        gr = jnp.einsum("bd,dge->bge", h, p["wr"])
        g = gxt + gr                                     # (B,4,D)
        i_log, f_raw, z_raw, o_raw = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        f_log = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(f_log + m, i_log)
        i_s = jnp.exp(i_log - m_new)
        f_s = jnp.exp(f_log + m - m_new)
        c = f_s * c + i_s * jnp.tanh(z_raw)
        n = f_s * n + i_s
        h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    state, hs = jax.lax.scan(step, state, gx.transpose(1, 0, 2, 3))
    return hs.transpose(1, 0, 2).astype(x.dtype), state
