"""Architecture configuration — one frozen dataclass drives every family.

Families: dense (granite/qwen3/olmo/starcoder2), moe (kimi/mixtral),
ssm (xlstm), hybrid (hymba), vlm (internvl — vision stub + LM backbone),
audio (whisper — conv-frontend stub + enc-dec).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None       # tokens; None = full attention
    global_attn_every: int = 0              # hybrid: every k-th layer global
    nonparametric_norm: bool = False        # olmo-style LN without params
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: int = 0                       # per-expert hidden (kimi 2048)
    n_shared_experts: int = 0               # kimi-style always-on experts
    # Expert-TP: shard each expert's FFN hidden dim over the model axis
    # instead of sharding the expert dim.  Required when n_experts does not
    # divide the model-axis size (mixtral: 8 experts on a 16-way axis would
    # otherwise replicate every expert onto every chip — observed 16x FLOP
    # blow-up, §Perf iteration 1).
    moe_tp: bool = False

    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    slstm_every: int = 0                    # xlstm: every k-th block sLSTM
    mlstm_heads: int = 4

    # encoder-decoder / multimodal
    encoder_layers: int = 0
    encoder_seq: int = 0                    # frontend-stub sequence length
    cross_attention: bool = False
    frontend: str | None = None             # audio_stub | vision_stub
    frontend_tokens: int = 0                # prefix tokens from the stub

    # numerics / training
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: str = "block"                    # none | block | full
    optimizer_dtype: str = "float32"        # adam moment dtype
    fsdp: bool = True                       # shard weights over data axis

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def padded_vocab(self) -> int:
        """Embedding/head vocab dim padded to a multiple of 256 so the
        vocab axis shards over the 16-way model axis (and hits MXU-friendly
        tile sizes).  Unpadded odd vocabs (granite 49155, internvl 92553,
        whisper 51865, hymba 32001) otherwise replicate the largest matmul
        in the model onto every chip (§Perf iteration 6).  Pad logits are
        masked to -inf in the head, so semantics are unchanged."""
        return ((self.vocab_size + 255) // 256) * 256

    # Target tensor-parallel width the padding helpers align to (the
    # production mesh's model axis).
    TP_WIDTH = 16

    @property
    def padded_heads(self) -> int:
        """Query heads zero-padded *per KV group* so the head axis shards
        over the model axis (starcoder2's 36 heads otherwise replicate
        attention onto every chip — §Perf iteration 8).  Padding preserves
        the GQA q-head -> kv-head mapping (each group pads from g to g_pad),
        and padded heads have zero wq/wo so the output is bit-identical.
        Capped at 1.5x overhead: archs where alignment would cost more
        (hymba: 25 heads / 5 kv would need 80) stay unpadded and are
        recorded as replicated dims in the dry-run report instead."""
        h, kv = self.n_heads, self.n_kv_heads
        if h % self.TP_WIDTH == 0 or kv == 0:
            return h
        g = h // kv
        g_pad = g
        while (kv * g_pad) % self.TP_WIDTH != 0:
            g_pad += 1
        h_pad = kv * g_pad
        return h_pad if h_pad <= 1.5 * h else h

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence scaling: SSM state or sliding window."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    @property
    def has_decode_step(self) -> bool:
        return True     # all assigned archs are decoder-bearing

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size                  # head
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        for _ in range(self.n_layers):
            n += attn
            if self.is_moe:
                n += d * self.n_experts               # router
                n += self.n_experts * 3 * d * self.expert_d_ff
                n += self.n_shared_experts * 3 * d * self.expert_d_ff
            elif self.family == "ssm":
                pass                                  # handled below
            if self.d_ff and self.family != "ssm" and not self.is_moe:
                n += 3 * d * self.d_ff                # swiglu
            n += 2 * d                                # norms
        if self.family == "ssm":
            n += self.n_layers * (8 * d * d // 4)     # lstm proj approx
        if self.encoder_layers:
            n += self.encoder_layers * (attn + 3 * d * self.d_ff + 2 * d)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * d * self.expert_d_ff
        active = self.n_layers * (self.top_k + self.n_shared_experts) \
            * 3 * d * self.expert_d_ff
        return total - all_experts + active


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One (shape-id x mode) cell of the assignment."""
    name: str                       # train_4k | prefill_32k | ...
    mode: str                       # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}
