"""Model assembly: init + forward for every assigned architecture family.

All stacks scan over layers with stacked weights (compile time is
depth-independent), remat-wrapped per cfg.remat.  Forward modes:

  train_logits(params, cfg, tokens, ...)          -> logits (B,S,V), aux
  prefill(params, cfg, tokens, cache_len)         -> logits_last, caches
  decode_step(params, cfg, token, caches, index)  -> logits, caches

Caches are family-appropriate: (k, v) stacks for attention layers,
(ssm_state, conv_state) for mamba heads, (C, n, m) for mLSTM, etc.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_attention, apply_mlp, block_norm,
                     causal_mask_bias, init_attention, init_mlp, init_norms,
                     pdtype, rms_norm, _dense_init)
from .moe import apply_moe, init_moe
from .ssm import (apply_mamba, apply_mlstm, apply_slstm, init_mamba,
                  init_mlstm, init_slstm)


# ================================================================= init

def init_model(key, cfg: ModelConfig):
    ks = jax.random.split(key, 12)
    dt = pdtype(cfg)
    params: dict = {}
    axes: dict = {}

    params["embed"] = _dense_init(ks[0], (cfg.padded_vocab, cfg.d_model),
                                  cfg.d_model, dt)
    axes["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings:
        params["head"] = _dense_init(ks[1], (cfg.d_model, cfg.padded_vocab),
                                     cfg.d_model, dt)
        axes["head"] = ("embed", "vocab")
    if not cfg.nonparametric_norm:
        params["final_norm"] = jnp.ones((cfg.d_model,), dt)
        axes["final_norm"] = ("embed",)

    L = cfg.n_layers
    if cfg.family == "ssm":
        # xlstm: pattern of (slstm_every-1) mLSTM + 1 sLSTM per repetition
        rep = cfg.slstm_every or L
        assert L % rep == 0
        n_rep = L // rep
        mp, ma = init_mlstm(ks[2], cfg, n_rep * (rep - 1)) \
            if rep > 1 else ({}, {})
        if rep > 1:
            mp = jax.tree.map(
                lambda a: a.reshape((n_rep, rep - 1) + a.shape[1:]), mp)
            ma = {k: ("repeat",) + v for k, v in ma.items()}
        sp, sa = init_slstm(ks[3], cfg, n_rep)
        sa = {k: ("repeat",) + v[1:] for k, v in sa.items()}
        np_, na = init_norms(cfg, n_rep * rep)
        np_ = jax.tree.map(
            lambda a: a.reshape((n_rep, rep) + a.shape[1:]), np_)
        na = {k: ("repeat",) + v for k, v in na.items()}
        params["blocks"] = {"mlstm": mp, "slstm": sp, "norms": np_}
        axes["blocks"] = {"mlstm": ma, "slstm": sa, "norms": na}
        return params, axes

    ap, aa = init_attention(ks[2], cfg, L)
    np_, na = init_norms(cfg, L)
    blocks = {"attn": ap, "norms": np_}
    baxes = {"attn": aa, "norms": na}
    if cfg.family == "hybrid":
        mp, ma = init_mamba(ks[3], cfg, L)
        blocks["mamba"] = mp
        baxes["mamba"] = ma
    if cfg.is_moe:
        ep, ea = init_moe(ks[4], cfg, L)
        blocks["moe"] = ep
        baxes["moe"] = ea
    else:
        fp, fa = init_mlp(ks[5], cfg, L)
        blocks["mlp"] = fp
        baxes["mlp"] = fa
    params["blocks"] = blocks
    axes["blocks"] = baxes

    if cfg.encoder_layers:       # whisper encoder + cross-attention stacks
        eap, eaa = init_attention(ks[6], cfg, cfg.encoder_layers)
        efp, efa = init_mlp(ks[7], cfg, cfg.encoder_layers)
        enp, ena = init_norms(cfg, cfg.encoder_layers)
        params["encoder"] = {"attn": eap, "mlp": efp, "norms": enp}
        axes["encoder"] = {"attn": eaa, "mlp": efa, "norms": ena}
        cap, caa = init_attention(ks[8], cfg, L)
        cnp, cna = init_norms(cfg, L, n_norms=1)
        params["cross"] = {"attn": cap, "norms": cnp}
        axes["cross"] = {"attn": caa, "norms": cna}
    if cfg.frontend is not None:
        params["frontend_proj"] = _dense_init(
            ks[9], (cfg.d_model, cfg.d_model), cfg.d_model, dt)
        axes["frontend_proj"] = ("embed", "embed")
    return params, axes


# ============================================================ body helpers

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _dense_block(bp, x, cfg: ModelConfig, *, positions, mask_bias,
                 kv_cache=None, cache_index=None, mamba_state=None,
                 single_step=False, enc_out=None, cross_p=None):
    """One decoder block (attention [+mamba] + mlp/moe).  Generic across
    dense/moe/hybrid/vlm/audio-decoder families."""
    aux = jnp.float32(0.0)
    h = block_norm(x, bp["norms"], 0, cfg)
    attn_out, new_kv = apply_attention(
        bp["attn"], h, cfg, positions=positions, mask_bias=mask_bias,
        kv_cache=kv_cache, cache_index=cache_index)
    new_mamba = None
    if cfg.family == "hybrid":
        state, conv_state = mamba_state if mamba_state is not None \
            else (None, None)
        m_out, new_mamba = apply_mamba(bp["mamba"], h, cfg, state=state,
                                       conv_state=conv_state,
                                       single_step=single_step)
        # hymba: parallel attention + mamba heads, outputs averaged after
        # per-branch normalization
        attn_out = 0.5 * (rms_norm(attn_out, eps=cfg.norm_eps)
                          + rms_norm(m_out, eps=cfg.norm_eps))
    x = x + attn_out
    if cross_p is not None:     # whisper decoder cross-attention
        h = block_norm(x, cross_p["norms"], 0, cfg)
        # cross attention: kv from encoder output, non-causal, no rope
        b, sq = h.shape[0], h.shape[1]
        sk = enc_out.shape[1]
        zero_bias = jnp.zeros((1, 1, sq, sk), jnp.float32)
        kq = jnp.einsum("bsd,dhk->bshk", h, cross_p["attn"]["wq"])
        kk = jnp.einsum("bsd,dhk->bshk", enc_out, cross_p["attn"]["wk"])
        kv = jnp.einsum("bsd,dhk->bshk", enc_out, cross_p["attn"]["wv"])
        from .layers import _attend
        c_out = _attend(kq, kk, kv, zero_bias, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", c_out, cross_p["attn"]["wo"])
    h = block_norm(x, bp["norms"], 1, cfg)
    if cfg.is_moe:
        ff, aux = apply_moe(bp["moe"], h, cfg)
    else:
        ff = apply_mlp(bp["mlp"], h)
    return x + ff, aux, new_kv, new_mamba


def _window_for_layer(cfg: ModelConfig, layer_flag):
    """hybrid/moe archs with sliding windows: layer_flag==1 -> global."""
    return cfg.sliding_window


# ============================================================== embeddings

def embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens]                       # (B,S,D) gather
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def _prepend_frontend(params, cfg: ModelConfig, x, frontend_embeds):
    """vlm: project stub patch embeddings and prepend to the text tokens."""
    fe = jnp.einsum("bsd,de->bse", frontend_embeds.astype(x.dtype),
                    params["frontend_proj"])
    return jnp.concatenate([fe, x[:, : x.shape[1] - fe.shape[1]]], axis=1)


# ============================================================== train mode

def _constrain_tree(tree, specs):
    """FSDP weight-gather: constrain scanned weight slices to their
    compute shardings (parallel/sharding.block_compute_shardings)."""
    if specs is None:
        return tree
    return jax.tree.map(jax.lax.with_sharding_constraint, tree, specs)


def _c(x, spec):
    """Activation sharding constraint (None = let GSPMD propagate).

    Pinning (B, S, D) activations to batch-over-data at block boundaries is
    load-bearing: the embedding gather otherwise inherits the table's
    d-over-data (FSDP) sharding and GSPMD silently replicates the batch —
    a 16x FLOP blow-up observed in the 256-chip dry run.
    """
    return x if spec is None else jax.lax.with_sharding_constraint(x, spec)


def train_logits(params, cfg: ModelConfig, tokens, *,
                 frontend_embeds=None, block_specs=None, act_spec=None):
    """tokens (B,S) -> (logits (B,S,V), aux_loss)."""
    b, s = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm" and frontend_embeds is not None:
        x = _prepend_frontend(params, cfg, x, frontend_embeds)
    x = _c(x, act_spec)
    positions = jnp.arange(s)[None, :]
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(params, cfg, frontend_embeds)

    if cfg.family == "ssm":
        x = _run_xlstm(params, cfg, x)
        aux = jnp.float32(0.0)
    else:
        mask_full = causal_mask_bias(s, s, None, 0)
        mask_sw = causal_mask_bias(s, s, cfg.sliding_window, 0) \
            if cfg.sliding_window else mask_full
        layer_ids = jnp.arange(cfg.n_layers)

        def body(carry, scanned):
            xc, aux_acc = carry
            xc = _c(xc, act_spec)
            bp, cp, lid = scanned
            bp = _constrain_tree(bp, block_specs)
            if cfg.sliding_window and cfg.global_attn_every:
                is_global = (lid % cfg.global_attn_every) == 0
                bias = jnp.where(is_global, mask_full, mask_sw)
            elif cfg.sliding_window:
                bias = mask_sw
            else:
                bias = mask_full
            xc, aux, _, _ = _dense_block(
                bp, xc, cfg, positions=positions, mask_bias=bias,
                enc_out=enc_out, cross_p=cp)
            return (xc, aux_acc + aux), None

        body = _maybe_remat(body, cfg)
        cross = params.get("cross")
        scanned = (params["blocks"], cross, layer_ids) if cross is not None \
            else (params["blocks"], None, layer_ids)
        if cross is None:
            (x, aux), _ = jax.lax.scan(
                lambda c, sc: body(c, (sc[0], None, sc[1])),
                (x, jnp.float32(0.0)), (params["blocks"], layer_ids))
        else:
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.float32(0.0)), scanned)

    logits = _final_logits(params, cfg, _c(x, act_spec))
    return logits, aux


def _final_logits(params, cfg: ModelConfig, x):
    if cfg.nonparametric_norm:
        from .layers import layer_norm_nonparametric
        x = layer_norm_nonparametric(x, cfg.norm_eps)
    else:
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:      # mask pad columns
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def _run_encoder(params, cfg: ModelConfig, frontend_embeds):
    """whisper encoder: non-causal self-attention over stub features."""
    enc = params["encoder"]
    x = frontend_embeds.astype(pdtype(cfg))
    if "frontend_proj" in params:
        x = jnp.einsum("bsd,de->bse", x, params["frontend_proj"])
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    zero_bias = jnp.zeros((1, 1, s, s), jnp.float32)

    def body(xc, bp):
        h = block_norm(xc, bp["norms"], 0, cfg)
        a, _ = apply_attention(bp["attn"], h, cfg, positions=positions,
                               mask_bias=zero_bias)
        xc = xc + a
        h = block_norm(xc, bp["norms"], 1, cfg)
        return xc + apply_mlp(bp["mlp"], h), None

    body = _maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, enc)
    return x


def _run_xlstm(params, cfg: ModelConfig, x, states=None,
               single_step: bool = False):
    """xlstm pattern scan: (slstm_every-1) mLSTM blocks + 1 sLSTM block per
    repetition.  states (decode): pytree matching the scan structure."""
    blocks = params["blocks"]
    rep = cfg.slstm_every or cfg.n_layers
    b = x.shape[0]
    h_heads, d = cfg.mlstm_heads, cfg.d_model
    hd = d // h_heads
    n_rep = cfg.n_layers // rep

    if states is None:
        m_state0 = (jnp.zeros((n_rep, rep - 1, b, h_heads, hd, hd),
                              jnp.float32),
                    jnp.zeros((n_rep, rep - 1, b, h_heads, hd), jnp.float32),
                    jnp.full((n_rep, rep - 1, b, h_heads), -1e30,
                             jnp.float32))
        z = jnp.zeros((n_rep, b, d), jnp.float32)
        s_state0 = (z, z, z, jnp.full((n_rep, b, d), -1e30, jnp.float32))
    else:
        m_state0, s_state0 = states

    def body(xc, scanned):
        mp, sp, norms, mst, sst = scanned
        new_mst, new_sst = [], None
        for i in range(rep - 1):
            bp = jax.tree.map(lambda a, i=i: a[i], mp)
            st = jax.tree.map(lambda a, i=i: a[i], mst)
            h = rms_norm(xc, norms["norm_0"][i], cfg.norm_eps)
            out, st_new = apply_mlstm(bp, h, cfg, state=st,
                                      single_step=single_step)
            xc = xc + out              # xLSTM blocks carry no separate FFN
            new_mst.append(st_new)
        h = rms_norm(xc, norms["norm_0"][rep - 1], cfg.norm_eps)
        out, new_sst = apply_slstm(sp, h, cfg, state=sst)
        xc = xc + out
        mst_out = jax.tree.map(lambda *xs: jnp.stack(xs), *new_mst) \
            if new_mst else mst
        return xc, (mst_out, new_sst)

    body = _maybe_remat(body, cfg)
    x, new_states = jax.lax.scan(
        body, x, (blocks["mlstm"], blocks["slstm"], blocks["norms"],
                  m_state0, s_state0))
    return (x, new_states) if states is not None or single_step else x


# ======================================================== prefill / decode

def make_caches(cfg: ModelConfig, batch: int, cache_len: int):
    """Allocate decode caches for the whole layer stack.

    dense/moe/vlm/audio: (k, v) of (L, B, C, Kh, hd).
    hybrid: kv + per-layer (ssm_state, conv_state).
    ssm: xlstm scan-structured recurrent states, no KV at all.
    """
    dt = pdtype(cfg)
    b = batch
    if cfg.family == "ssm":
        rep = cfg.slstm_every or cfg.n_layers
        n_rep = cfg.n_layers // rep
        h, d = cfg.mlstm_heads, cfg.d_model
        hd = d // h
        m_state = (jnp.zeros((n_rep, rep - 1, b, h, hd, hd), jnp.float32),
                   jnp.zeros((n_rep, rep - 1, b, h, hd), jnp.float32),
                   jnp.full((n_rep, rep - 1, b, h), -1e30, jnp.float32))
        z = jnp.zeros((n_rep, b, d), jnp.float32)
        s_state = (z, z, z, jnp.full((n_rep, b, d), -1e30, jnp.float32))
        return {"states": (m_state, s_state)}
    c = cache_len if cfg.sliding_window is None \
        else min(cache_len, cfg.sliding_window)
    kv = (jnp.zeros((cfg.n_layers, b, c, cfg.n_kv_heads, cfg.head_dim), dt),
          jnp.zeros((cfg.n_layers, b, c, cfg.n_kv_heads, cfg.head_dim), dt))
    caches = {"kv": kv}
    if cfg.family == "hybrid":
        caches["mamba"] = (
            jnp.zeros((cfg.n_layers, b, cfg.d_model, cfg.ssm_state),
                      jnp.float32),
            jnp.zeros((cfg.n_layers, b, cfg.ssm_conv - 1, cfg.d_model), dt))
    return caches


def decode_step(params, cfg: ModelConfig, token, caches, index, *,
                enc_out=None, block_specs=None, act_spec=None):
    """One decode step: token (B, 1) int32, index = absolute position
    (also the cache write slot; for sliding-window caches the wrapper maps
    absolute position -> ring slot before calling).

    Returns (logits (B, V), new_caches).
    """
    x = _c(embed_tokens(params, cfg, token), act_spec)

    if cfg.family == "ssm":
        x, new_states = _run_xlstm(params, cfg, x, states=caches["states"],
                                   single_step=True)
        logits = _final_logits(params, cfg, x)
        return logits[:, 0], {"states": new_states}

    positions = jnp.full((1, 1), index, jnp.int32)
    ck, cv = caches["kv"]
    c = ck.shape[2]
    # ring slot for sliding-window caches; plain slot otherwise
    slot = index % c if cfg.sliding_window is not None else index
    mask = _decode_mask_bias(cfg, c, index)

    mamba = caches.get("mamba")

    def body(carry, scanned):
        xc, aux_acc = carry
        xc = _c(xc, act_spec)
        if cfg.family == "hybrid":
            bp, cp, k_l, v_l, ms_l, mc_l = scanned
            mstate = (ms_l, mc_l)
        else:
            bp, cp, k_l, v_l = scanned
            mstate = None
        bp = _constrain_tree(bp, block_specs)
        xc, aux, new_kv, new_m = _dense_block(
            bp, xc, cfg, positions=positions, mask_bias=mask,
            kv_cache=(k_l, v_l), cache_index=slot, mamba_state=mstate,
            single_step=True, enc_out=enc_out, cross_p=cp)
        ys = (new_kv[0], new_kv[1]) + ((new_m[0], new_m[1])
                                       if new_m is not None else ())
        return (xc, aux_acc + aux), ys

    cross = params.get("cross")
    if cfg.family == "hybrid":
        scanned = (params["blocks"], cross, ck, cv, mamba[0], mamba[1]) \
            if cross is not None else \
            (params["blocks"], None, ck, cv, mamba[0], mamba[1])
    else:
        scanned = (params["blocks"], cross, ck, cv) if cross is not None \
            else (params["blocks"], None, ck, cv)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.float32(0.0)), scanned)

    new_caches = dict(caches)
    new_caches["kv"] = (ys[0], ys[1])
    if cfg.family == "hybrid":
        new_caches["mamba"] = (ys[2], ys[3])
    logits = _final_logits(params, cfg, x)
    return logits[:, 0], new_caches


def _decode_mask_bias(cfg: ModelConfig, cache_len: int, index):
    """(1,1,1,C) bias over the cache for one new token at absolute
    ``index``.  Contiguous cache: allow slots <= index.  Ring cache
    (sliding window): every resident slot is within the window by
    construction; mask only slots not yet written (index < window)."""
    col = jnp.arange(cache_len)[None, None, None, :]
    if cfg.sliding_window is None:
        keep = col <= index
    else:
        keep = col <= jnp.minimum(index, cache_len - 1)
    return jnp.where(keep, 0.0, -1e30).astype(jnp.float32)


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, *,
            frontend_embeds=None, block_specs=None, act_spec=None):
    """Run the full prompt, return (last-position logits, filled caches).

    The dry-run's prefill_32k cell lowers this.  Cache fill is done by
    running train-mode attention and writing k/v per layer — implemented by
    scanning with per-layer cache writes.
    """
    b, s = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm" and frontend_embeds is not None:
        x = _prepend_frontend(params, cfg, x, frontend_embeds)
    x = _c(x, act_spec)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(params, cfg, frontend_embeds)

    if cfg.family == "ssm":
        x, new_states = _run_xlstm(
            params, cfg, x,
            states=make_caches(cfg, b, cache_len)["states"])
        logits = _final_logits(params, cfg, x[:, -1:])
        return logits[:, 0], {"states": new_states}

    caches = make_caches(cfg, b, cache_len)
    ck, cv = caches["kv"]
    c = ck.shape[2]
    positions = jnp.arange(s)[None, :]
    mask_sw = causal_mask_bias(s, s, cfg.sliding_window, 0)
    mask_global = causal_mask_bias(s, s, None, 0)
    mamba = caches.get("mamba")
    layer_ids = jnp.arange(cfg.n_layers)

    def body(carry, scanned):
        xc, aux_acc = carry
        xc = _c(xc, act_spec)
        if cfg.family == "hybrid":
            bp, cp, lid, k_l, v_l, ms_l, mc_l = scanned
            mstate = (ms_l, mc_l)
        else:
            bp, cp, lid, k_l, v_l = scanned
            mstate = None
        bp = _constrain_tree(bp, block_specs)
        if cfg.sliding_window and cfg.global_attn_every:
            bias = jnp.where((lid % cfg.global_attn_every) == 0,
                             mask_global, mask_sw)
        else:
            bias = mask_sw
        # Cache fill from the block INPUT (the same normed h the attention
        # projections consume), last C positions.
        h_in = block_norm(xc, bp["norms"], 0, cfg)
        tail = h_in[:, -c:] if s >= c else h_in
        kh = jnp.einsum("bsd,dhk->bshk", tail, bp["attn"]["wk"])
        vh = jnp.einsum("bsd,dhk->bshk", tail, bp["attn"]["wv"])
        if cfg.qk_norm:
            kh = rms_norm(kh, bp["attn"]["k_norm"], cfg.norm_eps)
        tail_pos = positions[:, -c:] if s >= c else positions
        kh = _rope_cache(kh, tail_pos, cfg)
        if cfg.sliding_window is not None and s >= c:
            # ring-cache invariant: position p lives in slot p % c
            shift = (s - c) % c
            kh = jnp.roll(kh, shift, axis=1)
            vh = jnp.roll(vh, shift, axis=1)
        k_new = jax.lax.dynamic_update_slice(
            k_l, kh.astype(k_l.dtype), (0, 0, 0, 0))
        v_new = jax.lax.dynamic_update_slice(
            v_l, vh.astype(v_l.dtype), (0, 0, 0, 0))
        xc, aux, _, new_m = _dense_block(
            bp, xc, cfg, positions=positions, mask_bias=bias,
            mamba_state=mstate, enc_out=enc_out, cross_p=cp)
        ys = (k_new, v_new) + ((new_m[0], new_m[1])
                               if new_m is not None else ())
        return (xc, aux_acc + aux), ys

    cross = params.get("cross")
    if cfg.family == "hybrid":
        scanned = (params["blocks"], cross, layer_ids, ck, cv,
                   mamba[0], mamba[1]) if cross is not None else \
            (params["blocks"], None, layer_ids, ck, cv, mamba[0], mamba[1])
    else:
        scanned = (params["blocks"], cross, layer_ids, ck, cv) \
            if cross is not None \
            else (params["blocks"], None, layer_ids, ck, cv)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.float32(0.0)), scanned)
    caches = dict(caches)
    caches["kv"] = (ys[0], ys[1])
    if cfg.family == "hybrid":
        caches["mamba"] = (ys[2], ys[3])
    if cfg.encoder_layers:
        caches["enc_out"] = enc_out
    logits = _final_logits(params, cfg, x[:, -1:])
    return logits[:, 0], caches


def _rope_cache(k, positions, cfg: ModelConfig):
    from .layers import rope
    return rope(k, positions, cfg.rope_theta)

