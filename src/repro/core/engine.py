"""Functional model of one SiM chip (paper §III, §IV-B).

Semantics only — time and energy live in flash/ssd.py.  The model is
bit-exact about everything the paper's circuit does:

  * pages are stored *randomized* (per-chunk streams, §IV-C1);
  * `page_open` senses the array into Latch 1 and ships header+chunk0 to the
    controller for the Optimistic-Error-Correction check (§IV-C2);
  * `page_close` rotates L1 -> L2, freeing the array for the next sense
    (the latch pipeline that lets sensing overlap matching);
  * `search` broadcasts a randomized query into Latch 4, XORs against L2 into
    Latch 3, and the FBC per-64-bitline group reduction yields the 512-bit
    match bitmap (here: an exact OR-reduce; see DESIGN.md §2 note 1);
  * `gather` selects chunks through the column decoder and de-randomizes +
    inner-code-verifies them on the controller side.

Bit errors are injected into the *stored* (randomized) image so every
integrity mechanism is exercised for real: header CRC catches chunk-0 damage,
inner CRCs catch chunk damage, and matching on a damaged page can genuinely
return wrong bitmaps when the optimistic check misses body-only errors —
exactly the risk the paper's sampling argument accepts (§IV-C2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import ecc
from .bits import CHUNK_BYTES, CHUNKS_PER_PAGE, PAGE_BYTES, unpack_bitmap
from .commands import (Command, GatherResponse, Op, ReadFullResponse,
                       SearchResponse)
from .ecc import EccConfig, OpenVerdict, optimistic_open
from .page import BuiltPage, build_page, page_slot_words
from .randomize import chunk_stream_words, randomize_query, stream_words


@dataclasses.dataclass
class StoredPage:
    raw: np.ndarray                # randomized on-flash image, (4096,) uint8
    chunk_parities: np.ndarray     # (64,) uint32 (out-of-band)
    timestamp_ns: int
    injected_error_bits: int = 0
    n_entries: int = 0
    # Simulator-only ground truth: the error-free image.  A t-error-
    # correcting outer code deterministically recovers it when the raw
    # bit-error count is <= t; storing it is how ECC simulators realize that
    # recovery without implementing BCH decoding.
    clean_raw: np.ndarray | None = None


@dataclasses.dataclass
class ChipCounters:
    array_reads: int = 0           # NAND sense operations
    searches: int = 0
    gathers: int = 0
    chunks_gathered: int = 0
    programs: int = 0
    full_reads: int = 0
    open_fallbacks: int = 0
    open_refreshes: int = 0
    pipelined_opens: int = 0       # opens whose sense overlapped matching


class SimChip:
    """One flash chip with match-mode (SLC) pages."""

    def __init__(self, n_pages: int, device_seed: int = 0,
                 ecc_cfg: EccConfig | None = None):
        self.n_pages = n_pages
        self.device_seed = device_seed
        self.ecc_cfg = ecc_cfg or EccConfig()
        self.pages: dict[int, StoredPage] = {}
        self.counters = ChipCounters()
        # Write-path observers: called with the local page address whenever a
        # stored image mutates (program, bit-error injection, ECC repair).
        # Backends that mirror pages off-host (the device-resident plane
        # store) subscribe here to invalidate exactly the dirty row.
        self.observers: list = []
        # Latch pipeline state: addresses currently held in L1 / L2.
        self._l1_addr: int | None = None
        self._l2_addr: int | None = None
        self._rng = np.random.default_rng(device_seed ^ 0xD1CE)

    def _notify(self, page_addr: int) -> None:
        for fn in self.observers:
            fn(page_addr)

    # ------------------------------------------------------------------ I/O
    def program_entries(self, page_addr: int, entries: np.ndarray, *,
                        timestamp_ns: int = 0,
                        header_user: np.ndarray | None = None) -> BuiltPage:
        if not (0 <= page_addr < self.n_pages):
            raise IndexError(page_addr)
        built = build_page(entries, page_addr, timestamp_ns=timestamp_ns,
                           header_user=header_user,
                           device_seed=self.device_seed)
        self.pages[page_addr] = StoredPage(
            raw=built.raw.copy(), chunk_parities=built.chunk_parities,
            timestamp_ns=timestamp_ns, n_entries=built.n_entries,
            clean_raw=built.raw.copy())
        self.counters.programs += 1
        self._notify(page_addr)
        return built

    def inject_bit_errors(self, page_addr: int, n_bits: int,
                          rng: np.random.Generator | None = None,
                          byte_region: tuple[int, int] | None = None) -> None:
        """Flip n random bits in the stored image (retention/read-disturb).

        ``byte_region=(start, stop)`` confines the flips — tests use
        (0, 64) to hit the verification-header chunk deterministically and
        (64, 4096) to model the body-only damage the optimistic check is
        blind to (the acknowledged risk of §IV-C2).
        """
        rng = rng or self._rng
        sp = self.pages[page_addr]
        lo, hi = byte_region or (0, PAGE_BYTES)
        bit_idx = lo * 8 + rng.choice((hi - lo) * 8, size=n_bits,
                                      replace=False)
        bytes_idx, bit_in_byte = bit_idx // 8, bit_idx % 8
        np.bitwise_xor.at(sp.raw, bytes_idx,
                          (1 << bit_in_byte).astype(np.uint8))
        sp.injected_error_bits += int(n_bits)
        self._notify(page_addr)

    # ------------------------------------------------------------ commands
    def page_open(self, page_addr: int, *, now_ns: int = 0):
        """Sense into L1 and run the optimistic header check.

        Returns (OpenResult, pipelined: bool).  ``pipelined`` is True when L2
        still held the previous page, i.e. this sense overlapped matching.
        """
        sp = self._get(page_addr)
        pipelined = self._l2_addr is not None and self._l1_addr is None
        self.counters.array_reads += 1
        if pipelined:
            self.counters.pipelined_opens += 1
        self._l1_addr = page_addr

        header_plain = self._derandomized_chunk(sp, page_addr, 0)
        result = optimistic_open(
            header_plain, now_ns=now_ns,
            injected_error_bits=sp.injected_error_bits,
            cfg=self.ecc_cfg, rng=self._rng)
        if result.verdict in (OpenVerdict.FALLBACK_ECC,
                              OpenVerdict.UNCORRECTABLE):
            self.counters.open_fallbacks += 1
            if result.verdict is OpenVerdict.FALLBACK_ECC:
                # Outer decode repaired the stored image.
                self._repair(sp, page_addr)
        elif result.verdict is OpenVerdict.CLEAN_NEEDS_REFRESH:
            self.counters.open_refreshes += 1
        return result, pipelined

    def page_close(self, page_addr: int) -> None:
        if self._l1_addr != page_addr:
            raise RuntimeError(f"page {page_addr} is not in L1")
        self._l2_addr, self._l1_addr = page_addr, None

    def search(self, cmd: Command) -> SearchResponse:
        """Execute a search against the page currently latched in L2."""
        if cmd.op is not Op.SEARCH:
            raise ValueError(cmd.op)
        if self._l2_addr != cmd.page_addr:
            # Implicit open/close for convenience paths (engine-level only;
            # the SSD scheduler always issues opens explicitly).
            result, _ = self.page_open(cmd.page_addr)
            self.page_close(cmd.page_addr)
            verdict = result.verdict.value
        else:
            verdict = OpenVerdict.CLEAN.value
        sp = self.pages[cmd.page_addr]
        words = page_slot_words(sp.raw)
        # Deserializer randomizes the query with the page's stream (§IV-C1):
        q = randomize_query(np.array(cmd.query, dtype=np.uint32),
                            cmd.page_addr, self.device_seed)
        mask = np.array(cmd.mask, dtype=np.uint32)
        mismatch = ((words[:, 0] ^ q[:, 0]) & mask[0]) | (
            (words[:, 1] ^ q[:, 1]) & mask[1])
        bits = (mismatch == 0).astype(np.uint32)
        from .bits import pack_bitmap
        bitmap = pack_bitmap(bits)
        self.counters.searches += 1
        return SearchResponse(bitmap_words=bitmap,
                              match_count=int(bits.sum()),
                              open_verdict=verdict)

    def gather(self, cmd: Command) -> GatherResponse:
        if cmd.op is not Op.GATHER:
            raise ValueError(cmd.op)
        sp = self._get(cmd.page_addr)
        if self._l2_addr != cmd.page_addr and self._l1_addr != cmd.page_addr:
            self.counters.array_reads += 1      # cold gather needs a sense
            self._l1_addr = cmd.page_addr
        bm = np.array(cmd.chunk_bitmap, dtype=np.uint32)
        bits = unpack_bitmap(bm, n_bits=CHUNKS_PER_PAGE)
        chunk_ids = np.nonzero(bits)[0]
        plain = np.stack([
            self._derandomized_chunk(sp, cmd.page_addr, int(c))
            for c in chunk_ids]) if chunk_ids.size else np.zeros(
                (0, CHUNK_BYTES), dtype=np.uint8)
        parity_ok = (ecc.crc32_chunks(self._derandomize_page(sp, cmd.page_addr))
                     [chunk_ids] == sp.chunk_parities[chunk_ids]
                     ) if chunk_ids.size else np.zeros(0, dtype=bool)
        self.counters.gathers += 1
        self.counters.chunks_gathered += int(chunk_ids.size)
        return GatherResponse(chunks=plain, chunk_ids=chunk_ids,
                              parity_ok=parity_ok)

    def read_full(self, page_addr: int) -> ReadFullResponse:
        sp = self._get(page_addr)
        self.counters.array_reads += 1
        self.counters.full_reads += 1
        return ReadFullResponse(plain=self._derandomize_page(sp, page_addr))

    # ------------------------------------------------------------- helpers
    def _get(self, page_addr: int) -> StoredPage:
        if page_addr not in self.pages:
            raise KeyError(f"page {page_addr} unprogrammed")
        return self.pages[page_addr]

    def _derandomize_page(self, sp: StoredPage, page_addr: int) -> np.ndarray:
        from .bits import bytes_to_slot_words, slot_words_to_bytes
        words = bytes_to_slot_words(sp.raw)
        plain = words ^ stream_words(page_addr, self.device_seed)
        return slot_words_to_bytes(plain)

    def _derandomized_chunk(self, sp: StoredPage, page_addr: int,
                            chunk_idx: int) -> np.ndarray:
        from .bits import bytes_to_slot_words, slot_words_to_bytes
        start = chunk_idx * CHUNK_BYTES
        chunk = sp.raw[start:start + CHUNK_BYTES]
        words = bytes_to_slot_words(chunk)
        plain = words ^ chunk_stream_words(page_addr, chunk_idx,
                                           self.device_seed)
        return slot_words_to_bytes(plain)

    def _repair(self, sp: StoredPage, page_addr: int) -> None:
        """Outer-code decode success (error count <= t): restore the clean
        image from the simulator's ground truth and verify the inner codes
        agree — a real BCH/LDPC decode is deterministic under the t-bound."""
        assert sp.clean_raw is not None
        sp.raw = sp.clean_raw.copy()
        sp.injected_error_bits = 0
        self._notify(page_addr)
        plain = self._derandomize_page(sp, page_addr)
        ok = ecc.crc32_chunks(plain) == sp.chunk_parities
        assert ok.all(), "repaired image fails inner parities — layout bug"


class SimChipArray:
    """A convenience wrapper over several chips (one per channel/die) that
    routes page addresses by simple striping.  The SSD simulator uses its own
    geometry; this class serves the functional/index layers."""

    def __init__(self, n_chips: int, pages_per_chip: int,
                 device_seed: int = 0):
        self.chips = [SimChip(pages_per_chip, device_seed=device_seed + i)
                      for i in range(n_chips)]
        self.pages_per_chip = pages_per_chip
        # Array-level write observers, called with the *global* page address.
        # Each chip's local notifications are translated back through the
        # striping so subscribers (e.g. the device-resident plane store) see
        # the same address space callers use.
        self.observers: list = []
        for idx, chip in enumerate(self.chips):
            chip.observers.append(
                lambda local, _i=idx: self._notify_global(
                    local * len(self.chips) + _i))

    def _notify_global(self, page_addr: int) -> None:
        for fn in self.observers:
            fn(page_addr)

    def add_observer(self, fn) -> None:
        """Subscribe to stored-image mutations (fn(global_page_addr))."""
        self.observers.append(fn)

    def route(self, page_addr: int) -> tuple["SimChip", int]:
        return (self.chips[page_addr % len(self.chips)],
                page_addr // len(self.chips))

    def program_entries(self, page_addr: int, entries, **kw):
        chip, local = self.route(page_addr)
        return chip.program_entries(local, entries, **kw)

    def search(self, cmd: Command) -> SearchResponse:
        chip, local = self.route(cmd.page_addr)
        return chip.search(dataclasses.replace(cmd, page_addr=local))

    def gather(self, cmd: Command) -> GatherResponse:
        chip, local = self.route(cmd.page_addr)
        return chip.gather(dataclasses.replace(cmd, page_addr=local))

    def read_full(self, page_addr: int) -> ReadFullResponse:
        chip, local = self.route(page_addr)
        return chip.read_full(local)
