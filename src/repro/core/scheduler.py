"""Deadline-based batch command scheduler (paper §IV-E, evaluated §VII-E).

Search commands wait in a queue until their deadline expires; at expiry every
queued command that targets the same page is released as one batch, so a
single NAND array sense (the 16 us that dominates a match) is amortized over
the whole batch.  The paper's (negative) finding — batching only pays off at
unrealistic skew — is reproduced in benchmarks/fig17_batch.py.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict
from typing import Iterator

from .commands import Command


@dataclasses.dataclass
class BatchStats:
    submitted: int = 0
    batches: int = 0
    batched_commands: int = 0      # commands that shared a page sense
    max_batch: int = 0

    @property
    def mean_batch(self) -> float:
        return self.batched_commands / self.batches if self.batches else 0.0


class DeadlineScheduler:
    """Holds commands until deadline expiry, then batches by page address."""

    def __init__(self, deadline_ns: int):
        self.deadline_ns = int(deadline_ns)
        self._heap: list[tuple[int, int, Command]] = []
        self._by_page: dict[int, list[Command]] = defaultdict(list)
        self._seq = 0
        self.stats = BatchStats()

    def submit(self, cmd: Command, now_ns: int) -> None:
        cmd.submit_ns = now_ns
        cmd.deadline_ns = now_ns + self.deadline_ns
        heapq.heappush(self._heap, (cmd.deadline_ns, self._seq, cmd))
        self._by_page[cmd.page_addr].append(cmd)
        self._seq += 1
        self.stats.submitted += 1

    def next_expiry(self) -> int | None:
        while self._heap:
            deadline, _, cmd = self._heap[0]
            if cmd in self._by_page.get(cmd.page_addr, ()):
                return deadline
            heapq.heappop(self._heap)       # already drained with a batch
        return None

    def pop_expired(self, now_ns: int) -> Iterator[list[Command]]:
        """Yield batches whose head deadline has expired."""
        while self._heap:
            deadline, _, head = self._heap[0]
            if deadline > now_ns:
                return
            heapq.heappop(self._heap)
            pending = self._by_page.get(head.page_addr)
            if not pending or head not in pending:
                continue                    # superseded by an earlier batch
            batch = list(pending)
            self._by_page.pop(head.page_addr)
            self.stats.batches += 1
            self.stats.batched_commands += len(batch)
            self.stats.max_batch = max(self.stats.max_batch, len(batch))
            yield batch

    def drain(self) -> Iterator[list[Command]]:
        """Flush everything (end of run)."""
        for page, batch in list(self._by_page.items()):
            self._by_page.pop(page)
            self.stats.batches += 1
            self.stats.batched_commands += len(batch)
            self.stats.max_batch = max(self.stats.max_batch, len(batch))
            yield batch

    def __len__(self) -> int:
        return sum(len(v) for v in self._by_page.values())
