"""The SiM SIMD command ISA (paper §III-B) as host-side datatypes.

These are deliberately dumb — the RISC philosophy of the paper: complex index
operations are decomposed in software into sequences of these four commands.
The engine (engine.py) executes them functionally; the SSD simulator
(flash/ssd.py) executes them in time/energy.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from .bits import u64_to_pair


class Op(enum.Enum):
    PAGE_OPEN = "page_open"
    PAGE_CLOSE = "page_close"
    SEARCH = "search"
    GATHER = "gather"
    LOOKUP = "lookup"           # fused search + same-slot value gather
    PLAN = "plan"               # multi-pass range plan, combined in-latch
    READ_FULL = "read_full"     # storage-mode full-page read (baseline path)
    PROGRAM = "program"         # storage-mode page program
    ERASE = "erase"


@dataclasses.dataclass
class Command:
    op: Op
    page_addr: int
    # search operands
    query: tuple[int, int] | None = None    # (lo, hi) uint32 pair
    mask: tuple[int, int] | None = None
    # gather operand: 64-bit chunk-select bitmap as (lo, hi)
    chunk_bitmap: tuple[int, int] | None = None
    # lookup operand: the paired value page whose same-slot chunk is
    # gathered after the key-page search (paper §V-A paired pages)
    value_page: int | None = None
    # plan operands (Op.PLAN): pass rows as ((q_lo, q_hi), (m_lo, m_hi))
    # uint32 pair tuples.  The chip ORs the include passes, AND-NOTs the
    # exclude passes in-latch (paper Fig 10) and transmits ONE combined
    # 64 B bitmap — never the per-pass bitmaps.  Tuples (not lists) so a
    # plan is hashable and backends can dedup identical plans in a burst.
    plan_include: tuple = None
    plan_exclude: tuple = None
    # scheduling metadata
    submit_ns: int = 0
    deadline_ns: int = 0
    tag: int = 0          # caller correlation id

    @staticmethod
    def search(page_addr: int, query_u64: int, mask_u64: int = 0xFFFFFFFFFFFFFFFF,
               **kw) -> "Command":
        return Command(Op.SEARCH, page_addr, query=u64_to_pair(query_u64),
                       mask=u64_to_pair(mask_u64), **kw)

    @staticmethod
    def gather(page_addr: int, chunk_bitmap_u64: int, **kw) -> "Command":
        return Command(Op.GATHER, page_addr,
                       chunk_bitmap=u64_to_pair(chunk_bitmap_u64), **kw)

    @staticmethod
    def lookup(key_page: int, value_page: int, query_u64: int,
               mask_u64: int = 0xFFFFFFFFFFFFFFFF, **kw) -> "Command":
        """Fused point lookup: search ``key_page``, then gather the first
        matching user slot's chunk from the paired ``value_page``."""
        return Command(Op.LOOKUP, key_page, query=u64_to_pair(query_u64),
                       mask=u64_to_pair(mask_u64), value_page=value_page,
                       **kw)

    @staticmethod
    def plan(page_addr: int, include, exclude=(), **kw) -> "Command":
        """Multi-pass range plan (paper Fig 10, §V-C): OR over ``include``
        passes, AND-NOT over ``exclude`` passes, accumulated in the chip's
        latches; one combined bitmap crosses the bus instead of one per
        pass.  Items are ``(query_u64, mask_u64)`` pairs or any object
        with ``query``/``mask`` attributes (``range_query.MaskedQuery``)."""
        def _pairs(items):
            out = []
            for it in items:
                q, mk = (it.query, it.mask) if hasattr(it, "query") else it
                out.append((u64_to_pair(q), u64_to_pair(mk)))
            return tuple(out)
        return Command(Op.PLAN, page_addr, plan_include=_pairs(include),
                       plan_exclude=_pairs(exclude), **kw)

    @property
    def n_passes(self) -> int:
        """Match passes a PLAN command executes on-chip."""
        return len(self.plan_include or ()) + len(self.plan_exclude or ())

    @staticmethod
    def page_open(page_addr: int, **kw) -> "Command":
        return Command(Op.PAGE_OPEN, page_addr, **kw)

    @staticmethod
    def page_close(page_addr: int, **kw) -> "Command":
        return Command(Op.PAGE_CLOSE, page_addr, **kw)

    @staticmethod
    def read_full(page_addr: int, **kw) -> "Command":
        return Command(Op.READ_FULL, page_addr, **kw)

    @staticmethod
    def program(page_addr: int, **kw) -> "Command":
        """Storage-mode page program.  The deferred write path does not
        route entry images through Command objects — see
        ``MatchBackend.submit_program``, which queues (page, entries)
        directly and coalesces last-wins per page."""
        return Command(Op.PROGRAM, page_addr, **kw)


@dataclasses.dataclass
class SearchResponse:
    bitmap_words: np.ndarray        # (16,) uint32 — the 64 B bus payload
    match_count: int
    open_verdict: str               # OpenVerdict.value of the page-open check


@dataclasses.dataclass
class GatherResponse:
    chunks: np.ndarray              # (k, 64) uint8 de-randomized chunk bytes
    chunk_ids: np.ndarray           # (k,) int
    parity_ok: np.ndarray           # (k,) bool inner-code verdicts


@dataclasses.dataclass
class LookupResponse:
    """Result of a fused key-search + value-gather point lookup."""
    search: SearchResponse          # the key-page search, bit-identical to
                                    # an explicit SEARCH command's response
    value_slot: Optional[int]       # first matching user slot, None on miss
    value: Optional[bytes]          # the slot's 8 value bytes, None on miss
    parity_ok: bool = True          # inner-code verdict of the value chunk


@dataclasses.dataclass
class ReadFullResponse:
    plain: np.ndarray               # (4096,) uint8 de-randomized page
