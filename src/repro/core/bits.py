"""Bit-level helpers shared by the host (numpy) and device (jax.numpy) paths.

The SiM data unit is a 64-bit slot.  JAX runs with x64 disabled, so every
64-bit quantity is carried as a pair of little-endian ``uint32`` words
``(lo, hi)`` on both paths; helpers here convert between Python ints, word
pairs, byte views and packed bitmaps.

All mixing/packing functions take an ``xp`` module argument so the exact same
code serves as the numpy host implementation and the jnp oracle used to
validate the Pallas kernels.
"""
from __future__ import annotations

import numpy as np

U32_MASK = 0xFFFFFFFF
U64_MASK = 0xFFFFFFFFFFFFFFFF

# Slot / page geometry (paper §III-A: 4 KiB page = 512 slots of 8 B; 8 slots
# = one 64 B chunk; 64 chunks per page).
SLOT_BYTES = 8
SLOTS_PER_PAGE = 512
SLOTS_PER_CHUNK = 8
CHUNKS_PER_PAGE = SLOTS_PER_PAGE // SLOTS_PER_CHUNK  # 64
CHUNK_BYTES = SLOT_BYTES * SLOTS_PER_CHUNK           # 64
PAGE_BYTES = SLOT_BYTES * SLOTS_PER_PAGE             # 4096
BITMAP_WORDS = SLOTS_PER_PAGE // 32                  # 16 x uint32 = 64 B


def u64_to_pair(value: int) -> tuple[int, int]:
    """Split a Python int (treated as uint64) into (lo, hi) uint32 ints."""
    value &= U64_MASK
    return value & U32_MASK, (value >> 32) & U32_MASK


def pair_to_u64(lo: int, hi: int) -> int:
    return ((int(hi) & U32_MASK) << 32) | (int(lo) & U32_MASK)


def u64_array_to_pairs(values: np.ndarray) -> np.ndarray:
    """(N,) uint64 -> (N, 2) uint32 little-endian word pairs."""
    v = np.asarray(values, dtype=np.uint64)
    return v.view(np.uint32).reshape(*v.shape, 2)


def pairs_to_u64_array(pairs: np.ndarray) -> np.ndarray:
    p = np.ascontiguousarray(pairs, dtype=np.uint32)
    return p.view(np.uint64).reshape(p.shape[:-1])


def bytes_to_slot_words(page_bytes: np.ndarray) -> np.ndarray:
    """(..., 4096) uint8 -> (..., 512, 2) uint32 slot word pairs (LE)."""
    b = np.ascontiguousarray(page_bytes, dtype=np.uint8)
    assert b.shape[-1] % SLOT_BYTES == 0
    n_slots = b.shape[-1] // SLOT_BYTES
    return b.view('<u4').reshape(*b.shape[:-1], n_slots, 2)


def slot_words_to_bytes(words: np.ndarray) -> np.ndarray:
    w = np.ascontiguousarray(words, dtype=np.uint32)
    return w.view(np.uint8).reshape(*w.shape[:-2], w.shape[-2] * SLOT_BYTES)


# ---------------------------------------------------------------------------
# 32-bit mixers (murmur3 fmix32 and a two-round xorshift-mult) used for the
# per-chunk data randomization streams (paper §IV-C1).  Pure uint32 math so
# they run identically under numpy and jnp.
# ---------------------------------------------------------------------------

def fmix32(x, xp=np):
    x = xp.asarray(x, dtype=xp.uint32)
    c1 = xp.uint32(0x85EBCA6B)
    c2 = xp.uint32(0xC2B2AE35)
    x = x ^ (x >> xp.uint32(16))
    x = (x * c1).astype(xp.uint32)
    x = x ^ (x >> xp.uint32(13))
    x = (x * c2).astype(xp.uint32)
    x = x ^ (x >> xp.uint32(16))
    return x


def mix2_32(x, salt, xp=np):
    """Two fmix rounds with a salt between them; decorrelates lo/hi streams."""
    x = fmix32(x, xp)
    x = x ^ xp.uint32(salt)
    return fmix32(x, xp)


# ---------------------------------------------------------------------------
# Bitmap packing: (..., 512) {0,1} -> (..., 16) uint32.  Bit i of word w is
# slot 32*w + i (little-endian within word), matching the byte order the chip
# would put on the bus.
# ---------------------------------------------------------------------------

def pack_bitmap(bits, xp=np):
    bits = xp.asarray(bits)
    n = bits.shape[-1]
    assert n % 32 == 0, n
    b = bits.astype(xp.uint32).reshape(*bits.shape[:-1], n // 32, 32)
    shifts = xp.arange(32, dtype=xp.uint32)
    return (b << shifts).sum(axis=-1).astype(xp.uint32)


def unpack_bitmap(words, n_bits: int | None = None, xp=np):
    words = xp.asarray(words, dtype=xp.uint32)
    shifts = xp.arange(32, dtype=xp.uint32)
    bits = (words[..., None] >> shifts) & xp.uint32(1)
    bits = bits.reshape(*words.shape[:-1], words.shape[-1] * 32)
    if n_bits is not None:
        bits = bits[..., :n_bits]
    return bits.astype(xp.uint32)


def popcount_words(words: np.ndarray) -> np.ndarray:
    """Population count over trailing word axis -> int32 counts."""
    return unpack_bitmap(words, xp=np).sum(axis=-1).astype(np.int32)


def chunk_bitmap_from_slot_bitmap(slot_words, xp=np):
    """Reduce a 512-bit slot bitmap to a 64-bit chunk-select bitmap (2 words).

    A chunk is selected when any of its 8 slots matched — this is what feeds
    the gather command after a search (paper §III-B).
    """
    bits = unpack_bitmap(slot_words, xp=xp)                    # (..., 512)
    s = bits.reshape(*bits.shape[:-1], CHUNKS_PER_PAGE, SLOTS_PER_CHUNK)
    chunk_bits = (s.sum(axis=-1) > 0).astype(xp.uint32)        # (..., 64)
    return pack_bitmap(chunk_bits, xp=xp)                      # (..., 2)
