"""Range-query decomposition onto masked equality tests (paper §V-C).

SiM hardware only does masked equality.  The paper decomposes a range
``L <= k < U`` into:

  * an *approximate* one-pass form — round the upper bound up to the next
    power of two and test that the high prefix bits are zero (plus the
    complemented lower-bound test); result is a superset of the true range;
  * an *exact* multi-pass form, sketched as "masking out the
    previously-compared MSB region and recursively comparing" — which is the
    classic trie/prefix decomposition: any [L, U) splits into at most
    2*width - 2 prefix-aligned blocks, each testable with one masked
    equality.  We implement both.

Fields (columns BitWeaving-packed into the 64-bit key, §V-B) are handled by
shifting the decomposition into the field's bit range.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

import numpy as np

from .commands import Command

if TYPE_CHECKING:                                    # avoid core -> backend cycle
    from repro.backend.base import MatchBackend

U64 = 0xFFFFFFFFFFFFFFFF


@dataclasses.dataclass(frozen=True)
class MaskedQuery:
    """One search command operand pair: compare (key & mask) == (query & mask)."""
    query: int
    mask: int

    def matches(self, keys: np.ndarray) -> np.ndarray:
        k = np.asarray(keys, dtype=np.uint64)
        return (k & np.uint64(self.mask)) == np.uint64(self.query & self.mask)


@dataclasses.dataclass(frozen=True)
class RangePlan:
    """Evaluation plan: OR over ``include``, minus OR over ``exclude``.

    The approximate plan uses include=[upper-bound test] and
    exclude=[below-lower-bound test] (bitmap AND-NOT, paper Fig 10); the
    exact plan uses include-only prefix blocks.
    """
    include: tuple[MaskedQuery, ...]
    exclude: tuple[MaskedQuery, ...] = ()
    exact: bool = True

    @property
    def n_passes(self) -> int:
        return len(self.include) + len(self.exclude)

    def evaluate(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.uint64)
        inc = np.zeros(keys.shape, dtype=bool)
        for q in self.include:
            inc |= q.matches(keys)
        for q in self.exclude:
            inc &= ~q.matches(keys)
        return inc


def evaluate_plan_on_pages(backend: "MatchBackend", plan: RangePlan,
                           page_addrs: Sequence[int]) -> np.ndarray:
    """Run a RangePlan over many pages through a MatchBackend.

    ONE ``Op.PLAN`` command per page, flushed together: the backend's
    fused plan path (``kernels/sim_plan`` on the kernel backends, the
    per-pass split reference on scalar) accumulates OR over include
    passes and AND-NOT over exclude passes *in-latch* (paper Fig 10) and
    ships one combined 64 B bitmap per page — device->host result bytes
    shrink by the pass count versus the per-pass path
    (:func:`evaluate_plan_per_pass`).  Returns the combined
    (len(page_addrs), 16) uint32 slot bitmaps.
    """
    from repro.reliability import require_clean
    tickets = [backend.submit_plan(Command.plan(p, plan.include,
                                                plan.exclude))
               for p in page_addrs]
    backend.flush()
    out = np.zeros((len(page_addrs), 16), dtype=np.uint32)
    for i, t in enumerate(tickets):
        # Propagates UncorrectableReadError from a reliability-tier backend
        # — a page that failed outer-code decode must not contribute an
        # all-zero bitmap that reads as "no keys in range".
        out[i] = require_clean(t.result()).bitmap_words
    return out


def evaluate_plan_per_pass(backend: "MatchBackend", plan: RangePlan,
                           page_addrs: Sequence[int]) -> np.ndarray:
    """The pre-PLAN split path: one SEARCH per (page, pass), one flush,
    per-pass bitmaps combined on the host.

    Kept as the bit-exactness reference for ``Op.PLAN``
    (tests/test_plan_backend.py) and as the baseline the kernel_micro
    ``range_plan`` section measures the fused kernel against — this path
    crosses 64 B per pass per page where PLAN crosses 64 B per page.
    """
    from repro.reliability import require_clean
    include = [[backend.submit_search(Command.search(p, mq.query, mq.mask))
                for mq in plan.include] for p in page_addrs]
    exclude = [[backend.submit_search(Command.search(p, mq.query, mq.mask))
                for mq in plan.exclude] for p in page_addrs]
    backend.flush()
    out = np.zeros((len(page_addrs), 16), dtype=np.uint32)
    for i in range(len(page_addrs)):
        acc = np.zeros(16, dtype=np.uint32)
        for t in include[i]:
            acc |= require_clean(t.result()).bitmap_words
        for t in exclude[i]:
            acc &= ~require_clean(t.result()).bitmap_words
        out[i] = acc
    return out


def _field_mask(shift: int, width: int) -> int:
    return ((1 << width) - 1) << shift


def prefix_query(prefix_value: int, free_bits: int, shift: int,
                 width: int) -> MaskedQuery:
    """Equality on the top ``width - free_bits`` bits of a field."""
    mask = _field_mask(shift, width) & ~_field_mask(shift, free_bits)
    return MaskedQuery(query=(prefix_value << shift) & U64, mask=mask & U64)


def approximate_range(lo: int, hi: int, *, shift: int = 0,
                      width: int = 64) -> RangePlan:
    """Paper §V-C one-pass-per-bound superset plan for lo <= k < hi."""
    if not (0 <= lo < hi <= (1 << width)):
        raise ValueError((lo, hi, width))
    include: list[MaskedQuery] = []
    exclude: list[MaskedQuery] = []
    # Upper bound k < hi -> k <= 2^ceil(log2(hi)) - 1: high bits above
    # ceil(log2(hi)) must be zero.
    ub_bits = max(int(hi - 1).bit_length(), 0)
    if ub_bits < width:
        include.append(prefix_query(0, ub_bits, shift, width))
    else:
        include.append(MaskedQuery(query=0, mask=0))   # all keys pass
    # Lower bound k >= lo -> NOT (k < 2^floor(log2(lo)) ... ) exactly as the
    # paper: k < lo approximated by k <= 2^ceil(log2(lo))-1 using the
    # *floor* power so the excluded set is a subset (keeps superset
    # semantics of the overall plan).
    if lo > 0:
        lb_bits = int(lo).bit_length() - 1   # floor(log2(lo))
        if lb_bits >= 0:
            exclude.append(prefix_query(0, lb_bits, shift, width))
    return RangePlan(include=tuple(include), exclude=tuple(exclude),
                     exact=False)


def exact_range(lo: int, hi: int, *, shift: int = 0,
                width: int = 64) -> RangePlan:
    """Exact prefix decomposition of [lo, hi) into masked equality blocks."""
    if not (0 <= lo < hi <= (1 << width)):
        raise ValueError((lo, hi, width))
    blocks: list[MaskedQuery] = []
    cur = lo
    while cur < hi:
        s = 0
        while s < width:
            block = 1 << (s + 1)
            if (cur & (block - 1)) != 0 or cur + block > hi:
                break
            s += 1
        blocks.append(prefix_query(cur, s, shift, width))
        cur += 1 << s
    return RangePlan(include=tuple(blocks), exact=True)


def false_positive_bound(plan: RangePlan, lo: int, hi: int,
                         width: int = 64) -> float:
    """Upper bound on the superset blow-up of an approximate plan under a
    uniform key distribution (paper §V-C cites low error for uniform keys).

    This bounds the *decomposition* error only: an exact plan has zero.
    Under the reliability tier a second, independent error source exists —
    per-sense bit flips in match mode (§IV-C3) — whose per-page
    false-positive probability is bounded analytically by
    :func:`repro.reliability.sense_false_positive_bound` (and driven to
    ~zero by k-pass voting + selective hit verification; the
    ``reliability_sweep`` benchmark measures both against these bounds).
    """
    if plan.exact:
        return 0.0
    ub_bits = max(int(hi - 1).bit_length(), 0)
    lb_bits = int(lo).bit_length() - 1 if lo > 0 else 0
    covered = (1 << ub_bits) - (1 << lb_bits if lo > 0 else 0)
    true_span = hi - lo
    return covered / true_span - 1.0
