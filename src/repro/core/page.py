"""SiM page construction and views (paper §III-A).

A match-mode page is an array of 512 aligned 8-byte slots; eight slots form a
64 B chunk, the minimal transfer unit.  Chunk 0 is the verification header
(see ecc.py).  Key/value index pages place a compact array of 8-byte entries
in chunks 1..63 (504 usable slots).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import ecc
from .bits import (PAGE_BYTES, SLOTS_PER_CHUNK, SLOTS_PER_PAGE,
                   bytes_to_slot_words, slot_words_to_bytes,
                   u64_array_to_pairs)
from .randomize import randomize_page_words

# Slots available for user data when chunk 0 carries the header.
USER_SLOTS = SLOTS_PER_PAGE - SLOTS_PER_CHUNK  # 504
EMPTY_SLOT = 0xFFFFFFFFFFFFFFFF                # all-ones = vacant


@dataclasses.dataclass
class BuiltPage:
    """A page as it exists on flash plus its out-of-band metadata."""
    raw: np.ndarray            # (4096,) uint8 — randomized, as stored
    plain: np.ndarray          # (4096,) uint8 — pre-randomization content
    chunk_parities: np.ndarray  # (64,) uint32 inner-code CRCs (over plain bytes)
    page_addr: int
    timestamp_ns: int
    n_entries: int


def build_page(entries: np.ndarray, page_addr: int, *, timestamp_ns: int = 0,
               header_user: np.ndarray | None = None, device_seed: int = 0,
               randomize: bool = True) -> BuiltPage:
    """Lay out up to 504 uint64 entries into a match-mode page.

    Vacant slots are filled with EMPTY_SLOT so an equality search for a real
    key can never alias a hole (keys are required to differ from it).
    """
    entries = np.asarray(entries, dtype=np.uint64).ravel()
    if entries.size > USER_SLOTS:
        raise ValueError(f"{entries.size} entries > {USER_SLOTS} user slots")
    slots = np.full(USER_SLOTS, EMPTY_SLOT, dtype=np.uint64)
    slots[:entries.size] = entries

    header = ecc.build_header_chunk(timestamp_ns, header_user)
    body = slot_words_to_bytes(u64_array_to_pairs(slots))
    plain = np.concatenate([header, body]).astype(np.uint8)
    assert plain.size == PAGE_BYTES

    parities = ecc.build_chunk_parities(plain)
    if randomize:
        words = bytes_to_slot_words(plain)
        rnd = randomize_page_words(words, page_addr, device_seed)
        raw = slot_words_to_bytes(rnd)
    else:
        raw = plain.copy()
    return BuiltPage(raw=raw, plain=plain, chunk_parities=parities,
                     page_addr=page_addr, timestamp_ns=timestamp_ns,
                     n_entries=int(entries.size))


def page_slot_words(page_bytes: np.ndarray) -> np.ndarray:
    """(4096,) uint8 -> (512, 2) uint32 slot view (no copy semantics needed)."""
    return bytes_to_slot_words(np.asarray(page_bytes, dtype=np.uint8))


def entries_from_plain(plain: np.ndarray, n_entries: int) -> np.ndarray:
    """Recover the uint64 entry array from a plain page image."""
    words = bytes_to_slot_words(plain)[SLOTS_PER_CHUNK:]
    from .bits import pairs_to_u64_array
    return pairs_to_u64_array(words)[:n_entries]


def slot_to_chunk(slot_idx: int) -> int:
    return slot_idx // SLOTS_PER_CHUNK


def user_slot_for_entry(entry_idx: int) -> int:
    """Slot index (within the page) of user entry ``entry_idx``."""
    return SLOTS_PER_CHUNK + entry_idx


def mask_header_slots(bitmap_words, xp=np):
    """Clear bitmap bits of the header chunk (slots 0..7).

    The chip matches *every* slot — it has no notion of a header — so a query
    that happens to equal a header field (e.g. key 0 vs zero-filled metadata
    slots) aliases into chunk 0.  Index software always strips those bits
    before interpreting a search result; this is the software half of the
    paper's RISC-style decomposition.
    """
    out = xp.asarray(bitmap_words, dtype=xp.uint32).copy() if xp is np else \
        xp.asarray(bitmap_words, dtype=xp.uint32)
    first = out[..., 0] & xp.uint32(0xFFFFFF00)
    if xp is np:
        out[..., 0] = first
        return out
    return out.at[..., 0].set(first)
