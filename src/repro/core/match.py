"""The matching semantics of the SiM chip, defined once.

This is the *specification* both the numpy host engine and the Pallas TPU
kernels implement: a masked 64-bit equality test per 8-byte slot.

    match[s] = (((slot_lo[s] ^ q_lo) & m_lo) | ((slot_hi[s] ^ q_hi) & m_hi)) == 0

A set mask bit means "compare this bit position"; cleared bits are
"don't care" (paper §III-B).  The all-zero mask therefore matches *every*
slot — the degenerate full-page select used by redistribution (§V-D).
"""
from __future__ import annotations

import numpy as np

from .bits import pack_bitmap, chunk_bitmap_from_slot_bitmap


def match_slots(slot_words, query_pair, mask_pair, xp=np):
    """(..., S, 2) uint32 x (2,) x (2,) -> (..., S) uint32 {0,1} match bits."""
    w = xp.asarray(slot_words, dtype=xp.uint32)
    q = xp.asarray(query_pair, dtype=xp.uint32)
    m = xp.asarray(mask_pair, dtype=xp.uint32)
    mismatch = ((w[..., 0] ^ q[..., 0]) & m[..., 0]) | (
        (w[..., 1] ^ q[..., 1]) & m[..., 1])
    return (mismatch == 0).astype(xp.uint32)


def search_page(slot_words, query_pair, mask_pair, xp=np):
    """Full search command semantics: packed (..., 16) uint32 slot bitmap."""
    return pack_bitmap(match_slots(slot_words, query_pair, mask_pair, xp), xp)


def search_to_chunk_bitmap(slot_words, query_pair, mask_pair, xp=np):
    """search + slot->chunk reduction: (..., 2) uint32 chunk-select bitmap."""
    bitmap = search_page(slot_words, query_pair, mask_pair, xp)
    return chunk_bitmap_from_slot_bitmap(bitmap, xp)


def gather_chunks(page_chunks, chunk_bitmap_words, max_out: int, xp=np):
    """Gather command semantics (order-preserving compaction).

    page_chunks: (64, CB) chunk-major page content (any dtype)
    chunk_bitmap_words: (2,) uint32 chunk-select bitmap
    Returns (out, count): out (max_out, CB) with selected chunks packed to the
    front (tail zero-filled), count = number selected.
    """
    from .bits import unpack_bitmap  # local to avoid cycle at import time
    bits = unpack_bitmap(xp.asarray(chunk_bitmap_words, dtype=xp.uint32),
                         n_bits=page_chunks.shape[0], xp=xp)
    positions = xp.cumsum(bits) - bits          # output slot for each chunk
    onehot = (
        (positions[None, :] == xp.arange(max_out)[:, None]) & (bits[None, :] == 1)
    ).astype(page_chunks.dtype)                 # (max_out, 64)
    out = onehot @ page_chunks                  # MXU-style one-hot gather
    count = bits.sum().astype(xp.int32)
    return out, count
