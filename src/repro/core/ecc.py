"""Data integrity: CRCs, the verification header, Optimistic Error Correction
and the concatenated chunk-level code (paper §IV-C2/C3).

Layout implemented here (per 4 KiB match-mode page):

  chunk 0 (the *verification header* chunk, 64 B):
    slot 0  : CRC-64 over slots 1..7 of chunk 0        (8 B)
    slot 1  : magic number 0x5349_4D43_4849_5021        (8 B, "SIMCHIP!")
    slot 2  : write timestamp (uint64 nanoseconds)      (8 B)
    slots 3..7 : user metadata (B+Tree header etc.)

  out-of-band area (modelled separately, as on a real chip):
    64 x CRC-32 chunk parities  (the concatenated *inner* code)
    1  x page-level parity + correction budget t (the *outer* code; real
        chips use BCH/LDPC — we model a t-error-correcting code whose
        decode succeeds iff the injected bit-error count is <= t)

`page_open` transfers header+chunk0 only; the controller checks the CRC-64.
Clean -> proceed with on-chip matching (the optimistic fast path).
Dirty -> full-page fallback: outer-code decode, then bounded read-retries.
Stale timestamp -> page is queued for refresh (rewrite) even when clean.
"""
from __future__ import annotations

import dataclasses
from enum import Enum

import numpy as np

from .bits import (CHUNK_BYTES, CHUNKS_PER_PAGE, bytes_to_slot_words,
                   pair_to_u64, slot_words_to_bytes, u64_to_pair)

MAGIC = 0x53494D4348495021  # "SIMCHIP!"
HEADER_CRC_SLOT = 0
HEADER_MAGIC_SLOT = 1
HEADER_TIMESTAMP_SLOT = 2
HEADER_USER_SLOTS = slice(3, 8)

# --------------------------------------------------------------------------
# Table-driven CRC-32 (Castagnoli) and CRC-64 (ECMA-182), vectorized in numpy.
# --------------------------------------------------------------------------

def _make_crc32_table(poly: int = 0x82F63B78) -> np.ndarray:
    table = np.zeros(256, dtype=np.uint32)
    for i in range(256):
        crc = i
        for _ in range(8):
            crc = (crc >> 1) ^ (poly if crc & 1 else 0)
        table[i] = crc
    return table


def _make_crc64_table(poly: int = 0xC96C5795D7870F42) -> np.ndarray:
    table = np.zeros(256, dtype=np.uint64)
    for i in range(256):
        crc = i
        for _ in range(8):
            crc = (crc >> 1) ^ (poly if crc & 1 else 0)
        table[i] = np.uint64(crc)
    return table


_CRC32_TABLE = _make_crc32_table()
_CRC64_TABLE = _make_crc64_table()


def crc32(data: np.ndarray | bytes) -> int:
    buf = np.frombuffer(bytes(data), dtype=np.uint8) if isinstance(
        data, (bytes, bytearray)) else np.asarray(data, dtype=np.uint8).ravel()
    crc = np.uint32(0xFFFFFFFF)
    for b in buf:
        crc = _CRC32_TABLE[(crc ^ b) & np.uint32(0xFF)] ^ (crc >> np.uint32(8))
    return int(crc ^ np.uint32(0xFFFFFFFF))


def crc64(data: np.ndarray | bytes) -> int:
    buf = np.frombuffer(bytes(data), dtype=np.uint8) if isinstance(
        data, (bytes, bytearray)) else np.asarray(data, dtype=np.uint8).ravel()
    crc = np.uint64(0xFFFFFFFFFFFFFFFF)
    for b in buf:
        crc = _CRC64_TABLE[(crc ^ np.uint64(b)) & np.uint64(0xFF)] ^ (
            crc >> np.uint64(8))
    return int(crc ^ np.uint64(0xFFFFFFFFFFFFFFFF))


def crc32_rows(rows: np.ndarray) -> np.ndarray:
    """Row-wise CRC-32 over a (k, n) uint8 array -> (k,) uint32."""
    rows = np.asarray(rows, dtype=np.uint8)
    crc = np.full(rows.shape[0], 0xFFFFFFFF, dtype=np.uint32)
    for i in range(rows.shape[1]):
        crc = _CRC32_TABLE[(crc ^ rows[:, i]) & 0xFF] ^ (crc >> np.uint32(8))
    return crc ^ np.uint32(0xFFFFFFFF)


def crc32_chunks(page_bytes: np.ndarray) -> np.ndarray:
    """CRC-32 of each 64 B chunk of a page -> (64,) uint32 (vectorized)."""
    return crc32_rows(np.asarray(page_bytes, dtype=np.uint8).reshape(
        CHUNKS_PER_PAGE, CHUNK_BYTES))


# --------------------------------------------------------------------------
# Verification header
# --------------------------------------------------------------------------

def build_header_chunk(timestamp_ns: int,
                       user_slots: np.ndarray | None = None) -> np.ndarray:
    """Return the 64 B verification-header chunk as uint8."""
    words = np.zeros((8, 2), dtype=np.uint32)
    words[HEADER_MAGIC_SLOT] = u64_to_pair(MAGIC)
    words[HEADER_TIMESTAMP_SLOT] = u64_to_pair(timestamp_ns)
    if user_slots is not None:
        u = np.asarray(user_slots, dtype=np.uint32).reshape(-1, 2)
        words[HEADER_USER_SLOTS][:u.shape[0]] = u
    body = slot_words_to_bytes(words[1:])          # slots 1..7
    crc = crc64(body)
    words[HEADER_CRC_SLOT] = u64_to_pair(crc)
    return slot_words_to_bytes(words)


@dataclasses.dataclass
class Header:
    crc: int
    magic: int
    timestamp_ns: int
    user: np.ndarray  # (5, 2) uint32
    crc_ok: bool
    magic_ok: bool


def parse_header_chunk(chunk_bytes: np.ndarray) -> Header:
    words = bytes_to_slot_words(np.asarray(chunk_bytes, dtype=np.uint8))
    crc_stored = pair_to_u64(*words[HEADER_CRC_SLOT])
    magic = pair_to_u64(*words[HEADER_MAGIC_SLOT])
    ts = pair_to_u64(*words[HEADER_TIMESTAMP_SLOT])
    body = slot_words_to_bytes(words[1:])
    return Header(
        crc=crc_stored, magic=magic, timestamp_ns=ts,
        user=np.array(words[HEADER_USER_SLOTS]),
        crc_ok=(crc64(body) == crc_stored), magic_ok=(magic == MAGIC))


# --------------------------------------------------------------------------
# Optimistic Error Correction pipeline
# --------------------------------------------------------------------------

class OpenVerdict(Enum):
    CLEAN = "clean"                  # fast path: match on-chip immediately
    CLEAN_NEEDS_REFRESH = "refresh"  # clean, but older than the safety margin
    FALLBACK_ECC = "fallback"        # CRC mismatch -> full-page outer decode
    UNCORRECTABLE = "uncorrectable"  # outer decode failed after read-retries


@dataclasses.dataclass
class EccConfig:
    t_correctable: int = 40           # outer-code budget (bits / 4 KiB page)
    max_read_retries: int = 5         # sensing-voltage retries (paper [17])
    refresh_margin_ns: int = int(30 * 24 * 3600 * 1e9)  # 30 days
    retry_fix_prob: float = 0.5       # per-retry chance a marginal page reads clean


@dataclasses.dataclass
class OpenResult:
    verdict: OpenVerdict
    header: Header | None
    retries_used: int = 0
    bits_corrected: int = 0


def optimistic_open(header_chunk: np.ndarray, *, now_ns: int,
                    injected_error_bits: int, cfg: EccConfig,
                    rng: np.random.Generator | None = None) -> OpenResult:
    """Model the page-open decision tree of §IV-C2.

    ``injected_error_bits`` is the simulator's ground-truth raw bit-error
    count for the page (the header chunk's own errors are already reflected
    in the bytes passed in, so the CRC check is real, not modelled).
    """
    header = parse_header_chunk(header_chunk)
    if header.crc_ok and header.magic_ok:
        if now_ns - header.timestamp_ns > cfg.refresh_margin_ns:
            return OpenResult(OpenVerdict.CLEAN_NEEDS_REFRESH, header)
        return OpenResult(OpenVerdict.CLEAN, header)

    # Fallback: full page is read out, outer code decodes.
    if injected_error_bits <= cfg.t_correctable:
        return OpenResult(OpenVerdict.FALLBACK_ECC, header,
                          bits_corrected=injected_error_bits)

    # Read-retry loop with adjusted sensing voltage; the magic number gives
    # the controller a known-plaintext anchor for calibrating the retry.
    rng = rng or np.random.default_rng(0)
    for attempt in range(1, cfg.max_read_retries + 1):
        if rng.random() < cfg.retry_fix_prob:
            return OpenResult(OpenVerdict.FALLBACK_ECC, header,
                              retries_used=attempt,
                              bits_corrected=cfg.t_correctable)
    return OpenResult(OpenVerdict.UNCORRECTABLE, header,
                      retries_used=cfg.max_read_retries)


# --------------------------------------------------------------------------
# Concatenated chunk-level code (inner CRC-32 per chunk)
# --------------------------------------------------------------------------

def build_chunk_parities(page_bytes: np.ndarray) -> np.ndarray:
    """(64,) uint32 inner-code parities stored out-of-band with the page."""
    return crc32_chunks(page_bytes)


def verify_chunks(page_bytes: np.ndarray, parities: np.ndarray,
                  chunk_ids: np.ndarray) -> np.ndarray:
    """Check selected chunks against their stored parities -> (k,) bool."""
    fresh = crc32_chunks(page_bytes)
    chunk_ids = np.asarray(chunk_ids, dtype=np.int64)
    return fresh[chunk_ids] == np.asarray(parities, dtype=np.uint32)[chunk_ids]
