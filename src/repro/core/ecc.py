"""Data integrity: CRCs, the verification header, Optimistic Error Correction
and the concatenated chunk-level code (paper §IV-C2/C3).

Layout implemented here (per 4 KiB match-mode page):

  chunk 0 (the *verification header* chunk, 64 B):
    slot 0  : CRC-64 over slots 1..7 of chunk 0        (8 B)
    slot 1  : magic number 0x5349_4D43_4849_5021        (8 B, "SIMCHIP!")
    slot 2  : write timestamp (uint64 nanoseconds)      (8 B)
    slots 3..7 : user metadata (B+Tree header etc.)

  out-of-band area (modelled separately, as on a real chip):
    64 x CRC-32 chunk parities  (the concatenated *inner* code)
    1  x page-level parity + correction budget t (the *outer* code; real
        chips use BCH/LDPC — we model a t-error-correcting code whose
        decode succeeds iff the injected bit-error count is <= t)

`page_open` transfers header+chunk0 only; the controller checks the CRC-64.
Clean -> proceed with on-chip matching (the optimistic fast path).
Dirty -> full-page fallback: outer-code decode, then bounded read-retries.
Stale timestamp -> page is queued for refresh (rewrite) even when clean.
"""
from __future__ import annotations

import dataclasses
import functools
from enum import Enum

import numpy as np

from .bits import (CHUNK_BYTES, CHUNKS_PER_PAGE, bytes_to_slot_words,
                   pair_to_u64, slot_words_to_bytes, u64_to_pair)

MAGIC = 0x53494D4348495021  # "SIMCHIP!"
HEADER_CRC_SLOT = 0
HEADER_MAGIC_SLOT = 1
HEADER_TIMESTAMP_SLOT = 2
HEADER_USER_SLOTS = slice(3, 8)

# --------------------------------------------------------------------------
# Table-driven CRC-32 (Castagnoli) and CRC-64 (ECMA-182), vectorized in numpy.
# --------------------------------------------------------------------------

_CRC32_POLY = 0x82F63B78            # Castagnoli, reflected
_CRC64_POLY = 0xC96C5795D7870F42    # ECMA-182, reflected


def _make_crc32_table(poly: int = _CRC32_POLY) -> np.ndarray:
    table = np.zeros(256, dtype=np.uint32)
    for i in range(256):
        crc = i
        for _ in range(8):
            crc = (crc >> 1) ^ (poly if crc & 1 else 0)
        table[i] = crc
    return table


def _make_crc64_table(poly: int = _CRC64_POLY) -> np.ndarray:
    table = np.zeros(256, dtype=np.uint64)
    for i in range(256):
        crc = i
        for _ in range(8):
            crc = (crc >> 1) ^ (poly if crc & 1 else 0)
        table[i] = np.uint64(crc)
    return table


_CRC32_TABLE = _make_crc32_table()
_CRC64_TABLE = _make_crc64_table()


def _as_u8(data: np.ndarray | bytes) -> np.ndarray:
    return np.frombuffer(bytes(data), dtype=np.uint8) if isinstance(
        data, (bytes, bytearray)) else np.asarray(data, dtype=np.uint8).ravel()


def _crc32_bytewise(data: np.ndarray | bytes) -> int:
    """Reference per-byte CRC-32; kept as the property-test oracle and the
    short-buffer path of the vectorized :func:`crc32`."""
    buf = _as_u8(data)
    crc = np.uint32(0xFFFFFFFF)
    for b in buf:
        crc = _CRC32_TABLE[(crc ^ b) & np.uint32(0xFF)] ^ (crc >> np.uint32(8))
    return int(crc ^ np.uint32(0xFFFFFFFF))


def _crc64_bytewise(data: np.ndarray | bytes) -> int:
    """Reference per-byte CRC-64 (see :func:`_crc32_bytewise`)."""
    buf = _as_u8(data)
    crc = np.uint64(0xFFFFFFFFFFFFFFFF)
    for b in buf:
        crc = _CRC64_TABLE[(crc ^ np.uint64(b)) & np.uint64(0xFF)] ^ (
            crc >> np.uint64(8))
    return int(crc ^ np.uint64(0xFFFFFFFFFFFFFFFF))


# GF(2) length-shift operators (the zlib crc32_combine construction): the
# final CRC of A||B is  M_len(B) @ crc(A)  ^  crc(B), where M_n is the linear
# operator that advances a (reflected, pre/post-conditioned) CRC register by
# n zero bytes.  Splitting a buffer into equal rows therefore reduces a
# whole-buffer CRC to ONE vectorized row-wise table pass plus a cheap
# per-row fold with a cached matrix — no per-byte Python loop.

def _gf2_times(mat: tuple[int, ...], vec: int) -> int:
    s = 0
    i = 0
    while vec:
        if vec & 1:
            s ^= mat[i]
        vec >>= 1
        i += 1
    return s


def _gf2_square(mat: tuple[int, ...]) -> tuple[int, ...]:
    return tuple(_gf2_times(mat, m) for m in mat)


@functools.lru_cache(maxsize=None)
def _shift_matrix(poly: int, width: int, len_bytes: int) -> tuple[int, ...]:
    """Operator advancing a reflected CRC register by ``len_bytes`` zeros."""
    op = (poly,) + tuple(1 << (i - 1) for i in range(1, width))  # 1-bit shift
    op = _gf2_square(_gf2_square(op))                            # 4-bit shift
    mat = tuple(1 << i for i in range(width))                    # identity
    n = len_bytes
    while n:
        op = _gf2_square(op)        # 8, 16, 32, ... bit shifts
        if n & 1:
            mat = tuple(_gf2_times(op, m) for m in mat)
        n >>= 1
    return mat


_ROW_BYTES = 64  # fold granularity of the vectorized single-buffer CRCs


def _crc_fold(row_crcs: np.ndarray, tail: np.ndarray, poly: int, width: int,
              bytewise) -> int:
    """Fold per-row CRCs (rows of _ROW_BYTES each) + a short tail into the
    stream CRC via the cached shift operators."""
    shift_row = _shift_matrix(poly, width, _ROW_BYTES)
    crc = int(row_crcs[0])
    for r in row_crcs[1:]:
        crc = _gf2_times(shift_row, crc) ^ int(r)
    if tail.size:
        crc = _gf2_times(_shift_matrix(poly, width, int(tail.size)), crc) \
            ^ bytewise(tail)
    return crc


def crc32(data: np.ndarray | bytes) -> int:
    buf = _as_u8(data)
    if buf.size < 2 * _ROW_BYTES:
        return _crc32_bytewise(buf)
    full = buf.size // _ROW_BYTES
    rows = crc32_rows(buf[:full * _ROW_BYTES].reshape(full, _ROW_BYTES))
    return _crc_fold(rows, buf[full * _ROW_BYTES:], _CRC32_POLY, 32,
                     _crc32_bytewise)


def crc64(data: np.ndarray | bytes) -> int:
    buf = _as_u8(data)
    if buf.size < 2 * _ROW_BYTES:
        return _crc64_bytewise(buf)
    full = buf.size // _ROW_BYTES
    rows = crc64_rows(buf[:full * _ROW_BYTES].reshape(full, _ROW_BYTES))
    return _crc_fold(rows, buf[full * _ROW_BYTES:], _CRC64_POLY, 64,
                     _crc64_bytewise)


def crc32_rows(rows: np.ndarray) -> np.ndarray:
    """Row-wise CRC-32 over a (k, n) uint8 array -> (k,) uint32."""
    rows = np.asarray(rows, dtype=np.uint8)
    crc = np.full(rows.shape[0], 0xFFFFFFFF, dtype=np.uint32)
    for i in range(rows.shape[1]):
        crc = _CRC32_TABLE[(crc ^ rows[:, i]) & 0xFF] ^ (crc >> np.uint32(8))
    return crc ^ np.uint32(0xFFFFFFFF)


def crc64_rows(rows: np.ndarray) -> np.ndarray:
    """Row-wise CRC-64 over a (k, n) uint8 array -> (k,) uint64.

    One table pass verifies every page's header body in a flush's open
    burst (see :func:`parse_header_chunks`) instead of k per-byte loops.
    """
    rows = np.asarray(rows, dtype=np.uint8)
    crc = np.full(rows.shape[0], 0xFFFFFFFFFFFFFFFF, dtype=np.uint64)
    for i in range(rows.shape[1]):
        crc = _CRC64_TABLE[(crc ^ rows[:, i]) & np.uint64(0xFF)] ^ (
            crc >> np.uint64(8))
    return crc ^ np.uint64(0xFFFFFFFFFFFFFFFF)


def crc32_chunks(page_bytes: np.ndarray) -> np.ndarray:
    """CRC-32 of each 64 B chunk of a page -> (64,) uint32 (vectorized)."""
    return crc32_rows(np.asarray(page_bytes, dtype=np.uint8).reshape(
        CHUNKS_PER_PAGE, CHUNK_BYTES))


# --------------------------------------------------------------------------
# Verification header
# --------------------------------------------------------------------------

def build_header_chunk(timestamp_ns: int,
                       user_slots: np.ndarray | None = None) -> np.ndarray:
    """Return the 64 B verification-header chunk as uint8."""
    words = np.zeros((8, 2), dtype=np.uint32)
    words[HEADER_MAGIC_SLOT] = u64_to_pair(MAGIC)
    words[HEADER_TIMESTAMP_SLOT] = u64_to_pair(timestamp_ns)
    if user_slots is not None:
        u = np.asarray(user_slots, dtype=np.uint32).reshape(-1, 2)
        words[HEADER_USER_SLOTS][:u.shape[0]] = u
    body = slot_words_to_bytes(words[1:])          # slots 1..7
    crc = crc64(body)
    words[HEADER_CRC_SLOT] = u64_to_pair(crc)
    return slot_words_to_bytes(words)


@dataclasses.dataclass
class Header:
    crc: int
    magic: int
    timestamp_ns: int
    user: np.ndarray  # (5, 2) uint32
    crc_ok: bool
    magic_ok: bool


def _header_from_words(words: np.ndarray, body_crc: int) -> Header:
    crc_stored = pair_to_u64(*words[HEADER_CRC_SLOT])
    magic = pair_to_u64(*words[HEADER_MAGIC_SLOT])
    ts = pair_to_u64(*words[HEADER_TIMESTAMP_SLOT])
    return Header(
        crc=crc_stored, magic=magic, timestamp_ns=ts,
        user=np.array(words[HEADER_USER_SLOTS]),
        crc_ok=(body_crc == crc_stored), magic_ok=(magic == MAGIC))


def parse_header_chunk(chunk_bytes: np.ndarray) -> Header:
    words = bytes_to_slot_words(np.asarray(chunk_bytes, dtype=np.uint8))
    body = slot_words_to_bytes(words[1:])
    return _header_from_words(words, crc64(body))


def parse_header_chunks(chunk_bytes: np.ndarray) -> list[Header]:
    """Parse many 64 B header chunks at once -> list of :class:`Header`.

    The CRC-64 body check for every page runs as ONE :func:`crc64_rows`
    table pass, so a flush-wide open burst doesn't pay a per-page CRC loop.
    """
    chunks = np.asarray(chunk_bytes, dtype=np.uint8).reshape(-1, CHUNK_BYTES)
    body_crcs = crc64_rows(chunks[:, 8:])  # bytes of slots 1..7
    return [_header_from_words(bytes_to_slot_words(chunks[i]),
                               int(body_crcs[i]))
            for i in range(chunks.shape[0])]


# --------------------------------------------------------------------------
# Optimistic Error Correction pipeline
# --------------------------------------------------------------------------

class OpenVerdict(Enum):
    CLEAN = "clean"                  # fast path: match on-chip immediately
    CLEAN_NEEDS_REFRESH = "refresh"  # clean, but older than the safety margin
    FALLBACK_ECC = "fallback"        # CRC mismatch -> full-page outer decode
    UNCORRECTABLE = "uncorrectable"  # outer decode failed after read-retries


@dataclasses.dataclass
class EccConfig:
    t_correctable: int = 40           # outer-code budget (bits / 4 KiB page)
    max_read_retries: int = 5         # sensing-voltage retries (paper [17])
    refresh_margin_ns: int = int(30 * 24 * 3600 * 1e9)  # 30 days
    retry_fix_prob: float = 0.5       # per-retry chance a marginal page reads clean


@dataclasses.dataclass
class OpenResult:
    verdict: OpenVerdict
    header: Header | None
    retries_used: int = 0
    bits_corrected: int = 0


def optimistic_open(header_chunk: np.ndarray | None, *, now_ns: int,
                    injected_error_bits: int, cfg: EccConfig,
                    rng: np.random.Generator | None = None,
                    header: Header | None = None) -> OpenResult:
    """Model the page-open decision tree of §IV-C2.

    ``injected_error_bits`` is the simulator's ground-truth raw bit-error
    count for the page (the header chunk's own errors are already reflected
    in the bytes passed in, so the CRC check is real, not modelled).
    Callers that already parsed the header (e.g. a flush-wide open burst
    through :func:`parse_header_chunks`) pass ``header=`` and may leave
    ``header_chunk`` as None.
    """
    if header is None:
        header = parse_header_chunk(header_chunk)
    if header.crc_ok and header.magic_ok:
        if now_ns - header.timestamp_ns > cfg.refresh_margin_ns:
            return OpenResult(OpenVerdict.CLEAN_NEEDS_REFRESH, header)
        return OpenResult(OpenVerdict.CLEAN, header)

    # Fallback: full page is read out, outer code decodes.
    if injected_error_bits <= cfg.t_correctable:
        return OpenResult(OpenVerdict.FALLBACK_ECC, header,
                          bits_corrected=injected_error_bits)

    # Read-retry loop with adjusted sensing voltage; the magic number gives
    # the controller a known-plaintext anchor for calibrating the retry.
    if rng is None:
        raise ValueError(
            "optimistic_open reached the read-retry path without an RNG: "
            "pass the owning chip's seeded generator.  A shared default "
            "generator would replay the identical retry-outcome sequence "
            "for every marginal page in the fleet, making retry statistics "
            "degenerate.")
    for attempt in range(1, cfg.max_read_retries + 1):
        if rng.random() < cfg.retry_fix_prob:
            return OpenResult(OpenVerdict.FALLBACK_ECC, header,
                              retries_used=attempt,
                              bits_corrected=cfg.t_correctable)
    return OpenResult(OpenVerdict.UNCORRECTABLE, header,
                      retries_used=cfg.max_read_retries)


# --------------------------------------------------------------------------
# Concatenated chunk-level code (inner CRC-32 per chunk)
# --------------------------------------------------------------------------

def build_chunk_parities(page_bytes: np.ndarray) -> np.ndarray:
    """(64,) uint32 inner-code parities stored out-of-band with the page."""
    return crc32_chunks(page_bytes)


def verify_chunks(page_bytes: np.ndarray, parities: np.ndarray,
                  chunk_ids: np.ndarray) -> np.ndarray:
    """Check selected chunks against their stored parities -> (k,) bool."""
    fresh = crc32_chunks(page_bytes)
    chunk_ids = np.asarray(chunk_ids, dtype=np.int64)
    return fresh[chunk_ids] == np.asarray(parities, dtype=np.uint32)[chunk_ids]
