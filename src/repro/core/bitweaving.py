"""BitWeaving-style column packing into 8-byte SiM slots (paper §V-B, Fig 9/10).

Rows of a table are encoded into 64-bit keys with columns at fixed bit
ranges, ordered so that the *sort-significant* column occupies the most
significant bits (big-endian packing) — this keeps masked-prefix range tests
order-preserving, which §V-C's range decomposition relies on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .range_query import (MaskedQuery, RangePlan, approximate_range,
                          exact_range)

U64 = 0xFFFFFFFFFFFFFFFF


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    width: int            # bits


class RowCodec:
    """Packs named columns into a uint64, MSB-first in declaration order."""

    def __init__(self, columns: list[Column]):
        total = sum(c.width for c in columns)
        if total > 64:
            raise ValueError(f"columns need {total} bits > 64")
        self.columns = list(columns)
        self.shifts: dict[str, int] = {}
        self.widths: dict[str, int] = {}
        pos = 64
        for c in columns:
            pos -= c.width
            self.shifts[c.name] = pos
            self.widths[c.name] = c.width
        self.spare_bits = pos   # low bits left unused (zero-filled)

    # ---------------------------------------------------------------- encode
    def encode(self, **values: int) -> int:
        key = 0
        for c in self.columns:
            v = int(values.get(c.name, 0))
            if v >> c.width:
                raise ValueError(f"{c.name}={v} exceeds {c.width} bits")
            key |= v << self.shifts[c.name]
        return key & U64

    def encode_rows(self, rows: dict[str, np.ndarray]) -> np.ndarray:
        n = len(next(iter(rows.values())))
        key = np.zeros(n, dtype=np.uint64)
        for c in self.columns:
            v = np.asarray(rows.get(c.name, np.zeros(n)), dtype=np.uint64)
            if ((v >> np.uint64(c.width)) != 0).any():
                raise ValueError(f"{c.name} exceeds {c.width} bits")
            key |= v << np.uint64(self.shifts[c.name])
        return key

    def decode(self, key: int, name: str) -> int:
        return (int(key) >> self.shifts[name]) & ((1 << self.widths[name]) - 1)

    def decode_rows(self, keys: np.ndarray, name: str) -> np.ndarray:
        k = np.asarray(keys, dtype=np.uint64)
        return (k >> np.uint64(self.shifts[name])) & np.uint64(
            (1 << self.widths[name]) - 1)

    # ---------------------------------------------------------------- query
    def equals(self, name: str, value: int) -> MaskedQuery:
        """Point predicate column == value -> one masked search command."""
        shift, width = self.shifts[name], self.widths[name]
        mask = ((1 << width) - 1) << shift
        return MaskedQuery(query=(int(value) << shift) & U64, mask=mask)

    def range(self, name: str, lo: int, hi: int, *,
              exact: bool = True) -> RangePlan:
        """Range predicate lo <= column < hi."""
        shift, width = self.shifts[name], self.widths[name]
        fn = exact_range if exact else approximate_range
        return fn(lo, hi, shift=shift, width=width)
