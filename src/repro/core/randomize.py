"""Per-chunk data randomization (paper §IV-C1).

Modern SSDs XOR stored data with a deterministic pseudo-random stream so the
cell charge distribution stays balanced.  SiM's twist: the stream seed is
derived from the *chunk* address (not the page), so non-contiguous chunks can
be de-randomized independently by the gather command, and the *query key* is
randomized in the deserializer with the same stream — the stream then cancels
out inside the XOR match and matching runs directly on randomized data.

We implement the stream as a counter-based PRNG (two decorrelated fmix32
lanes per slot word), which is exactly the kind of LFSR-equivalent circuit a
flash deserializer uses, and is reproducible under both numpy and jnp (the
Pallas kernel regenerates the same stream on the fly in-VMEM).
"""
from __future__ import annotations

import numpy as np

from .bits import (CHUNKS_PER_PAGE, SLOTS_PER_CHUNK, SLOTS_PER_PAGE, mix2_32)

_LO_SALT = 0x9E3779B9
_HI_SALT = 0x7F4A7C15


def stream_words(page_addr, device_seed: int = 0, xp=np):
    """Randomization stream for one page: (512, 2) uint32.

    The counter for slot ``s`` of chunk ``c`` of page ``p`` is the global slot
    address ``(p*64 + c)*8 + s`` mixed with a device seed.  Chunk-addressed
    seeding means a chunk's stream never depends on its page offset.
    """
    page_addr = int(page_addr)
    chunk_base = np.uint32((page_addr * CHUNKS_PER_PAGE) & 0xFFFFFFFF)
    slot_idx = xp.arange(SLOTS_PER_PAGE, dtype=xp.uint32)
    ctr = (chunk_base * xp.uint32(SLOTS_PER_CHUNK) + slot_idx).astype(xp.uint32)
    ctr = ctr ^ xp.uint32(device_seed & 0xFFFFFFFF)
    lo = mix2_32(ctr, _LO_SALT, xp)
    hi = mix2_32(ctr, _HI_SALT, xp)
    return xp.stack([lo, hi], axis=-1)


def chunk_stream_words(page_addr: int, chunk_idx: int, device_seed: int = 0,
                       xp=np):
    """Stream for a single chunk: (8, 2) uint32 — used by gather-side
    de-randomization of non-contiguous chunks."""
    page_addr = int(page_addr)
    chunk_addr = np.uint32((page_addr * CHUNKS_PER_PAGE + chunk_idx) & 0xFFFFFFFF)
    slot_idx = xp.arange(SLOTS_PER_CHUNK, dtype=xp.uint32)
    ctr = (chunk_addr * xp.uint32(SLOTS_PER_CHUNK) + slot_idx).astype(xp.uint32)
    ctr = ctr ^ xp.uint32(device_seed & 0xFFFFFFFF)
    lo = mix2_32(ctr, _LO_SALT, xp)
    hi = mix2_32(ctr, _HI_SALT, xp)
    return xp.stack([lo, hi], axis=-1)


def chunk_stream_words_batch(page_addrs, chunk_ids, device_seeds, xp=np):
    """Streams for K (page, chunk, seed) triples at once: (K, 8, 2) uint32.

    Vectorized form of ``chunk_stream_words`` — one call de-randomizes every
    chunk of a whole gather/lookup burst instead of K per-chunk calls (the
    host tail of the batched backend's flush).  ``device_seeds`` may be a
    scalar (one chip) or a (K,) array (burst spanning chips).
    """
    pages = xp.asarray(page_addrs, dtype=xp.uint32)
    chunks = xp.asarray(chunk_ids, dtype=xp.uint32)
    seeds = xp.broadcast_to(xp.asarray(device_seeds).astype(xp.uint32),
                            pages.shape)
    chunk_addr = (pages * xp.uint32(CHUNKS_PER_PAGE) + chunks).astype(
        xp.uint32)
    slot_idx = xp.arange(SLOTS_PER_CHUNK, dtype=xp.uint32)
    ctr = (chunk_addr[:, None] * xp.uint32(SLOTS_PER_CHUNK)
           + slot_idx[None, :]).astype(xp.uint32)
    ctr = ctr ^ seeds[:, None]
    lo = mix2_32(ctr, _LO_SALT, xp)
    hi = mix2_32(ctr, _HI_SALT, xp)
    return xp.stack([lo, hi], axis=-1)


def randomize_page_words(words, page_addr, device_seed: int = 0, xp=np):
    """XOR a page of (512, 2) slot words with its stream (involution)."""
    return xp.asarray(words, dtype=xp.uint32) ^ stream_words(
        page_addr, device_seed, xp)


def randomize_query(query_pair, page_addr, device_seed: int = 0, xp=np):
    """Randomize an 8-byte query against every slot position of a page.

    Returns (512, 2) uint32: the per-slot randomized query the deserializer
    broadcasts down the bitlines.  XORing this with the randomized page data
    equals XORing the plain query with plain data — the cancellation property
    the whole scheme rests on (verified by tests/property).
    """
    q = xp.asarray(query_pair, dtype=xp.uint32)
    return q[None, :] ^ stream_words(page_addr, device_seed, xp)
