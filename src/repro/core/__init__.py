"""SiM core: the paper's contribution as a composable library.

Layers:
  bits/match     — the matching specification (shared numpy/jnp)
  page/randomize — on-flash layout and per-chunk randomization
  ecc            — verification header, Optimistic Error Correction,
                   concatenated chunk code
  commands       — the 4-command SIMD ISA
  engine         — functional chip model (latch pipeline, counters)
  range_query    — range -> masked-equality decomposition (approx + exact)
  bitweaving     — column packing for secondary indexes
  scheduler      — deadline-based batch matching
"""
from .bits import (BITMAP_WORDS, CHUNK_BYTES, CHUNKS_PER_PAGE, PAGE_BYTES,
                   SLOT_BYTES, SLOTS_PER_CHUNK, SLOTS_PER_PAGE, pack_bitmap,
                   pair_to_u64, popcount_words, u64_to_pair, unpack_bitmap)
from .bitweaving import Column, RowCodec
from .commands import (Command, GatherResponse, Op, ReadFullResponse,
                       SearchResponse)
from .ecc import EccConfig, OpenVerdict, optimistic_open
from .engine import SimChip, SimChipArray
from .match import gather_chunks, match_slots, search_page
from .page import EMPTY_SLOT, USER_SLOTS, BuiltPage, build_page
from .range_query import (MaskedQuery, RangePlan, approximate_range,
                          exact_range)
from .scheduler import DeadlineScheduler

__all__ = [
    "BITMAP_WORDS", "CHUNK_BYTES", "CHUNKS_PER_PAGE", "PAGE_BYTES",
    "SLOT_BYTES", "SLOTS_PER_CHUNK", "SLOTS_PER_PAGE", "pack_bitmap",
    "pair_to_u64", "popcount_words", "u64_to_pair", "unpack_bitmap",
    "Column", "RowCodec", "Command", "GatherResponse", "Op",
    "ReadFullResponse", "SearchResponse", "EccConfig", "OpenVerdict",
    "optimistic_open", "SimChip", "SimChipArray", "gather_chunks",
    "match_slots", "search_page", "EMPTY_SLOT", "USER_SLOTS", "BuiltPage",
    "build_page", "MaskedQuery", "RangePlan", "approximate_range",
    "exact_range", "DeadlineScheduler",
]
