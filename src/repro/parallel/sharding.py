"""Logical-axis sharding rules -> NamedShardings (DP/TP/EP/FSDP + pod).

Every parameter carries a tuple of logical axis names (models/*.py ``axes``
trees).  Rules map logical names to mesh axes; a dimension that does not
divide the mesh axis size is replicated instead (recorded — the roofline
notes call these out, e.g. hymba's 25 heads on a 16-way model axis).

Mesh contract (launch/mesh.py): axes ``(data, model)`` single-pod or
``(pod, data, model)`` multi-pod.  ``batch`` shards over (pod, data);
``fsdp``-tagged weight dims shard over the same product when cfg.fsdp.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _mesh_axes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


LOGICAL_TO_MESH = {
    "batch": "DATA",          # resolved to (pod, data)
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "expert_mlp": None,
    "embed": "FSDP",          # resolved to (pod, data) when cfg.fsdp
    "kv_seq": "model",
    "head_dim": None,
    "layers": None,
    "repeat": None,
}


def resolve_axis(logical: str | None, mesh: Mesh, *, fsdp: bool):
    if logical is None:
        return None
    kind = LOGICAL_TO_MESH.get(logical)
    if kind == "DATA":
        axes = data_axes(mesh)
        return axes if len(axes) > 1 else axes[0]
    if kind == "FSDP":
        if not fsdp:
            return None
        axes = data_axes(mesh)
        return axes if len(axes) > 1 else axes[0]
    return kind


def _axis_size(mesh: Mesh, resolved) -> int:
    sizes = _mesh_axes(mesh)
    if resolved is None:
        return 1
    if isinstance(resolved, tuple):
        n = 1
        for a in resolved:
            n *= sizes[a]
        return n
    return sizes[resolved]


def spec_for(dim_sizes: tuple[int, ...], logical_axes: tuple,
             mesh: Mesh, *, fsdp: bool = True,
             report: list | None = None) -> P:
    """Build a PartitionSpec; skip axes that don't divide evenly."""
    parts = []
    used = set()
    for size, logical in zip(dim_sizes, logical_axes):
        resolved = resolve_axis(logical, mesh, fsdp=fsdp)
        flat = tuple(resolved) if isinstance(resolved, tuple) else \
            ((resolved,) if resolved else ())
        if resolved is None or used & set(flat):
            parts.append(None)
            continue
        if size % _axis_size(mesh, resolved) != 0:
            if report is not None:
                report.append((logical, size, resolved))
            parts.append(None)
            continue
        used.update(flat)
        parts.append(resolved)
    return P(*parts)


def _lookup_axes(axes_tree, path):
    node = axes_tree
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            node = node[k.key]
        elif isinstance(k, jax.tree_util.SequenceKey):
            node = node[k.idx]
        else:                                   # GetAttrKey etc.
            node = getattr(node, k.name)
    return node


def shardings_for_tree(params, axes_tree, mesh: Mesh, *, fsdp: bool = True,
                       report: list | None = None):
    """NamedSharding tree matching ``params`` (arrays or ShapeDtypeStructs).

    ``axes_tree`` mirrors the params dict structure with logical-axis tuples
    at the leaves (tuples are containers to jax pytrees, hence the path-based
    lookup rather than a two-tree map).
    """
    def one(path, leaf):
        ax = _lookup_axes(axes_tree, path)
        spec = spec_for(tuple(leaf.shape), tuple(ax), mesh, fsdp=fsdp,
                        report=report)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def batch_sharding(mesh: Mesh) -> NamedSharding:
    axes = data_axes(mesh)
    return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def block_compute_shardings(blocks_sds, blocks_axes, mesh: Mesh):
    """Per-layer *compute* shardings for scanned block params: the leading
    ``layers`` stacking axis is dropped (scan slices it) and fsdp axes are
    gathered (mapped to None), keeping only tensor-parallel (model) axes.

    Constraining the scan-body weight slices to these shardings forces
    GSPMD into the FSDP pattern — all-gather the layer's weights over the
    data axis, compute, and reduce-scatter the weight gradients — instead
    of the partial-sum strategy (activation-sized all-reduces per layer)
    it otherwise picks.  §Perf quantifies the difference.
    """
    def one(path, leaf):
        ax = _lookup_axes(blocks_axes, path)
        spec = spec_for(tuple(leaf.shape)[1:], tuple(ax)[1:], mesh,
                        fsdp=False)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, blocks_sds)


# ---- activation constraint helpers (used by hillclimb variants) ----------

def constrain(x, mesh: Mesh, *dims):
    """with_sharding_constraint by logical dims, e.g. constrain(x, mesh,
    'batch', None, 'heads')."""
    parts = []
    used = set()
    for d in dims:
        r = resolve_axis(d, mesh, fsdp=True)
        flat = tuple(r) if isinstance(r, tuple) else ((r,) if r else ())
        if r is None or used & set(flat):
            parts.append(None)
        else:
            used.update(flat)
            parts.append(r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
