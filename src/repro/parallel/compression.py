"""Cross-pod gradient compression (hierarchy-aware distributed optimization).

Within a pod the ICI fabric is fast; across pods (DCI) bandwidth is scarce.
``make_compressed_train_step`` therefore keeps XLA's implicit in-pod
reductions (auto axes) and runs the *cross-pod* gradient reduction through
an explicit int8 error-feedback stage under a partial-manual shard_map over
the ``pod`` axis — 4x less DCI traffic than bf16 (8x vs f32), with each
pod's quantization residual carried into its next step (EF-SGD /
1-bit-Adam lineage; error feedback keeps the compressed reduction unbiased
over time).

Design constraint: this variant replicates parameters across pods (classic
cross-pod data parallelism).  FSDP spanning the pod axis would shard params
across pods and turn the cross-pod leg into a reduce-scatter of *disjoint*
shards — compressible too, but with per-shard scales; kimi-k2 (which needs
pod-spanning FSDP to fit) therefore runs uncompressed, as recorded in
DESIGN.md §5.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.train.optimizer import AdamWConfig, adamw_update
from repro.train.train_step import lm_loss


def quantize_int8(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def compressed_psum_pod(g, err, axis_name: str = "pod"):
    """int8 error-feedback mean over ``axis_name`` for one gradient leaf.

    g:   this pod's gradient (f32);  err: this pod's carried residual.
    Returns (mean gradient, new residual).  Wire format: int8 payload +
    one f32 scale per leaf per pod.
    """
    target = g + err
    scale = jnp.maximum(jnp.max(jnp.abs(target)) / 127.0, 1e-12)
    q = quantize_int8(target, scale)
    deq = q.astype(jnp.float32) * scale
    new_err = target - deq
    # Per-pod scales differ: reduce scale-weighted payloads.  The int8
    # tensor is the only O(n) cross-pod traffic.
    total = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    return total / n, new_err


def init_error_state(params, n_pods: int):
    """Per-pod error feedback state: leading ``pod`` dim on every leaf."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + tuple(p.shape), jnp.float32), params)


def error_state_shardings(params_sds, mesh):
    def one(leaf):
        return NamedSharding(mesh, P("pod"))
    return jax.tree.map(one, params_sds)


def make_compressed_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                               mesh, *, block_specs=None, act_spec=None):
    """Train step with int8 EF cross-pod gradient reduction.

    Signature: (params, opt_state, err_state, batch) ->
               (params, opt_state, err_state, metrics).
    Params must be replicated over ``pod`` (sharded over data/model only).
    """
    assert "pod" in mesh.axis_names

    def per_pod(params, err, tokens, labels, fe):
        # inside shard_map over {pod}: tokens/labels/err are this pod's
        # shard; data/model axes remain auto (XLA reduces in-pod).
        err = jax.tree.map(lambda e: e[0], err)      # drop pod-shard dim
        grad_fn = jax.value_and_grad(lm_loss, has_aux=True)
        (_, (loss, aux)), grads = grad_fn(params, cfg, tokens, labels, fe,
                                          block_specs, act_spec)
        flat = jax.tree.map(compressed_psum_pod, grads, err)
        g_new = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        e_new = jax.tree.map(lambda t: t[1][None], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        loss = jax.lax.pmean(loss, "pod")
        aux = jax.lax.pmean(aux, "pod")
        return g_new, e_new, loss, aux

    def per_pod_stacked(params, err_state, tokens, labels, fe):
        """jax 0.4.x fallback: the same per-pod compressed reduction as an
        explicit vmap over a leading pod axis.

        Partial-manual shard_map (manual ``pod``, auto data/model) trips an
        XLA CHECK (``sharding.IsManualSubgroup()``) in the pinned
        jaxlib 0.4.36, so on old jax we compute each pod's gradient with
        vmap (params broadcast — the replicated-over-pod contract), run the
        identical int8 error-feedback math on the stacked leaves, and take
        the dequantized mean — the same psum semantics, just expressed
        without a named pod axis.  XLA still shards the stacked batch over
        the mesh from the operand shardings.
        """
        n_pods = jax.tree.leaves(err_state)[0].shape[0]
        tok = tokens.reshape(n_pods, -1, *tokens.shape[1:])
        lab = labels.reshape(n_pods, -1, *labels.shape[1:])
        fe_p = fe.reshape(n_pods, -1, *fe.shape[1:]) if fe is not None \
            else None

        def one_pod(tokens, labels, fe):
            grad_fn = jax.value_and_grad(lm_loss, has_aux=True)
            (_, (loss, aux)), grads = grad_fn(params, cfg, tokens, labels,
                                              fe, block_specs, act_spec)
            return grads, loss, aux

        grads_stack, loss, aux = jax.vmap(
            one_pod, in_axes=(0, 0, 0 if fe_p is not None else None)
        )(tok, lab, fe_p)

        def compress(g_stack, err_stack):
            target = g_stack + err_stack              # (n_pods, ...)
            reduce_axes = tuple(range(1, target.ndim))
            scale = jnp.maximum(
                jnp.max(jnp.abs(target), axis=reduce_axes, keepdims=True)
                / 127.0, 1e-12)
            q = quantize_int8(target, scale)
            deq = q.astype(jnp.float32) * scale
            return deq.mean(axis=0), target - deq

        flat = jax.tree.map(compress, grads_stack, err_state)
        grads = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        err_state = jax.tree.map(lambda t: t[1], flat,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return grads, err_state, loss.mean(), aux.mean()

    def train_step(params, opt_state, err_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        fe = batch.get("frontend")
        if hasattr(jax, "shard_map"):
            sm = jax.shard_map(
                per_pod, mesh=mesh, axis_names={"pod"},
                in_specs=(P(), jax.tree.map(lambda _: P("pod"), err_state),
                          P("pod"), P("pod"),
                          P("pod") if fe is not None else P()),
                out_specs=(P(), jax.tree.map(lambda _: P("pod"), err_state),
                           P(), P()),
                check_vma=False)
            grads, err_state, loss, aux = sm(params, err_state, tokens,
                                             labels, fe)
        else:
            grads, err_state, loss, aux = per_pod_stacked(
                params, err_state, tokens, labels, fe)
        params, opt_state, opt_metrics = adamw_update(grads, opt_state,
                                                      params, opt_cfg)
        metrics = {"loss": loss, "aux_loss": aux, **opt_metrics}
        return params, opt_state, err_state, metrics

    return train_step
