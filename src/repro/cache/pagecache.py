"""OS page-cache model: LRU with dirty tracking and write absorption.

The experiments stress exactly the behaviours the paper leans on (§VII):
  * read-inserted *clean* pages compete with write-buffered *dirty* pages;
  * evicting a dirty page costs a flash program (write-back) — the latency
    chain behind the baseline's write-heavy collapse;
  * repeated writes to a cached dirty page are absorbed (coalescing) — the
    effect SiM amplifies by bypassing the cache for reads (§VII-A).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    absorbed_writes: int = 0
    clean_evictions: int = 0
    dirty_evictions: int = 0
    inserts: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class PageCache:
    """LRU page cache; capacity 0 disables caching entirely.

    ``max_dirty_fraction`` models Linux's vm.dirty_ratio writer throttling:
    once dirty pages exceed the fraction, inserting another dirty page first
    forces write-back of the least-recently-used dirty page.  The CPU-centric
    baseline runs with the kernel default (~0.2); SiM's application-managed
    write buffer is unconstrained (1.0) — this asymmetry, together with read
    bypass, is exactly the "frees the cache for write buffering" effect the
    paper's write-heavy speedups rest on (§VII-A).
    """

    def __init__(self, capacity_pages: int, max_dirty_fraction: float = 1.0):
        self.capacity = int(capacity_pages)
        self.max_dirty = max(1, int(capacity_pages * max_dirty_fraction)) \
            if capacity_pages else 0
        self._lru: OrderedDict[int, bool] = OrderedDict()   # page -> dirty
        self._dirty_count = 0
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, page: int) -> bool:
        return page in self._lru

    @property
    def dirty_count(self) -> int:
        return self._dirty_count

    def lookup(self, page: int) -> bool:
        """Read probe; refreshes recency on hit."""
        if self.capacity and page in self._lru:
            self._lru.move_to_end(page)
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        return False

    def _pop_lru(self, dirty_only: bool) -> tuple[int, bool] | None:
        if dirty_only:
            for p, d in self._lru.items():          # LRU order
                if d:
                    del self._lru[p]
                    self._dirty_count -= 1
                    self.stats.dirty_evictions += 1
                    return (p, True)
            return None
        victim, was_dirty = self._lru.popitem(last=False)
        if was_dirty:
            self._dirty_count -= 1
            self.stats.dirty_evictions += 1
        else:
            self.stats.clean_evictions += 1
        return (victim, was_dirty)

    def insert(self, page: int, dirty: bool) -> list[tuple[int, bool]]:
        """Insert/update a page; returns evicted [(page, was_dirty), ...].

        Writing a page that is already resident marks it dirty and counts as
        an absorbed write (no flash I/O now or later for the overwritten
        version).  Dirty inserts above the dirty budget force write-back of
        the LRU dirty page (writer throttling).
        """
        if self.capacity == 0:
            return []
        evicted: list[tuple[int, bool]] = []
        if page in self._lru:
            was = self._lru[page]
            if dirty and was:
                self.stats.absorbed_writes += 1
            if dirty and not was:
                if self._dirty_count >= self.max_dirty:
                    ev = self._pop_lru(dirty_only=True)
                    if ev:
                        evicted.append(ev)
                self._dirty_count += 1
            self._lru[page] = was or dirty
            self._lru.move_to_end(page)
            return evicted
        self.stats.inserts += 1
        if dirty and self._dirty_count >= self.max_dirty:
            ev = self._pop_lru(dirty_only=True)
            if ev:
                evicted.append(ev)
        if len(self._lru) >= self.capacity:
            ev = self._pop_lru(dirty_only=False)
            if ev:
                evicted.append(ev)
        self._lru[page] = dirty
        if dirty:
            self._dirty_count += 1
        return evicted

    def flush_all(self) -> list[int]:
        """Drop everything; returns dirty pages that need write-back."""
        dirty = [p for p, d in self._lru.items() if d]
        self._lru.clear()
        self._dirty_count = 0
        return dirty
