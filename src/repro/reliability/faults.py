"""Deterministic fault injection for fault-enabled replays (paper §IV-C).

Two orthogonal error sources, both fully determined by a single fault seed:

  * **Stored-image errors** — retention/endurance damage to the on-flash
    (randomized) page image.  :class:`FaultModel` turns a retention age and
    P-E cycle count into a per-page raw bit-error count (a binomial draw at
    the page's raw BER) and applies it through the engine's
    ``inject_bit_errors`` + write-observer path, so the kernel backends'
    device-resident arenas see exactly the corrupted planes the scalar
    reference matches against.
  * **Transient sense noise** — per-pass comparator flips during match-mode
    sensing.  Match-mode reads cannot ECC-decode inside the latch (§IV-C),
    so this noise lands directly in the 512-bit match bitmap; the
    reliability policy suppresses it by majority voting across ``vote_k``
    repeated sense passes and by selective verification reads on hits.

Every random draw is keyed on ``(fault seed, chip seed, page, ...)`` SeedSequence
entropy, never on a shared stream, so a sweep reproduces bit-identically
across scalar/batched/sharded backends and across process restarts.

The BER growth law is the usual retention power law: the raw BER grows as
``(1 + age / retention_ref_days) ** retention_exp`` and linearly-in-log with
P-E cycling, anchored at ``base_ber``.  The reference margin matches
``EccConfig.refresh_margin_ns`` (30 days) so pages older than the refresh
margin are exactly the pages whose BER has visibly drifted.
"""
from __future__ import annotations

import dataclasses
from math import comb

import numpy as np

from repro.core.bits import PAGE_BYTES, SLOTS_PER_PAGE, pack_bitmap
from repro.core.page import USER_SLOTS

DAY_NS = int(24 * 3600 * 1e9)


@dataclasses.dataclass
class FaultModel:
    """Seeded per-page raw-BER model plus transient sense noise."""

    seed: int = 0
    base_ber: float = 1e-4          # raw BER at age 0, 0 P-E cycles
    retention_days: float = 0.0     # page age at replay time
    pe_cycles: int = 0
    retention_ref_days: float = 30.0   # matches EccConfig.refresh_margin_ns
    retention_exp: float = 2.5
    pe_ref_cycles: int = 3000
    pe_exp: float = 1.0
    sense_ber: float = 0.0          # per-slot comparator flip prob / pass

    def raw_ber(self) -> float:
        """Raw bit-error rate after aging/endurance scaling."""
        age = (1.0 + self.retention_days / self.retention_ref_days) \
            ** self.retention_exp
        wear = (1.0 + self.pe_cycles / self.pe_ref_cycles) ** self.pe_exp
        return min(self.base_ber * age * wear, 1.0)

    @property
    def now_ns(self) -> int:
        """Replay clock implied by the retention age (page writes are t=0)."""
        return int(self.retention_days * DAY_NS)

    def error_bits_for(self, chip_seed: int, local_addr: int) -> int:
        """Ground-truth raw error count for one page — a binomial draw at
        the page's BER, keyed on (fault seed, chip, page) only."""
        rng = np.random.default_rng(
            [self.seed, chip_seed & 0xFFFFFFFF, local_addr])
        return int(rng.binomial(PAGE_BYTES * 8, self.raw_ber()))

    def inject(self, chips) -> int:
        """Corrupt every programmed page of a SimChipArray in place.

        Flips ride ``SimChip.inject_bit_errors`` so the write observers fire
        and any device-resident arena row is invalidated — batched/sharded
        backends match against the same damaged planes as the scalar
        reference.  Returns the total number of injected error bits.
        """
        total = 0
        for chip in chips.chips:
            for local in sorted(chip.pages):
                n = self.error_bits_for(chip.device_seed, local)
                if n:
                    rng = np.random.default_rng(
                        [self.seed ^ 0x5EED, chip.device_seed & 0xFFFFFFFF,
                         local])
                    chip.inject_bit_errors(local, n, rng=rng)
                    total += n
        return total

    def slot_noise_words(self, page_addr: int, epoch: int, pass_idx: int,
                        query_hash: int) -> np.ndarray:
        """(16,) uint32 XOR mask for one match-mode sense pass.

        Each of the 512 comparator outputs flips independently with
        probability ``sense_ber``.  The draw is keyed on the page, the
        page-open epoch, the vote pass index and the query, so repeated
        sense passes of one open see *independent* noise (what voting
        averages over) while a replay of the same flush sequence — on any
        backend — sees identical noise.
        """
        if self.sense_ber <= 0.0:
            return np.zeros(16, dtype=np.uint32)
        rng = np.random.default_rng(
            [self.seed ^ 0xA11CE, page_addr, epoch, pass_idx,
             query_hash & 0xFFFFFFFF])
        flips = rng.random(SLOTS_PER_PAGE) < self.sense_ber
        return pack_bitmap(flips.astype(np.uint32))


# --------------------------------------------------------------------------
# Analytic bounds for the BER sweep (documented next to
# range_query.false_positive_bound, which bounds the *plan decomposition's*
# structural false positives; these bound the *sensing noise's*).
# --------------------------------------------------------------------------

def majority_flip_prob(p: float, k: int) -> float:
    """P[a comparator bit is flipped in the majority of k sense passes]."""
    k = max(int(k), 1)
    need = k // 2 + 1
    return float(sum(comb(k, j) * p ** j * (1.0 - p) ** (k - j)
                     for j in range(need, k + 1)))


def sense_false_positive_bound(sense_ber: float, vote_k: int = 1,
                               n_slots: int = USER_SLOTS) -> float:
    """Per-query bound: P[>= 1 spurious user slot survives voting].

    With per-slot flip probability p and k-pass majority voting, a
    non-matching slot reads as a hit with probability q = majority_flip
    (p, k); a union bound over the page's user slots gives
    ``1 - (1 - q) ** n_slots``.  Unverified match results violate this
    bound with probability 0 — the sweep asserts the measured rate under it.
    """
    q = majority_flip_prob(sense_ber, vote_k)
    return 1.0 - (1.0 - q) ** n_slots


def sense_false_negative_bound(sense_ber: float, vote_k: int = 1) -> float:
    """Per-hit bound: P[a genuinely matching slot is voted out]."""
    return majority_flip_prob(sense_ber, vote_k)
