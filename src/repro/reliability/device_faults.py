"""Device-level fault model: outages, stalls, grown bad blocks (§IV-C).

PR 7's :class:`FaultModel` damages *bits*; this module damages *devices*.
A :class:`FaultSchedule` is a frozen, seeded description of everything
that goes wrong with the hardware during one replay:

  * **transient stalls** (:class:`StallWindow`) — a die or channel is
    unavailable for a window of simulated time (a retention scrub, a
    thermal throttle, a firmware hiccup).  Stalls are *scheduled onto the
    SSDSim resource lines* (``die_sense_free``/``die_prog_free``/
    ``chan_free``) by :meth:`BurstTimeline service <repro.flash.timeline.
    BurstTimeline.observe_flush>`, so a burst that lands in a window
    queues behind it exactly like any other resource contention — which
    is how stalls surface as command timeouts in the event frontend;
  * **permanent outages** (:class:`ChipOutage`) — a chip (== die in the
    adapter geometry) stops answering at ``t_fail_ns`` and never comes
    back.  The sharded backend serves its pages from replicas
    (``failovers``) or degrades to host-side full-page reads; a page with
    no surviving replica fails its ticket with a typed
    :class:`DegradedReadError`;
  * **program failures** — a page program fails with probability
    ``program_fail_prob`` (a seeded per-(page, attempt) draw), growing
    the bad-block set: the backend remaps the page to a spare and
    reprograms (``remapped_blocks``), bounded-retry, never silently.

Every draw is keyed on ``(schedule seed, page, attempt)`` SeedSequence
entropy — the same discipline as :class:`repro.reliability.faults.
FaultModel` — so one seed reproduces byte-identical fault counters
across backends and process restarts (the chaos-sweep CI contract).

:class:`DeviceFaultState` is the mutable replay-side wrapper: it carries
the monotone fault clock (advanced by the event loop at every dispatch),
the grown bad-block set, the remap table, and the :class:`FaultStats`
counters that ``RunReport.faults`` snapshots.
"""
from __future__ import annotations

import dataclasses

import numpy as np

MS_NS = 1_000_000.0


class DegradedReadError(RuntimeError):
    """A page's chip is dead and no replica survives: the typed per-ticket
    error surfaced in place of a wrong (or hung) match result."""

    def __init__(self, page_addr: int, message: str | None = None):
        self.page_addr = page_addr
        super().__init__(message or
                         f"page {page_addr}: chip offline and no live "
                         f"replica (degraded read impossible)")


class CommandTimeoutError(RuntimeError):
    """A request exceeded its deadline on every allowed attempt: the typed
    completion the event loop reports instead of blocking forever."""

    def __init__(self, qi: int, attempts: int, deadline_ns: float):
        self.qi = qi
        self.attempts = attempts
        self.deadline_ns = deadline_ns
        super().__init__(f"op {qi}: {attempts} attempt(s) all exceeded the "
                         f"{deadline_ns:.0f} ns deadline")


class OverloadShedError(RuntimeError):
    """The NCQ and its overflow queue are full: the arrival is shed with a
    typed error instead of queueing unboundedly (backpressure, not OOM)."""

    def __init__(self, qi: int):
        self.qi = qi
        super().__init__(f"op {qi}: shed at admission (queue at capacity)")


@dataclasses.dataclass(frozen=True)
class StallWindow:
    """One die or channel unavailable during [t_start_ns, t_end_ns)."""
    kind: str                   # "die" | "channel"
    target: int                 # die index or channel index
    t_start_ns: float
    t_end_ns: float

    def __post_init__(self) -> None:
        if self.kind not in ("die", "channel"):
            raise ValueError(f"stall kind {self.kind!r} not die/channel")
        if self.t_end_ns <= self.t_start_ns:
            raise ValueError("stall window must have t_end_ns > t_start_ns")


@dataclasses.dataclass(frozen=True)
class ChipOutage:
    """Chip (== die) permanently offline from ``t_fail_ns`` on."""
    chip: int
    t_fail_ns: float = 0.0


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Frozen, seeded description of one replay's device faults."""
    seed: int = 0
    stalls: tuple = ()          # tuple[StallWindow, ...]
    outages: tuple = ()         # tuple[ChipOutage, ...]
    program_fail_prob: float = 0.0

    def __post_init__(self) -> None:
        object.__setattr__(self, "stalls", tuple(self.stalls))
        object.__setattr__(self, "outages", tuple(self.outages))
        if not 0.0 <= self.program_fail_prob < 1.0:
            raise ValueError("program_fail_prob must be in [0, 1)")

    # ------------------------------------------------------------ scenarios
    @classmethod
    def healthy(cls, seed: int = 0) -> "FaultSchedule":
        """No faults — the parity anchor (replay must be bit-identical to
        the fault-free replay, counters all zero)."""
        return cls(seed=seed)

    @classmethod
    def transient_stall(cls, *, die: int = 0, t_start_ms: float = 0.1,
                        dur_ms: float = 2.0, seed: int = 0
                        ) -> "FaultSchedule":
        """One die stalls mid-run (a scrub/throttle window): reads queue
        behind the window, time out, and recover via retry/backoff."""
        t0 = t_start_ms * MS_NS
        return cls(seed=seed, stalls=(
            StallWindow("die", die, t0, t0 + dur_ms * MS_NS),))

    @classmethod
    def dying_die(cls, *, die: int = 1, t_fail_ms: float = 0.5,
                  program_fail_prob: float = 0.02, seed: int = 0
                  ) -> "FaultSchedule":
        """A die browns out (repeated stalls), then fails for good, with
        elevated program failures growing bad blocks along the way."""
        t_fail = t_fail_ms * MS_NS
        stalls = tuple(
            StallWindow("die", die, t_fail * f, t_fail * (f + 0.15))
            for f in (0.2, 0.5, 0.8))
        return cls(seed=seed, stalls=stalls,
                   outages=(ChipOutage(die, t_fail),),
                   program_fail_prob=program_fail_prob)

    @classmethod
    def dead_chip(cls, *, chip: int = 0, seed: int = 0) -> "FaultSchedule":
        """A chip dead from t=0: every read of its pages must fail over to
        a replica (or degrade host-side) — none may return wrong data."""
        return cls(seed=seed, outages=(ChipOutage(chip, 0.0),))


@dataclasses.dataclass
class FaultStats:
    """Fault-path outcome counters (the ``faults`` report section).

    All counts are deterministic under one (workload seed, fault seed)
    pair — the chaos-sweep regression gate holds them exactly.
    """
    timeouts: int = 0           # deadline expiries (one per timed-out burst
                                # membership, before the retry decision)
    retries: int = 0            # NCQ re-admissions of timed-out requests
    backoff_waits: int = 0      # backoff delays served before re-admission
    hedges_won: int = 0         # hedged duplicate bursts that finished first
    failovers: int = 0          # reads served from a replica page
    remapped_blocks: int = 0    # grown bad blocks remapped to spares
    degraded_ops: int = 0       # host-side full-page degraded executions
    shed_requests: int = 0      # arrivals shed at admission (backpressure)
    replica_programs: int = 0   # extra page programs fanning out to replicas
    program_failures: int = 0   # seeded program-failure draws that fired

    def snapshot(self) -> "FaultStats":
        return dataclasses.replace(self)


class DeviceFaultState:
    """Mutable replay-side fault state shared by backend and frontend.

    One instance per replay: the event loop advances :attr:`now_ns` at
    every dispatch, the sharded backend consults :meth:`chip_dead` /
    :meth:`program_fails` at flush time, and the timeline schedules
    :meth:`stalls_active_at` onto the SSDSim resource lines — so timing
    and functional behaviour agree on what has failed *when*.
    """

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self.now_ns = 0.0
        self.stats = FaultStats()
        self.bad_blocks: set[int] = set()      # global page addrs gone bad
        self.remap: dict[int, int] = {}        # global addr -> spare addr

    # --------------------------------------------------------------- clock
    def advance(self, t_ns: float) -> None:
        """Monotone fault clock: dispatch timestamps only move it forward."""
        if t_ns > self.now_ns:
            self.now_ns = t_ns

    # -------------------------------------------------------------- faults
    def chip_dead(self, chip: int, at_ns: float | None = None) -> bool:
        t = self.now_ns if at_ns is None else at_ns
        return any(o.chip == chip and t >= o.t_fail_ns
                   for o in self.schedule.outages)

    def dead_chips(self, at_ns: float | None = None) -> set[int]:
        t = self.now_ns if at_ns is None else at_ns
        return {o.chip for o in self.schedule.outages if t >= o.t_fail_ns}

    def stalls_active_at(self, t_ns: float):
        """Windows that have started by ``t_ns`` and not yet ended —
        the set the timeline blocks its resource lines with."""
        return [w for w in self.schedule.stalls
                if w.t_start_ns <= t_ns < w.t_end_ns]

    def program_fails(self, page_addr: int, attempt: int) -> bool:
        """Seeded per-(page, attempt) program-failure draw."""
        p = self.schedule.program_fail_prob
        if p <= 0.0:
            return False
        rng = np.random.default_rng(
            [self.schedule.seed ^ 0xBADB10C, page_addr, attempt])
        fired = bool(rng.random() < p)
        if fired:
            self.stats.program_failures += 1
        return fired

    def mark_bad(self, page_addr: int, spare_addr: int) -> None:
        """Grow the bad-block set and record the spare remap."""
        self.bad_blocks.add(page_addr)
        self.remap[page_addr] = spare_addr
        self.stats.remapped_blocks += 1
