"""ECC-aware match execution: the reliability tier behind every backend.

Match-mode reads cannot ECC-decode inside the latch (paper §IV-C), so a
fault-enabled replay wraps every search/plan/lookup burst in the §IV-C2/C3
machinery:

  * **Open burst** — once per flush, every touched page runs
    ``optimistic_open`` against its *current* (possibly damaged) header:
    CLEAN proceeds on the fast path, FALLBACK_ECC charges a full-page
    storage-mode read (and repairs the stored image through the write
    observers, so kernel arenas restage the corrected plane in the same
    flush), CLEAN_NEEDS_REFRESH queues the page for a refresh rewrite, and
    UNCORRECTABLE fails the page's tickets with a typed
    :class:`UncorrectableReadError` instead of returning a wrong bitmap.
  * **Voting** — the raw match bitmap is re-sensed ``vote_k`` times under
    independent transient noise and majority-voted, suppressing comparator
    false positives/negatives before any bus transfer.
  * **Selective verification** — only the chunks holding match *hits* are
    re-read and checked against their inner CRC-32 parities
    (``verify_chunks``); a parity mismatch escalates to the full-page
    outer-code fallback.  Verified hit chunks are replaced by an exact
    host-side recompute, so every surviving hit equals the oracle's.

The finalize steps are *chunk-wise idempotent*: a verified hit chunk's bits
equal the clean image's bits whether the page was repaired before, during,
or after this command's resolution, so scalar (eager, submission-order
resolve) and the kernel backends (lazy, phase-order resolve) produce
bit-identical bitmaps, values, and error outcomes under one fault seed.
Reliability traffic is accounted in :class:`ReliabilityStats` (and, for the
sharded backend, on the flash timelines) — never in ``BackendStats``, whose
staged/result byte counters stay reconciled against the traced jaxpr.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import ecc
from repro.core.bits import (SLOTS_PER_CHUNK, SLOTS_PER_PAGE, pack_bitmap,
                             popcount_words, unpack_bitmap)
from repro.core.commands import (Command, GatherResponse, LookupResponse,
                                 SearchResponse)
from repro.core.ecc import EccConfig, OpenVerdict, optimistic_open
from repro.core.page import mask_header_slots, page_slot_words
from repro.core.randomize import randomize_query

from .faults import FaultModel


class UncorrectableReadError(RuntimeError):
    """A page's outer code failed after read-retries: the per-ticket error
    surfaced in place of a wrong match result (typed, so callers can count
    it instead of consuming garbage)."""

    def __init__(self, page_addr: int, message: str | None = None):
        self.page_addr = page_addr
        super().__init__(message or
                         f"page {page_addr}: uncorrectable after read-retry "
                         f"(raw error count above the outer-code budget)")


def require_clean(resp):
    """Acknowledge the verdict channel of a match response.

    Raises :class:`UncorrectableReadError` when the response's page open
    reported UNCORRECTABLE (reached only on legacy paths that bypass the
    per-ticket error channel), and returns the response otherwise.  This is
    the canonical consumption marker the SIM005 analysis rule looks for:
    every site that reads ``bitmap_words``/``match_count``/``value_slot``
    either calls this, inspects ``open_verdict``/``parity_ok`` itself, or
    handles :class:`UncorrectableReadError`.
    """
    search = getattr(resp, "search", None)
    verdict = getattr(search if search is not None else resp,
                      "open_verdict", None)
    if verdict == OpenVerdict.UNCORRECTABLE.value:
        raise UncorrectableReadError(-1, "match result consumed from an "
                                         "uncorrectable page open")
    return resp


@dataclasses.dataclass
class ReliabilityPolicy:
    """Knobs of the §IV-C2/C3 pipeline (see README "Reliability tier")."""

    ecc: EccConfig = dataclasses.field(default_factory=EccConfig)
    verify_hits: bool = True      # chunk-parity verification reads on hits
    fallback_on_miss: bool = True  # full-page fallback when a LOOKUP misses
    vote_k: int = 1               # sense passes for majority voting


@dataclasses.dataclass
class ReliabilityStats:
    opens: int = 0              # optimistic page opens performed
    clean_opens: int = 0
    retries: int = 0            # sensing-voltage read-retries
    fallbacks: int = 0          # open-time full-page ECC fallbacks
    uncorrectable: int = 0      # outer-code decode failures (typed errors)
    corrected_bits: int = 0
    refresh_marked: int = 0     # distinct pages queued for refresh
    refreshes: int = 0          # refresh rewrites executed (runner drains)
    vote_passes: int = 0        # extra sense passes charged by voting
    verify_reads: int = 0       # selective hit-chunk verification reads
    verify_failures: int = 0    # inner-parity mismatches found by them
    fallback_reads: int = 0     # full-page storage-mode reads (open+resolve)
    miss_fallbacks: int = 0     # lookup misses escalated to a full read
    wrong_value_parity: int = 0  # corrupted value chunks served unverified


@dataclasses.dataclass
class PageOpen:
    """One page's open outcome within a flush, captured into the flush's
    resolve closures (state dicts move on — the next flush may re-open the
    page before this flush's lazy tails run)."""

    result: ecc.OpenResult
    epoch: int                  # open sequence number, keys the sense noise

    @property
    def verdict(self) -> OpenVerdict:
        return self.result.verdict


def match_bitmap(chip, local_addr: int, query, mask) -> np.ndarray:
    """Noise-free host recompute of one masked-equality search against the
    chip's *current* stored image — the bits a full-page storage-mode read
    plus controller-side compare would produce (the §IV-C3 verified path).
    No latch or counter side effects."""
    sp = chip.pages[local_addr]
    words = page_slot_words(sp.raw)
    q = randomize_query(np.array(query, dtype=np.uint32), local_addr,
                        chip.device_seed)
    mk = np.array(mask, dtype=np.uint32)
    mismatch = ((words[:, 0] ^ q[:, 0]) & mk[0]) | (
        (words[:, 1] ^ q[:, 1]) & mk[1])
    return pack_bitmap((mismatch == 0).astype(np.uint32))


def plan_bitmap(chip, local_addr: int, plan_include, plan_exclude
                ) -> np.ndarray:
    """Host recompute of a multi-pass plan (OR includes, AND-NOT excludes)."""
    acc = np.zeros(16, dtype=np.uint32)
    for q, mk in plan_include:
        acc |= match_bitmap(chip, local_addr, q, mk)
    for q, mk in plan_exclude or ():
        acc &= ~match_bitmap(chip, local_addr, q, mk)
    return acc


def _mix_ints(*vals: int) -> int:
    h = 0x811C9DC5
    for v in vals:
        h = ((h * 1000003) ^ (int(v) & 0xFFFFFFFF)) & 0xFFFFFFFF
    return h


def _search_hash(cmd: Command) -> int:
    return _mix_ints(*cmd.query, *cmd.mask)


def _plan_hash(cmd: Command) -> int:
    flat: list[int] = [len(cmd.plan_include), len(cmd.plan_exclude or ())]
    for q, mk in list(cmd.plan_include) + list(cmd.plan_exclude or ()):
        flat += [*q, *mk]
    return _mix_ints(*flat)


class ReliabilityState:
    """Per-replay reliability context shared by a backend's flushes.

    Holds the policy, the fault model, the running stats, the refresh queue
    and the per-page open-epoch counters.  One instance is attached to one
    backend via ``MatchBackend.enable_reliability`` (usually through
    ``replay(..., RunConfig.reliable(...))``).
    """

    def __init__(self, policy: ReliabilityPolicy | None = None,
                 fault_model: FaultModel | None = None, *,
                 seed: int = 0, now_ns: int | None = None):
        self.policy = policy or ReliabilityPolicy()
        self.fault_model = fault_model
        self.seed = seed if fault_model is None else fault_model.seed
        self.now_ns = now_ns if now_ns is not None else (
            fault_model.now_ns if fault_model is not None else 0)
        self.stats = ReliabilityStats()
        self.refresh_due: set[int] = set()
        self._epochs: dict[int, int] = {}

    def install(self, backend) -> int:
        """Attach to a backend and corrupt its stored pages per the fault
        model.  Returns the number of injected error bits."""
        backend.enable_reliability(self)
        if self.fault_model is not None:
            return self.fault_model.inject(backend.chips)
        return 0

    @property
    def vote_factor(self) -> int:
        """Sense/match multiplier voting imposes on the timeline (1 when
        there is no transient noise to vote over)."""
        fm = self.fault_model
        if fm is None or fm.sense_ber <= 0.0:
            return 1
        return max(self.policy.vote_k, 1)

    # ----------------------------------------------------------- open burst
    def open_burst(self, chips, page_addrs) -> dict[int, PageOpen]:
        """Optimistically open every unique page a flush touches.

        Runs *before* the kernel backends stage plane rows, so an open-time
        ECC fallback repairs the stored image and the same flush's staging
        pass ships the corrected row.  Header CRCs for the whole burst are
        checked in ONE vectorized pass (``parse_header_chunks``).  Retry
        randomness is keyed per (fault seed, chip, page, open epoch) — the
        satellite fix to the shared-default-generator degeneracy.
        """
        addrs = sorted({int(a) for a in page_addrs})
        if not addrs:
            return {}
        routed = []
        header_chunks = []
        for a in addrs:
            chip, local = chips.route(a)
            sp = chip.pages[local]
            routed.append((a, chip, local, sp))
            header_chunks.append(chip._derandomized_chunk(sp, local, 0))
        headers = ecc.parse_header_chunks(np.stack(header_chunks))
        out: dict[int, PageOpen] = {}
        for (a, chip, local, sp), header in zip(routed, headers):
            epoch = self._epochs.get(a, 0)
            self._epochs[a] = epoch + 1
            rng = np.random.default_rng(
                [self.seed, chip.device_seed & 0xFFFFFFFF, local, epoch])
            res = optimistic_open(
                None, now_ns=self.now_ns,
                injected_error_bits=sp.injected_error_bits,
                cfg=self.policy.ecc, rng=rng, header=header)
            self.stats.opens += 1
            self.stats.retries += res.retries_used
            if res.verdict is OpenVerdict.CLEAN:
                self.stats.clean_opens += 1
            elif res.verdict is OpenVerdict.CLEAN_NEEDS_REFRESH:
                if a not in self.refresh_due:
                    self.refresh_due.add(a)
                    self.stats.refresh_marked += 1
                chip.counters.open_refreshes += 1
            elif res.verdict is OpenVerdict.FALLBACK_ECC:
                self.stats.fallbacks += 1
                self.stats.fallback_reads += 1
                self.stats.corrected_bits += res.bits_corrected
                chip.counters.open_fallbacks += 1
                chip._repair(sp, local)
            else:  # UNCORRECTABLE — leave damaged; tickets fail typed
                self.stats.uncorrectable += 1
                chip.counters.open_fallbacks += 1
            out[a] = PageOpen(res, epoch)
        return out

    # ------------------------------------------------------- finalize paths
    def _vote(self, page_addr: int, epoch: int, query_hash: int,
              bitmap: np.ndarray) -> np.ndarray:
        """Majority-vote the raw bitmap across vote_k noisy sense passes."""
        fm = self.fault_model
        if fm is None or fm.sense_ber <= 0.0:
            return bitmap
        k = max(self.policy.vote_k, 1)
        votes = np.zeros(SLOTS_PER_PAGE, dtype=np.int32)
        for j in range(k):
            noisy = bitmap ^ fm.slot_noise_words(page_addr, epoch, j,
                                                 query_hash)
            votes += unpack_bitmap(noisy, SLOTS_PER_PAGE)
        self.stats.vote_passes += k - 1
        return pack_bitmap((votes * 2 > k).astype(np.uint32))

    def _resolve_fallback(self, chips, page_addr: int) -> None:
        """Full-page storage-mode read + outer decode at resolve time
        (verification failure or lookup-miss escalation)."""
        chip, local = chips.route(page_addr)
        sp = chip.pages[local]
        self.stats.fallback_reads += 1
        chip.counters.array_reads += 1
        chip.counters.full_reads += 1
        if sp.injected_error_bits == 0:
            return
        if sp.injected_error_bits <= self.policy.ecc.t_correctable:
            self.stats.corrected_bits += sp.injected_error_bits
            chip._repair(sp, local)
        else:
            self.stats.uncorrectable += 1
            raise UncorrectableReadError(page_addr)

    def _verify_hits(self, chips, page_addr: int, bitmap: np.ndarray,
                     recompute) -> np.ndarray:
        """Selective verification (§IV-C3): re-read only the chunks holding
        hits, check inner parities, and replace their bits with the exact
        host recompute.  A parity mismatch escalates to the full-page
        fallback (repairing the page, or raising when above budget)."""
        hits = unpack_bitmap(mask_header_slots(bitmap), SLOTS_PER_PAGE)
        hit_chunks = np.unique(np.nonzero(hits)[0] // SLOTS_PER_CHUNK)
        if hit_chunks.size == 0:
            return bitmap
        chip, local = chips.route(page_addr)
        sp = chip.pages[local]
        self.stats.verify_reads += int(hit_chunks.size)
        chip.counters.chunks_gathered += int(hit_chunks.size)
        ok = ecc.verify_chunks(chip._derandomize_page(sp, local),
                               sp.chunk_parities, hit_chunks)
        if not ok.all():
            self.stats.verify_failures += int((~ok).sum())
            self._resolve_fallback(chips, page_addr)
        true_bits = unpack_bitmap(recompute(), SLOTS_PER_PAGE)
        out = unpack_bitmap(bitmap, SLOTS_PER_PAGE).copy()
        for c in hit_chunks:
            lo = int(c) * SLOTS_PER_CHUNK
            out[lo:lo + SLOTS_PER_CHUNK] = true_bits[lo:lo + SLOTS_PER_CHUNK]
        return pack_bitmap(out)

    def _finalize_bitmap(self, chips, cmd: Command, raw_bitmap: np.ndarray,
                         opens: dict[int, PageOpen], query_hash: int,
                         recompute) -> SearchResponse:
        po = opens[cmd.page_addr]
        if po.verdict is OpenVerdict.UNCORRECTABLE:
            raise UncorrectableReadError(cmd.page_addr)
        bitmap = self._vote(cmd.page_addr, po.epoch, query_hash,
                            np.asarray(raw_bitmap, dtype=np.uint32))
        if self.policy.verify_hits:
            bitmap = self._verify_hits(chips, cmd.page_addr, bitmap,
                                       recompute)
        return SearchResponse(bitmap_words=bitmap,
                              match_count=int(popcount_words(bitmap).sum()),
                              open_verdict=po.verdict.value)

    def finalize_search(self, chips, cmd: Command, raw_bitmap,
                        opens: dict[int, PageOpen]) -> SearchResponse:
        chip, local = chips.route(cmd.page_addr)
        return self._finalize_bitmap(
            chips, cmd, raw_bitmap, opens, _search_hash(cmd),
            lambda: match_bitmap(chip, local, cmd.query, cmd.mask))

    def finalize_plan(self, chips, cmd: Command, raw_bitmap,
                      opens: dict[int, PageOpen]) -> SearchResponse:
        chip, local = chips.route(cmd.page_addr)
        return self._finalize_bitmap(
            chips, cmd, raw_bitmap, opens, _plan_hash(cmd),
            lambda: plan_bitmap(chip, local, cmd.plan_include,
                                cmd.plan_exclude))

    def finalize_lookup(self, chips, cmd: Command, raw_bitmap,
                        opens: dict[int, PageOpen]) -> LookupResponse:
        if opens[cmd.value_page].verdict is OpenVerdict.UNCORRECTABLE:
            raise UncorrectableReadError(cmd.value_page)
        search = self.finalize_search(chips, cmd, raw_bitmap, opens)
        slots = np.nonzero(unpack_bitmap(
            mask_header_slots(search.bitmap_words), SLOTS_PER_PAGE))[0]
        if slots.size == 0 and self.policy.fallback_on_miss:
            # A miss on a key page may be a sensing false negative or body
            # damage the optimistic check was blind to: escalate to the
            # full-page read before reporting the miss (lookups only —
            # zero-hit pages are legitimate for searches and plans).
            self.stats.miss_fallbacks += 1
            self._resolve_fallback(chips, cmd.page_addr)
            chip, local = chips.route(cmd.page_addr)
            bitmap = mask_header_slots(
                match_bitmap(chip, local, cmd.query, cmd.mask))
            search = SearchResponse(
                bitmap_words=bitmap,
                match_count=int(popcount_words(bitmap).sum()),
                open_verdict=search.open_verdict)
            slots = np.nonzero(unpack_bitmap(bitmap, SLOTS_PER_PAGE))[0]
        if slots.size == 0:
            return LookupResponse(search=search, value_slot=None, value=None)
        slot = int(slots[0])
        value, parity = self._read_value(chips, cmd.value_page, slot)
        return LookupResponse(search=search, value_slot=slot, value=value,
                              parity_ok=parity)

    def _read_value(self, chips, value_page: int,
                    slot: int) -> tuple[bytes, bool]:
        """Gather the selected slot's chunk from the value page, inner-code
        checked.  A parity failure escalates to the full-page fallback when
        verification is on; otherwise the corrupted bytes are served (the
        measured wrong-result case the sweep quantifies)."""
        chunk = slot // SLOTS_PER_CHUNK
        chip, local = chips.route(value_page)
        sp = chip.pages[local]
        chip.counters.chunks_gathered += 1
        plain = chip._derandomized_chunk(sp, local, chunk)
        ok = bool(ecc.crc32_rows(plain[None, :])[0] == sp.chunk_parities[chunk])
        if not ok:
            if self.policy.verify_hits:
                self.stats.verify_failures += 1
                self._resolve_fallback(chips, value_page)  # repair or raise
                sp = chip.pages[local]
                plain = chip._derandomized_chunk(sp, local, chunk)
                ok = True
            else:
                self.stats.wrong_value_parity += 1
        off = (slot % SLOTS_PER_CHUNK) * 8
        return bytes(plain[off:off + 8]), ok

    def finalize_gather(self, chips, cmd: Command, resp: GatherResponse,
                        opens: dict[int, PageOpen]) -> GatherResponse:
        po = opens[cmd.page_addr]
        if po.verdict is OpenVerdict.UNCORRECTABLE:
            raise UncorrectableReadError(cmd.page_addr)
        if (self.policy.verify_hits and resp.chunk_ids.size
                and not np.asarray(resp.parity_ok).all()):
            bad = int((~np.asarray(resp.parity_ok)).sum())
            self.stats.verify_failures += bad
            self._resolve_fallback(chips, cmd.page_addr)  # repair or raise
            chip, local = chips.route(cmd.page_addr)
            sp = chip.pages[local]
            chunks = np.stack([chip._derandomized_chunk(sp, local, int(c))
                               for c in resp.chunk_ids])
            return GatherResponse(chunks=chunks, chunk_ids=resp.chunk_ids,
                                  parity_ok=np.ones(len(resp.chunk_ids),
                                                    dtype=bool))
        return resp
