"""Reliability tier: deterministic fault injection and ECC-aware matching.

``FaultModel`` (faults.py) corrupts stored pages and match-mode senses under
one seed; ``ReliabilityState`` (policy.py) threads the §IV-C2/C3 optimistic
open / voting / selective-verification pipeline through every backend's
flush, surfacing outer-code failures as typed per-ticket
``UncorrectableReadError``s.  ``FaultSchedule``/``DeviceFaultState``
(device_faults.py) model *device*-level failures — die/channel stalls,
permanent chip outages, grown bad blocks — behind replica failover and
typed ``DegradedReadError``s.  See README "Reliability tier" and "Fault
tolerance & graceful degradation".
"""
from .device_faults import (ChipOutage, CommandTimeoutError,
                            DegradedReadError, DeviceFaultState,
                            FaultSchedule, FaultStats, OverloadShedError,
                            StallWindow)
from .faults import (DAY_NS, FaultModel, majority_flip_prob,
                     sense_false_negative_bound, sense_false_positive_bound)
from .policy import (PageOpen, ReliabilityPolicy, ReliabilityState,
                     ReliabilityStats, UncorrectableReadError, match_bitmap,
                     plan_bitmap, require_clean)

__all__ = [
    "DAY_NS", "FaultModel", "majority_flip_prob",
    "sense_false_negative_bound", "sense_false_positive_bound",
    "PageOpen", "ReliabilityPolicy", "ReliabilityState", "ReliabilityStats",
    "UncorrectableReadError", "match_bitmap", "plan_bitmap", "require_clean",
    "ChipOutage", "CommandTimeoutError", "DegradedReadError",
    "DeviceFaultState", "FaultSchedule", "FaultStats", "OverloadShedError",
    "StallWindow",
]
