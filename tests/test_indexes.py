"""Integration tests: SiM-backed index structures vs the CPU-centric baseline."""
import numpy as np
import pytest

from repro.core.bitweaving import Column, RowCodec
from repro.core.engine import SimChipArray
from repro.index.baseline import BaselineBTree
from repro.index.btree import SimBTree
from repro.index.hashindex import SimHashIndex
from repro.index.secondary import SimSecondaryIndex


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(42)
    keys = (rng.choice(10**9, size=3000, replace=False) + 1).astype(np.uint64)
    values = keys * np.uint64(13)
    return keys, values


@pytest.fixture(scope="module")
def trees(dataset):
    keys, values = dataset
    bt = SimBTree(SimChipArray(n_chips=8, pages_per_chip=64))
    bt.bulk_load(keys, values)
    bb = BaselineBTree(SimChipArray(n_chips=8, pages_per_chip=64))
    bb.bulk_load(keys, values)
    return bt, bb


def test_btree_point_lookups_match_baseline(trees, dataset):
    bt, bb = trees
    keys, _ = dataset
    for k in keys[::100]:
        assert bt.lookup(int(k)) == bb.lookup(int(k)) == int(k) * 13


def test_btree_misses(trees, dataset):
    bt, bb = trees
    keys, _ = dataset
    present = set(keys.tolist())
    probes = [int(k) + 1 for k in keys[:30] if int(k) + 1 not in present]
    for k in probes:
        assert bt.lookup(k) is None and bb.lookup(k) is None


def test_btree_range_matches_baseline(trees, dataset):
    bt, bb = trees
    keys, _ = dataset
    lo, hi = int(np.percentile(keys, 40)), int(np.percentile(keys, 43))
    assert sorted(bt.range_query(lo, hi)) == sorted(bb.range_query(lo, hi))


def test_btree_point_io_is_two_orders_lower(trees, dataset):
    bt, bb = trees
    keys, _ = dataset
    bt.stats.bitmap_bytes = bt.stats.chunk_bytes = 0
    bb.pages_read = bb.bytes_read = 0
    for k in keys[:64]:
        bt.lookup(int(k))
        bb.lookup(int(k))
    sim_io = bt.stats.bitmap_bytes + bt.stats.chunk_bytes
    assert sim_io * 50 < bb.bytes_read        # 64x by design (128 B vs 8 KiB)


def test_hash_index_crud_and_splits():
    rng = np.random.default_rng(3)
    keys = (rng.choice(10**9, size=2500, replace=False) + 1).astype(np.uint64)
    h = SimHashIndex(SimChipArray(n_chips=8, pages_per_chip=512))
    for k in keys:
        h.insert(int(k), int(k) % 99991)
    assert h.splits > 0
    for k in keys[::37]:
        assert h.lookup(int(k)) == int(k) % 99991
    assert h.lookup(10**12 + 7) is None
    # overwrite
    h.insert(int(keys[0]), 777)
    assert h.lookup(int(keys[0])) == 777
    # splits used real search+gather commands (§V-D redistribution)
    assert h.split_searches == h.splits
    assert h.split_gathered_chunks > 0


def test_secondary_index_fig9_fig10():
    rng = np.random.default_rng(4)
    codec = RowCodec([Column("gender", 1), Column("age", 7),
                      Column("salary", 20), Column("uid", 32)])
    si = SimSecondaryIndex(SimChipArray(n_chips=4, pages_per_chip=64), codec)
    n = 3000
    rows = {"gender": rng.integers(0, 2, n), "age": rng.integers(0, 128, n),
            "salary": rng.integers(0, 10_000, n), "uid": np.arange(n)}
    si.load_rows(rows)

    fem = si.select_equals("gender", 1)
    assert sorted(codec.decode_rows(fem, "uid").tolist()) == \
        sorted(np.nonzero(rows["gender"] == 1)[0].tolist())

    exp = set(np.nonzero((rows["salary"] >= 2001)
                         & (rows["salary"] < 7000))[0].tolist())
    got = si.select_range("salary", 2001, 7000, exact=True)
    assert set(codec.decode_rows(got, "uid").tolist()) == exp
    got_a = si.select_range("salary", 2001, 7000, exact=False)
    assert set(codec.decode_rows(got_a, "uid").tolist()) == exp


# --------------------------------------------------------------------------
# Hash index: degenerate splits (depth cap) + buffered bucket programs
# --------------------------------------------------------------------------

_M64 = (1 << 64) - 1


def _inv_shift_xor(z: int, r: int) -> int:
    """Invert y = z ^ (z >> r) for 64-bit z."""
    y = z
    for _ in range(64 // r + 1):
        y = z ^ (y >> r)
    return y & _M64


def _unhash64(h: int) -> int:
    """Exact inverse of hashindex._hash64 (splitmix64 is a bijection)."""
    inv1 = pow(0x94D049BB133111EB, -1, 1 << 64)
    inv2 = pow(0xBF58476D1CE4E5B9, -1, 1 << 64)
    z = _inv_shift_xor(h, 31)
    z = (z * inv1) & _M64
    z = _inv_shift_xor(z, 27)
    z = (z * inv2) & _M64
    z = _inv_shift_xor(z, 30)
    return (z - 0x9E3779B97F4A7C15) & _M64


def test_unhash64_is_inverse():
    from repro.index.hashindex import _hash64
    rng = np.random.default_rng(0)
    hs = rng.integers(1, 2**63, 64, dtype=np.uint64)
    keys = np.array([_unhash64(int(h)) for h in hs], dtype=np.uint64)
    np.testing.assert_array_equal(_hash64(keys), hs)


def test_hash_index_adversarial_keys_no_unbounded_recursion():
    """Every key shares the low hash bits up to the depth cap: the old
    recursive insert split forever (all keys on one side at every depth);
    the iterative path splits to the cap and overflows in place."""
    from repro.index.hashindex import BUCKET_CAPACITY
    depth_cap = 8
    n = BUCKET_CAPACITY + 6                 # forces splits, then overflow
    # identical low-8 hash bits -> one directory slot at every depth <= 8
    keys = [_unhash64((i << depth_cap) | 0x5A) for i in range(1, n + 1)]
    assert all(0 < k < 2**64 - 1 for k in keys)
    h = SimHashIndex(SimChipArray(n_chips=4, pages_per_chip=2048),
                     depth_cap=depth_cap)
    for i, k in enumerate(keys):
        h.insert(int(k), i + 1)             # must terminate
    assert h.splits > 0
    target = h.buckets[h.directory[h._dir_slot(keys[0])]]
    assert target.local_depth == depth_cap
    assert target.n == n                    # overflowed past BUCKET_CAPACITY
    got = h.lookup_batch([int(k) for k in keys[::29]])
    assert got == [keys.index(k) + 1 for k in keys[::29]]


def test_hash_index_overflow_past_page_raises():
    """At the depth cap the overflow is bounded by the page's user slots:
    a key set degenerate past 504 entries fails loudly, not silently."""
    from repro.core.page import USER_SLOTS
    depth_cap = 4
    keys = [_unhash64((i << depth_cap) | 0x3) for i in range(1, USER_SLOTS + 2)]
    h = SimHashIndex(SimChipArray(n_chips=2, pages_per_chip=256),
                     depth_cap=depth_cap)
    with pytest.raises(RuntimeError, match="depth cap"):
        for i, k in enumerate(keys):
            h.insert(int(k), i + 1)
    # ...but a value UPDATE of a resident key needs no new slot and must
    # still succeed against the full capped bucket
    h.insert(int(keys[0]), 4242)
    assert h.lookup(int(keys[0])) == 4242


def test_hash_index_inserts_coalesce_programs():
    """Consecutive inserts ride the write buffer: far fewer bucket-page
    programs than the 2-per-insert eager path, and lookups (which flush
    first) stay correct mid-stream."""
    rng = np.random.default_rng(9)
    keys = (rng.choice(10**9, size=600, replace=False) + 1).astype(np.uint64)
    arr = SimChipArray(n_chips=4, pages_per_chip=512)
    h = SimHashIndex(arr, write_high_water=16)
    programs0 = sum(c.counters.programs for c in arr.chips)
    for k in keys[:300]:
        h.insert(int(k), int(k) % 1097)
    # mid-stream read-your-writes through the flush-on-lookup path
    assert h.lookup(int(keys[0])) == int(keys[0]) % 1097
    for k in keys[300:]:
        h.insert(int(k), int(k) % 1097)
    h.flush_writes()
    programs = sum(c.counters.programs for c in arr.chips) - programs0
    assert programs < 2 * len(keys) / 4, \
        f"{programs} programs for {len(keys)} inserts: no coalescing"
    assert h.write_buffer.stats.coalesced > 0
    for k in keys[::43]:
        assert h.lookup(int(k)) == int(k) % 1097
