"""Integration tests: SiM-backed index structures vs the CPU-centric baseline."""
import numpy as np
import pytest

from repro.core.bitweaving import Column, RowCodec
from repro.core.engine import SimChipArray
from repro.index.baseline import BaselineBTree
from repro.index.btree import SimBTree
from repro.index.hashindex import SimHashIndex
from repro.index.secondary import SimSecondaryIndex


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(42)
    keys = (rng.choice(10**9, size=3000, replace=False) + 1).astype(np.uint64)
    values = keys * np.uint64(13)
    return keys, values


@pytest.fixture(scope="module")
def trees(dataset):
    keys, values = dataset
    bt = SimBTree(SimChipArray(n_chips=8, pages_per_chip=64))
    bt.bulk_load(keys, values)
    bb = BaselineBTree(SimChipArray(n_chips=8, pages_per_chip=64))
    bb.bulk_load(keys, values)
    return bt, bb


def test_btree_point_lookups_match_baseline(trees, dataset):
    bt, bb = trees
    keys, _ = dataset
    for k in keys[::100]:
        assert bt.lookup(int(k)) == bb.lookup(int(k)) == int(k) * 13


def test_btree_misses(trees, dataset):
    bt, bb = trees
    keys, _ = dataset
    present = set(keys.tolist())
    probes = [int(k) + 1 for k in keys[:30] if int(k) + 1 not in present]
    for k in probes:
        assert bt.lookup(k) is None and bb.lookup(k) is None


def test_btree_range_matches_baseline(trees, dataset):
    bt, bb = trees
    keys, _ = dataset
    lo, hi = int(np.percentile(keys, 40)), int(np.percentile(keys, 43))
    assert sorted(bt.range_query(lo, hi)) == sorted(bb.range_query(lo, hi))


def test_btree_point_io_is_two_orders_lower(trees, dataset):
    bt, bb = trees
    keys, _ = dataset
    bt.stats.bitmap_bytes = bt.stats.chunk_bytes = 0
    bb.pages_read = bb.bytes_read = 0
    for k in keys[:64]:
        bt.lookup(int(k))
        bb.lookup(int(k))
    sim_io = bt.stats.bitmap_bytes + bt.stats.chunk_bytes
    assert sim_io * 50 < bb.bytes_read        # 64x by design (128 B vs 8 KiB)


def test_hash_index_crud_and_splits():
    rng = np.random.default_rng(3)
    keys = (rng.choice(10**9, size=2500, replace=False) + 1).astype(np.uint64)
    h = SimHashIndex(SimChipArray(n_chips=8, pages_per_chip=512))
    for k in keys:
        h.insert(int(k), int(k) % 99991)
    assert h.splits > 0
    for k in keys[::37]:
        assert h.lookup(int(k)) == int(k) % 99991
    assert h.lookup(10**12 + 7) is None
    # overwrite
    h.insert(int(keys[0]), 777)
    assert h.lookup(int(keys[0])) == 777
    # splits used real search+gather commands (§V-D redistribution)
    assert h.split_searches == h.splits
    assert h.split_gathered_chunks > 0


def test_secondary_index_fig9_fig10():
    rng = np.random.default_rng(4)
    codec = RowCodec([Column("gender", 1), Column("age", 7),
                      Column("salary", 20), Column("uid", 32)])
    si = SimSecondaryIndex(SimChipArray(n_chips=4, pages_per_chip=64), codec)
    n = 3000
    rows = {"gender": rng.integers(0, 2, n), "age": rng.integers(0, 128, n),
            "salary": rng.integers(0, 10_000, n), "uid": np.arange(n)}
    si.load_rows(rows)

    fem = si.select_equals("gender", 1)
    assert sorted(codec.decode_rows(fem, "uid").tolist()) == \
        sorted(np.nonzero(rows["gender"] == 1)[0].tolist())

    exp = set(np.nonzero((rows["salary"] >= 2001)
                         & (rows["salary"] < 7000))[0].tolist())
    got = si.select_range("salary", 2001, 7000, exact=True)
    assert set(codec.decode_rows(got, "uid").tolist()) == exp
    got_a = si.select_range("salary", 2001, 7000, exact=False)
    assert set(codec.decode_rows(got_a, "uid").tolist()) == exp
