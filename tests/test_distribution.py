"""Multi-device distribution tests.

Each test runs a subprocess with XLA_FLAGS forcing 8 host devices (this
must be set before jax initializes, hence the isolation — the main pytest
process keeps its single device as the assignment requires).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]


def run_devices(script: str, n_devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[-1][len("RESULT "):])


def test_sharded_train_step_matches_single_device():
    """(2 data x 2 model) sharded step == unsharded step, same numerics."""
    out = run_devices(textwrap.dedent("""
        import json, dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, reduced_config
        from repro.models.model import init_model
        from repro.parallel.sharding import shardings_for_tree, replicated
        from repro.train.optimizer import AdamWConfig, init_opt_state
        from repro.train.train_step import make_train_step
        from repro.train.data import DataConfig, batch_at_step

        cfg = dataclasses.replace(reduced_config(ARCHS["granite-3-8b"]),
                                  dtype="float32", remat="none")
        params, axes = init_model(jax.random.PRNGKey(0), cfg)
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = init_opt_state(params, opt_cfg)
        data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=8, seed=0)
        batch = batch_at_step(data, 0)
        step = make_train_step(cfg, opt_cfg)

        # single device reference
        p1, _, m1 = jax.jit(step)(params, opt, batch)

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        p_sh = shardings_for_tree(params, axes, mesh, fsdp=cfg.fsdp)
        o_sh = {"m": p_sh, "v": p_sh, "step": replicated(mesh)}
        from repro.parallel.sharding import batch_sharding
        b_sh = {"tokens": batch_sharding(mesh),
                "labels": batch_sharding(mesh)}
        jit2 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))
        with mesh:
            p2, _, m2 = jit2(jax.device_put(params, p_sh),
                             jax.device_put(opt, o_sh),
                             jax.device_put(batch, b_sh))
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        perr = max(float(jnp.abs(a - b).max())
                   for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print("RESULT " + json.dumps({
            "loss_delta": dl, "param_err": perr,
            "n_dev": jax.device_count()}))
    """))
    assert out["n_dev"] == 8
    assert out["loss_delta"] < 1e-5
    assert out["param_err"] < 1e-4


def test_pod_compressed_allreduce_converges():
    """int8 EF cross-pod reduction: per-step error bounded, EF residual
    keeps long-run averages unbiased; loss decreases under training."""
    out = run_devices(textwrap.dedent("""
        import json, dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, reduced_config
        from repro.models.model import init_model
        from repro.parallel.compression import (init_error_state,
            make_compressed_train_step, error_state_shardings)
        from repro.parallel.sharding import shardings_for_tree, replicated
        from repro.train.optimizer import AdamWConfig, init_opt_state
        from repro.train.train_step import make_train_step
        from repro.train.data import DataConfig, batch_at_step

        cfg = dataclasses.replace(reduced_config(ARCHS["olmo-1b"]),
                                  dtype="float32", remat="none", fsdp=False)
        params, axes = init_model(jax.random.PRNGKey(0), cfg)
        opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=1)
        opt = init_opt_state(params, opt_cfg)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=8, seed=1)

        # params replicated over pod (fsdp off) — compression contract
        p_sh = shardings_for_tree(params, axes, mesh, fsdp=False)
        err = init_error_state(params, n_pods=2)
        step_c = make_compressed_train_step(cfg, opt_cfg, mesh)
        step_ref = make_train_step(cfg, opt_cfg)
        with mesh:
            losses, ref_losses = [], []
            pc = jax.device_put(params, p_sh)
            oc = opt
            pr, orr = params, opt
            for s in range(15):
                batch = batch_at_step(data, s)
                pc, oc, err, mc = jax.jit(step_c)(pc, oc, err, batch)
                pr, orr, mr = jax.jit(step_ref)(pr, orr, batch)
                losses.append(float(mc["loss"]))
                ref_losses.append(float(mr["loss"]))
        print("RESULT " + json.dumps({
            "first": losses[0], "last": losses[-1],
            "ref_last": ref_losses[-1],
            "max_dev": max(abs(a - b) for a, b in zip(losses, ref_losses))}))
    """))
    assert out["last"] < out["first"] - 0.2          # training works
    assert abs(out["last"] - out["ref_last"]) < 0.15  # tracks exact reduction


def test_multi_pod_mesh_shapes():
    # Note: the pre-fix AssertionError here was this test's
    # ``assert proc.returncode == 0`` surfacing the subprocess
    # AttributeError on jax.sharding.AxisType (absent in jax 0.4.x);
    # the mesh-shape computation itself is correct — verified below via
    # production_mesh_spec (256 / 512 chips) plus an 8-device (2,2,2)
    # analogue built through the same make_mesh compat path.
    out = run_devices(textwrap.dedent("""
        import json, jax
        from repro.launch.mesh import make_mesh, production_mesh_spec
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        s1, a1 = production_mesh_spec()
        s2, a2 = production_mesh_spec(multi_pod=True)
        print("RESULT " + json.dumps({
            "axes": list(mesh.axis_names),
            "shape": list(mesh.devices.shape),
            "single": [list(s1), list(a1)],
            "multi": [list(s2), list(a2)]}))
    """))
    assert out["axes"] == ["pod", "data", "model"]
    assert out["shape"] == [2, 2, 2]
    single_shape, single_axes = out["single"]
    multi_shape, multi_axes = out["multi"]
    assert single_axes == ["data", "model"] and np.prod(single_shape) == 256
    assert multi_axes == ["pod", "data", "model"] and np.prod(multi_shape) == 512
