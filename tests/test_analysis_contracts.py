"""Contract linter (repro.analysis): per-rule fixtures, baseline, CLI gate."""
from pathlib import Path

import pytest

from repro.analysis.__main__ import main
from repro.analysis.baseline import (BaselineEntry, _parse_minimal,
                                     apply_baseline, load_baseline,
                                     write_baseline)
from repro.analysis.contracts import parse_module, run_contracts
from repro.analysis.findings import Finding, dedupe_slugs
from repro.analysis.rules import RULES_BY_ID

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "analysis_fixtures"


def lint_fixture(name: str, rule_id: str) -> list[Finding]:
    findings = run_contracts(ROOT, paths=[FIXTURES / name],
                             rules=[RULES_BY_ID[rule_id]])
    return [f for f in findings if f.rule == rule_id]


def slugs(findings) -> set:
    return {f.slug for f in findings}


# ------------------------------------------------------------ rule fixtures
def test_sim001_true_positives():
    found = lint_fixture("sim001_tp.py", "SIM001")
    assert "dropped:submit_search" in slugs(found)
    assert "drops_ticket" in {f.symbol for f in found}


def test_sim001_no_longer_owns_result_no_flush():
    """The flush-before-result check moved to SIM009 (dataflow-grounded);
    SIM001 keeps only the dropped-ticket sub-rule."""
    found = lint_fixture("sim001_tp.py", "SIM001")
    assert not any(f.slug.startswith("result-no-flush") for f in found)
    # ...and SIM009 picks up the genuinely-implicit burst in that fixture
    found9 = lint_fixture("sim001_tp.py", "SIM009")
    assert "result-no-flush:submit_gather" in slugs(found9)
    assert {f.symbol for f in found9} == {"mixed_burst"}
    # the single straight-line submit+result is the documented immediate
    # mode — the old rule's false positive, now proven clean
    assert "result_without_flush" not in {f.symbol for f in found9}


def test_sim001_true_negatives():
    assert lint_fixture("sim001_tn.py", "SIM001") == []


def test_sim002_true_positives():
    found = lint_fixture("sim002_tp.py", "SIM002")
    assert slugs(found) == {"mutates:pages"}
    assert found[0].symbol == "FixtureChip.silent_rewrite"
    # the pragma re-homed the fixture into the rule's scope
    assert found[0].path == "src/repro/core/engine.py"


def test_sim002_true_negatives():
    assert lint_fixture("sim002_tn.py", "SIM002") == []


def test_sim003_true_positives():
    found = lint_fixture("sim003_tp.py", "SIM003")
    assert {"host-sync:np.asarray", "host-sync:int",
            "host-sync:block_until_ready"} <= slugs(found)
    assert all(f.symbol == "_flush_searches" for f in found)


def test_sim003_true_negatives():
    assert lint_fixture("sim003_tn.py", "SIM003") == []


def test_sim004_true_positives():
    found = lint_fixture("sim004_tp.py", "SIM004")
    assert {"mutates:result_bytes", "mutates:<stats>"} <= slugs(found)


def test_sim004_true_negatives():
    assert lint_fixture("sim004_tn.py", "SIM004") == []


def test_sim005_true_positives():
    found = lint_fixture("sim005_tp.py", "SIM005")
    assert {"consumes:bitmap_words", "consumes:match_count",
            "consumes:value_slot"} <= slugs(found)
    assert {"silent_bitmap_consumer", "silent_count_and_slot"} \
        <= {f.symbol for f in found}


def test_sim005_true_negatives():
    assert lint_fixture("sim005_tn.py", "SIM005") == []


def test_sim006_true_positives():
    found = lint_fixture("sim006_tp.py", "SIM006")
    assert {"unbounded-retry", "swallows:Exception",
            "swallows:ValueError+IOError"} <= slugs(found)
    assert {"retries_forever", "swallows_silently",
            "swallows_with_ellipsis"} <= {f.symbol for f in found}


def test_sim006_true_negatives():
    assert lint_fixture("sim006_tn.py", "SIM006") == []


def test_sim006_unseeded_rng_superseded_by_sim008():
    """SIM006's syntactic bare-default_rng() check retired; SIM008's taint
    analysis owns the fixture's unseeded jitter now."""
    found = lint_fixture("sim006_tp.py", "SIM006")
    assert not any(f.slug == "unseeded-rng" for f in found)
    found8 = lint_fixture("sim006_tp.py", "SIM008")
    assert ("unseeded_jitter", "unseeded-rng") in \
        {(f.symbol, f.slug) for f in found8}
    # ...and the seeded entropy-list idiom next door stays clean
    assert lint_fixture("sim006_tn.py", "SIM008") == []


def test_sim007_true_positives():
    found = lint_fixture("sim007_tp.py", "SIM007")
    assert {"mix:ns+pj", "mis-assign:energy_pj", "mis-call:charge.cost_pj",
            "mix:bytes+ns", "mis-return:pj"} <= slugs(found)
    # the interprocedural leak: a summarized ns return landing in a
    # pj-suffixed positional parameter two calls away
    assert ("cross_function_leak", "mis-call:charge_energy.energy_pj") in \
        {(f.symbol, f.slug) for f in found}


def test_sim007_true_negatives():
    assert lint_fixture("sim007_tn.py", "SIM007") == []


def test_sim008_true_positives():
    found = lint_fixture("sim008_tp.py", "SIM008")
    got = {(f.symbol, f.slug) for f in found}
    assert ("no_entropy_at_all", "unseeded-rng") in got
    assert ("os_entropy_laundered", "untraced-rng") in got
    # interprocedural: the parameter's provenance fails at a call site
    assert ("_fixture_rng_from_knob", "untraced-rng:knob") in got


def test_sim008_true_negatives():
    assert lint_fixture("sim008_tn.py", "SIM008") == []


def test_sim009_true_positives():
    found = lint_fixture("sim009_tp.py", "SIM009")
    got = {(f.symbol, f.slug) for f in found}
    assert ("looped_implicit_burst", "result-no-flush:submit_search") in got
    assert ("two_pending_at_result", "result-no-flush:submit_search") in got
    # interprocedural: the submits hide inside a helper whose
    # leaves-pending summary carries the tickets to the caller
    assert ("helper_hidden_burst", "result-no-flush:_stage_probe") in got


def test_sim009_true_negatives():
    assert lint_fixture("sim009_tn.py", "SIM009") == []


def test_sim006_out_of_scope_paths_exempt():
    """The same patterns outside backend/frontend/reliability are out of
    scope — an infinite poll loop in the workload layer is legitimate."""
    import tempfile
    src = (FIXTURES / "sim006_tp.py").read_text().splitlines()
    src[0] = "# analysis: pretend-path=src/repro/workload/fixture.py"
    with tempfile.TemporaryDirectory() as d:
        p = Path(d) / "sim006_workload.py"
        p.write_text("\n".join(src))
        found = run_contracts(ROOT, paths=[p],
                              rules=[RULES_BY_ID["SIM006"]])
    assert found == []


def test_sim005_exempt_layers():
    """The same silent consumption inside backend/ is the plumbing that
    PRODUCES responses — out of scope by path."""
    import shutil
    import tempfile
    src = (FIXTURES / "sim005_tp.py").read_text().splitlines()
    src[0] = "# analysis: pretend-path=src/repro/backend/fixture.py"
    with tempfile.TemporaryDirectory() as d:
        p = Path(d) / "sim005_backend.py"
        p.write_text("\n".join(src))
        found = run_contracts(ROOT, paths=[p],
                              rules=[RULES_BY_ID["SIM005"]])
    assert found == []


def test_pragma_rehomes_fixture():
    mod = parse_module(FIXTURES / "sim002_tp.py", ROOT)
    assert mod.rel_path == "src/repro/core/engine.py"
    assert mod.real_path.endswith("tests/analysis_fixtures/sim002_tp.py")


# ------------------------------------------------------------------ baseline
def test_baseline_roundtrip(tmp_path):
    findings = [
        Finding("SIM001", "src/a.py", "f", 'dropped:submit_search',
                message='reason with "quotes" and \\ backslash'),
        Finding("SIM004", "src/b.py", "C.g", "mutates:flushes"),
    ]
    path = tmp_path / "baseline.toml"
    write_baseline(path, findings)
    entries = load_baseline(path)
    assert {e.key() for e in entries} == {f.key() for f in findings}
    # reasons default to the finding message, escaping intact
    by_key = {e.key(): e for e in entries}
    assert by_key[findings[0].key()].reason == \
        'reason with "quotes" and \\ backslash'

    new, accepted, stale = apply_baseline(findings, entries)
    assert new == [] and len(accepted) == 2 and stale == []

    extra = Finding("SIM002", "src/c.py", "h", "mutates:pages")
    new, _, _ = apply_baseline(findings + [extra], entries)
    assert new == [extra]

    _, _, stale = apply_baseline([findings[0]], entries)
    assert [e.key() for e in stale] == [findings[1].key()]


def test_minimal_parser_matches_tomllib():
    text = (ROOT / "src/repro/analysis/baseline.toml").read_text()
    tomllib = pytest.importorskip("tomllib")
    assert _parse_minimal(text) == tomllib.loads(text)


def test_load_missing_baseline_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.toml") == []


def test_stale_entry_reported():
    entry = BaselineEntry("SIM001", "gone.py", "f", "dropped:submit_x")
    new, accepted, stale = apply_baseline([], [entry])
    assert stale == [entry] and new == [] and accepted == []


def test_dedupe_slugs_ordinal():
    f = Finding("SIM001", "a.py", "f", "dropped:submit_search")
    out = dedupe_slugs([f, f, f])
    assert [x.slug for x in out] == [
        "dropped:submit_search", "dropped:submit_search#2",
        "dropped:submit_search#3"]


# ----------------------------------------------------------------- CLI gate
def test_repo_lint_is_clean_under_baseline(capsys):
    assert main(["--check", "--no-audit", "--no-conservation"]) == 0
    err = capsys.readouterr().err
    assert "0 new finding(s)" in err
    assert "0 stale baseline entr" in err


def test_fixture_violations_trip_the_gate(capsys):
    rc = main(["--check", "--no-audit", "--no-conservation",
               "--paths", str(FIXTURES)])
    assert rc == 1
    out = capsys.readouterr().out
    # the syntactic and the dataflow rule generations both fire
    for rule in ("SIM001", "SIM002", "SIM003", "SIM004",
                 "SIM007", "SIM008", "SIM009"):
        assert rule in out


def test_github_annotations_and_json_artifact(tmp_path, capsys):
    """--github emits ::error problem-matcher lines at the fixtures' real
    coordinates; --json-out dumps the same finding sets as an artifact."""
    import json
    art = tmp_path / "findings.json"
    rc = main(["--check", "--no-audit", "--no-conservation", "--github",
               "--json-out", str(art),
               "--paths", str(FIXTURES / "sim007_tp.py")])
    assert rc == 1
    out = capsys.readouterr().out
    assert "::error file=src/repro/fixtures/sim007_tp.py,line=" in out
    assert "title=SIM007" in out
    payload = json.loads(art.read_text())
    assert any(f["rule"] == "SIM007" for f in payload["new"])
    assert payload["accepted"] == []


def test_unknown_rule_id_rejected():
    with pytest.raises(SystemExit):
        main(["--no-audit", "--rules", "SIM999"])


def test_write_baseline_preserves_reasons(tmp_path):
    findings = [Finding("SIM001", "src/a.py", "f", "dropped:submit_search",
                        message="msg")]
    path = tmp_path / "b.toml"
    write_baseline(path, findings,
                   reasons={findings[0].key(): "reviewed: intentional"})
    entries = load_baseline(path)
    assert entries[0].reason == "reviewed: intentional"
