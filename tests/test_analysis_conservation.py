"""Conservation audit (repro.analysis.conservation, SIM201-204): the
pure checks must trip on corrupted accounting, the metered timeline must
record real intervals, and the seeded replay must audit clean."""
import pytest

from repro.analysis.conservation import (LineEvent, _Auditor,
                                         busy_violations, energy_violations,
                                         make_metered_timeline,
                                         run_conservation)
from repro.flash.params import FlashParams


def _ev(line, start, end, **kw):
    return LineEvent(line, float(start), float(end), **kw)


# ------------------------------------------------------- SIM201 pure check
def test_busy_clean_books_balance():
    events = [_ev("die_sense:0", 0, 10), _ev("die_sense:0", 10, 20),
              _ev("die_sense:1", 5, 15), _ev("pcie", 2, 4)]
    assert busy_violations(events, makespan_ns=20.0) == []


def test_busy_double_charge_trips_sim201():
    """The same sense billed twice: identical intervals on one serial
    line must surface as an overlap."""
    events = [_ev("die_sense:0", 0, 10), _ev("die_sense:0", 0, 10)]
    slugs = [s for s, _ in busy_violations(events, makespan_ns=10.0)]
    assert "overlap:die_sense:0" in slugs
    # and the doubled busy time also exceeds the makespan
    assert "busy-exceeds-makespan:die_sense:0" in slugs


def test_busy_partial_overlap_trips_sim201():
    events = [_ev("chan:1", 0, 10), _ev("chan:1", 9, 12)]
    slugs = [s for s, _ in busy_violations(events, makespan_ns=50.0)]
    assert slugs == ["overlap:chan:1"]


def test_busy_negative_span_trips_sim201():
    events = [_ev("pcie", 10, 3)]
    slugs = [s for s, _ in busy_violations(events, makespan_ns=50.0)]
    assert slugs == ["negative-span:pcie"]


def test_busy_lines_are_independent():
    """Concurrent occupancy on *different* lines is the whole point of
    the parallel simulator — never a violation."""
    events = [_ev(f"die_sense:{d}", 0, 100) for d in range(8)]
    assert busy_violations(events, makespan_ns=100.0) == []


# ------------------------------------------------------- SIM202 pure check
@pytest.fixture()
def params():
    return FlashParams()


def _clean_account(params, n_senses, n_programs, bus_events, match_queries):
    from repro.flash.ssd import EnergyAccount
    acct = EnergyAccount()
    acct.sense_pj = n_senses * params.e_sense_pj()
    acct.program_pj = n_programs * params.e_program_pj()
    acct.bus_pj = sum(params.e_bus_pj(n, m) for n, m in bus_events)
    acct.match_pj = match_queries * params.e_match_pj()
    return acct


def test_energy_clean_books_balance(params):
    bus = [(4096, False), (64, True)]
    acct = _clean_account(params, 10, 3, bus, 7)
    assert energy_violations(acct, params, n_senses=10, n_programs=3,
                             bus_events=bus, match_queries=7) == []


def test_energy_dropped_charge_trips_sim202(params):
    """Drop one sense charge from the account: the component check must
    flag exactly the sense bucket."""
    acct = _clean_account(params, 9, 3, [], 0)       # 9 booked...
    viols = energy_violations(acct, params, n_senses=10,  # ...10 metered
                              n_programs=3, bus_events=[],
                              match_queries=0)
    assert [s for s, _ in viols] == ["component-mismatch:sense_pj"]


def test_energy_double_charge_trips_sim202(params):
    bus = [(4096, False)]
    acct = _clean_account(params, 5, 0, bus + bus, 2)   # bus billed twice
    viols = energy_violations(acct, params, n_senses=5, n_programs=0,
                              bus_events=bus, match_queries=2)
    assert [s for s, _ in viols] == ["component-mismatch:bus_pj"]


def test_energy_total_drift_trips_sim202(params):
    """Components fine but the total out of step with their sum (a stale
    cached total) must trip the total check."""
    class DriftingAccount:
        def __init__(self, acct):
            for c in ("sense_pj", "program_pj", "bus_pj", "match_pj"):
                setattr(self, c, getattr(acct, c))
            self.total_pj = acct.total_pj * 1.01 + 1.0
    acct = DriftingAccount(_clean_account(params, 4, 1, [], 3))
    viols = energy_violations(acct, params, n_senses=4, n_programs=1,
                              bus_events=[], match_queries=3)
    assert [s for s, _ in viols] == ["total-mismatch:energy_pj"]


# ------------------------------------------------------ metered timeline
def test_metered_timeline_records_real_intervals():
    tl = make_metered_timeline(n_chips=2)
    for chip in (0, 1, 0):
        tl.observe_program(chip)
    assert tl.events, "programming pages produced no metered events"
    lines = {e.line.split(":")[0] for e in tl.events}
    assert lines == {"die_prog", "pcie"}
    # every PCIe event carries a full page
    assert all(e.n_bytes > 0 for e in tl.events if e.line == "pcie")
    assert busy_violations(tl.events, max(e.end_ns for e in tl.events)) \
        == []
    # reset() wipes the record and re-instruments the fresh sim
    tl.reset()
    assert tl.events == [] and tl.match_queries == 0


def test_auditor_collects_findings():
    aud = _Auditor("batched")
    aud.check(True, "SIM201", "timeline", "ok", "never recorded")
    aud.check(False, "SIM203", "replay", "no-result-bytes", "boom")
    aud.add("SIM201", "timeline", [("overlap:pcie", "double billed")])
    assert [(f.rule, f.path, f.slug) for f in aud.findings] == [
        ("SIM203", "audit:batched", "no-result-bytes"),
        ("SIM201", "audit:batched", "overlap:pcie")]


# -------------------------------------------------------- the full audit
def test_conservation_audit_clean_on_real_tree():
    """The seeded sharded replay's books must balance end to end: busy
    time, energy, bytes and fault accounting (the slow gate leg)."""
    findings = run_conservation(kinds=("sharded",))
    assert findings == [], [f.format() for f in findings]
