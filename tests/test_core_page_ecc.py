"""Unit tests: page layout, randomization, verification header, optimistic ECC."""
import numpy as np
import pytest

from repro.core import (CHUNKS_PER_PAGE, EMPTY_SLOT, PAGE_BYTES, USER_SLOTS,
                        EccConfig, OpenVerdict, build_page)
from repro.core import ecc
from repro.core.bits import (bytes_to_slot_words, pairs_to_u64_array,
                             slot_words_to_bytes, u64_array_to_pairs,
                             u64_to_pair, pair_to_u64, pack_bitmap,
                             unpack_bitmap)
from repro.core.page import entries_from_plain
from repro.core.randomize import (chunk_stream_words, randomize_page_words,
                                  randomize_query, stream_words)


def test_u64_pair_roundtrip():
    for v in [0, 1, 0xDEADBEEF, 0xFFFFFFFFFFFFFFFF, 1 << 63]:
        lo, hi = u64_to_pair(v)
        assert pair_to_u64(lo, hi) == v


def test_u64_array_pair_roundtrip():
    v = np.random.default_rng(0).integers(0, 2**63, size=100).astype(np.uint64)
    assert np.array_equal(pairs_to_u64_array(u64_array_to_pairs(v)), v)


def test_bitmap_pack_unpack_roundtrip():
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 2, size=(7, 512)).astype(np.uint32)
    assert np.array_equal(unpack_bitmap(pack_bitmap(bits)), bits)


def test_byte_slot_view_roundtrip():
    rng = np.random.default_rng(2)
    page = rng.integers(0, 256, size=PAGE_BYTES).astype(np.uint8)
    assert np.array_equal(slot_words_to_bytes(bytes_to_slot_words(page)), page)


def test_build_page_layout_and_recovery():
    keys = np.arange(1000, 1504, dtype=np.uint64)   # exactly 504 entries
    built = build_page(keys, page_addr=5, timestamp_ns=42)
    assert built.plain.size == PAGE_BYTES
    rec = entries_from_plain(built.plain, 504)
    assert np.array_equal(rec, keys)


def test_build_page_vacant_slots_are_empty_sentinel():
    built = build_page(np.array([7], dtype=np.uint64), page_addr=0)
    rec = entries_from_plain(built.plain, USER_SLOTS)
    assert rec[0] == 7
    assert (rec[1:] == EMPTY_SLOT).all()


def test_build_page_overflow_rejected():
    with pytest.raises(ValueError):
        build_page(np.zeros(505, dtype=np.uint64), page_addr=0)


def test_randomization_is_involution_and_chunk_addressed():
    words = bytes_to_slot_words(
        np.random.default_rng(3).integers(0, 256, PAGE_BYTES).astype(np.uint8))
    r1 = randomize_page_words(words, page_addr=9)
    assert not np.array_equal(r1, words)
    assert np.array_equal(randomize_page_words(r1, page_addr=9), words)
    # per-chunk stream equals the page stream slice (gather de-randomization)
    full = stream_words(9)
    for c in [0, 13, 63]:
        np.testing.assert_array_equal(
            chunk_stream_words(9, c), full[c * 8:(c + 1) * 8])


def test_query_randomization_cancels():
    """(data ^ stream) ^ (query ^ stream) == data ^ query — §IV-C1."""
    rng = np.random.default_rng(4)
    words = bytes_to_slot_words(
        rng.integers(0, 256, PAGE_BYTES).astype(np.uint8))
    q = np.array(u64_to_pair(0x1234_5678_9ABC_DEF0), dtype=np.uint32)
    stored = randomize_page_words(words, page_addr=17)
    rq = randomize_query(q, page_addr=17)
    assert np.array_equal(stored ^ rq, words ^ q[None, :])


def test_header_roundtrip_and_crc():
    chunk = ecc.build_header_chunk(timestamp_ns=123456789)
    h = ecc.parse_header_chunk(chunk)
    assert h.crc_ok and h.magic_ok and h.timestamp_ns == 123456789
    # any single-bit flip in the body must break the CRC
    bad = chunk.copy()
    bad[17] ^= 0x20
    hb = ecc.parse_header_chunk(bad)
    assert not hb.crc_ok


def test_crc32_chunks_matches_scalar():
    rng = np.random.default_rng(5)
    page = rng.integers(0, 256, PAGE_BYTES).astype(np.uint8)
    vec = ecc.crc32_chunks(page)
    for c in [0, 31, 63]:
        assert vec[c] == ecc.crc32(page[c * 64:(c + 1) * 64])


def test_optimistic_open_clean_fast_path():
    chunk = ecc.build_header_chunk(timestamp_ns=0)
    res = ecc.optimistic_open(chunk, now_ns=10, injected_error_bits=0,
                              cfg=EccConfig())
    assert res.verdict is OpenVerdict.CLEAN


def test_optimistic_open_stale_refresh():
    cfg = EccConfig(refresh_margin_ns=100)
    chunk = ecc.build_header_chunk(timestamp_ns=0)
    res = ecc.optimistic_open(chunk, now_ns=1000, injected_error_bits=0,
                              cfg=cfg)
    assert res.verdict is OpenVerdict.CLEAN_NEEDS_REFRESH


def test_optimistic_open_fallback_and_uncorrectable():
    cfg = EccConfig(t_correctable=10, max_read_retries=3, retry_fix_prob=0.0)
    chunk = ecc.build_header_chunk(timestamp_ns=0)
    bad = chunk.copy()
    bad[9] ^= 0xFF
    res = ecc.optimistic_open(bad, now_ns=0, injected_error_bits=5, cfg=cfg)
    assert res.verdict is OpenVerdict.FALLBACK_ECC
    assert res.bits_corrected == 5
    # The read-retry path draws from the owning chip's generator; passing
    # none is a configuration bug and must fail loudly, not silently fall
    # back to a shared default stream.
    with pytest.raises(ValueError, match="seeded generator"):
        ecc.optimistic_open(bad, now_ns=0, injected_error_bits=50, cfg=cfg)
    res2 = ecc.optimistic_open(bad, now_ns=0, injected_error_bits=50, cfg=cfg,
                               rng=np.random.default_rng(0))
    assert res2.verdict is OpenVerdict.UNCORRECTABLE
    assert res2.retries_used == 3


def test_chunk_parity_verify():
    built = build_page(np.arange(100, dtype=np.uint64), page_addr=0)
    ok = ecc.verify_chunks(built.plain, built.chunk_parities,
                           np.arange(CHUNKS_PER_PAGE))
    assert ok.all()
    damaged = built.plain.copy()
    damaged[200] ^= 1          # chunk 3
    ok2 = ecc.verify_chunks(damaged, built.chunk_parities,
                            np.arange(CHUNKS_PER_PAGE))
    assert not ok2[3] and ok2[[0, 1, 2] + list(range(4, 64))].all()
