"""Hypothesis property tests on the system's core invariants."""
import numpy as np
import pytest
# hypothesis is an optional dev dependency (requirements-dev.txt);
# skip cleanly on minimal installs so tier-1 collection stays green.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bits import (pack_bitmap, u64_array_to_pairs, u64_to_pair,
                             unpack_bitmap)
from repro.core.match import match_slots, search_page
from repro.core.page import build_page
from repro.core.randomize import randomize_query
from repro.kernels.layout import pages_to_planes
from repro.kernels.sim_search.ref import sim_search_ref

u64s = st.integers(0, 2**64 - 1)


@settings(max_examples=60, deadline=None)
@given(u64s, u64s, st.integers(0, 503), st.integers(1, 400))
def test_search_finds_planted_key(key, mask, pos, n_keys):
    """A planted key always matches itself under any mask, at its slot."""
    rng = np.random.default_rng(abs(hash((key, pos))) % 2**32)
    n = max(n_keys, pos + 1)
    keys = rng.integers(0, 2**63, size=n, dtype=np.uint64)
    keys[pos] = key
    built = build_page(keys, page_addr=0, randomize=False)
    from repro.core.bits import bytes_to_slot_words
    words = bytes_to_slot_words(built.plain)
    bits = match_slots(words, np.array(u64_to_pair(key), np.uint32),
                       np.array(u64_to_pair(mask), np.uint32))
    assert bits[8 + pos] == 1          # slot 8+pos (after header chunk)


@settings(max_examples=40, deadline=None)
@given(u64s, u64s, st.integers(0, 2**32 - 1))
def test_match_invariant_under_randomization(key, other, seed):
    """match(data^r, query^r) == match(data, query) for any stream r —
    the §IV-C1 cancellation that makes on-chip matching of randomized
    pages possible."""
    rng = np.random.default_rng(seed % 2**32)
    keys = rng.integers(0, 2**63, size=64, dtype=np.uint64)
    keys[7] = key
    built_plain = build_page(keys, page_addr=3, randomize=False)
    built_rand = build_page(keys, page_addr=3, device_seed=seed,
                            randomize=True)
    from repro.core.bits import bytes_to_slot_words
    plain_words = bytes_to_slot_words(built_plain.plain)
    rand_words = bytes_to_slot_words(built_rand.raw)
    q = np.array(u64_to_pair(key), np.uint32)
    full = np.array([0xFFFFFFFF, 0xFFFFFFFF], np.uint32)
    rq = randomize_query(q, page_addr=3, device_seed=seed)
    mism_rand = ((rand_words[:, 0] ^ rq[:, 0]) & full[0]) | (
        (rand_words[:, 1] ^ rq[:, 1]) & full[1])
    bits_rand = (mism_rand == 0).astype(np.uint32)
    bits_plain = match_slots(plain_words, q, full)
    np.testing.assert_array_equal(bits_rand, bits_plain)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 2**32 - 1), min_size=16, max_size=16))
def test_bitmap_roundtrip_property(words):
    w = np.array(words, dtype=np.uint32)
    assert np.array_equal(pack_bitmap(unpack_bitmap(w)), w)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**64 - 1))
def test_mask_zero_matches_all_mask_full_matches_exact(key):
    rng = np.random.default_rng(key % 2**32)
    keys = rng.integers(0, 2**63, size=100, dtype=np.uint64)
    built = build_page(keys, page_addr=0, randomize=False)
    from repro.core.bits import bytes_to_slot_words
    words = bytes_to_slot_words(built.plain)
    zero = np.zeros(2, np.uint32)
    assert match_slots(words, zero, zero).all()          # mask 0: all match
    q = np.array(u64_to_pair(int(keys[0])), np.uint32)
    full = np.array([0xFFFFFFFF] * 2, np.uint32)
    exact = match_slots(words, q, full)
    expect = np.zeros(512, np.uint32)
    for i, k in enumerate(keys):
        if k == keys[0]:
            expect[8 + i] = 1
    np.testing.assert_array_equal(exact[8:8 + 100], expect[8:8 + 100])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(0, 2**32 - 1))
def test_kernel_ref_agrees_with_core_match(n_pages, seed):
    """The jnp oracle (kernel spec) == the numpy core match for random
    pages and queries."""
    rng = np.random.default_rng(seed)
    pages = rng.integers(0, 256, size=(n_pages, 4096)).astype(np.uint8)
    lo, hi = pages_to_planes(pages)
    q64 = rng.integers(0, 2**63, size=2, dtype=np.uint64)
    m64 = rng.integers(0, 2**63, size=2, dtype=np.uint64)
    out = np.asarray(sim_search_ref(lo, hi, u64_array_to_pairs(q64),
                                    u64_array_to_pairs(m64)))
    from repro.core.bits import bytes_to_slot_words
    for p in range(n_pages):
        words = bytes_to_slot_words(pages[p])
        for qi in range(2):
            expect = search_page(words, u64_array_to_pairs(q64)[qi],
                                 u64_array_to_pairs(m64)[qi])
            np.testing.assert_array_equal(out[qi, p], expect)


# ---------------------------------------------------------------------------
# core/range_query: the §V-C masked-equality decompositions.
# ---------------------------------------------------------------------------

range_widths = st.sampled_from([4, 8, 12, 16, 32, 48, 64])


@st.composite
def lo_hi_width(draw):
    width = draw(range_widths)
    hi = draw(st.integers(1, (1 << width)))
    lo = draw(st.integers(0, hi - 1))
    return lo, hi, width


@settings(max_examples=120, deadline=None)
@given(lo_hi_width(), st.integers(0, 2**32 - 1))
def test_exact_range_agrees_with_direct_evaluation(lhw, seed):
    """exact_range's prefix-block decomposition == lo <= k < hi, for random
    keys drawn across the field width (boundary keys forced in)."""
    from repro.core.range_query import exact_range
    lo, hi, width = lhw
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 1 << min(width, 63), size=200,
                        dtype=np.uint64)
    edges = [lo, hi - 1, max(lo - 1, 0), min(hi, (1 << width) - 1)]
    keys[:len(edges)] = np.array(edges, dtype=np.uint64)
    plan = exact_range(lo, hi, width=width)
    got = plan.evaluate(keys)
    # k < hi compared as k <= hi - 1: hi may be 2**64, which uint64 can't
    # represent, but hi - 1 always fits.
    want = (keys >= np.uint64(lo)) & (keys <= np.uint64(hi - 1))
    np.testing.assert_array_equal(got, want)
    # ...and the pass count respects the trie bound of §V-C.
    assert 1 <= plan.n_passes <= max(2 * width - 2, 1)


@settings(max_examples=120, deadline=None)
@given(lo_hi_width(), st.integers(0, 2**32 - 1))
def test_approximate_range_is_superset_of_true_range(lhw, seed):
    """The one-pass-per-bound approximate plan never drops a true match
    (superset semantics) and never admits a key outside the covered
    power-of-two envelope."""
    from repro.core.range_query import approximate_range
    lo, hi, width = lhw
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 1 << min(width, 63), size=200, dtype=np.uint64)
    keys[:2] = np.array([lo, hi - 1], dtype=np.uint64)
    plan = approximate_range(lo, hi, width=width)
    got = plan.evaluate(keys)
    # k <= hi - 1 form: hi == 2**64 overflows uint64, hi - 1 never does.
    true = (keys >= np.uint64(lo)) & (keys <= np.uint64(hi - 1))
    assert (got | ~true).all()               # true range -> matched
    ub_bits = max(int(hi - 1).bit_length(), 0)
    lb = (1 << (int(lo).bit_length() - 1)) if lo > 0 else 0
    envelope = (keys < np.uint64(1 << ub_bits)) & (keys >= np.uint64(lb)) \
        if ub_bits < 64 else keys >= np.uint64(lb)
    np.testing.assert_array_equal(got, envelope)


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 10), st.data())
def test_false_positive_bound_holds_on_uniform_keys(width, data):
    """Enumerating the full uniform keyspace of a small field, the
    measured superset blow-up of the approximate plan equals (and so never
    exceeds) false_positive_bound."""
    from repro.core.range_query import (approximate_range, exact_range,
                                        false_positive_bound)
    hi = data.draw(st.integers(2, 1 << width), label="hi")
    lo = data.draw(st.integers(0, hi - 1), label="lo")
    keys = np.arange(1 << width, dtype=np.uint64)
    plan = approximate_range(lo, hi, width=width)
    matched = int(plan.evaluate(keys).sum())
    true = hi - lo
    blowup = matched / true - 1.0
    bound = false_positive_bound(plan, lo, hi, width=width)
    assert blowup <= bound + 1e-12
    assert false_positive_bound(exact_range(lo, hi, width=width),
                                lo, hi, width=width) == 0.0


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 40), st.integers(2, 16), st.data(),
       st.integers(0, 2**32 - 1))
def test_shifted_field_decomposition_ignores_other_bits(shift, width, data,
                                                        seed):
    """A range plan on a BitWeaving field (shift, width) must test ONLY
    that field: random garbage in the other bit positions never changes
    membership."""
    from repro.core.range_query import exact_range
    shift = min(shift, 64 - width)
    hi = data.draw(st.integers(1, 1 << width), label="hi")
    lo = data.draw(st.integers(0, hi - 1), label="lo")
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**63, size=150, dtype=np.uint64)
    plan = exact_range(lo, hi, shift=shift, width=width)
    fields = (keys >> np.uint64(shift)) & np.uint64((1 << width) - 1)
    want = (fields >= np.uint64(lo)) & (fields < np.uint64(hi))
    np.testing.assert_array_equal(plan.evaluate(keys), want)
