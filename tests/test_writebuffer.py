"""Write path: the coalescing DRAM write buffer, the deferred Op.PROGRAM
group path, and the timing executor's scan-op accounting.

Contracts held here:

  * ``WriteBuffer`` semantics — last-wins coalescing, read-your-writes
    overlay, high-water trip, one deferred program per dirty page per flush;
  * ``MatchBackend.submit_program`` — per-page last-wins coalescing inside
    a burst, programs execute before the burst's other commands, grouped
    plane-store staging ships each programmed row exactly once;
  * buffered ``replay`` — bit-identical ``read_values``/
    ``read_hits`` to the eager unbuffered scalar reference across scalar /
    batched / sharded x split / fused, with ``programs < n_writes`` on the
    skewed YCSB-A stream (hot-page coalescing) and overlay reads counted;
  * the timing executor ``run()`` — YCSB-E scans are match-mode multi-page
    READS: a scan-bearing workload issues zero writes and zero programs
    (they used to fall into the write branch).
"""
import numpy as np
import pytest

from repro.backend import make_backend
from repro.backend.sharded import ShardedSsdBackend
from repro.buffer.writebuffer import WriteBuffer
from repro.core.commands import Command
from repro.core.engine import SimChipArray
from repro.flash.params import DEFAULT_PARAMS, PAGE_BYTES
from repro.frontend import RunConfig, replay
from repro.workload.runner import run
from repro.workload.ycsb import (KEYS_PER_PAGE, Workload, generate,
                                 value_page_of)


# --------------------------------------------------------------------------
# WriteBuffer unit semantics
# --------------------------------------------------------------------------

def test_writebuffer_coalesces_and_overlays():
    wb = WriteBuffer(high_water=4)
    a = np.arange(1, 11, dtype=np.uint64)
    b = a * np.uint64(3)
    wb.put(7, a)
    src = a.copy()
    a[:] = 0                              # callers may mutate their mirror
    np.testing.assert_array_equal(wb.get(7), src)
    wb.put(7, b)                          # coalesce: last image wins
    np.testing.assert_array_equal(wb.get(7), b)
    assert wb.get(8) is None              # clean pages served by the device
    assert wb.stats.writes == 2 and wb.stats.coalesced == 1
    assert wb.stats.read_hits == 2
    assert wb.n_dirty == 1 and not wb.should_flush
    wb.put(8, b)
    wb.put(9, b)
    wb.put(10, b)
    assert wb.should_flush and wb.stats.max_dirty == 4


def test_writebuffer_flush_is_one_program_group():
    arr = SimChipArray(n_chips=2, pages_per_chip=8)
    be = make_backend("scalar", arr)
    wb = WriteBuffer(high_water=8)
    img = np.arange(1, 101, dtype=np.uint64)
    for _ in range(5):                    # five writes, one page
        wb.put(3, img)
    wb.put(4, img * np.uint64(2))
    assert wb.flush(be) == 2              # two dirty pages -> two programs
    assert be.stats.programs == 2
    assert wb.n_dirty == 0 and wb.stats.flushes == 1
    assert wb.flush(be) == 0              # empty flush is free
    r = be.search(Command.search(3, int(img[6])))
    assert r.match_count == 1


def test_high_water_validation():
    with pytest.raises(ValueError):
        WriteBuffer(high_water=0)


# --------------------------------------------------------------------------
# Deferred Op.PROGRAM on the backends
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["scalar", "batched"])
def test_submit_program_coalesces_last_wins(name):
    arr = SimChipArray(n_chips=2, pages_per_chip=8)
    be = make_backend(name, arr)
    keys = np.arange(1, 101, dtype=np.uint64)
    be.program_entries(0, keys)
    t1 = be.submit_program(0, keys * np.uint64(2))
    t2 = be.submit_program(0, keys * np.uint64(3))
    assert be.pending == 1                # coalesced before the chip
    be.flush()
    assert be.stats.programs == 1 and be.stats.programs_coalesced == 1
    assert t1.result() is t2.result()     # both resolve to the final image
    assert be.search(Command.search(0, 30)).match_count == 1   # 10*3
    assert be.search(Command.search(0, 20)).match_count == 0   # 10*2 gone


def test_programs_execute_before_flushed_searches():
    """A search flushed alongside a program of its page must match the NEW
    image — same ordering as the eager program_entries path."""
    for name in ("scalar", "batched"):
        arr = SimChipArray(n_chips=2, pages_per_chip=8)
        be = make_backend(name, arr)
        keys = np.arange(1, 101, dtype=np.uint64)
        be.program_entries(0, keys)
        be.submit_program(0, keys + np.uint64(1000))
        t = be.submit_search(Command.search(0, 1005))
        be.flush()
        assert t.result().match_count == 1, name


def test_grouped_staging_ships_each_programmed_row_once():
    arr = SimChipArray(n_chips=4, pages_per_chip=8)
    be = make_backend("batched", arr)
    keys = np.arange(1, 405, dtype=np.uint64)
    for p in range(6):
        be.program_entries(p, keys + np.uint64(p))
    for p in range(6):                    # warm the arena
        be.search(Command.search(p, int(keys[0]) + p))
    warm = be.stats.staged_bytes
    for p in range(4):                    # grouped reprogram of 4 pages
        be.submit_program(p, keys * np.uint64(2) + np.uint64(p))
    be.flush()
    assert be.stats.staged_bytes - warm == 4 * PAGE_BYTES
    # rows are current: the next burst re-ships NOTHING
    for p in range(6):
        q = int(keys[3]) * 2 + p if p < 4 else int(keys[3]) + p
        assert be.search(Command.search(p, q)).match_count == 1
    assert be.stats.staged_bytes - warm == 4 * PAGE_BYTES


def test_sharded_program_group_reports_to_timeline():
    be = ShardedSsdBackend.from_geometry(
        channels=2, dies_per_channel=2, pages_per_chip=8, timeline=True)
    keys = np.arange(1, 101, dtype=np.uint64)
    for p in range(4):
        be.program_entries(p, keys + np.uint64(p))
    for p in range(4):
        be.search(Command.search(p, int(keys[0]) + p))
    be.timeline.reset()
    prog_free_0 = be.timeline.sim.die_prog_free.copy()
    for p in range(4):
        be.submit_program(p, keys * np.uint64(5) + np.uint64(p))
    be.flush()
    # one write latency per program, programs queued on the die lines,
    # dirty restages charged to the storage-mode bus
    assert len(be.timeline.write_latencies) == 4
    assert (be.timeline.sim.die_prog_free > prog_free_0).all()
    assert be.timeline.sim.stats.programs == 4
    assert be.timeline.sim.stats.internal_bytes == 4 * PAGE_BYTES


# --------------------------------------------------------------------------
# Buffered replay: read-your-writes + parity + coalescing
# --------------------------------------------------------------------------

def _manual_workload(ops, keys, n_key_pages):
    ops = np.asarray(ops, dtype=np.uint8)
    keys = np.asarray(keys, dtype=np.int64)
    kp = (keys // KEYS_PER_PAGE).astype(np.int32)
    vp = value_page_of(kp, n_key_pages).astype(np.int32)
    return Workload(ops=ops, key_pages=kp, value_pages=vp, alpha=0.0,
                    read_ratio=0.5, n_index_pages=2 * n_key_pages,
                    keys=keys)


def test_read_your_writes_served_from_buffer():
    """read - write - read - write - read of one key inside one burst: the
    post-write reads come from the DRAM overlay (no device command) and
    still equal the eager reference bit for bit."""
    n_key_pages = 2
    wl = _manual_workload([0, 1, 0, 1, 0, 0],
                          [5, 5, 5, 5, 5, 900], n_key_pages)

    def mk(name):
        return make_backend(name, SimChipArray(n_chips=2, pages_per_chip=8,
                                               device_seed=3))

    ref = replay(wl, mk("scalar"), RunConfig(burst=64))
    for name in ("scalar", "batched"):
        r = replay(wl, mk(name), RunConfig(
            burst=64, fused=(name == "batched"), write_buffer=True))
        np.testing.assert_array_equal(ref.read_values, r.read_values)
        np.testing.assert_array_equal(ref.read_hits, r.read_hits)
        # reads 2 and 4 hit the dirty page in the buffer; key 900 lives on
        # the other (clean) page and goes to the device
        assert r.buffer_read_hits == 2
        # two writes to one hot page coalesce to ONE program at end drain
        assert r.n_writes == 2 and r.programs == 1 and r.write_flushes == 1
    assert ref.programs == ref.n_writes == 2   # eager path: 1 program/write


def test_high_water_groups_programs_mid_stream():
    n_key_pages = 8
    # 10 writes / 8 distinct pages, repeats inside one buffer window, with
    # high_water=4 -> two mid-stream group flushes + the end drain, and the
    # two same-window repeat writes coalesce away
    keys = [0, 3, 7 * KEYS_PER_PAGE, 7 * KEYS_PER_PAGE + 9] \
        + [p * KEYS_PER_PAGE for p in range(1, 7)]
    wl = _manual_workload([1] * 10, keys, n_key_pages)
    be = make_backend("batched", SimChipArray(n_chips=2, pages_per_chip=16,
                                              device_seed=1))
    r = replay(wl, be, RunConfig.buffered(burst=64, write_high_water=4))
    assert r.write_flushes == 2
    assert r.programs == 10 - 2            # pages 0 and 7 written twice
    assert be.stats.programs == r.programs


@pytest.mark.parametrize("fused", [False, True])
def test_ycsb_a_buffered_parity_all_backends(fused):
    """YCSB-A (read_ratio=0.5, alpha=0.9): buffered replay is bit-identical
    to the eager unbuffered scalar reference on scalar, batched and
    sharded backends, with measurable hot-page coalescing."""
    wl = generate(400, n_key_pages=8, read_ratio=0.5, alpha=0.9, seed=11)
    pages_per_chip = max(wl.n_index_pages // 4 + 1, 8)

    def mk(name):
        if name == "sharded":
            return ShardedSsdBackend.from_geometry(
                channels=2, dies_per_channel=2,
                pages_per_chip=pages_per_chip, device_seed=3)
        return make_backend(name, SimChipArray(
            n_chips=4, pages_per_chip=pages_per_chip, device_seed=3))

    ref = replay(wl, mk("scalar"), RunConfig(burst=64))
    assert ref.programs == ref.n_writes
    for name in ("scalar", "batched", "sharded"):
        r = replay(wl, mk(name), RunConfig.buffered(
            burst=64, fused=fused, write_high_water=8))
        np.testing.assert_array_equal(ref.read_values, r.read_values)
        np.testing.assert_array_equal(ref.read_hits, r.read_hits)
        assert r.n_writes == ref.n_writes
        assert r.programs < r.n_writes, \
            f"{name}: no hot-page coalescing ({r.programs} programs)"
        assert r.buffer_read_hits > 0


def test_buffered_sharded_timeline_write_accounting():
    wl = generate(300, n_key_pages=8, read_ratio=0.5, alpha=0.9, seed=5)
    be = ShardedSsdBackend.from_geometry(
        channels=2, dies_per_channel=2,
        pages_per_chip=max(wl.n_index_pages // 4 + 1, 8),
        device_seed=3, timeline=True)
    r = replay(wl, be, RunConfig.buffered(burst=64, fused=True,
                                           write_high_water=4))
    assert r.programs < r.n_writes
    assert len(r.write_latencies_ns) == r.programs
    assert (r.write_latencies_ns > 0).all()
    assert r.sim_energy_pj > 0


def test_buffered_scan_workload_parity():
    """Scans + buffered writes in one stream stay bit-identical."""
    wl = generate(300, n_key_pages=8, read_ratio=0.5, alpha=0.5, seed=3,
                  scan_ratio=0.2)
    pages_per_chip = max(wl.n_index_pages // 4 + 1, 8)

    def mk(name):
        return make_backend(name, SimChipArray(
            n_chips=4, pages_per_chip=pages_per_chip, device_seed=3))

    ref = replay(wl, mk("scalar"), RunConfig(burst=64))
    r = replay(wl, mk("batched"), RunConfig.buffered(
        burst=64, fused=True, write_high_water=8))
    np.testing.assert_array_equal(ref.read_values, r.read_values)
    np.testing.assert_array_equal(ref.scan_counts, r.scan_counts)
    assert r.n_scans == ref.n_scans > 0


# --------------------------------------------------------------------------
# Timing executor: scans are reads, not writes
# --------------------------------------------------------------------------

def test_run_scan_ops_issue_zero_programs():
    """ops == 2 used to fall into the write branch of run(): every scan
    was simulated as a page write.  A scan-bearing read/scan workload must
    issue ZERO writes and ZERO programs."""
    wl = generate(2000, n_key_pages=64, read_ratio=0.7, alpha=0.5, seed=2,
                  scan_ratio=0.3)
    assert int((wl.ops == 2).sum()) > 0 and int((wl.ops == 1).sum()) == 0
    for system in ("sim", "baseline"):
        r = run(wl, params=DEFAULT_PARAMS, system=system,
                cache_coverage=0.25)
        assert r.writes == 0, system
        assert r.programs == 0, system
        assert r.scans > 0, system


def test_run_scan_latency_not_in_write_path():
    """Scan latencies accumulate on their own distribution and scans/writes
    are counted separately when both appear in one stream."""
    wl = generate(2000, n_key_pages=64, read_ratio=0.5, alpha=0.5, seed=4,
                  scan_ratio=0.2)
    n_scan = int((wl.ops == 2).sum())
    n_write = int((wl.ops == 1).sum())
    assert n_scan > 0 and n_write > 0
    r = run(wl, params=DEFAULT_PARAMS, system="sim", cache_coverage=0.25)
    # post-warmup counts: scans + writes partition the non-read ops
    assert 0 < r.scans < n_scan + 1
    assert 0 < r.writes < n_write + 1
    assert r.scans + r.writes <= n_scan + n_write


# --------------------------------------------------------------------------

def test_flush_raises_on_unresolved_program_tickets():
    """SIM001 regression: flush() must verify every buffered page program
    resolved in THIS backend flush.  A backend that defers the program to a
    later burst would break read-your-writes once the overlay is clean."""

    class _StuckTicket:
        done = False

    class _DeferringBackend:
        def submit_program(self, page_addr, entries, **kw):
            return _StuckTicket()

        def flush(self):
            pass        # leaves the ticket unresolved

    buf = WriteBuffer(high_water=4)
    buf.put(3, np.arange(8, dtype=np.uint64))
    with pytest.raises(RuntimeError, match="unresolved"):
        buf.flush(_DeferringBackend())
    # the dirty set drained before the check: no double-program on retry
    assert buf.n_dirty == 0


def test_flush_counts_resolved_programs():
    chips = SimChipArray(n_chips=2, pages_per_chip=16, device_seed=5)
    backend = make_backend("batched", chips, page_block=8)
    buf = WriteBuffer(high_water=4)
    buf.put(0, np.arange(8, dtype=np.uint64))
    buf.put(1, np.arange(8, 16, dtype=np.uint64))
    assert buf.flush(backend) == 2
    assert buf.stats.programs == 2 and buf.stats.flushes == 1
