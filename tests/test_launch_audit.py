"""Launch auditor (repro.analysis.launch_audit): jaxpr gate behavior."""
import jax
import jax.numpy as jnp
import pytest

import repro.backend.batched as batched_mod
from repro.analysis.launch_audit import (FORBIDDEN_PRIMITIVES, audit_backend,
                                         iter_eqns, record_launches,
                                         summarize_jaxpr)


# ------------------------------------------------------------ jaxpr walking
def test_iter_eqns_recurses_through_pjit():
    @jax.jit
    def inner(x):
        return jnp.sin(x) + 1.0

    def outer(x):
        return inner(x) * 2.0

    closed = jax.make_jaxpr(outer)(jnp.ones(4))
    prims = {e.primitive.name for e in iter_eqns(closed.jaxpr)}
    assert "sin" in prims          # only visible through the pjit body


def test_forbidden_primitive_detected():
    def f(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    s = summarize_jaxpr(jax.make_jaxpr(f)(jnp.ones(4)))
    assert "pure_callback" in s.forbidden
    assert set(s.forbidden) <= FORBIDDEN_PRIMITIVES


def test_summary_bytes_and_signature():
    def f(x, y):
        return x @ y

    closed = jax.make_jaxpr(f)(jnp.ones((2, 3), jnp.float32),
                               jnp.ones((3, 4), jnp.float32))
    s = summarize_jaxpr(closed)
    assert s.in_bytes == 4 * (6 + 12)
    assert s.out_bytes == 4 * 8
    assert s.signature == (((2, 3), "float32"), ((3, 4), "float32"))
    assert s.n_pallas == 0 and s.forbidden == ()


# -------------------------------------------------------------- the recorder
def test_recorder_restores_entry_points():
    orig = batched_mod.sim_search
    with record_launches("batched") as records:
        assert batched_mod.sim_search is not orig
    assert batched_mod.sim_search is orig
    assert records == []


# ----------------------------------------------------------- the full audits
def test_batched_audit_is_clean():
    findings = audit_backend("batched", hlo=True)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_sharded_audit_is_clean():
    findings = audit_backend("sharded", hlo=True)
    assert findings == [], "\n".join(f.format() for f in findings)


# ------------------------------------------------------------- gate tripping
def test_second_pallas_call_trips_sim101(monkeypatch):
    """Doctor sim_search to launch twice; the audit must flag every search
    phase (value-identical, so only the launch *shape* differs)."""
    orig = batched_mod.sim_search

    def doubled(*args, **kwargs):
        first = orig(*args, **kwargs)
        again = orig(*args, **kwargs)
        return first | (again & 0)     # second launch contributes nothing

    monkeypatch.setattr(batched_mod, "sim_search", doubled)
    findings = audit_backend("batched", hlo=False)
    bad = [f for f in findings
           if f.rule == "SIM101" and f.slug == "pallas-count:sim_search"]
    assert bad, "doctored double-launch sim_search was not flagged"
    assert {f.symbol for f in bad} >= {"search-cold", "search-warm"}


def test_callback_in_flush_trips_sim102(monkeypatch):
    """Doctor sim_search with a host callback; the audit must flag it."""
    orig = batched_mod.sim_search

    def with_callback(lo, hi, q, m, **kwargs):
        probe = jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(q.shape, q.dtype), q)
        return orig(lo, hi, probe, m, **kwargs)

    monkeypatch.setattr(batched_mod, "sim_search", with_callback)
    findings = audit_backend("batched", hlo=False)
    assert any(f.rule == "SIM102" and "pure_callback" in f.message
               for f in findings)


def test_unknown_backend_kind_rejected():
    with pytest.raises(KeyError):
        with record_launches("scalar"):
            pass
