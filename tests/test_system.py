"""End-to-end behaviour tests: the paper's system claims exercised through
the full stack (workload -> SSD simulator -> metrics), plus cross-layer
consistency between the functional chip, the kernels, and the indexes.
"""
import numpy as np

from repro.core import Command, SimChip
from repro.core.engine import SimChipArray
from repro.flash.params import DEFAULT_PARAMS
from repro.index.baseline import BaselineBTree
from repro.index.btree import SimBTree
from repro.kernels.sim_search.ops import sim_search_pages
from repro.workload.runner import run
from repro.workload.ycsb import generate


# ---------------------------------------------------------- paper claims

def _pair(rr, alpha, cov, n=4000, seed=1):
    wl = generate(n, n_key_pages=1024, read_ratio=rr, alpha=alpha, seed=seed)
    b = run(wl, params=DEFAULT_PARAMS, system="baseline", cache_coverage=cov)
    s = run(wl, params=DEFAULT_PARAMS, system="sim", cache_coverage=cov)
    return b, s


def test_claim_write_heavy_speedup():
    """Paper §VII-A: SiM wins substantially in write-intensive workloads."""
    b, s = _pair(rr=0.2, alpha=0.5, cov=0.50)
    assert s.qps / b.qps > 2.0


def test_claim_read_only_baseline_advantage():
    """Paper §VII-A: cache-backed baseline wins in read-only workloads."""
    b, s = _pair(rr=1.0, alpha=0.5, cov=0.25)
    assert 0.5 < s.qps / b.qps < 1.0


def test_claim_energy_savings_at_typical_coverage():
    """Paper §VII-B: 10-45 % NAND-side energy savings at typical coverage."""
    b, s = _pair(rr=0.4, alpha=0.5, cov=0.25)
    assert 0.5 < s.energy_pj / b.energy_pj < 0.95


def test_claim_pcie_traffic_reduction():
    """Paper §VII-B: SiM cuts PCIe bytes dramatically (64x per point read)."""
    b, s = _pair(rr=1.0, alpha=0.0, cov=0.0)
    assert b.pcie_bytes / s.pcie_bytes > 20


def test_claim_write_volume_reduction():
    """Paper Fig 16a: SiM programs fewer flash pages at equal work."""
    b, s = _pair(rr=0.4, alpha=0.0, cov=0.50)
    assert s.programs < 0.8 * b.programs


def test_claim_tail_corner_case_exists():
    """Paper §VII-D: skewed write-heavy + big cache can regress SiM's p99."""
    b, s = _pair(rr=0.2, alpha=0.9, cov=0.75)
    assert s.read_p99_ns > b.read_p99_ns      # the acknowledged corner case


# ------------------------------------------------- cross-layer consistency

def test_chip_and_kernel_agree_on_search():
    """The functional chip and the Pallas kernel produce identical bitmaps
    for the same randomized page content."""
    chip = SimChip(n_pages=8, device_seed=13)
    keys = np.arange(500, 1004, dtype=np.uint64)
    chip.program_entries(2, keys, timestamp_ns=5)
    resp = chip.search(Command.search(2, 777))

    raw = chip.pages[2].raw[None]       # as stored (randomized)
    # kernel sees the page at its *global* randomization address
    out = sim_search_pages(raw, [777], [0xFFFFFFFFFFFFFFFF],
                           randomized=True, device_seed=13, page_base=2)
    np.testing.assert_array_equal(np.asarray(out[0, 0]), resp.bitmap_words)


def test_index_results_survive_bit_errors():
    """Optimistic ECC end-to-end: header damage triggers repair, lookups
    still return correct values afterwards."""
    chips = SimChipArray(n_chips=4, pages_per_chip=32)
    keys = np.arange(10_000, 12_000, dtype=np.uint64)
    bt = SimBTree(chips)
    bt.bulk_load(keys, keys * np.uint64(3))
    # damage the header chunk of every key page
    for chip in chips.chips:
        for addr in list(chip.pages):
            chip.inject_bit_errors(addr, 3, byte_region=(0, 64))
    for k in keys[::97]:
        assert bt.lookup(int(k)) == int(k) * 3
    assert sum(c.counters.open_fallbacks for c in chips.chips) > 0


def test_btree_equivalence_property():
    """Random ops: SiM B+Tree == baseline B+Tree on lookups and ranges."""
    rng = np.random.default_rng(7)
    keys = (rng.choice(10**8, size=2000, replace=False) + 1).astype(np.uint64)
    vals = rng.integers(1, 2**60, size=2000).astype(np.uint64)
    bt = SimBTree(SimChipArray(n_chips=4, pages_per_chip=64))
    bb = BaselineBTree(SimChipArray(n_chips=4, pages_per_chip=64))
    bt.bulk_load(keys, vals)
    bb.bulk_load(keys, vals)
    for k in rng.choice(keys, 50, replace=False):
        assert bt.lookup(int(k)) == bb.lookup(int(k))
    for _ in range(5):
        lo = int(rng.integers(0, 10**8))
        hi = lo + int(rng.integers(1, 10**6))
        assert sorted(bt.range_query(lo, hi)) == sorted(bb.range_query(lo,
                                                                       hi))


def test_power_budget_favors_match_mode():
    """Paper §II-B: under a peak-current cap, SiM's low-current match-mode
    transfers admit more parallelism than storage-mode full-page reads."""
    wl = generate(3000, n_key_pages=1024, read_ratio=1.0, alpha=0.0, seed=2)
    budget = 300.0          # mA — ~2 storage-mode bursts vs ~27 match-mode
    b = run(wl, params=DEFAULT_PARAMS, system="baseline", cache_coverage=0.0,
            power_budget_ma=budget)
    s = run(wl, params=DEFAULT_PARAMS, system="sim", cache_coverage=0.0,
            power_budget_ma=budget)
    b0 = run(wl, params=DEFAULT_PARAMS, system="baseline",
             cache_coverage=0.0)
    # the cap hurts the baseline more than SiM
    assert b0.qps / b.qps > 1.05
    assert s.qps / b.qps > 1.05
