"""HLO analyzer validation: trip counts, dot flops, collective parsing."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (_shape_bytes, analyze_hlo,
                                       parse_computations)
from repro.launch.roofline import Roofline, model_flops
from repro.models.config import SHAPES
from repro.configs import ARCHS


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_cost_analysis_undercounts_scan_bodies():
    """The reason the analyzer exists: XLA counts a while body once."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, x, x)
    raw = c.cost_analysis()
    raw = raw[0] if isinstance(raw, list) else raw
    expected = 10 * 2 * 128 ** 3
    assert raw["flops"] == pytest.approx(expected / 10)   # body counted once
    a = analyze_hlo(c.as_text())
    assert a.dot_flops == pytest.approx(expected)          # trip-scaled
    assert a.while_trips == [10]


def test_analyzer_exact_on_fwd_bwd_scan():
    def g(params, x):
        def loss(p):
            h = x
            def body(c, w):
                return jnp.tanh(c @ w), None
            h, _ = jax.lax.scan(body, h, p)
            return jnp.sum(h ** 2)
        return jax.grad(loss)(params)
    p = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    c = _compile(g, p, x)
    a = analyze_hlo(c.as_text())
    expected = 6 * 2 * 8 * 64 * 64 * 3      # fwd + 2 bwd matmuls per layer
    assert a.dot_flops == pytest.approx(expected, rel=0.01)
    assert sorted(a.while_trips) == [6, 6]


def test_nested_scan_multipliers():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = _compile(f, x, x)
    a = analyze_hlo(c.as_text())
    assert a.dot_flops == pytest.approx(3 * 4 * 2 * 64 ** 3)


def test_collective_parsing_smoke():
    """Parser recognizes all-reduce lines in a hand-built HLO snippet."""
    hlo = """
ENTRY %main.1 (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%p0), replica_groups=[1,4]<=[4]
  ROOT %out = f32[128,64]{1,0} add(%ar, %p0)
}
"""
    a = analyze_hlo(hlo)
    assert a.collective_bytes["all-reduce"] == 128 * 64 * 4
    assert a.result_bytes > 0


def test_roofline_terms_and_dominance():
    rl = Roofline(flops=197e12, bytes_accessed=819e9 * 2,
                  coll={"all-reduce": int(50e9)}, n_devices=256)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(2.0)
    assert rl.collective_s == pytest.approx(1.0)
    assert rl.dominant == "memory"
    assert rl.roofline_fraction(197e12 / 2) == pytest.approx(0.25)


def test_model_flops_modes():
    cfg = ARCHS["granite-3-8b"]
    t = model_flops(cfg, SHAPES["train_4k"])
    p = model_flops(cfg, SHAPES["prefill_32k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert t == pytest.approx(6 * n * 256 * 4096)
    assert p == pytest.approx(2 * n * 32 * 32768)
    assert d == pytest.approx(2 * n * 128)


def test_moe_active_vs_total_flops():
    kimi = ARCHS["kimi-k2-1t-a32b"]
    assert kimi.active_param_count() < kimi.param_count() / 10


def test_parse_computations_structure():
    hlo = """
%helper.1 (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  ROOT %r = f32[4]{0} add(%a, %a)
}

ENTRY %main.2 (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %c = f32[4]{0} call(%x), to_apply=%helper.1
}
"""
    comps = parse_computations(hlo)
    assert set(comps) == {"helper.1", "main.2"}
    assert comps["main.2"].is_entry and not comps["helper.1"].is_entry


def test_parse_unoptimized_hlo_dialect():
    """`lower().compiler_ir("hlo")` text: no % sigils, bare `ENTRY name {`
    headers — the dialect the launch auditor's byte cross-check parses."""
    hlo = """\
HloModule jit_pure, entry_computation_layout={(u32[4,8]{1,0})->(u32[4]{0}, s32[])}

ENTRY main.15 {
  Arg_0.1 = u32[4,8]{1,0} parameter(0)
  reduce.9 = u32[4]{0} reduce(Arg_0.1), dimensions={1}, to_apply=region_0.5
  constant.2 = s32[] constant(7)
  ROOT tuple.14 = (u32[4]{0}, s32[]) tuple(reduce.9, constant.2)
}
"""
    comps = parse_computations(hlo)
    assert "HloModule" not in comps
    entry = next(c for c in comps.values() if c.is_entry)
    assert entry.name == "main.15"
    params = [i for i in entry.instrs if i.op == "parameter"]
    assert sum(_shape_bytes(i.type_str) for i in params) == 4 * 8 * 4
    assert entry.instrs[-1].op == "tuple"
    assert _shape_bytes(entry.instrs[-1].type_str) == 4 * 4 + 4


def test_parse_lowered_compiler_ir_roundtrip():
    """Live check against whatever jax currently emits: entry parameter and
    ROOT bytes parsed from the unoptimized dump match the known shapes."""
    def f(x, y):
        return x + y, jnp.sum(x)

    x = jnp.ones((4, 8), jnp.float32)
    text = jax.jit(f).lower(x, x).compiler_ir(dialect="hlo").as_hlo_text()
    comps = parse_computations(text)
    entry = next(c for c in comps.values() if c.is_entry)
    params = [i for i in entry.instrs if i.op == "parameter"]
    assert sum(_shape_bytes(i.type_str) for i in params) == 2 * 4 * 8 * 4
    assert _shape_bytes(entry.instrs[-1].type_str) == 4 * 8 * 4 + 4


def test_shape_bytes_token_and_nested_tuple():
    assert _shape_bytes("token[]") == 0
    assert _shape_bytes("(f32[2]{0}, token[])") == 8
    assert _shape_bytes("(f32[2]{0}, (s32[], u8[3]))") == 8 + 4 + 3


def test_instr_regex_one_level_nested_tuple():
    text = ("ENTRY main.1 {\n"
            "  ROOT t.1 = ((f32[2]{0}, s32[]), u8[4]{0}) tuple(a.1, b.2)\n"
            "}\n")
    root = parse_computations(text)["main.1"].instrs[-1]
    assert root.op == "tuple"
    assert _shape_bytes(root.type_str) == 8 + 4 + 4
